//! Figure 8 — theoretical vs simulated CAB throughput under the four
//! task-size distributions.
//!
//! Theory is Eq. 16 (P1-biased S_max = (1, N2)); simulation is the closed
//! network at N = 20 over the η grid.  The paper's claim: "almost
//! identical", with visibly higher variance for bounded Pareto.

use hetsched::cli::Args;
use hetsched::model::affinity::Regime;
use hetsched::model::throughput::x_max_theoretical;
use hetsched::policy::PolicyKind;
use hetsched::report::Series;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::workload;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let measure: u64 = args.get_parse("measure", 20_000).expect("--measure");
    args.finish().expect("flags");

    let mu = workload::paper_two_type_mu();
    let mut theory = Series::new("theory");
    let mut sims: Vec<Series> = Distribution::all()
        .iter()
        .map(|d| Series::new(format!("sim-{}", d.name())))
        .collect();
    let mut worst = vec![0.0f64; 4];

    for eta in workload::eta_grid() {
        let (n1, n2) = workload::split_populations(20, eta);
        let th = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
        theory.push(eta, th);
        for (i, dist) in Distribution::all().iter().enumerate() {
            let mut cfg = SimConfig::paper_default(vec![n1, n2]);
            cfg.dist = *dist;
            cfg.measure = measure;
            cfg.seed = 0xF18 + i as u64;
            let net = ClosedNetwork::new(&mu, cfg).unwrap();
            let r = net.run(PolicyKind::Cab.build().as_mut()).unwrap();
            sims[i].push(eta, r.throughput);
            worst[i] = worst[i].max((r.throughput - th).abs() / th);
        }
    }

    let mut all = vec![theory];
    all.extend(sims);
    print!(
        "{}",
        Series::render_block("Fig 8: CAB theory vs simulation", "eta", &all)
    );
    for (i, dist) in Distribution::all().iter().enumerate() {
        println!(
            "fig8: {} worst relative deviation from theory: {:.2}%",
            dist.name(),
            100.0 * worst[i]
        );
    }
}
