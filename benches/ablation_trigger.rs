//! Ablation: change-point-triggered vs threshold-drift re-solves.
//!
//! Three questions, three tables:
//!
//! 1. **Detection delay** (estimator in isolation): completions from a
//!    rate flip to the first detector firing — per-cell CUSUM alarm vs
//!    the polled drift metric crossing its threshold at `check_every`
//!    ticks.
//! 2. **False alarms** (stationary load): drift-triggered re-solves per
//!    replication when there is no change point to find.
//! 3. **Throughput** (end to end): mean X ± t-corrected 95% CI for the
//!    two triggers on the `phase_shift`, `slow_drift` and `abrupt_flip`
//!    two-type scenarios, plus the sharded plane on the three-class
//!    affinity rotation.

use hetsched::cli::Args;
use hetsched::coordinator::RateEstimator;
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::dynamic::{DriftConfig, DynamicConfig, Phase, ResolveMode, Trigger};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::rng::Rng;
use hetsched::sim::workload::{
    self, scenario_phases, three_class_flip_scale, three_class_mu, ScenarioKind,
    ScenarioParams,
};

/// Completions until each detector first fires after a rate flip of
/// `scale` on cell (0, 0), averaged over `runs` seeds.  The threshold
/// detector is polled every `check_every` completions, like the
/// adaptive loop does.  Runs where the detector never fires within the
/// 20k-completion cap are reported as censored rather than folded into
/// a plausible-looking mean.
fn detection_delay(scale: f64, trigger: Trigger, runs: u64) -> String {
    const CAP: u64 = 20_000;
    let mu = workload::paper_two_type_mu();
    let drift = DriftConfig { trigger, ..Default::default() };
    let mut total = 0u64;
    let mut censored = 0u64;
    for seed in 0..runs {
        let mut rng = Rng::new(0xDE7EC7 + seed);
        let mut est = RateEstimator::from_drift(&mu, &drift).unwrap();
        for _ in 0..256 {
            est.observe(0, 0, rng.exp(mu.rate(0, 0)));
        }
        let flipped = mu.rate(0, 0) * scale;
        let mut n = 0u64;
        loop {
            est.observe(0, 0, rng.exp(flipped));
            n += 1;
            let fired = match trigger {
                Trigger::Cusum => est.alarm_pending(),
                Trigger::Threshold => {
                    n % drift.check_every == 0 && est.drift(&mu) > drift.threshold
                }
            };
            if fired {
                break;
            }
            if n >= CAP {
                censored += 1;
                break;
            }
        }
        total += n;
    }
    let mean = total as f64 / runs as f64;
    if censored > 0 {
        format!(">{mean:.0} ({censored}/{runs} censored at {CAP})")
    } else {
        format!("{mean:.0}")
    }
}

fn scenario_cells(quick: bool) -> Vec<DynCell> {
    let completions = if quick { 800 } else { 2_500 };
    let warmup = if quick { 100 } else { 300 };
    let params = ScenarioParams {
        phases: 5,
        completions,
        warmup,
        ..Default::default()
    };
    let two_type = [
        ScenarioKind::PhaseShift,
        ScenarioKind::SlowDrift,
        ScenarioKind::AbruptFlip,
    ];
    let mut cells = Vec::new();
    for kind in two_type {
        for trigger in Trigger::all() {
            let mut cfg =
                DynamicConfig::new(scenario_phases(kind, &params).unwrap());
            cfg.resolve = ResolveMode::Adaptive;
            cfg.drift.trigger = trigger;
            cfg.seed = 0xAB1;
            cells.push(DynCell {
                label: format!("{} {}", kind.name(), trigger.name()),
                mu: workload::paper_two_type_mu(),
                cfg,
                policy: PolicyKind::GrIn,
            });
        }
    }
    // Stationary control: false re-solves with no change point to find.
    for trigger in Trigger::all() {
        let mut cfg = DynamicConfig::new(vec![Phase::new(
            vec![10, 10],
            warmup,
            completions * 2,
        )]);
        cfg.resolve = ResolveMode::Adaptive;
        cfg.drift.trigger = trigger;
        cfg.seed = 0xAB2;
        cells.push(DynCell {
            label: format!("stationary {}", trigger.name()),
            mu: workload::paper_two_type_mu(),
            cfg,
            policy: PolicyKind::GrIn,
        });
    }
    // Sharded plane on the three-class affinity rotation.
    let scale = three_class_flip_scale();
    let mut phases = vec![Phase::new(vec![8, 8, 8], warmup, completions)];
    for _ in 0..3 {
        phases.push(Phase::new(vec![8, 8, 8], warmup, completions).with_mu_scale(scale.clone()));
    }
    for trigger in Trigger::all() {
        let mut cfg = DynamicConfig::new(phases.clone());
        cfg.resolve = ResolveMode::Sharded;
        cfg.drift.trigger = trigger;
        cfg.shard.shards = 3;
        cfg.seed = 0xAB3;
        cells.push(DynCell {
            label: format!("three_class_flip sharded {}", trigger.name()),
            mu: three_class_mu(),
            cfg,
            policy: PolicyKind::GrIn,
        });
    }
    cells
}

fn main() {
    let args = Args::from_env().unwrap();
    args.ignore_harness_flags();
    let quick = args.switch("quick");
    args.finish().unwrap();

    // 1. Detection delay, estimator in isolation.
    let runs = if quick { 8 } else { 32 };
    let mut t = Table::new(
        "detection delay after a rate flip on one cell (completions to first firing)",
        &["flip", "cusum", "threshold (polled)"],
    );
    for (label, scale) in [("2x slowdown", 0.5), ("2x speedup", 2.0), ("4x slowdown", 0.25)] {
        t.row(vec![
            label.to_string(),
            detection_delay(scale, Trigger::Cusum, runs),
            detection_delay(scale, Trigger::Threshold, runs),
        ]);
    }
    t.print();

    // 2 + 3. End-to-end arms, replicated.
    let cells = scenario_cells(quick);
    let plan = ReplicationPlan {
        reps: if quick { 2 } else { 4 },
        threads: 0,
        base_seed: 0x7119,
    };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let mut t = Table::new(
        format!(
            "trigger ablation (R = {}, mean ± t-corrected 95% CI)",
            plan.reps
        ),
        &["scenario + trigger", "mean X", "re-solves/run"],
    );
    for s in &stats {
        t.row(vec![
            s.label.clone(),
            format!("{:.4} ± {:.4}", s.mean_x, s.ci95_x),
            format!("{:.1}", s.mean_resolves),
        ]);
    }
    t.print();
    println!(
        "ablation_trigger: CUSUM detects abrupt flips in tens of completions and \
         stays silent on stationary load; the polled threshold waits for its \
         check tick and re-solves on estimator noise"
    );
}
