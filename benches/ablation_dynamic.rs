//! Ablation: piece-wise closed systems and on-line re-solve (§3.1, §4.1).
//!
//! Population mixes shift over five phases; a policy that re-solves at
//! each boundary (CAB/GrIn via `prepare`) tracks the per-phase optimum,
//! while a *frozen* CAB solved for the first phase decays.  Also times
//! the GrIn re-solve itself — the paper's argument for a fast heuristic
//! ("if we want to solve the problem on the fly … a fast algorithm is
//! needed").

use std::time::Instant;

use hetsched::model::affinity::Regime;
use hetsched::model::throughput::x_max_theoretical;
use hetsched::policy::{
    cab::Cab, grin, target::TargetSteering, Policy, PolicyKind, PreparedTarget, SolveRequest,
    SystemView,
};
use hetsched::report::Table;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::dynamic::{run_dynamic, DynamicConfig, Phase};
use hetsched::sim::processor::Discipline;
use hetsched::sim::replicate::parallel_map;
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;

/// CAB frozen at its first `prepare` (the no-re-solve ablation arm).
struct FrozenCab {
    steering: Option<TargetSteering>,
}

impl Policy for FrozenCab {
    fn name(&self) -> &'static str {
        "CAB-frozen"
    }

    fn prepare(&mut self, req: &SolveRequest<'_>) -> hetsched::Result<PreparedTarget> {
        req.ensure_baseline(self.name())?;
        if self.steering.is_none() {
            let (_, target) = Cab::target_state(req.mu, req.populations)?;
            self.steering = Some(TargetSteering::new(target));
        }
        Ok(PreparedTarget::default())
    }

    fn dispatch(&mut self, ttype: usize, view: &SystemView<'_>, _rng: &mut Rng) -> usize {
        self.steering.as_ref().expect("prepared").dispatch(ttype, view)
    }
}

fn main() {
    let mu = workload::paper_two_type_mu();
    let phases = vec![
        Phase::new(vec![10, 10], 500, 8_000),
        Phase::new(vec![2, 18], 500, 8_000),
        Phase::new(vec![18, 2], 500, 8_000),
        Phase::new(vec![5, 15], 500, 8_000),
        Phase::new(vec![15, 5], 500, 8_000),
    ];
    let mut cfg = DynamicConfig::new(phases.clone());
    cfg.discipline = Discipline::Ps;
    cfg.dist = Distribution::Exponential;
    cfg.seed = 0xD1;

    // The two ablation arms are independent runs: fan them across cores
    // through the replication runner's worker pool.
    let arms = [true, false]; // re-solving CAB vs frozen CAB
    let mut results = parallel_map(&arms, 0, |_, &resolve| {
        let mut policy: Box<dyn Policy> = if resolve {
            PolicyKind::Cab.build()
        } else {
            Box::new(FrozenCab { steering: None })
        };
        run_dynamic(&mu, &cfg, policy.as_mut()).unwrap()
    })
    .into_iter();
    let rs_resolve = results.next().expect("resolve arm");
    let rs_frozen = results.next().expect("frozen arm");

    let mut t = Table::new(
        "ablation: per-phase throughput, re-solving vs frozen CAB",
        &["phase (N1,N2)", "theory", "CAB re-solve", "CAB frozen", "frozen loss"],
    );
    for i in 0..phases.len() {
        let (n1, n2) = (phases[i].populations[0], phases[i].populations[1]);
        let theory = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
        let a = rs_resolve[i].throughput;
        let b = rs_frozen[i].throughput;
        t.row(vec![
            format!("({n1},{n2})"),
            format!("{theory:.3}"),
            format!("{a:.3}"),
            format!("{b:.3}"),
            format!("{:.1}%", 100.0 * (1.0 - b / a)),
        ]);
    }
    t.print();

    // Re-solve cost: GrIn across sizes (the §4.1 "on the fly" budget).
    let mut t2 = Table::new("GrIn re-solve latency", &["size", "µs/solve"]);
    let mut rng = Rng::new(0xD2);
    for size in [2usize, 4, 8, 12, 16] {
        let m = workload::random_mu(&mut rng, size, size, 0.5, 30.0).unwrap();
        let p = workload::random_populations(&mut rng, size, 10);
        let t0 = Instant::now();
        let n = 50;
        for _ in 0..n {
            grin::solve(&m, &p).unwrap();
        }
        t2.row(vec![
            format!("{size}x{size}"),
            format!("{:.1}", t0.elapsed().as_secs_f64() / n as f64 * 1e6),
        ]);
    }
    t2.print();
    println!("ablation_dynamic: re-solving CAB tracks per-phase theory; frozen decays");
}
