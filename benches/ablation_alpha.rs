//! Ablation: the power exponent α (Lemma 7 bounds).
//!
//! 𝒫 = k·μ^α interpolates between constant power (α = 0, Eq. 22) and
//! proportional power (α = 1, Eq. 23); α ≤ 0 is the strong-affinity
//! regime.  The sweep shows measured E[ℰ] and EDP of simulated CAB
//! landing inside the Lemma-7 envelope for every α, and the CAB-vs-LB
//! energy advantage across the regime boundary.

use hetsched::model::energy::PowerScenario;
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::workload;

fn main() {
    let mu = workload::paper_two_type_mu();
    let mut t = Table::new(
        "ablation: power exponent α sweep (CAB, N=20, η=0.5)",
        &["alpha", "E[ℰ] CAB", "bound lo", "bound hi", "inside", "EDP CAB", "EDP LB", "LB/CAB"],
    );
    for &alpha in &[-1.0, -0.5, 0.0, 0.25, 0.5, 0.75, 1.0] {
        let run = |kind: PolicyKind| {
            let mut cfg = SimConfig::paper_default(vec![10, 10]);
            cfg.dist = Distribution::Exponential;
            cfg.measure = 15_000;
            cfg.power = if alpha == 0.0 {
                PowerScenario::Constant
            } else if alpha == 1.0 {
                PowerScenario::Proportional
            } else {
                PowerScenario::Exponent(alpha)
            };
            let net = ClosedNetwork::new(&mu, cfg).unwrap();
            net.run(kind.build().as_mut()).unwrap()
        };
        let cab = run(PolicyKind::Cab);
        let lb = run(PolicyKind::LoadBalance);
        // Lemma-7 envelope at the measured throughput (2 busy procs, k=1).
        let (lo, hi) = if alpha <= 0.0 {
            (0.0, 2.0 / cab.throughput)
        } else {
            (2.0 / cab.throughput, 1.0)
        };
        // Sampling slack: E[size] has ~1% noise at this run length.
        let inside = cab.mean_energy >= lo - 1e-9 && cab.mean_energy <= hi * 1.05;
        t.row(vec![
            format!("{alpha:+.2}"),
            format!("{:.4}", cab.mean_energy),
            format!("{lo:.4}"),
            format!("{hi:.4}"),
            if inside { "yes".into() } else { "NO".into() },
            format!("{:.4}", cab.edp),
            format!("{:.4}", lb.edp),
            format!("{:.2}x", lb.edp / cab.edp),
        ]);
        assert!(inside, "α={alpha}: energy outside Lemma-7 envelope");
        assert!(lb.edp >= cab.edp * 0.98, "α={alpha}: LB beat CAB in EDP");
    }
    t.print();
    println!("ablation_alpha: Lemma-7 bounds hold; CAB's EDP advantage spans all α");
}
