//! Ablation: the energy/EDP objective axis vs the throughput default.
//!
//! Two tables:
//!
//! 1. **Solve-level trade** (no simulation): the GrIn target under each
//!    objective on the Table-3 general-symmetric system — what the
//!    energy objectives pay in X and buy in E[ℰ]/EDP, and where the
//!    throughput-per-watt floor lands between the two extremes.
//! 2. **End to end** (replicated): throughput- vs energy- vs
//!    EDP-objective adaptive arms on the slow-drift scenario under the
//!    α = 0.5 power model — mean X ± t-corrected CI and metered
//!    E[ℰ]/task per arm.

use hetsched::cli::Args;
use hetsched::model::energy::PowerScenario;
use hetsched::model::objective::{Objective, ObjectiveEval, PowerProfile};
use hetsched::policy::grin;
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::dynamic::{DynamicConfig, ResolveMode};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::workload::{self, scenario_phases, ScenarioKind, ScenarioParams};

fn scenario_cfg(objective: Objective, power: PowerProfile, quick: bool) -> DynamicConfig {
    let params = ScenarioParams {
        phases: 4,
        completions: if quick { 800 } else { 3_000 },
        warmup: if quick { 100 } else { 300 },
        ..Default::default()
    };
    let mut cfg =
        DynamicConfig::new(scenario_phases(ScenarioKind::SlowDrift, &params).unwrap());
    cfg.resolve = ResolveMode::Adaptive;
    cfg.seed = 0xE97;
    cfg.objective = objective;
    cfg.power = power;
    cfg
}

fn main() {
    let args = Args::from_env().unwrap();
    args.ignore_harness_flags();
    let quick = args.switch("quick");
    args.finish().unwrap();

    let mu = workload::table3::general_symmetric();
    let pops = [10u32, 10];
    let profile = PowerProfile::new(1.0, PowerScenario::Exponent(0.5)).with_idle(0.5);
    let objectives = [
        Objective::Throughput,
        Objective::EnergyPerTask,
        Objective::Edp,
        Objective::ThroughputPerWatt { min_x_frac: 0.9 },
    ];

    // 1. The solve-level trade on the Table-3 system.
    let x_star = grin::solve(&mu, &pops).unwrap().throughput;
    let mut t = Table::new(
        format!(
            "GrIn target by objective (μ = table-3 general-symmetric, \
             𝒫 = μ^0.5 + idle {:.1})",
            profile.idle_power
        ),
        &["objective", "target", "X", "X/X*", "𝒫_sys", "E[ℰ]/task", "EDP"],
    );
    for objective in objectives {
        let sol = grin::solve_objective(&mu, &pops, objective, &profile).unwrap();
        let eval = ObjectiveEval::new(&mu, &sol.state, &profile, objective, x_star).unwrap();
        let (x, p) = eval.base();
        t.row(vec![
            objective.name().to_string(),
            format!("{:?}", sol.state.data()),
            format!("{x:.2}"),
            format!("{:.3}", x / x_star),
            format!("{p:.2}"),
            format!("{:.5}", eval.energy_per_task()),
            format!("{:.5}", eval.edp()),
        ]);
    }
    t.print();

    // 2. End to end on the slow-drift scenario, replicated.
    let arms: [(Objective, &str); 3] = [
        (Objective::Throughput, "adaptive throughput"),
        (Objective::EnergyPerTask, "adaptive energy"),
        (Objective::Edp, "adaptive edp"),
    ];
    let cells: Vec<DynCell> = arms
        .iter()
        .map(|&(objective, label)| DynCell {
            label: label.to_string(),
            mu: mu.clone(),
            cfg: scenario_cfg(objective, profile, quick),
            policy: PolicyKind::GrIn,
        })
        .collect();
    let plan = ReplicationPlan {
        reps: if quick { 2 } else { 4 },
        threads: 0,
        base_seed: 0xEA57,
    };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let mut t = Table::new(
        format!(
            "energy ablation on slow_drift (R = {}, mean ± t-corrected 95% CI; \
             𝒫 = μ̂^0.5, idle {:.1})",
            plan.reps, profile.idle_power
        ),
        &["arm", "mean X", "E[ℰ]/task", "re-solves/run"],
    );
    for s in &stats {
        t.row(vec![
            s.label.clone(),
            format!("{:.4} ± {:.4}", s.mean_x, s.ci95_x),
            format!("{:.5}", s.mean_energy),
            format!("{:.1}", s.mean_resolves),
        ]);
    }
    t.print();
    println!(
        "ablation_energy: the energy objective parks work on the devices where \
         μ^(α-1) is smallest and the EDP objective splits the difference, \
         trading a bounded slice of throughput for per-task energy; tpw:0.9 \
         pins the solve to the cheapest target that still clears 90% of X*"
    );
}
