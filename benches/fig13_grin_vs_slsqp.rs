//! Figure 13 — GrIn's integer solution vs SLSQP's continuous solution,
//! across system sizes 3×3 … 10×10.
//!
//! §6 setup: random μ per size, results averaged over 100 runs.  The
//! paper reports GrIn *better* and the improvement growing with the
//! number of processor types (5.7% at 10×10).  SLSQP convergence
//! failures are counted, as the paper observes them too.

use hetsched::cli::Args;
use hetsched::policy::grin;
use hetsched::report::Table;
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;
use hetsched::solver::slsqp::Slsqp;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let runs: usize = args.get_parse("runs", 100).expect("--runs");
    args.finish().expect("flags");

    let mut t = Table::new(
        format!("Fig 13: GrIn improvement over SLSQP ({runs} runs per size)"),
        &["types (k=l)", "GrIn X (avg)", "SLSQP X (avg)", "improvement", "slsqp fails"],
    );
    let mut rng = Rng::new(0xF13);
    for size in 3..=10usize {
        let mut grin_sum = 0.0;
        let mut slsqp_sum = 0.0;
        let mut fails = 0u32;
        for _ in 0..runs {
            let mu = workload::random_mu(&mut rng, size, size, 0.5, 30.0).unwrap();
            let pops = workload::random_populations(&mut rng, size, 8);
            let g = grin::solve(&mu, &pops).unwrap();
            let s = Slsqp::default().solve(&mu, &pops).unwrap();
            grin_sum += g.throughput;
            slsqp_sum += s.throughput;
            if !s.converged {
                fails += 1;
            }
        }
        let ga = grin_sum / runs as f64;
        let sa = slsqp_sum / runs as f64;
        t.row(vec![
            format!("{size}x{size}"),
            format!("{ga:.3}"),
            format!("{sa:.3}"),
            format!("{:+.2}%", 100.0 * (ga / sa - 1.0)),
            fails.to_string(),
        ]);
    }
    t.print();
    println!(
        "fig13: paper shape — GrIn ≥ SLSQP, improvement grows with processor types"
    );
}
