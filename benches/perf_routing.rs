//! §Perf — serving front-end microbenchmarks feeding EXPERIMENTS.md §Perf.
//!
//! Measures routing decisions/sec through the lock-free
//! `ConcurrentRouter`: the exact-mode (CAS-validated) single-thread
//! cost, reconciled-mode scaling at 1/2/4 threads, router-level batch
//! amortization via `route_batch`, and a saturation arm where offered
//! load exceeds routing capacity — batched routing must sustain
//! strictly higher served throughput because one steering decision
//! admits a whole same-class batch.
//!
//! All arms are route-only: completions are off the decision hot path
//! (see the module docs in `coordinator/frontend.rs`), so the numbers
//! here isolate the per-decision cost that `serve --frontend-threads N`
//! pays per request.
//!
//! Flags: `--quick` shrinks every loop for CI smoke runs; `--json PATH`
//! writes a `BENCH_*.json`-style document.  CI merges these metrics
//! into `BENCH_perf_hotpath.json`, so `routing_decisions_per_s_4t`
//! rides the same regression gate as `sim_events_per_s`.

use std::sync::Arc;
use std::time::Instant;

use hetsched::cli::Args;
use hetsched::config::json::Json;
use hetsched::coordinator::{ConcurrentRouter, RouterConfig};
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;

/// A fresh front end on the Table-3 general-symmetric affinity: CAB
/// solves the boot target, two classes steer across two devices.
fn frontend() -> Arc<ConcurrentRouter> {
    let mu = workload::table3::general_symmetric();
    let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
    let mut policy = PolicyKind::Cab.build();
    Arc::new(
        ConcurrentRouter::new(
            RouterConfig::new(mu, omega, vec![64, 64]).with_seed(7),
            policy.as_mut(),
        )
        .expect("front end"),
    )
}

/// Drive `per_thread` seeded decisions of batch size `batch` from each
/// of `threads` routing threads; returns elapsed seconds.
fn run_arm(
    front: &Arc<ConcurrentRouter>,
    threads: usize,
    per_thread: u64,
    batch: u32,
    reconcile: u32,
) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let front = Arc::clone(front);
            s.spawn(move || {
                let mut handle = front.handle_with_reconcile(reconcile);
                let mut rng = Rng::new(0xF00D ^ t as u64);
                for _ in 0..per_thread {
                    let class = rng.index(2);
                    handle.route_batch(class, batch).expect("route");
                }
                handle.flush();
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

/// Saturation: route as many requests as fit in `budget_s` seconds of
/// wall clock on one thread.  The request generator is never the
/// bottleneck, so served count measures routing capacity alone.
fn saturate(budget_s: f64, batch: u32) -> u64 {
    let front = frontend();
    let mut handle = front.handle_with_reconcile(64);
    let mut rng = Rng::new(3);
    let t0 = Instant::now();
    let mut served = 0u64;
    while t0.elapsed().as_secs_f64() < budget_s {
        for _ in 0..512 {
            let class = rng.index(2);
            handle.route_batch(class, batch).expect("route");
            served += batch as u64;
        }
    }
    handle.flush();
    served
}

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let quick = args.switch("quick");
    let json_path = args.get("json").map(str::to_string);
    args.finish().expect("flags");

    let scale = |full: u64, quick_n: u64| if quick { quick_n } else { full };
    let mut t = Table::new("perf_routing", &["metric", "value"]);
    // (key, value) pairs mirrored into the JSON artifact.
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // --- exact mode: every decision CAS-validates its cell ---------------
    let n = scale(2_000_000, 200_000);
    let mut exact_per_s = 0.0f64;
    for _ in 0..3 {
        let front = frontend();
        let secs = run_arm(&front, 1, n, 1, 1);
        assert_eq!(front.decisions(), n);
        exact_per_s = exact_per_s.max(n as f64 / secs);
    }
    t.row(vec![
        "decisions/s (exact CAS, 1 thread)".into(),
        format!("{:.2}M", exact_per_s / 1e6),
    ]);
    metrics.push(("routing_exact_decisions_per_s_1t".into(), exact_per_s));

    // --- reconciled mode scaling: 1 / 2 / 4 threads ----------------------
    // Best-of-3 per arm on fresh front ends so the occupancy history of
    // one rep never steers the next.
    let mut per_s = [0.0f64; 3];
    for (slot, threads) in [(0usize, 1usize), (1, 2), (2, 4)] {
        for _ in 0..3 {
            let front = frontend();
            let secs = run_arm(&front, threads, n, 1, 64);
            let total = threads as u64 * n;
            assert_eq!(front.decisions(), total);
            assert_eq!(front.routed(), total);
            per_s[slot] = per_s[slot].max(total as f64 / secs);
        }
        t.row(vec![
            format!("decisions/s (reconciled, {threads} thread(s))"),
            format!("{:.2}M", per_s[slot] / 1e6),
        ]);
        metrics.push((format!("routing_decisions_per_s_{threads}t"), per_s[slot]));
    }
    let scaling = per_s[2] / per_s[0].max(1e-9);
    t.row(vec!["scaling 4t vs 1t".into(), format!("{scaling:.2}x")]);
    metrics.push(("routing_scaling_4t".into(), scaling));

    // --- router-level batching: requests/s at batch 8 --------------------
    let decisions = scale(500_000, 50_000);
    for (label, threads) in [("1t", 1usize), ("4t", 4)] {
        let mut req_per_s = 0.0f64;
        for _ in 0..3 {
            let front = frontend();
            let secs = run_arm(&front, threads, decisions, 8, 64);
            let requests = threads as u64 * decisions * 8;
            assert_eq!(front.routed(), requests);
            assert_eq!(front.decisions(), threads as u64 * decisions);
            req_per_s = req_per_s.max(requests as f64 / secs);
        }
        t.row(vec![
            format!("requests/s (batch 8, {threads} thread(s))"),
            format!("{:.2}M", req_per_s / 1e6),
        ]);
        metrics.push((format!("routing_requests_per_s_batch8_{label}"), req_per_s));
    }

    // --- saturation: offered load beyond routing capacity ----------------
    // Fixed wall budget, unbatched vs batch-8.  One steering decision
    // per 8 requests must serve strictly more — the amortization that
    // `serve --frontend-threads N --batch 8` exploits on the Saturation
    // scenario's geometric load ramp.
    let budget = if quick { 0.04 } else { 0.2 };
    let served_1 = saturate(budget, 1);
    let served_8 = saturate(budget, 8);
    assert!(
        served_8 > served_1,
        "batched routing must out-serve unbatched at overload ({served_8} vs {served_1})"
    );
    let gain = served_8 as f64 / served_1 as f64;
    t.row(vec![
        "saturation served/s (unbatched)".into(),
        format!("{:.2}M", served_1 as f64 / budget / 1e6),
    ]);
    metrics.push((
        "saturation_served_per_s_unbatched".into(),
        served_1 as f64 / budget,
    ));
    t.row(vec![
        "saturation served/s (batch 8)".into(),
        format!("{:.2}M", served_8 as f64 / budget / 1e6),
    ]);
    metrics.push((
        "saturation_served_per_s_batch8".into(),
        served_8 as f64 / budget,
    ));
    t.row(vec!["saturation batch gain".into(), format!("{gain:.2}x")]);
    metrics.push(("saturation_batch_gain".into(), gain));

    t.print();
    if !quick && scaling < 3.0 {
        println!("WARN: 4-thread routing below the 3x scaling target ({scaling:.2}x)");
    }

    if let Some(path) = json_path {
        let doc = Json::Obj(vec![
            ("bench".to_string(), Json::Str("perf_routing".to_string())),
            ("quick".to_string(), Json::Bool(quick)),
            (
                "metrics".to_string(),
                Json::Obj(
                    metrics
                        .into_iter()
                        .map(|(k, v)| (k, Json::Num(v)))
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(&path, doc.to_string_compact()).expect("write --json output");
        println!("wrote {path}");
    }
}
