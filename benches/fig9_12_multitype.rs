//! Figures 9–12 — six policies (GrIn, BF, RD, JSQ, LB, Opt) × four
//! metrics over random 3×3 systems, under the four distributions.
//!
//! §6 setup: random μ entries and random N_i per sample; the paper shows
//! 10 samples per figure and reports the 1000-run average GrIn-to-Opt gap
//! of 1.6%.  `--samples` controls the displayed samples, `--gap-runs` the
//! gap average (default 1000, the paper's number — solver-only, fast).

use hetsched::cli::Args;
use hetsched::policy::{grin, PolicyKind};
use hetsched::report::Series;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;
use hetsched::solver::exhaustive::ExhaustiveSolver;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let samples: usize = args.get_parse("samples", 10).expect("--samples");
    let gap_runs: usize = args.get_parse("gap-runs", 1000).expect("--gap-runs");
    let measure: u64 = args.get_parse("measure", 8_000).expect("--measure");
    let gap_only = args.switch("gap");
    args.finish().expect("flags");

    // ---- the 1.6% claim (solver-level, like the paper's average) ----
    let mut rng = Rng::new(0x916);
    let mut gap_sum = 0.0;
    let mut gap_max = 0.0f64;
    for _ in 0..gap_runs {
        let mu = workload::random_mu(&mut rng, 3, 3, 0.5, 30.0).unwrap();
        let pops = workload::random_populations(&mut rng, 3, 7);
        let opt = ExhaustiveSolver.solve(&mu, &pops).unwrap();
        let g = grin::solve(&mu, &pops).unwrap();
        let gap = 1.0 - g.throughput / opt.throughput;
        gap_sum += gap;
        gap_max = gap_max.max(gap);
    }
    println!(
        "fig9-12: GrIn-to-Opt gap over {gap_runs} random 3x3 systems: \
         avg {:.2}% (paper: 1.6%), max {:.2}%",
        100.0 * gap_sum / gap_runs as f64,
        100.0 * gap_max
    );
    if gap_only {
        return;
    }

    // ---- the figure blocks ----
    let kinds = PolicyKind::six_multi_type();
    let figure = |d: Distribution| match d {
        Distribution::Exponential => "Fig 9",
        Distribution::BoundedPareto { .. } => "Fig 10",
        Distribution::Uniform => "Fig 11",
        Distribution::Constant => "Fig 12",
    };
    // One random system per sample point (shared across distributions,
    // like the paper's "10 random samples of a random μ matrix").
    let mut rng = Rng::new(0x912);
    let systems: Vec<_> = (0..samples)
        .map(|_| {
            let mu = workload::random_mu(&mut rng, 3, 3, 0.5, 30.0).unwrap();
            let pops = workload::random_populations(&mut rng, 3, 7);
            (mu, pops)
        })
        .collect();

    for dist in Distribution::all() {
        let mut x_s: Vec<Series> = kinds.iter().map(|k| Series::new(k.name())).collect();
        let mut t_s = x_s.clone();
        let mut edp_s = x_s.clone();
        let mut little_s = x_s.clone();
        for (sample, (mu, pops)) in systems.iter().enumerate() {
            for (i, kind) in kinds.iter().enumerate() {
                let mut cfg = SimConfig::paper_default(pops.clone());
                cfg.dist = dist;
                cfg.measure = measure;
                cfg.seed = 0x1000 + sample as u64;
                let net = ClosedNetwork::new(mu, cfg).unwrap();
                let r = net.run(kind.build().as_mut()).unwrap();
                let x = sample as f64;
                x_s[i].push(x, r.throughput);
                t_s[i].push(x, r.mean_response);
                edp_s[i].push(x, r.edp);
                little_s[i].push(x, r.little_product);
            }
        }
        let f = figure(dist);
        let d = dist.name();
        print!("{}", Series::render_block(&format!("{f} ({d}): throughput X"), "sample", &x_s));
        print!("{}", Series::render_block(&format!("{f} ({d}): mean response E[T]"), "sample", &t_s));
        print!("{}", Series::render_block(&format!("{f} ({d}): EDP"), "sample", &edp_s));
        print!("{}", Series::render_block(&format!("{f} ({d}): X·E[T] (≈N)"), "sample", &little_s));
        println!();
    }
}
