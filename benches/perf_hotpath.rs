//! §Perf — hot-path microbenchmarks feeding EXPERIMENTS.md §Perf.
//!
//! L3 targets (DESIGN.md §6): simulator ≥ 5M events/s; dispatch decisions
//! O(l) and allocation-free; GrIn solve well under SLSQP at 10×10; the
//! incremental X(S) evaluator a large constant factor under the full
//! Eq.-28 evaluation; the engine request path dominated by kernel time,
//! not dispatch overhead.
//!
//! Flags: `--quick` shrinks every loop for CI smoke runs; `--json PATH`
//! writes the measured values as a `BENCH_*.json`-style document for the
//! perf trajectory.

use std::time::Instant;

use hetsched::cli::Args;
use hetsched::config::json::Json;
use hetsched::model::throughput::{x_of_state, IncrementalX};
use hetsched::policy::{grin, PolicyKind, SolveRequest, SystemView};
use hetsched::report::{Stopwatch, Table};
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::replicate::{run_cells, ReplicationPlan, SimCell};
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;
use hetsched::solver::slsqp::Slsqp;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let quick = args.switch("quick");
    let json_path = args.get("json").map(str::to_string);
    args.finish().expect("flags");

    let scale = |full: u64, quick_n: u64| if quick { quick_n } else { full };
    let mut t = Table::new("perf_hotpath", &["metric", "value"]);
    // (key, value) pairs mirrored into the JSON artifact.
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // --- simulator event throughput -------------------------------------
    let mu = workload::paper_two_type_mu();
    let mut cfg = SimConfig::paper_default(vec![10, 10]);
    cfg.dist = Distribution::Exponential;
    cfg.warmup = 1_000;
    cfg.measure = scale(400_000, 50_000);
    // Every completion is one event, warm-up included: derive the event
    // count from the config rather than hardcoding the warm-up constant.
    let measured = cfg.measure;
    let total_events = cfg.warmup + cfg.measure;
    let net = ClosedNetwork::new(&mu, cfg).unwrap();
    // Best-of-3 through a warm arena: the CI regression gate compares
    // this number across runs, so a single cold-cache sample won't do.
    let mut arena = hetsched::sim::engine::SimArena::new();
    let mut events_per_s = 0.0f64;
    for _ in 0..3 {
        let t0 = Instant::now();
        let r = net
            .run_in(PolicyKind::Cab.build().as_mut(), &mut arena)
            .unwrap();
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(r.completed, measured);
        events_per_s = events_per_s.max(total_events as f64 / secs);
    }
    t.row(vec![
        "sim events/s (CAB, 2 procs, N=20)".into(),
        format!("{:.2}M", events_per_s / 1e6),
    ]);
    metrics.push(("sim_events_per_s".into(), events_per_s));

    // --- parallel replication runner scaling ------------------------------
    // R seeded replications through per-thread arenas: 1 thread vs 4.
    let sweep_cells: Vec<SimCell> = [0.2f64, 0.5, 0.8]
        .iter()
        .map(|&eta| {
            let (n1, n2) = workload::split_populations(20, eta);
            let mut sim = SimConfig::paper_default(vec![n1, n2]);
            sim.warmup = 200;
            sim.measure = scale(20_000, 4_000);
            sim.seed = 99;
            SimCell {
                label: format!("eta={eta}"),
                mu: mu.clone(),
                sim,
                policy: PolicyKind::Cab,
            }
        })
        .collect();
    let reps = scale(16, 8) as u32;
    let mut sweep_secs = [0.0f64; 2];
    for (slot, threads) in [(0usize, 1usize), (1, 4)] {
        let plan = ReplicationPlan { reps, threads, base_seed: 99 };
        let t0 = Instant::now();
        let stats = run_cells(&sweep_cells, &plan).unwrap();
        sweep_secs[slot] = t0.elapsed().as_secs_f64();
        assert!(stats.iter().all(|s| s.mean_x > 0.0));
        t.row(vec![
            format!("sweep {}x{} reps, {} thread(s)", sweep_cells.len(), reps, threads),
            format!("{:.3}s", sweep_secs[slot]),
        ]);
        metrics.push((format!("sweep_secs_{threads}t"), sweep_secs[slot]));
    }
    let speedup = sweep_secs[0] / sweep_secs[1].max(1e-9);
    t.row(vec!["sweep speedup 4t vs 1t".into(), format!("{speedup:.2}x")]);
    metrics.push(("sweep_speedup_4t".into(), speedup));

    // --- dispatch decision latency ---------------------------------------
    let pops = [10u32, 10];
    let state = hetsched::model::state::StateMatrix::from_two_type(1, 10, 10, 10).unwrap();
    let work = vec![1.0, 2.0];
    let mut rng = Rng::new(1);
    for kind in PolicyKind::five_two_type() {
        let mut p = kind.build();
        p.prepare(&SolveRequest::new(&mu, &pops)).unwrap();
        let view = SystemView { mu: &mu, state: &state, work: &work, populations: &pops };
        let n = scale(2_000_000, 200_000);
        let t0 = Instant::now();
        let mut sink = 0usize;
        for i in 0..n {
            sink ^= p.dispatch((i & 1) as usize, &view, &mut rng);
        }
        std::hint::black_box(sink);
        let ns = t0.elapsed().as_nanos() as f64 / n as f64;
        t.row(vec![format!("dispatch ns/op ({})", kind.name()), format!("{ns:.1}")]);
        metrics.push((format!("dispatch_ns_{}", kind.name()), ns));
    }

    // --- objective evaluation: full vs incremental -----------------------
    let mu9 = workload::random_mu(&mut rng, 8, 8, 0.5, 30.0).unwrap();
    let pops9 = workload::random_populations(&mut rng, 8, 8);
    let s9 = grin::solve(&mu9, &pops9).unwrap().state;
    let n = scale(2_000_000, 200_000);
    let t0 = Instant::now();
    let mut acc = 0.0;
    for _ in 0..n {
        acc += x_of_state(std::hint::black_box(&mu9), std::hint::black_box(&s9));
    }
    std::hint::black_box(acc);
    let full_ns = t0.elapsed().as_nanos() as f64 / n as f64;
    t.row(vec!["x_of_state ns/op (8x8, full)".into(), format!("{full_ns:.1}")]);
    metrics.push(("x_of_state_full_ns".into(), full_ns));

    // The GrIn hot path: O(1) move-delta probes on the SoA column caches.
    let inc = IncrementalX::new(&mu9, &s9);
    let t0 = Instant::now();
    let mut acc = 0.0;
    for i in 0..n {
        let p = (i & 7) as usize;
        let j = ((i >> 3) & 7) as usize;
        acc += std::hint::black_box(&inc).delta_plus(p, j);
    }
    std::hint::black_box(acc);
    let inc_ns = t0.elapsed().as_nanos() as f64 / n as f64;
    t.row(vec!["move-delta ns/op (8x8, incremental)".into(), format!("{inc_ns:.1}")]);
    metrics.push(("move_delta_incremental_ns".into(), inc_ns));
    t.row(vec![
        "incremental speedup vs full eval".into(),
        format!("{:.1}x", full_ns / inc_ns.max(1e-9)),
    ]);
    metrics.push(("incremental_speedup".into(), full_ns / inc_ns.max(1e-9)));

    // Whole-row probe pass (the auto-vectorizing large-l path).
    let mut dplus = vec![0.0f64; 8];
    let t0 = Instant::now();
    let mut acc = 0.0;
    let rows = n / 8;
    for i in 0..rows {
        std::hint::black_box(&inc).delta_plus_row((i & 7) as usize, &mut dplus);
        acc += dplus[(i & 7) as usize];
    }
    std::hint::black_box(acc);
    let row_ns = t0.elapsed().as_nanos() as f64 / (rows * 8) as f64;
    t.row(vec!["move-delta ns/op (8x8, row pass)".into(), format!("{row_ns:.2}")]);
    metrics.push(("move_delta_row_ns".into(), row_ns));

    // --- solver latencies --------------------------------------------------
    for size in [4usize, 8, 10] {
        let mut sw_g = Stopwatch::new();
        let mut sw_s = Stopwatch::new();
        let mut rng2 = Rng::new(99);
        let runs = scale(30, 6) as usize;
        for _ in 0..runs {
            let m = workload::random_mu(&mut rng2, size, size, 0.5, 30.0).unwrap();
            let p = workload::random_populations(&mut rng2, size, 8);
            sw_g.time(|| grin::solve(&m, &p).unwrap());
            sw_s.time(|| Slsqp::default().solve(&m, &p).unwrap());
        }
        t.row(vec![
            format!("GrIn µs ({size}x{size})"),
            format!("{:.1}", sw_g.mean_s() * 1e6),
        ]);
        metrics.push((format!("grin_us_{size}x{size}"), sw_g.mean_s() * 1e6));
        t.row(vec![
            format!("SLSQP µs ({size}x{size})"),
            format!("{:.1}", sw_s.mean_s() * 1e6),
        ]);
        metrics.push((format!("slsqp_us_{size}x{size}"), sw_s.mean_s() * 1e6));
    }

    // --- engine request path (native kernels / PJRT with --features pjrt)
    match hetsched::runtime::Engine::open_default() {
        Ok(eng) => {
            let x = vec![0.1f32; 8 * 256];
            let w = vec![0.01f32; 256 * 256];
            let b = vec![0.0f32; 256];
            eng.nn_task("nn_small", &x, &w, &b).unwrap(); // compile/warm
            let mut sw = Stopwatch::new();
            sw.run_n(scale(200, 20) as usize, || {
                eng.nn_task("nn_small", &x, &w, &b).unwrap();
            });
            t.row(vec!["nn_small exec µs (warm)".into(), format!("{:.1}", sw.mean_s() * 1e6)]);
            metrics.push(("nn_small_exec_us".into(), sw.mean_s() * 1e6));
            let rows = vec![0.5f32; 16 * 256];
            eng.sort_task("sort_small", &rows).unwrap();
            let mut sw = Stopwatch::new();
            sw.run_n(scale(50, 10) as usize, || {
                eng.sort_task("sort_small", &rows).unwrap();
            });
            t.row(vec!["sort_small exec µs (warm)".into(), format!("{:.1}", sw.mean_s() * 1e6)]);
            metrics.push(("sort_small_exec_us".into(), sw.mean_s() * 1e6));

            // Batched exhaustive offload vs scalar.
            let mu3 = workload::random_mu(&mut rng, 3, 3, 1.0, 20.0).unwrap();
            let pops3 = vec![6u32, 6, 6];
            let (kp, lp, bsz) = (16usize, 16usize, 4096usize);
            let mut mu_p = vec![0f32; kp * lp];
            for i in 0..3 {
                for j in 0..3 {
                    mu_p[i * lp + j] = mu3.rate(i, j) as f32;
                }
            }
            let t0 = Instant::now();
            let scalar = hetsched::solver::exhaustive::ExhaustiveSolver
                .solve(&mu3, &pops3)
                .unwrap();
            let ts = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let batched = hetsched::solver::exhaustive::ExhaustiveSolver
                .solve_batched(&mu3, &pops3, bsz, kp, lp, |buf| eng.throughput_batch(&mu_p, buf))
                .unwrap();
            let tb = t1.elapsed().as_secs_f64();
            assert!((batched.throughput - scalar.throughput).abs() / scalar.throughput < 1e-4);
            t.row(vec![
                format!("exhaustive scalar ({} states)", scalar.evaluated),
                format!("{:.1} ms", ts * 1e3),
            ]);
            metrics.push(("exhaustive_scalar_ms".into(), ts * 1e3));
            t.row(vec![
                "exhaustive engine-batched (same)".into(),
                format!("{:.1} ms", tb * 1e3),
            ]);
            metrics.push(("exhaustive_batched_ms".into(), tb * 1e3));
        }
        Err(e) => {
            t.row(vec!["engine rows skipped".into(), e.to_string()]);
        }
    }

    t.print();
    if !quick && events_per_s < 5e6 {
        println!("WARN: sim below the 5M events/s target ({events_per_s:.0}/s)");
    }

    if let Some(path) = json_path {
        let doc = Json::Obj(vec![
            ("bench".to_string(), Json::Str("perf_hotpath".to_string())),
            ("quick".to_string(), Json::Bool(quick)),
            (
                "metrics".to_string(),
                Json::Obj(
                    metrics
                        .into_iter()
                        .map(|(k, v)| (k, Json::Num(v)))
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(&path, doc.to_string_compact()).expect("write --json output");
        println!("wrote {path}");
    }
}
