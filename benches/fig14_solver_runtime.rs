//! Figure 14 — algorithm runtime: GrIn vs SLSQP as the number of
//! processor types grows (3 … 10).
//!
//! §6 methodology: only runs where both solvers land within 5% of each
//! other's throughput are timed ("a more reliable runtime for both
//! algorithms when they can deliver similar solutions"); 100 runs per
//! size, averages reported.  Paper shape: GrIn up to 2× faster and
//! flatter in the number of types.

use std::time::Instant;

use hetsched::cli::Args;
use hetsched::policy::grin;
use hetsched::report::Table;
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;
use hetsched::solver::slsqp::Slsqp;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let runs: usize = args.get_parse("runs", 100).expect("--runs");
    args.finish().expect("flags");

    let mut t = Table::new(
        format!("Fig 14: solver runtime (runs with ≤5% throughput gap, of {runs})"),
        &["types (k=l)", "GrIn (µs)", "SLSQP (µs)", "speedup", "counted"],
    );
    let mut rng = Rng::new(0xF14);
    for size in 3..=10usize {
        let mut grin_ns = 0u128;
        let mut slsqp_ns = 0u128;
        let mut counted = 0u32;
        for _ in 0..runs {
            let mu = workload::random_mu(&mut rng, size, size, 0.5, 30.0).unwrap();
            let pops = workload::random_populations(&mut rng, size, 8);

            let t0 = Instant::now();
            let g = grin::solve(&mu, &pops).unwrap();
            let tg = t0.elapsed();
            let t1 = Instant::now();
            let s = Slsqp::default().solve(&mu, &pops).unwrap();
            let ts = t1.elapsed();

            // Paper's 5%-agreement filter.
            let rel = (g.throughput - s.throughput).abs() / g.throughput.max(1e-9);
            if rel <= 0.05 {
                grin_ns += tg.as_nanos();
                slsqp_ns += ts.as_nanos();
                counted += 1;
            }
        }
        if counted == 0 {
            t.row(vec![format!("{size}x{size}"), "-".into(), "-".into(), "-".into(), "0".into()]);
            continue;
        }
        let gu = grin_ns as f64 / counted as f64 / 1e3;
        let su = slsqp_ns as f64 / counted as f64 / 1e3;
        t.row(vec![
            format!("{size}x{size}"),
            format!("{gu:.1}"),
            format!("{su:.1}"),
            format!("{:.2}x", su / gu),
            counted.to_string(),
        ]);
    }
    t.print();
    println!("fig14: paper shape — GrIn faster and more scalable in #types");
}
