//! Table 1 — the optimal-policy state table.
//!
//! For each regime row of Table 1 we take a representative affinity
//! matrix, compute CAB's analytic S_max, and verify by exhaustive search
//! over the full (N11, N22) grid that no state beats it.  Prints the
//! regenerated table.

use hetsched::model::affinity::AffinityMatrix;
use hetsched::model::state::StateMatrix;
use hetsched::model::throughput::{s_max, x_max_theoretical, x_of_state};
use hetsched::report::Table;

fn main() {
    let (n1, n2) = (10u32, 10u32);
    let rows: Vec<(&str, AffinityMatrix)> = vec![
        ("homogeneous", AffinityMatrix::two_type(5.0, 5.0, 5.0, 5.0).unwrap()),
        ("big.LITTLE-like", AffinityMatrix::two_type(6.0, 2.0, 6.0, 2.0).unwrap()),
        ("symmetric", AffinityMatrix::two_type(9.0, 3.0, 3.0, 9.0).unwrap()),
        ("general-symmetric", AffinityMatrix::two_type(9.0, 2.0, 3.0, 7.0).unwrap()),
        ("P1-biased", AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap()),
        ("P2-biased", AffinityMatrix::two_type(7.0, 2.0, 9.0, 12.0).unwrap()),
    ];

    let mut t = Table::new(
        format!("Table 1: S_max per regime (N1={n1}, N2={n2})"),
        &["regime", "classified", "S_max", "X theory", "X exhaustive", "match"],
    );
    for (name, mu) in rows {
        let regime = mu.classify().expect("representative matrices classify");
        let (s11, s22) = s_max(regime, n1, n2);
        let theory = x_max_theoretical(&mu, regime, n1, n2);
        // Exhaustive grid.
        let mut best = f64::MIN;
        let mut arg = (0, 0);
        for a in 0..=n1 {
            for b in 0..=n2 {
                let s = StateMatrix::from_two_type(a, b, n1, n2).unwrap();
                let x = x_of_state(&mu, &s);
                if x > best {
                    best = x;
                    arg = (a, b);
                }
            }
        }
        let cab_x =
            x_of_state(&mu, &StateMatrix::from_two_type(s11, s22, n1, n2).unwrap());
        let ok = (cab_x - best).abs() < 1e-9;
        t.row(vec![
            name.into(),
            regime.name().into(),
            format!("({s11},{s22})"),
            format!("{theory:.4}"),
            format!("{best:.4} @({},{})", arg.0, arg.1),
            if ok { "yes".into() } else { "NO".into() },
        ]);
        assert!(ok, "{name}: CAB S_max is not the grid optimum");
    }
    t.print();
    println!("table1_smax: all regimes verified against exhaustive grid");
}
