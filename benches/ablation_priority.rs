//! Ablation: priority-weighted vs unweighted scheduling on the
//! contended-fast-device system.
//!
//! Two tables:
//!
//! 1. **Solve-level trade** (no simulation): the GrIn target, total X
//!    and per-class X at both population mixes of the `priority_mix`
//!    flip, unweighted vs 4:1 weighted — what the reservation costs in
//!    total throughput and buys the high-priority class.
//! 2. **End to end** (replicated): unweighted vs priority-aware arms
//!    under the single-leader adaptive loop and the sharded plane on
//!    the full flip scenario — priority-weighted mean X ± t-corrected
//!    CI, per-class X, the priority-weighted objective Σ w_i·X_i, the
//!    class-0 soft-deadline miss rate, and the class-0 p99 response.

use hetsched::cli::Args;
use hetsched::model::throughput::{x_of_state, WeightedIncrementalX};
use hetsched::policy::grin;
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::dynamic::{run_dynamic_report, DynamicConfig, ResolveMode};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::workload::{self, scenario_phases, ScenarioKind, ScenarioParams};

/// Per-class throughput contribution of a solved state:
/// X_i = Σ_j μ_ij·N_ij / occ_j.
fn class_x(
    mu: &hetsched::model::affinity::AffinityMatrix,
    n: &hetsched::model::state::StateMatrix,
    class: usize,
) -> f64 {
    (0..mu.procs())
        .map(|j| {
            let occ = n.col_sum(j);
            if occ == 0 {
                0.0
            } else {
                mu.rate(class, j) * n.get(class, j) as f64 / occ as f64
            }
        })
        .sum()
}

const PRIORITIES: [u32; 2] = [4, 1];

fn scenario_cfg(resolve: ResolveMode, weighted: bool, quick: bool) -> DynamicConfig {
    let params = ScenarioParams {
        phases: 4,
        completions: if quick { 800 } else { 3_000 },
        warmup: if quick { 100 } else { 300 },
        ..Default::default()
    };
    let mut cfg =
        DynamicConfig::new(scenario_phases(ScenarioKind::PriorityMix, &params).unwrap());
    cfg.resolve = resolve;
    cfg.seed = 0xAB5;
    cfg.drift.threshold = 0.4;
    cfg.shard.shards = 2;
    cfg.shard.sync_every = 250;
    if weighted {
        cfg.priorities = PRIORITIES.to_vec();
    }
    cfg.deadlines = vec![1.0, 0.0];
    cfg
}

fn main() {
    let args = Args::from_env().unwrap();
    args.ignore_harness_flags();
    let quick = args.switch("quick");
    args.finish().unwrap();

    let mu = workload::priority_mu();

    // 1. The solve-level trade at both mixes of the flip.
    let weights = grin::priority_weights(&PRIORITIES, &[1.0; 4], 2).unwrap();
    let mut t = Table::new(
        format!("GrIn target, unweighted vs {PRIORITIES:?}-weighted (μ = priority_mu)"),
        &["populations", "arm", "target", "total X", "Xw(S)", "X(class 0)", "X(class 1)"],
    );
    for pops in [[4u32, 16], [16, 4]] {
        let plain = grin::solve(&mu, &pops).unwrap();
        let weighted = grin::solve_weighted(&mu, &pops, &weights).unwrap();
        for (label, sol) in [("unweighted", &plain), ("priority", &weighted)] {
            // The weighted objective each arm is (implicitly or
            // explicitly) scored by — what the weighted greedy loop
            // maximizes.
            let xw = WeightedIncrementalX::new(&mu, &sol.state, &weights).unwrap().x();
            t.row(vec![
                format!("{pops:?}"),
                label.to_string(),
                format!("{:?}", sol.state.data()),
                format!("{:.3}", x_of_state(&mu, &sol.state)),
                format!("{xw:.3}"),
                format!("{:.3}", class_x(&mu, &sol.state, 0)),
                format!("{:.3}", class_x(&mu, &sol.state, 1)),
            ]);
        }
    }
    t.print();

    // 2. End to end on the flip scenario, replicated.
    let arms: [(ResolveMode, bool, &str); 4] = [
        (ResolveMode::Adaptive, false, "adaptive unweighted"),
        (ResolveMode::Adaptive, true, "adaptive priority"),
        (ResolveMode::Sharded, false, "sharded unweighted"),
        (ResolveMode::Sharded, true, "sharded priority"),
    ];
    let cells: Vec<DynCell> = arms
        .iter()
        .map(|&(mode, weighted, label)| DynCell {
            label: label.to_string(),
            mu: mu.clone(),
            cfg: scenario_cfg(mode, weighted, quick),
            policy: PolicyKind::GrIn,
        })
        .collect();
    let plan = ReplicationPlan {
        reps: if quick { 2 } else { 4 },
        threads: 0,
        base_seed: 0x9917,
    };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    // Single seeded runs for the p99 column (the replication aggregates
    // carry means, not percentiles).
    let pri_mean = PRIORITIES.iter().map(|&p| p as f64).sum::<f64>() / 2.0;
    let mut t = Table::new(
        format!(
            "priority ablation on priority_mix (R = {}, mean ± t-corrected 95% CI; \
             deadline 1.0 s on class 0)",
            plan.reps
        ),
        &[
            "arm",
            "mean X",
            "X(class 0)",
            "X(class 1)",
            "Σ w·X (weighted)",
            "miss(class 0)",
            "p99(class 0)",
        ],
    );
    for (s, &(mode, weighted, _)) in stats.iter().zip(&arms) {
        let wx: f64 = s
            .mean_class_x
            .iter()
            .zip(&PRIORITIES)
            .map(|(&x, &p)| p as f64 / pri_mean * x)
            .sum();
        let mut policy = PolicyKind::GrIn.build();
        let report =
            run_dynamic_report(&mu, &scenario_cfg(mode, weighted, quick), policy.as_mut())
                .unwrap();
        let p99 = report
            .phases
            .iter()
            .filter_map(|r| r.p99_by_class.first().copied())
            .fold(0.0f64, f64::max);
        t.row(vec![
            s.label.clone(),
            format!("{:.4} ± {:.4}", s.mean_x, s.ci95_x),
            format!("{:.4}", s.mean_class_x[0]),
            format!("{:.4}", s.mean_class_x[1]),
            format!("{wx:.4}"),
            format!("{:.1}%", s.mean_miss_rate[0] * 100.0),
            format!("{p99:.3}s"),
        ]);
    }
    t.print();
    println!(
        "ablation_priority: the 4:1 weighted solve reserves the contended fast \
         device for the high-priority class — multiplying its throughput and \
         cutting its deadline misses for a few percent of total X; the \
         unweighted optimum crowds the majority class onto the fast device \
         and starves the tier that matters"
    );
}
