//! Figures 15–16 — experimental throughput of all five policies on the
//! (emulated) CPU+GPU platform, plus the theoretical CAB line.
//!
//! §7 setup: N = 20 closed-loop benchmarks, FCFS device queues, η swept
//! 0.1…0.9.  Fig. 15 is the P2-biased case (CAB = AF), Fig. 16 the
//! general-symmetric case (CAB = BF).  Theory is Table-1's X_max computed
//! from the *measured* rates, exactly as the paper overlays it.
//!
//! Flags: `--case p2_biased|general_symmetric` (default both),
//! `--measure` completions per point (default 40), `--etas 0.2,0.5,0.8`.
//! Requires `make artifacts`.

use hetsched::cli::Args;
use hetsched::model::throughput::x_max_theoretical;
use hetsched::platform::bench_rig::{cases, run_platform, PlatformConfig};
use hetsched::platform::{calibrate, measure_rates};
use hetsched::policy::PolicyKind;
use hetsched::report::Series;
use hetsched::sim::workload;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let measure: u64 = args.get_parse("measure", 40).expect("--measure");
    let only_case = args.get("case").map(str::to_string);
    let etas: Vec<f64> = match args.get("etas") {
        Some(list) => list.split(',').map(|s| s.parse().expect("--etas")).collect(),
        None => vec![0.1, 0.3, 0.5, 0.7, 0.9],
    };
    args.finish().expect("flags");

    let cal = match calibrate(5) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fig15_16_platform: {e}\nrun `make artifacts` first");
            std::process::exit(0);
        }
    };

    let kinds = PolicyKind::five_two_type();
    for (case_name, fig, devices) in [
        ("p2_biased", "Fig 15", cases::p2_biased(&cal, 96)),
        ("general_symmetric", "Fig 16", cases::general_symmetric(&cal, 96)),
    ] {
        if let Some(only) = &only_case {
            if only != case_name {
                continue;
            }
        }
        eprintln!("{fig}: measuring rates ({case_name})...");
        let rates = measure_rates(&devices, 3).expect("measurement");
        let regime = rates.mu.classify().expect("regime");
        let mut series: Vec<Series> =
            kinds.iter().map(|k| Series::new(k.name())).collect();
        let mut theory = Series::new("theory(CAB)");
        for &eta in &etas {
            let (n1, n2) = workload::split_populations(20, eta);
            theory.push(eta, x_max_theoretical(&rates.mu, regime, n1, n2));
            for (i, kind) in kinds.iter().enumerate() {
                let cfg = PlatformConfig {
                    devices: devices.clone(),
                    populations: vec![n1, n2],
                    warmup: 20,
                    measure,
                    seed: 0x156 + (eta * 10.0) as u64,
                };
                let mut p = kind.build();
                let r = run_platform(&cfg, &rates, p.as_mut()).expect("platform run");
                series[i].push(eta, r.throughput);
                eprintln!(
                    "  η={eta:.1} {}: {:.2} tasks/s",
                    kind.name(),
                    r.throughput
                );
            }
        }
        let mut all = series;
        all.push(theory);
        print!(
            "{}",
            Series::render_block(
                &format!("{fig} ({case_name}, regime {}): experimental throughput", regime.name()),
                "eta",
                &all
            )
        );
        // CAB vs LB improvement band (paper: 3.27–9.07× / 2.37–4.48×).
        let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
        for i in 0..all[0].points.len() {
            let r = all[0].points[i].1 / all[4].points[i].1;
            lo = lo.min(r);
            hi = hi.max(r);
        }
        println!("{fig}: CAB vs LB improvement {lo:.2}x – {hi:.2}x\n");
    }
}
