//! Ablation: CAB vs the myopic one-step policy (Ahn et al. [22], §2).
//!
//! The paper argues prior myopic policies are "optimal under certain
//! conditions" only.  This ablation quantifies where one-step greed
//! fails: in the biased regimes the AF state requires placing tasks on a
//! *slower* processor for long-run gain, which a myopic maximizer of
//! X(S⁺) can refuse.  In the (general-)symmetric regimes myopic ≈ CAB.

use hetsched::model::affinity::AffinityMatrix;
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::workload;

fn main() {
    let systems: Vec<(&str, AffinityMatrix)> = vec![
        ("P1-biased (§5 matrix)", workload::paper_two_type_mu()),
        ("P2-biased (Table 3)", workload::table3::p2_biased()),
        ("general-symmetric (Table 3)", workload::table3::general_symmetric()),
        ("symmetric", AffinityMatrix::two_type(9.0, 3.0, 3.0, 9.0).unwrap()),
    ];
    let mut t = Table::new(
        "ablation: CAB vs Myopic vs BF (N=20, η=0.5, exponential)",
        &["system", "CAB X", "Myopic X", "BF X", "CAB/Myopic"],
    );
    for (name, mu) in systems {
        let run = |kind: PolicyKind| {
            let mut cfg = SimConfig::paper_default(vec![10, 10]);
            cfg.dist = Distribution::Exponential;
            cfg.measure = 15_000;
            cfg.seed = 0xAB1;
            let net = ClosedNetwork::new(&mu, cfg).unwrap();
            net.run(kind.build().as_mut()).unwrap().throughput
        };
        let cab = run(PolicyKind::Cab);
        let myo = run(PolicyKind::Myopic);
        let bf = run(PolicyKind::BestFit);
        t.row(vec![
            name.into(),
            format!("{cab:.3}"),
            format!("{myo:.3}"),
            format!("{bf:.3}"),
            format!("{:.3}x", cab / myo),
        ]);
        assert!(cab >= myo * 0.98, "{name}: myopic beat CAB");
    }
    t.print();
    println!("ablation_myopic: CAB ≥ Myopic everywhere; gap opens in biased regimes");
}
