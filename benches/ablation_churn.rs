//! Ablation: churn-aware control vs a failure-schedule oracle.
//!
//! Runs the `churn` scenario — alternating slow-node ("limping") cycles
//! on the class-0 fast device and full down/up outages on the other —
//! at three fault severities, under four control modes:
//!
//! * **static (frozen)**: the phase-0 target is never revisited; the
//!   only fault response is the physical dispatch fallback, so the
//!   frozen solve keeps steering work at crippled devices;
//! * **adaptive**: single leader with CUSUM/threshold estimation plus
//!   the explicit down/up signal path — masks dead columns, re-solves,
//!   re-dispatches evacuated work;
//! * **sharded**: the multi-leader plane with per-shard liveness and
//!   global re-partition on churn;
//! * **oracle**: the every-phase re-solver handed the exact effective
//!   rates at each fault event — the failure-schedule upper bound the
//!   reactive modes are measured against.
//!
//! `--quick` shrinks completions and replication for the CI smoke run.

use hetsched::cli::Args;
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::dynamic::{DynamicConfig, ResolveMode};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::workload::{
    self, churn_fault_plan, scenario_phases, ScenarioKind, ScenarioParams,
};

fn main() {
    let args = Args::from_env().unwrap();
    args.ignore_harness_flags();
    let quick = args.switch("quick");
    args.finish().unwrap();

    let completions = if quick { 600 } else { 2_500 };
    let warmup = if quick { 80 } else { 300 };
    // (label, outage fraction of a phase, slow-node rate factor).
    let severities = [
        ("light", 0.15, 0.50),
        ("default", 0.30, 0.25),
        ("heavy", 0.50, 0.15),
    ];
    let modes = [
        (ResolveMode::Static, "frozen"),
        (ResolveMode::Adaptive, "adaptive"),
        (ResolveMode::Sharded, "sharded"),
        (ResolveMode::EveryPhase, "oracle"),
    ];

    let mu = workload::paper_two_type_mu();
    let mut cells = Vec::new();
    for &(sev, down, limp) in &severities {
        let params = ScenarioParams {
            phases: 5,
            completions,
            warmup,
            churn_down: down,
            churn_limp: limp,
            ..Default::default()
        };
        let phases = scenario_phases(ScenarioKind::Churn, &params).unwrap();
        let faults = churn_fault_plan(&mu, &params).unwrap();
        for &(mode, label) in &modes {
            let mut cfg = DynamicConfig::new(phases.clone());
            cfg.resolve = mode;
            cfg.faults = faults.clone();
            cfg.seed = 0xC1C;
            cells.push(DynCell {
                label: format!("{sev} {label}"),
                mu: mu.clone(),
                cfg,
                policy: PolicyKind::GrIn,
            });
        }
    }

    let plan = ReplicationPlan {
        reps: if quick { 2 } else { 4 },
        threads: 0,
        base_seed: 0xFA11,
    };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();

    let mut t = Table::new(
        format!(
            "churn ablation (R = {}, mean ± t-corrected 95% CI; no task lost in any run)",
            plan.reps
        ),
        &["severity + mode", "mean X", "redisp/run", "down%", "re-solves/run"],
    );
    for s in &stats {
        t.row(vec![
            s.label.clone(),
            format!("{:.4} ± {:.4}", s.mean_x, s.ci95_x),
            format!("{:.1}", s.mean_redispatched),
            format!("{:.1}%", s.mean_downtime_frac * 100.0),
            format!("{:.1}", s.mean_resolves),
        ]);
    }
    t.print();

    for (si, &(sev, _, _)) in severities.iter().enumerate() {
        let base = si * modes.len();
        let (frozen, adaptive, sharded, oracle) = (
            &stats[base],
            &stats[base + 1],
            &stats[base + 2],
            &stats[base + 3],
        );
        println!(
            "{sev}: adaptive {:.2}x frozen / {:.0}% of oracle, sharded {:.2}x frozen / \
             {:.0}% of oracle",
            adaptive.mean_x / frozen.mean_x,
            100.0 * adaptive.mean_x / oracle.mean_x,
            sharded.mean_x / frozen.mean_x,
            100.0 * sharded.mean_x / oracle.mean_x,
        );
    }
    println!(
        "ablation_churn: the frozen target keeps feeding crippled devices; the \
         churn-aware modes evacuate, re-solve against the surviving fleet and \
         track the failure-schedule oracle"
    );
}
