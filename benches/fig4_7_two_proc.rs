//! Figures 4–7 — five policies × four metrics × nine η values, under the
//! four task-size distributions (two-processor P1-biased system).
//!
//! Reproduces the §5 setup exactly: N = 20 programs, μ = [[20,15],[3,8]],
//! PS processors, proportional power.  Prints one block per
//! (distribution × metric): columns are policies, rows are η — the data
//! behind each subplot.
//!
//! Flags: `--dist exp|pareto|uniform|const` to restrict (default: all),
//! `--measure N` completions per point.

use hetsched::cli::Args;
use hetsched::policy::PolicyKind;
use hetsched::report::Series;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::workload;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let dists: Vec<Distribution> = match args.get("dist") {
        Some(d) => vec![Distribution::parse(d).expect("--dist")],
        None => Distribution::all().to_vec(),
    };
    let measure: u64 = args.get_parse("measure", 12_000).expect("--measure");
    args.finish().expect("flags");

    let mu = workload::paper_two_type_mu();
    let kinds = PolicyKind::five_two_type();
    let figure = |d: Distribution| match d {
        Distribution::Exponential => "Fig 4",
        Distribution::BoundedPareto { .. } => "Fig 5",
        Distribution::Uniform => "Fig 6",
        Distribution::Constant => "Fig 7",
    };

    for dist in dists {
        // metric -> per-policy series
        let mut x_s: Vec<Series> = kinds.iter().map(|k| Series::new(k.name())).collect();
        let mut t_s = x_s.clone();
        let mut edp_s = x_s.clone();
        let mut little_s = x_s.clone();
        for eta in workload::eta_grid() {
            let (n1, n2) = workload::split_populations(20, eta);
            for (i, kind) in kinds.iter().enumerate() {
                let mut cfg = SimConfig::paper_default(vec![n1, n2]);
                cfg.dist = dist;
                cfg.measure = measure;
                cfg.seed = 0xF1905 + (eta * 100.0) as u64;
                let net = ClosedNetwork::new(&mu, cfg).unwrap();
                let r = net.run(kind.build().as_mut()).unwrap();
                x_s[i].push(eta, r.throughput);
                t_s[i].push(eta, r.mean_response);
                edp_s[i].push(eta, r.edp);
                little_s[i].push(eta, r.little_product);
            }
        }
        let f = figure(dist);
        let d = dist.name();
        print!("{}", Series::render_block(&format!("{f} ({d}): throughput X"), "eta", &x_s));
        print!("{}", Series::render_block(&format!("{f} ({d}): mean response E[T]"), "eta", &t_s));
        print!("{}", Series::render_block(&format!("{f} ({d}): EDP"), "eta", &edp_s));
        print!("{}", Series::render_block(&format!("{f} ({d}): X·E[T] (≈N=20)"), "eta", &little_s));

        // Paper-style summary: CAB improvement over LB across the sweep.
        let (mut min_r, mut max_r) = (f64::INFINITY, 0.0f64);
        for i in 0..x_s[0].points.len() {
            let cab = x_s[0].points[i].1;
            let lb = x_s[4].points[i].1;
            let r = cab / lb;
            min_r = min_r.min(r);
            max_r = max_r.max(r);
        }
        println!("{f} ({d}): CAB vs LB throughput improvement: {min_r:.2}x – {max_r:.2}x");
        let (mut min_e, mut max_e) = (f64::INFINITY, 0.0f64);
        for i in 0..edp_s[0].points.len() {
            let r = edp_s[4].points[i].1 / edp_s[0].points[i].1;
            min_e = min_e.min(r);
            max_e = max_e.max(r);
        }
        println!("{f} ({d}): CAB vs LB EDP improvement: {min_e:.2}x – {max_e:.2}x");
        println!();
    }
}
