//! Analytic CTMC study (§3.3, Fig. 3 / Eq. 9) — simulation-free
//! verification of Lemma 2 on the paper's system.
//!
//! Solves the balance equations exactly for several routing policies and
//! compares the Eq.-9 throughput against (a) the Lemma-2 bound max X(S)
//! and (b) the discrete-event simulation, per η.

use hetsched::model::ctmc::{solve, BfRouting, CabRouting, JsqRouting, RandomRouting};
use hetsched::policy::PolicyKind;
use hetsched::report::Table;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::workload;

fn main() {
    let mu = workload::paper_two_type_mu();
    let mut t = Table::new(
        "CTMC analysis (N = 12; exponential sizes)",
        &["(N1,N2)", "X_max", "CAB ctmc", "BF ctmc", "JSQ ctmc", "RD ctmc", "RD sim", "ctmc-sim err"],
    );
    for eta in [0.25, 0.5, 0.75] {
        let (n1, n2) = workload::split_populations(12, eta);
        let cab = solve(&mu, n1, n2, &CabRouting::new(&mu, n1, n2).unwrap()).unwrap();
        let bf = solve(&mu, n1, n2, &BfRouting::new(&mu)).unwrap();
        let jsq = solve(&mu, n1, n2, &JsqRouting::new(&mu)).unwrap();
        let rd = solve(&mu, n1, n2, &RandomRouting).unwrap();
        // Lemma 2: analytic CAB == X_max; every routing ≤ X_max.
        assert!((cab.throughput - cab.x_max).abs() < 1e-8);
        assert!(bf.throughput <= cab.x_max + 1e-9);
        assert!(jsq.throughput <= cab.x_max + 1e-9);
        assert!(rd.throughput <= cab.x_max + 1e-9);
        // Cross-check vs simulation on the irreducible RD chain
        // (deterministic routings split into recurrent classes; see
        // model::ctmc docs).
        let mut cfg = SimConfig::paper_default(vec![n1, n2]);
        cfg.dist = Distribution::Exponential;
        cfg.measure = 50_000;
        let net = ClosedNetwork::new(&mu, cfg).unwrap();
        let sim = net.run(PolicyKind::Random.build().as_mut()).unwrap().throughput;
        let err = (rd.throughput - sim).abs() / rd.throughput;
        t.row(vec![
            format!("({n1},{n2})"),
            format!("{:.4}", cab.x_max),
            format!("{:.4}", cab.throughput),
            format!("{:.4}", bf.throughput),
            format!("{:.4}", jsq.throughput),
            format!("{:.4}", rd.throughput),
            format!("{sim:.4}"),
            format!("{:.2}%", 100.0 * err),
        ]);
        assert!(err < 0.03, "CTMC vs sim mismatch for RD: {err}");
    }
    t.print();
    println!("ctmc_analysis: Lemma 2 verified analytically; CTMC matches simulation");
}
