//! Table 3 — measured kernel processing rates on both (emulated)
//! processors, through the real PJRT engines.
//!
//! §7.2: "We run each kernel 1000 times and calculate the average
//! execution time ω, and therefore, the processing rate μ = 1/ω."
//! `--runs` controls sampling (default 10; the measurement is offline so
//! the paper's 1000 is a precision choice, not a correctness one).
//!
//! Requires `make artifacts`.

use hetsched::cli::Args;
use hetsched::platform::bench_rig::cases;
use hetsched::platform::{calibrate, measure_rates};
use hetsched::report::Table;

fn main() {
    let args = Args::from_env().expect("args");
    args.ignore_harness_flags();
    let runs: u32 = args.get_parse("runs", 10).expect("--runs");
    let cap: u32 = args.get_parse("rep-cap", 96).expect("--rep-cap");
    args.finish().expect("flags");

    let cal = match calibrate(runs.min(20)) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("table3_rates: {e}");
            eprintln!("run `make artifacts` first");
            std::process::exit(0); // bench suite stays green without artifacts
        }
    };

    for (case, devices, bench_names) in [
        (
            "general-symmetric (§7.4)",
            cases::general_symmetric(&cal, cap),
            ["quicksort-500 (sort_small)", "NN-2000 (nn_small)"],
        ),
        (
            "P2-biased (§7.3)",
            cases::p2_biased(&cal, cap),
            ["quicksort-1000 (sort_large)", "NN-2000 (nn_small)"],
        ),
    ] {
        let rates = measure_rates(&devices, runs).expect("measurement");
        let mut t = Table::new(
            format!("Table 3 analog — measured rates, {case}"),
            &["benchmark", "μ_CPU (1/s)", "μ_GPU (1/s)", "reps CPU", "reps GPU"],
        );
        for (i, name) in bench_names.iter().enumerate() {
            t.row(vec![
                name.to_string(),
                format!("{:.2}", rates.mu.rate(i, 0)),
                format!("{:.2}", rates.mu.rate(i, 1)),
                devices[0].reps[i].to_string(),
                devices[1].reps[i].to_string(),
            ]);
        }
        t.print();
        println!(
            "classified regime: {} (paper: {})\n",
            rates.mu.classify().map(|r| r.name()).unwrap_or("UNCLASSIFIED"),
            if case.starts_with("general") { "general-symmetric" } else { "P2-biased" },
        );
    }
}
