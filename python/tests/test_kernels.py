"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and, where supported, dtypes/value regimes);
every property asserts allclose against ``compile.kernels.ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.nn_forward import nn_forward, vmem_bytes
from compile.kernels.sort_net import sort_rows
from compile.kernels.throughput import throughput_batch

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# nn_forward
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 2, 8, 32]),
    n=st.sampled_from([128, 256]),
    k=st.sampled_from([128, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nn_forward_matches_ref(m, n, k, seed):
    r = rng(seed)
    x = r.standard_normal((m, k), dtype=np.float32)
    w = r.standard_normal((k, n), dtype=np.float32)
    b = r.standard_normal(n, dtype=np.float32)
    got = nn_forward(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    want = ref.nn_forward_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (32, 128, 512), (16, 256, 256)])
def test_nn_forward_block_shapes_equivalent(bm, bn, bk):
    """Tiling must not change the numerics (accumulation order aside)."""
    r = rng(7)
    x = r.standard_normal((32, 512), dtype=np.float32)
    w = r.standard_normal((512, 256), dtype=np.float32)
    b = r.standard_normal(256, dtype=np.float32)
    got = nn_forward(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
        block_m=bm, block_n=bn, block_k=bk,
    )
    want = ref.nn_forward_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_nn_forward_relu_clamps():
    x = -jnp.ones((8, 128), jnp.float32)
    w = jnp.eye(128, dtype=jnp.float32)
    b = jnp.zeros(128, jnp.float32)
    got = nn_forward(x, w, b)
    assert float(jnp.min(got)) == 0.0


def test_nn_forward_shape_mismatch_raises():
    with pytest.raises(ValueError):
        nn_forward(
            jnp.zeros((4, 128)), jnp.zeros((64, 128)), jnp.zeros(128)
        )


def test_vmem_budget():
    """The shipped nn2000 tiling must fit a conservative VMEM budget."""
    assert vmem_bytes(32, 128, 512) <= 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# sort_rows
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    r_=st.sampled_from([1, 3, 4, 16]),
    n=st.sampled_from([2, 7, 16, 33, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sort_matches_ref(r_, n, seed):
    x = rng(seed).standard_normal((r_, n), dtype=np.float32)
    got = sort_rows(jnp.asarray(x))
    want = ref.sort_rows_ref(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(n=st.sampled_from([8, 32, 128]), seed=st.integers(0, 2**31 - 1))
def test_sort_is_permutation(n, seed):
    """Output must be a permutation of the input (no value invented/lost)."""
    x = rng(seed).standard_normal((4, n), dtype=np.float32)
    got = np.asarray(sort_rows(jnp.asarray(x)))
    np.testing.assert_array_equal(np.sort(x, axis=-1), got)


def test_sort_with_duplicates_and_extremes():
    x = np.array(
        [[3.0, 3.0, -np.inf, np.inf, 0.0, -0.0, 1e30, -1e30]], dtype=np.float32
    )
    got = np.asarray(sort_rows(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x, axis=-1))


def test_sort_already_sorted_fixed_point():
    x = np.arange(64, dtype=np.float32)[None, :]
    got = np.asarray(sort_rows(jnp.asarray(x)))
    np.testing.assert_array_equal(got, x)


# ---------------------------------------------------------------------------
# throughput_batch (Eq. 28)
# ---------------------------------------------------------------------------


def _random_candidates(r, b, k, l):
    """Integer-valued candidate matrices incl. some all-zero columns."""
    n = r.integers(0, 6, size=(b, k, l)).astype(np.float32)
    n[:, :, -1] = 0.0  # force a zero column: exercises the 0/0 guard
    return n


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 32, 256]),
    k=st.sampled_from([2, 3, 8]),
    l=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_throughput_matches_ref(b, k, l, seed):
    r = rng(seed)
    mu = r.uniform(0.5, 30.0, size=(k, l)).astype(np.float32)
    n = _random_candidates(r, b, k, l)
    got = throughput_batch(jnp.asarray(mu), jnp.asarray(n))
    want = ref.throughput_ref(jnp.asarray(mu), jnp.asarray(n))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_throughput_hand_example():
    """Paper Eq. 4 sanity: mu=[[20,15],[3,8]], S=(1, N2) P1-biased case."""
    mu = np.array([[20.0, 15.0], [3.0, 8.0]], dtype=np.float32)
    # N1=10, N2=10, S_max=(1,10): N = [[1, 9], [0, 10]]
    n = np.array([[[1.0, 9.0], [0.0, 10.0]]], dtype=np.float32)
    x = float(throughput_batch(jnp.asarray(mu), jnp.asarray(n))[0])
    # Eq. 16: X = (N1-1)/(N-1)*mu12 + N2/(N-1)*mu22 + mu11
    want = 9.0 / 19.0 * 15.0 + 10.0 / 19.0 * 8.0 + 20.0
    assert abs(x - want) < 1e-4


def test_throughput_zero_batch_columns():
    mu = np.ones((4, 4), dtype=np.float32)
    n = np.zeros((8, 4, 4), dtype=np.float32)
    x = np.asarray(throughput_batch(jnp.asarray(mu), jnp.asarray(n)))
    np.testing.assert_array_equal(x, np.zeros(8, dtype=np.float32))
