"""L2 model shapes + AOT pipeline round-trip tests."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_nn_task_shapes_and_checksum():
    x = jnp.ones((8, 256), jnp.float32)
    w = jnp.full((256, 256), 0.01, jnp.float32)
    b = jnp.zeros(256, jnp.float32)
    y, cs = model.nn_task(x, w, b)
    assert y.shape == (8, 256)
    np.testing.assert_allclose(float(cs), float(jnp.sum(y)), rtol=1e-6)


def test_sort_task_checksum_is_sum():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((16, 256), dtype=np.float32))
    y, cs = model.sort_task(x)
    # sorting preserves the multiset => checksum equals input sum
    np.testing.assert_allclose(float(cs), float(jnp.sum(jnp.sort(x, -1))), rtol=1e-5)


def test_throughput_batch_argmax_consistent():
    r = np.random.default_rng(1)
    mu = jnp.asarray(r.uniform(1, 10, (16, 16)).astype(np.float32))
    n = jnp.asarray(r.integers(0, 5, (64, 16, 16)).astype(np.float32))
    x, best, bestx = model.throughput_batch(mu, n)
    assert int(best) == int(jnp.argmax(x))
    np.testing.assert_allclose(float(bestx), float(jnp.max(x)), rtol=1e-6)


@pytest.mark.parametrize("name", list(aot.ENTRIES))
def test_every_entry_lowers_to_hlo_text(name):
    text, specs, out_arity = aot.lower_entry(name)
    assert text.startswith("HloModule"), text[:64]
    assert out_arity >= 1
    # 64-bit-id regression guard: the text must parse back via xla_client.
    assert "ENTRY" in text


def test_build_writes_manifest(tmp_path):
    m = aot.build(str(tmp_path), only=["nn_small"])
    assert (tmp_path / "nn_small.hlo.txt").exists()
    with open(tmp_path / "manifest.json") as f:
        loaded = json.load(f)
    assert loaded["entries"]["nn_small"]["out_arity"] == 2
    assert loaded["entries"]["nn_small"]["args"][0]["shape"] == [8, 256]
    assert m["format"] == 1


def test_manifest_matches_shipped_artifacts():
    """If `make artifacts` has run, files and hashes must agree."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built yet")
    with open(mpath) as f:
        manifest = json.load(f)
    for name, e in manifest["entries"].items():
        assert os.path.exists(os.path.join(art, e["file"])), name
