"""Cross-check the paper's SLSQP comparator (§6) with scipy.

The Rust crate implements SLSQP in-repo (`rust/src/solver/slsqp.rs`).
This test runs the *reference* scipy implementation on the same relaxed
problem (maximize Eq. 28 over real N_ij ≥ 0 with fixed row sums) and
verifies the structural facts both implementations rely on:

  * SLSQP's continuous optimum is ≥ the best integer state it rounds to,
  * SLSQP can land below the integer optimum (it is a local method on a
    discontinuous objective) — the Fig. 13 effect,
  * convergence failures do occur near emptied-column boundaries — the
    paper's own observation.
"""

from __future__ import annotations

import itertools

import numpy as np
import pytest
from scipy.optimize import minimize

rng = np.random.default_rng(20170711)


def x_sys(n: np.ndarray, mu: np.ndarray) -> float:
    """Eq. 28 with the 0/0 -> 0 convention."""
    den = n.sum(axis=0)
    num = (mu * n).sum(axis=0)
    with np.errstate(invalid="ignore", divide="ignore"):
        per = np.where(den > 1e-12, num / np.where(den > 1e-12, den, 1.0), 0.0)
    return float(per.sum())


def solve_slsqp(mu: np.ndarray, pops: np.ndarray):
    k, l = mu.shape
    x0 = np.repeat(pops / l, l).astype(float)

    def neg(nflat):
        return -x_sys(nflat.reshape(k, l), mu)

    cons = [
        {"type": "eq", "fun": (lambda nf, i=i: nf.reshape(k, l)[i].sum() - pops[i])}
        for i in range(k)
    ]
    res = minimize(
        neg, x0, method="SLSQP", bounds=[(0, None)] * (k * l), constraints=cons,
        options={"maxiter": 200},
    )
    return res


def best_integer(mu: np.ndarray, pops) -> float:
    """Exhaustive integer optimum (small sizes only)."""
    k, l = mu.shape

    def comps(total, parts):
        if parts == 1:
            yield (total,)
            return
        for head in range(total + 1):
            for rest in comps(total - head, parts - 1):
                yield (head, *rest)

    best = 0.0
    for rows in itertools.product(*[list(comps(int(p), l)) for p in pops]):
        n = np.array(rows, dtype=float)
        best = max(best, x_sys(n, mu))
    return best


@pytest.mark.parametrize("seed", range(6))
def test_slsqp_relaxation_vs_integer_optimum(seed):
    r = np.random.default_rng(seed)
    k = l = 3
    mu = r.uniform(0.5, 30.0, (k, l))
    pops = r.integers(1, 6, k)
    res = solve_slsqp(mu, pops.astype(float))
    x_cont = -res.fun
    x_int = best_integer(mu, pops)
    # A *global* continuous optimum would dominate the integer one; a local
    # SLSQP answer may not.  Both must at least be positive and the gap
    # bounded — the Fig. 13 regime (GrIn within ~±10% of SLSQP).
    assert x_cont > 0
    assert x_cont > 0.6 * x_int, f"SLSQP collapsed: {x_cont} vs int {x_int}"


def test_slsqp_feasibility():
    mu = np.array([[20.0, 15.0], [3.0, 8.0]])
    pops = np.array([10.0, 10.0])
    res = solve_slsqp(mu, pops)
    n = res.x.reshape(2, 2)
    np.testing.assert_allclose(n.sum(axis=1), pops, atol=1e-6)
    assert (n >= -1e-8).all()


def test_paper_p1_biased_case_structure():
    """On μ=[[20,15],[3,8]] the relaxed optimum approaches the AF corner:
    nearly all type-2 mass on P2 and a lone type-1 unit on P1."""
    mu = np.array([[20.0, 15.0], [3.0, 8.0]])
    pops = np.array([10.0, 10.0])
    res = solve_slsqp(mu, pops)
    n = res.x.reshape(2, 2)
    x_cont = -res.fun
    # Compare against the Eq. 16 integer optimum.
    x_eq16 = 9 / 19 * 15 + 10 / 19 * 8 + 20
    assert x_cont >= 0.9 * x_eq16, (n, x_cont, x_eq16)
    # Type-2 tasks should avoid P1 (their μ there is tiny).
    assert n[1, 0] < 2.0, n
