"""L1 Pallas kernel: odd-even transposition sort network.

Stand-in for the paper's *quicksort* OpenCL benchmark (§7, quicksort-500 /
quicksort-1000): quicksort's data-dependent recursion cannot lower to HLO,
so the platform's CPU-type workload is a sort *network* with a fixed
compare-exchange schedule — the same memory-bound, low-arithmetic-intensity
behaviour, and (like the paper's quicksort) strongly CPU-affine relative to
the NN matmul task.  DESIGN.md §3 records the substitution.

The network sorts each row of a ``[R, N]`` batch with N rounds of
alternating even/odd compare-exchange phases.  One Pallas grid step owns a
block of rows in VMEM and runs the full ``fori_loop`` schedule there — the
HBM<->VMEM traffic is exactly one load + one store per row regardless of
N, which is the TPU analog of the paper's in-local-memory OpenCL sort.

Vectorised compare-exchange (no gathers): for phase parity p, element i is
a *left* partner if ``i % 2 == p`` (and has a right neighbour), else a
*right* partner.  Left partners take ``min(x[i], x[i+1])``, right partners
take ``max(x[i-1], x[i])``; boundary elements keep their value.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _phase(x: jax.Array, parity: jax.Array) -> jax.Array:
    """One compare-exchange phase over the last axis."""
    n = x.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, len(x.shape) - 1)
    nxt = jnp.roll(x, -1, axis=-1)  # x[i+1] (wraps; masked below)
    prv = jnp.roll(x, 1, axis=-1)  # x[i-1]
    is_left = (idx % 2) == parity
    has_right = idx < (n - 1)
    has_left = idx > 0
    lo = jnp.minimum(x, nxt)
    hi = jnp.maximum(x, prv)
    out = jnp.where(
        is_left & has_right,
        lo,
        jnp.where(~is_left & has_left, hi, x),
    )
    return out


def _sort_kernel(x_ref, o_ref):
    x = x_ref[...]
    n = x.shape[-1]

    def body(i, acc):
        acc = _phase(acc, jnp.int32(0))
        acc = _phase(acc, jnp.int32(1))
        return acc

    # n/2 (even, odd) super-rounds sort any input of length n.
    o_ref[...] = jax.lax.fori_loop(0, (n + 1) // 2, body, x)


def sort_rows(
    x: jax.Array, *, block_r: int = 16, interpret: bool = True
) -> jax.Array:
    """Sort each row of ``f32[R, N]`` ascending via odd-even transposition.

    Args:
      x: batch of rows to sort.
      block_r: rows per VMEM block / grid step.
      interpret: must stay True for CPU PJRT execution.
    """
    r, n = x.shape
    br = min(block_r, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    return pl.pallas_call(
        _sort_kernel,
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(x)
