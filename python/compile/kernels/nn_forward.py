"""L1 Pallas kernel: single-layer neural-network forward pass.

This is the paper's GPU-type benchmark (§7, "single layer Neural Network
(NN)", e.g. NN-2000 with input size 2000).  The OpenCL NDRange kernel of the
paper maps to a Pallas kernel tiled for the TPU memory hierarchy:

  * work-group tiling          ->  ``BlockSpec`` grid over (M, N, K) tiles
  * per-thread MACs            ->  MXU-shaped ``jnp.dot`` on (bm, bk)x(bk, bn)
  * __local staging            ->  VMEM blocks sized by the BlockSpec
  * global memory walk         ->  HBM->VMEM schedule implied by index_map

The kernel computes ``relu(x @ w + b)`` with f32 accumulation.  K is walked
by the innermost grid dimension and partial products are accumulated into
the output block; bias + ReLU are applied on the last K step only, so the
epilogue is fused and the output block is written exactly once per (i, j).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret mode lowers to plain HLO that both the
pytest oracle check and the Rust runtime execute bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nn_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: accumulate x_blk @ w_blk into o_blk."""
    k = pl.program_id(2)

    # Zero the accumulator on the first K step.  The output block lives in
    # VMEM across the K walk (same (i, j) index_map for every k), so this is
    # the canonical Pallas accumulation idiom.
    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )
    o_ref[...] += acc

    # Fused epilogue: bias + ReLU on the last K step.
    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = jnp.maximum(o_ref[...] + b_ref[...], 0.0)


def nn_forward(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 32,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """``relu(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: ``f32[M, K]`` activations (one batch of NN tasks).
      w: ``f32[K, N]`` layer weights.
      b: ``f32[N]`` bias.
      block_m/n/k: VMEM tile sizes.  Defaults target MXU-friendly 128-wide
        N tiles; M may be small (task batches are small in the closed
        system, N programs ~ 20).
      interpret: must stay True for CPU PJRT execution (see module doc).

    Returns:
      ``f32[M, N]`` activations.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"dims must divide blocks: ({m},{n},{k}) vs ({bm},{bn},{bk})"
        )
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    kernel = functools.partial(_nn_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)


def vmem_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM working set of one grid step (f32).

    x block + w block + bias block + output accumulator.  Used by the
    DESIGN.md / EXPERIMENTS.md §Perf roofline estimate; interpret-mode
    wallclock is *not* a TPU proxy, so we optimise this footprint and the
    MXU tile alignment instead.
    """
    return 4 * (
        block_m * block_k + block_k * block_n + block_n + block_m * block_n
    )
