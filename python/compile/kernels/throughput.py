"""L1 Pallas kernel: batched closed-network throughput objective (Eq. 28).

The paper's optimisation problem is

    maximise  X_sys(N) = sum_j ( sum_i mu_ij * N_ij ) / ( sum_i N_ij )

over integer task-distribution matrices ``N`` (k task types x l processor
types) with fixed row sums.  The exhaustive oracle (paper §6, "Opt") has to
evaluate X_sys for *every* composition; this kernel evaluates a whole batch
of candidate matrices in one PJRT call so the Rust solver can offload the
objective sweep to XLA.

Layout: candidates are padded to a fixed (K_PAD, L_PAD) tile so that one
artifact serves every problem size up to the pad.  Padding columns are all
zero, which would make the per-column denominator zero; the kernel guards
with ``where(den > 0, num / den, 0)`` — a zero column contributes zero
throughput, exactly matching the convention of the Rust implementation
(`model::throughput`).

The batch dimension is tiled by the Pallas grid; each grid step reduces a
``(BB, K_PAD, L_PAD)`` block to ``(BB,)`` throughput values in VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Pad sizes baked into the shipped artifact (see aot.py).  The paper sweeps
# processor-type counts 3..10 (Fig. 13/14), so 16 covers everything with
# lane-aligned headroom.
K_PAD = 16
L_PAD = 16


def _throughput_kernel(mu_ref, n_ref, o_ref):
    """One grid step: X_sys for a block of candidate matrices.

    mu_ref: f32[K, L]      — affinity matrix (same block every step).
    n_ref:  f32[BB, K, L]  — candidate task-distribution matrices.
    o_ref:  f32[BB]        — throughput per candidate.
    """
    mu = mu_ref[...]
    n = n_ref[...]
    num = jnp.sum(mu[None, :, :] * n, axis=1)  # [BB, L]
    den = jnp.sum(n, axis=1)  # [BB, L]
    per_col = jnp.where(den > 0.0, num / jnp.where(den > 0.0, den, 1.0), 0.0)
    o_ref[...] = jnp.sum(per_col, axis=1)


def throughput_batch(
    mu: jax.Array,
    n: jax.Array,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """X_sys (Eq. 28) for a batch of candidate state matrices.

    Args:
      mu: ``f32[k, l]`` affinity matrix (zero-padded columns/rows allowed).
      n:  ``f32[B, k, l]`` batch of candidate matrices.
      block_b: batch tile per grid step.
      interpret: must stay True for CPU PJRT execution.

    Returns:
      ``f32[B]`` throughput of each candidate.
    """
    b, k, l = n.shape
    if mu.shape != (k, l):
        raise ValueError(f"mu {mu.shape} incompatible with n {n.shape}")
    bb = min(block_b, b)
    if b % bb:
        raise ValueError(f"batch {b} must divide block {bb}")
    return pl.pallas_call(
        _throughput_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((k, l), lambda i: (0, 0)),
            pl.BlockSpec((bb, k, l), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(mu, n)
