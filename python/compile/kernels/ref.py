"""Pure-jnp oracles for every L1 Pallas kernel.

These are the CORE correctness signal: pytest (python/tests) asserts
``assert_allclose(kernel(...), ref(...))`` across hypothesis-swept shapes,
and the Rust integration tests re-check the shipped artifacts against
values precomputed from these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def nn_forward_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """relu(x @ w + b) with f32 accumulation."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return jnp.maximum(y, 0.0)


def throughput_ref(mu: jax.Array, n: jax.Array) -> jax.Array:
    """Eq. 28: X_sys per candidate; zero columns contribute 0.

    mu: f32[k, l]; n: f32[B, k, l] -> f32[B].
    """
    num = jnp.sum(mu[None, :, :] * n, axis=1)
    den = jnp.sum(n, axis=1)
    safe = jnp.where(den > 0.0, den, 1.0)
    return jnp.sum(jnp.where(den > 0.0, num / safe, 0.0), axis=1)


def sort_rows_ref(x: jax.Array) -> jax.Array:
    """Ascending sort along the last axis."""
    return jnp.sort(x, axis=-1)
