"""AOT bridge: lower every L2 entry point to HLO *text* + a manifest.

Run once at build time (``make artifacts``); the Rust runtime
(``rust/src/runtime``) loads the text with ``HloModuleProto::from_text_file``,
compiles on the PJRT CPU client, and executes.  Python is never on the
request path.

Interchange format is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Every entry is lowered with ``return_tuple=True`` so the Rust side unwraps a
tuple of a known arity.  ``artifacts/manifest.json`` records, per entry, the
artifact file, the argument shapes/dtypes and the output arity; the Rust
config substrate parses it with the in-repo JSON parser.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.throughput import K_PAD, L_PAD

# ---------------------------------------------------------------------------
# Entry-point registry.
#
# Shapes mirror the paper's benchmarks:
#   nn2000     — the §7 "NN-2000" single-layer NN (input width 2000, padded
#                to 2048 for MXU/lane alignment; DESIGN.md §4).
#   nn_small   — serving-batch variant used by the coordinator's dynamic
#                batcher (8-task batches).
#   sort_large — quicksort-1000 stand-in (rows of 1024 keys).
#   sort_small — quicksort-500 stand-in (rows of 256 keys).
#   throughput_eval — Eq. 28 objective over a 4096-candidate batch, padded
#                to (K_PAD, L_PAD); offload target of the exhaustive oracle.
# ---------------------------------------------------------------------------

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


ENTRIES = {
    "nn2000": (model.nn_task, [_spec(32, 2048), _spec(2048, 256), _spec(256)]),
    "nn_small": (model.nn_task, [_spec(8, 256), _spec(256, 256), _spec(256)]),
    "sort_large": (model.sort_task, [_spec(16, 1024)]),
    "sort_small": (model.sort_task, [_spec(16, 256)]),
    "throughput_eval": (
        model.throughput_batch,
        [_spec(K_PAD, L_PAD), _spec(4096, K_PAD, L_PAD)],
    ),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str):
    fn, specs = ENTRIES[name]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    out_arity = len(jax.tree_util.tree_leaves(jax.eval_shape(fn, *specs)))
    return text, specs, out_arity


def build(out_dir: str, only: list[str] | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "entries": {}}
    names = only or list(ENTRIES)
    for name in names:
        text, specs, out_arity = lower_entry(name)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["entries"][name] = {
            "file": fname,
            "sha256_16": digest,
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "out_arity": out_arity,
        }
        print(f"  {name}: {len(text)} chars -> {fname} (outputs={out_arity})")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", nargs="*", help="subset of entries to build")
    args = ap.parse_args()
    print(f"AOT-lowering {len(args.only or ENTRIES)} entries -> {args.out}")
    build(args.out, args.only)
    print("done")


if __name__ == "__main__":
    sys.exit(main())
