"""L2: JAX compute graphs for the workloads and the solver offload.

Each public function here is an AOT entry point: ``aot.py`` lowers it once
to HLO text in ``artifacts/`` and the Rust runtime executes it on the PJRT
CPU client.  Python never runs on the request path.

Entry points
------------
``nn_task``          — the paper's GPU-type benchmark (single-layer NN,
                       §7 "NN-2000"): fused Pallas matmul+bias+ReLU.
``sort_task``        — the paper's CPU-type benchmark (quicksort stand-in):
                       odd-even transposition sort network.
``throughput_batch`` — Eq. 28 objective for a batch of candidate state
                       matrices, plus the argmax, for the batched
                       exhaustive search (paper §6 "Opt").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import nn_forward as _nn
from compile.kernels import sort_net as _sort
from compile.kernels import throughput as _tp


def nn_task(x: jax.Array, w: jax.Array, b: jax.Array):
    """Single-layer NN forward (paper benchmark NN-2000).

    Returns the activations and their checksum; the checksum gives the Rust
    side a cheap end-to-end numeric probe per executed task.
    """
    y = _nn.nn_forward(x, w, b)
    return y, jnp.sum(y, dtype=jnp.float32)


def sort_task(x: jax.Array):
    """Row-sort workload (quicksort stand-in). Returns rows + checksum."""
    y = _sort.sort_rows(x)
    return y, jnp.sum(y, dtype=jnp.float32)


def throughput_batch(mu: jax.Array, n: jax.Array):
    """X_sys per candidate (Eq. 28), best index and best value.

    mu: f32[K_PAD, L_PAD]; n: f32[B, K_PAD, L_PAD].
    Returns (x: f32[B], best_idx: i32[], best_x: f32[]).
    """
    x = _tp.throughput_batch(mu, n)
    best = jnp.argmax(x)
    return x, best.astype(jnp.int32), x[best]
