//! Negative tests for the three detlint analyses: each seeds a small
//! in-memory crate with one defect and asserts the exact rule name and
//! span of the resulting finding — plus the integration gate that runs
//! the real analyses over this repo's `src/` and requires a clean pass.

use std::path::Path;

use hetsched::analysis::{analyze_sources, checks};

fn src(files: &[(&str, &str)]) -> Vec<(String, String)> {
    files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect()
}

#[test]
fn reachable_panic_is_found_with_rule_and_span() {
    let files = src(&[(
        "sim/engine.rs",
        "pub fn run() {\n    step();\n}\nfn step(q: &[u64]) {\n    q.first().unwrap();\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_PANIC)
        .unwrap_or_else(|| panic!("no panic-reachable finding: {findings:?}"));
    assert_eq!(f.file, "sim/engine.rs");
    assert_eq!(f.line, 5, "anchored at the unwrap seed: {f:?}");
    assert!(f.msg.contains("via"), "sample call path in message: {}", f.msg);
}

#[test]
fn unreached_panic_is_not_reported() {
    // Same seed, but nothing on a hot path calls it.
    let files = src(&[(
        "sim/engine.rs",
        "pub fn run() {}\nfn orphan(q: &[u64]) {\n    q.first().unwrap();\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    assert!(
        findings.iter().all(|f| f.rule != checks::RULE_PANIC),
        "orphan fn must not fire: {findings:?}"
    );
}

#[test]
fn reachable_indexing_is_found_with_rule_and_span() {
    let files = src(&[(
        "policy/grin.rs",
        "pub fn solve(v: &[f64]) -> f64 {\n    inner(v)\n}\nfn inner(v: &[f64]) -> f64 {\n    v[0]\n        + v[1]\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_INDEX)
        .unwrap_or_else(|| panic!("no index-reachable finding: {findings:?}"));
    assert_eq!((f.file.as_str(), f.line), ("policy/grin.rs", 5));
    assert!(f.msg.contains("2 slice/array indexing site(s)"), "{}", f.msg);
}

#[test]
fn hash_iteration_in_result_path_is_found() {
    let files = src(&[(
        "sim/dynamic.rs",
        "pub fn run_dynamic() -> u64 {\n    let m: std::collections::HashMap<u64, f64> = make();\n    let mut acc = 0;\n    for (k, _v) in m.iter() {\n        acc += k;\n    }\n    acc\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_HASH_ITER)
        .unwrap_or_else(|| panic!("no hash-iteration finding: {findings:?}"));
    assert_eq!((f.file.as_str(), f.line), ("sim/dynamic.rs", 4));
    // One finding, not two: the `for` loop and the `.iter()` call are
    // the same defect at the same span.
    assert_eq!(
        findings.iter().filter(|f| f.rule == checks::RULE_HASH_ITER).count(),
        1,
        "{findings:?}"
    );
}

#[test]
fn clock_flowing_into_results_is_found() {
    let files = src(&[(
        "sim/metrics.rs",
        "pub fn snapshot() -> SimResult {\n    let t = std::time::Instant::now();\n    SimResult { stamp: t }\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_CLOCK)
        .unwrap_or_else(|| panic!("no clock-in-results finding: {findings:?}"));
    assert_eq!((f.file.as_str(), f.line), ("sim/metrics.rs", 2));
    // A fn that cannot reach a result construction may read the clock.
    let files = src(&[(
        "platform/measure.rs",
        "pub fn bench() -> f64 {\n    let t = std::time::Instant::now();\n    t.elapsed().as_secs_f64()\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    assert!(findings.iter().all(|f| f.rule != checks::RULE_CLOCK), "{findings:?}");
}

#[test]
fn unplumbed_sim_result_field_is_found() {
    let files = src(&[(
        "sim/metrics.rs",
        "pub struct SimResult {\n    pub mystery_metric: f64,\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_PLUMBING && f.msg.contains("mystery_metric"))
        .unwrap_or_else(|| panic!("no metric-plumbing finding: {findings:?}"));
    assert_eq!((f.file.as_str(), f.line), ("sim/metrics.rs", 2));
    assert!(f.msg.contains("not registered"), "{}", f.msg);
}

#[test]
fn truncating_cast_is_found_crate_wide() {
    let files = src(&[(
        "report/table.rs",
        "pub fn width(s: &str) -> u16 {\n    s.len() as u16\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_TRUNCATION)
        .unwrap_or_else(|| panic!("no as-truncation finding: {findings:?}"));
    assert_eq!((f.file.as_str(), f.line), ("report/table.rs", 2));
}

#[test]
fn raw_spawn_outside_sanctioned_modules_is_found() {
    let files = src(&[(
        "policy/grin.rs",
        "pub fn solve() {\n    std::thread::spawn(|| {});\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    let f = findings
        .iter()
        .find(|f| f.rule == checks::RULE_SPAWN)
        .unwrap_or_else(|| panic!("no raw-spawn finding: {findings:?}"));
    assert_eq!((f.file.as_str(), f.line), ("policy/grin.rs", 2));
    // The same spawn inside a sanctioned module is fine.
    let files = src(&[(
        "sim/replicate.rs",
        "pub fn fan_out() {\n    std::thread::spawn(|| {});\n}\n",
    )]);
    let findings = analyze_sources(&files, &[]);
    assert!(findings.iter().all(|f| f.rule != checks::RULE_SPAWN), "{findings:?}");
}

/// The gate the CI job enforces: this repository's own `src/` analyzes
/// clean, under both the default cfg and `--features model`, with every
/// surviving suppression carrying a real justification.
#[test]
fn repo_sources_analyze_clean() {
    let root = if Path::new("src/lib.rs").is_file() {
        Path::new("src")
    } else {
        Path::new("rust/src")
    };
    for features in [vec![], vec!["model".to_string()]] {
        let findings = hetsched::analysis::run(root, &features)
            .expect("walk src tree");
        assert!(
            findings.is_empty(),
            "detlint findings under features {:?}:\n{}",
            features,
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
