//! Launcher-level integration: the shipped example configs parse,
//! validate, and drive full simulations through the same path as
//! `hetsched simulate --config <file>`.

use hetsched::config::schema::{ExperimentSpec, ScenarioSpec};
use hetsched::sim::engine::ClosedNetwork;

fn repo_path(rel: &str) -> String {
    // The package lives in rust/; the shipped configs sit beside the
    // examples at the repository root.
    format!("{}/../{rel}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn shipped_configs_parse_and_run() {
    for cfg in [
        "examples/configs/p1_biased_cab.json",
        "examples/configs/table3_p2_biased_grin.json",
        "examples/configs/multitype_jsq.json",
    ] {
        let mut spec = ExperimentSpec::from_file(&repo_path(cfg))
            .unwrap_or_else(|e| panic!("{cfg}: {e}"));
        // Keep the integration run quick.
        spec.sim.warmup = 200;
        spec.sim.measure = 2_000;
        let net = ClosedNetwork::new(&spec.mu, spec.sim.clone()).unwrap();
        let r = net.run(spec.policy.build().as_mut()).unwrap();
        assert!(r.throughput > 0.0, "{cfg}");
        assert!(r.little_residual() < 0.15, "{cfg}: Little's law violated");
    }
}

#[test]
fn shipped_scenario_config_parses_and_runs() {
    use hetsched::sim::dynamic::{run_dynamic_report, ResolveMode};
    let mut spec =
        ScenarioSpec::from_file(&repo_path("examples/configs/slow_drift_adaptive.json"))
            .unwrap();
    assert_eq!(spec.dynamic.resolve, ResolveMode::Adaptive);
    assert_eq!(spec.dynamic.phases.len(), 6);
    // Shrink for test runtime, then drive the full adaptive path.
    for ph in &mut spec.dynamic.phases {
        ph.warmup = 50;
        ph.completions = 400;
    }
    let mut p = spec.policy.build();
    let report = run_dynamic_report(&spec.mu, &spec.dynamic, p.as_mut()).unwrap();
    assert_eq!(report.phases.len(), 6);
    assert!(report.mean_throughput() > 0.0);
}

#[test]
fn config_spec_round_trips_through_launcher_flags() {
    // The same experiment expressed via CLI flags must behave like the
    // JSON spec (same seed ⇒ same throughput).
    use hetsched::policy::PolicyKind;
    let spec =
        ExperimentSpec::from_file(&repo_path("examples/configs/p1_biased_cab.json")).unwrap();
    assert_eq!(spec.policy, PolicyKind::Cab);
    let mut a = spec.sim.clone();
    a.measure = 3_000;
    a.warmup = 300;
    let net = ClosedNetwork::new(&spec.mu, a.clone()).unwrap();
    let r1 = net.run(spec.policy.build().as_mut()).unwrap();
    let net = ClosedNetwork::new(&spec.mu, a).unwrap();
    let r2 = net.run(spec.policy.build().as_mut()).unwrap();
    assert_eq!(r1.throughput, r2.throughput, "determinism per seed");
}
