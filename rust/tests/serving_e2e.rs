//! Serving coordinator end-to-end: closed-loop clients through router +
//! batcher + PJRT workers.  Self-skips without built artifacts.

use hetsched::coordinator::{Coordinator, ServeConfig};
use hetsched::policy::PolicyKind;
use hetsched::runtime::ArtifactDir;

fn have_artifacts() -> bool {
    match ArtifactDir::open_default() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping serving e2e: {e}");
            false
        }
    }
}

#[test]
fn serves_all_requests_and_reports_sane_stats() {
    if !have_artifacts() {
        return;
    }
    let cfg = ServeConfig {
        policy: PolicyKind::Cab,
        total: 200,
        inflight: 16,
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 200);
    assert!(r.rps > 0.0);
    assert!(r.elapsed_s > 0.0);
    // Both classes saw traffic at sort_fraction = 0.5.
    assert!(r.sort_latency.count() > 20);
    assert!(r.nn_latency.count() > 20);
    assert_eq!(r.sort_latency.count() + r.nn_latency.count(), 200);
    // Latency percentiles are ordered.
    assert!(r.nn_latency.quantile_s(0.99) >= r.nn_latency.quantile_s(0.5));
    // Batching actually batched.
    assert!(r.batches >= 1);
    assert!(r.batch_fill > 0.0 && r.batch_fill <= 1.0);
    let flush_total: u64 = r.flushes.iter().sum();
    assert_eq!(flush_total, r.batches);
}

#[test]
fn batching_deadline_bounds_nn_latency() {
    if !have_artifacts() {
        return;
    }
    // With a tiny deadline the batcher must flush partial batches rather
    // than starve: all requests still complete.
    let cfg = ServeConfig {
        policy: PolicyKind::BestFit,
        total: 100,
        inflight: 4, // rarely fills an 8-slot batch
        batch_deadline: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 100);
    // Deadline (or drain) flushes must dominate at this concurrency.
    assert!(
        r.flushes[1] + r.flushes[2] > 0,
        "expected deadline flushes, got {:?}",
        r.flushes
    );
}

#[test]
fn all_policies_drive_the_server() {
    if !have_artifacts() {
        return;
    }
    for kind in [PolicyKind::Cab, PolicyKind::GrIn, PolicyKind::Jsq, PolicyKind::LoadBalance] {
        let cfg = ServeConfig { policy: kind, total: 60, inflight: 8, ..Default::default() };
        let r = Coordinator::run(&cfg).unwrap();
        assert_eq!(r.served, 60, "{}", kind.name());
    }
}
