//! Serving coordinator end-to-end: closed-loop clients through router +
//! batcher + engine workers.
//!
//! The artifact-shape tests self-skip without built artifacts; the
//! native-backend tests always run (the default engine executes the
//! kernels in-process).

use hetsched::coordinator::{Coordinator, ServeConfig};
use hetsched::policy::PolicyKind;
use hetsched::runtime::ArtifactDir;

fn have_artifacts() -> bool {
    match ArtifactDir::open_default() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping serving e2e: {e}");
            false
        }
    }
}

#[test]
fn serves_all_requests_and_reports_sane_stats() {
    if !have_artifacts() {
        return;
    }
    let cfg = ServeConfig {
        policy: PolicyKind::Cab,
        total: 200,
        inflight: 16,
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 200);
    assert!(r.rps > 0.0);
    assert!(r.elapsed_s > 0.0);
    // Both classes saw traffic at sort_fraction = 0.5.
    assert!(r.sort_latency.count() > 20);
    assert!(r.nn_latency.count() > 20);
    assert_eq!(r.sort_latency.count() + r.nn_latency.count(), 200);
    // Latency percentiles are ordered.
    assert!(r.nn_latency.quantile_s(0.99) >= r.nn_latency.quantile_s(0.5));
    // Batching actually batched.
    assert!(r.batches >= 1);
    assert!(r.batch_fill > 0.0 && r.batch_fill <= 1.0);
    let flush_total: u64 = r.flushes.iter().sum();
    assert_eq!(flush_total, r.batches);
}

#[test]
fn batching_deadline_bounds_nn_latency() {
    if !have_artifacts() {
        return;
    }
    // With a tiny deadline the batcher must flush partial batches rather
    // than starve: all requests still complete.
    let cfg = ServeConfig {
        policy: PolicyKind::BestFit,
        total: 100,
        inflight: 4, // rarely fills an 8-slot batch
        batch_deadline: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 100);
    // Deadline (or drain) flushes must dominate at this concurrency.
    assert!(
        r.flushes[1] + r.flushes[2] > 0,
        "expected deadline flushes, got {:?}",
        r.flushes
    );
}

#[test]
fn native_engine_serves_without_artifacts() {
    // The native kernel backend needs no manifest: the full coordinator
    // path (router → batcher → workers → stats) must run anywhere.
    let cfg = ServeConfig {
        policy: PolicyKind::Jsq,
        total: 80,
        inflight: 8,
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 80);
    assert!(r.rps > 0.0);
    assert_eq!(r.sort_latency.count() + r.nn_latency.count(), 80);
    assert_eq!(r.resolves, 0);
    assert!(r.mu_hat.is_none());
}

#[test]
fn adaptive_serving_estimates_and_reports_mu_hat() {
    // Adaptive mode on the live coordinator: the Table-3 prior is wildly
    // wrong for the native in-process kernels, so the estimator must
    // drift-detect, re-solve at least once, and report a finite μ̂.
    let cfg = ServeConfig {
        policy: PolicyKind::GrIn,
        total: 200,
        inflight: 12,
        adaptive: true,
        resolve_check: 32,
        drift_threshold: 0.25,
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 200);
    assert!(
        r.resolves >= 1,
        "prior μ is orders of magnitude off the native kernel rates; \
         the adaptive loop should have re-solved"
    );
    let mu_hat = r.mu_hat.expect("adaptive run reports μ̂");
    for i in 0..2 {
        for j in 0..2 {
            assert!(mu_hat.rate(i, j).is_finite() && mu_hat.rate(i, j) > 0.0);
        }
    }
}

#[test]
fn cusum_triggered_serving_resolves_on_the_live_change_point() {
    // Same setup as the threshold test above, but the re-solve fires
    // from the per-cell CUSUM detector: the native kernels' service
    // times sit far from the Table-3 prior, so every exercised cell
    // accumulates residual fast and the alarm-triggered re-solve lands
    // without waiting for a polled drift check.
    use hetsched::sim::dynamic::Trigger;
    let cfg = ServeConfig {
        policy: PolicyKind::GrIn,
        total: 200,
        inflight: 12,
        adaptive: true,
        trigger: Trigger::Cusum,
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 200);
    assert!(
        r.resolves >= 1,
        "the CUSUM detector should alarm on the prior-vs-native gap"
    );
    let mu_hat = r.mu_hat.expect("adaptive run reports μ̂");
    for i in 0..2 {
        for j in 0..2 {
            assert!(mu_hat.rate(i, j).is_finite() && mu_hat.rate(i, j) > 0.0);
        }
    }
}

#[test]
fn sharded_serving_covers_the_fleet_and_reports_mu_hat() {
    // Four devices in two shards under the sharded multi-leader plane
    // (native kernels, no artifacts needed): every request completes,
    // the per-shard estimators assemble a finite global μ̂, and the
    // batched re-solve loop engages — the Table-3 prior is wildly wrong
    // for the in-process kernels, so once the cold-start windows warm
    // the shards must report drift.
    let cfg = ServeConfig {
        policy: PolicyKind::GrIn,
        devices: 4,
        shards: 2,
        total: 240,
        inflight: 12,
        sync_every: 48,
        drift_threshold: 0.25,
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 240);
    assert!(r.rps > 0.0);
    assert_eq!(r.sort_latency.count() + r.nn_latency.count(), 240);
    let mu_hat = r.mu_hat.expect("sharded run reports the assembled μ̂");
    assert_eq!(mu_hat.procs(), 4);
    for i in 0..2 {
        for j in 0..4 {
            assert!(mu_hat.rate(i, j).is_finite() && mu_hat.rate(i, j) > 0.0);
        }
    }
    assert!(r.resolves >= 1, "batched re-solve never engaged");
}

#[test]
fn priority_weighted_serving_reports_class_accounting() {
    // Priority-weighted GrIn serving on the native backend: every
    // request completes, both classes are accounted, and the deadline
    // counters obey their definitions (a 0 deadline never misses; an
    // absurdly generous one never misses either).
    let cfg = ServeConfig {
        policy: PolicyKind::GrIn,
        total: 120,
        inflight: 8,
        adaptive: true,
        resolve_check: 32,
        priorities: vec![4, 1],
        deadlines: vec![3600.0, 0.0],
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 120);
    assert_eq!(r.class_served[0] + r.class_served[1], 120);
    assert!(r.class_served[0] > 0 && r.class_served[1] > 0);
    // nn has no deadline (0) and sort's is an hour: zero misses.
    assert_eq!(r.deadline_misses, [0, 0]);
    assert_eq!(r.deadline_miss_rate(0), 0.0);
    // A microscopic (but non-zero) deadline flags everything for the
    // class that carries it.
    let cfg = ServeConfig {
        policy: PolicyKind::GrIn,
        total: 120,
        inflight: 8,
        priorities: vec![4, 1],
        deadlines: vec![1e-9, 0.0],
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.deadline_misses[0], r.class_served[0]);
    assert_eq!(r.deadline_misses[1], 0);
    assert!(r.deadline_miss_rate(0) > 0.99);
}

#[test]
fn sharded_priority_serving_runs_end_to_end() {
    // Priorities through the sharded plane: set_priorities installs the
    // weighted targets at boot and every request still completes.
    let cfg = ServeConfig {
        policy: PolicyKind::GrIn,
        devices: 4,
        shards: 2,
        total: 160,
        inflight: 12,
        sync_every: 48,
        priorities: vec![4, 1],
        deadlines: vec![0.25, 0.5],
        ..Default::default()
    };
    let r = Coordinator::run(&cfg).unwrap();
    assert_eq!(r.served, 160);
    assert_eq!(r.class_served[0] + r.class_served[1], 160);
    // Misses are bounded by what each class served.
    assert!(r.deadline_misses[0] <= r.class_served[0]);
    assert!(r.deadline_misses[1] <= r.class_served[1]);
}

#[test]
fn all_policies_drive_the_server() {
    if !have_artifacts() {
        return;
    }
    for kind in [PolicyKind::Cab, PolicyKind::GrIn, PolicyKind::Jsq, PolicyKind::LoadBalance] {
        let cfg = ServeConfig { policy: kind, total: 60, inflight: 8, ..Default::default() };
        let r = Coordinator::run(&cfg).unwrap();
        assert_eq!(r.served, 60, "{}", kind.name());
    }
}
