//! Closed-network simulation integration tests: the §5 figure claims at
//! reduced scale (full scale lives in the benches).

use hetsched::model::affinity::Regime;
use hetsched::model::energy::PowerScenario;
use hetsched::model::throughput::x_max_theoretical;
use hetsched::policy::PolicyKind;
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, SimConfig};
use hetsched::sim::processor::Discipline;
use hetsched::sim::workload;

fn cfg(populations: Vec<u32>, dist: Distribution, seed: u64) -> SimConfig {
    let mut c = SimConfig::paper_default(populations);
    c.dist = dist;
    c.warmup = 400;
    c.measure = 4_000;
    c.seed = seed;
    c
}

#[test]
fn cab_wins_on_every_distribution_and_eta() {
    // Figs. 4–7, coarse grid: CAB ≥ BF/RD/JSQ/LB in throughput; per
    // Little's law the response-time ordering follows automatically.
    let mu = workload::paper_two_type_mu();
    for dist in Distribution::all() {
        for eta in [0.2, 0.5, 0.8] {
            let (n1, n2) = workload::split_populations(20, eta);
            let mut x_cab = 0.0;
            for kind in PolicyKind::five_two_type() {
                let net = ClosedNetwork::new(&mu, cfg(vec![n1, n2], dist, 99)).unwrap();
                let r = net.run(kind.build().as_mut()).unwrap();
                // Little's law self-check on every run (Fig 4–7 bottom-right).
                assert!(
                    r.little_residual() < 0.06,
                    "{} {} η={eta}: X·E[T]={}",
                    kind.name(),
                    dist.name(),
                    r.little_product
                );
                if kind == PolicyKind::Cab {
                    x_cab = r.throughput;
                } else {
                    assert!(
                        x_cab >= r.throughput * 0.98,
                        "{} beat CAB under {} at η={eta}: {} vs {x_cab}",
                        kind.name(),
                        dist.name(),
                        r.throughput
                    );
                }
            }
        }
    }
}

#[test]
fn theory_matches_simulation_fig8() {
    // Fig. 8: theoretical CAB throughput vs simulated, all distributions.
    let mu = workload::paper_two_type_mu();
    for dist in Distribution::all() {
        for eta in [0.3, 0.6] {
            let (n1, n2) = workload::split_populations(20, eta);
            let theory = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
            let net = ClosedNetwork::new(&mu, cfg(vec![n1, n2], dist, 1234)).unwrap();
            let r = net.run(PolicyKind::Cab.build().as_mut()).unwrap();
            let tol = if matches!(dist, Distribution::BoundedPareto { .. }) {
                0.15 // heavy tail: larger variance (paper observes this too)
            } else {
                0.05
            };
            let err = (r.throughput - theory).abs() / theory;
            assert!(
                err < tol,
                "{} η={eta}: sim {} vs theory {theory} (err {err:.3})",
                dist.name(),
                r.throughput
            );
        }
    }
}

#[test]
fn cab_improvement_over_lb_in_paper_range() {
    // §5: "1.08x to 2.24x better performance" vs load balancing.  Exact
    // factors depend on η; verify the factor stays in a sane band and
    // peaks well above 1.3× somewhere.
    let mu = workload::paper_two_type_mu();
    let mut best = 0.0f64;
    for eta in workload::eta_grid() {
        let (n1, n2) = workload::split_populations(20, eta);
        let x = |kind: PolicyKind| {
            let net = ClosedNetwork::new(
                &mu,
                cfg(vec![n1, n2], Distribution::Exponential, 5),
            )
            .unwrap();
            net.run(kind.build().as_mut()).unwrap().throughput
        };
        let ratio = x(PolicyKind::Cab) / x(PolicyKind::LoadBalance);
        assert!(ratio > 0.98, "CAB lost to LB at η={eta}: {ratio}");
        best = best.max(ratio);
    }
    assert!(best > 1.3, "peak CAB/LB improvement only {best}");
}

#[test]
fn af_beats_bf_in_biased_regime_counterintuitive_case() {
    // The paper's headline counter-intuitive result: in the P1-biased
    // case, running a single program on the fast processor (AF) beats
    // sending every task to its favorite processor (BF).
    let mu = workload::paper_two_type_mu();
    let (n1, n2) = (10, 10);
    let run = |kind: PolicyKind| {
        let net = ClosedNetwork::new(
            &mu,
            cfg(vec![n1, n2], Distribution::Exponential, 42),
        )
        .unwrap();
        net.run(kind.build().as_mut()).unwrap().throughput
    };
    let x_cab = run(PolicyKind::Cab);
    let x_bf = run(PolicyKind::BestFit);
    assert!(
        x_cab > x_bf * 1.05,
        "AF did not beat BF in the biased case: {x_cab} vs {x_bf}"
    );
}

#[test]
fn cab_and_bf_converge_at_low_eta() {
    // §5 observation: at η = 0.1, S_CAB = (1, 18) vs S_BF = (2, 18) —
    // X difference is (ηN−1)/(N−1)·(μ12−μ22) = 0.37, relatively tiny.
    let mu = workload::paper_two_type_mu();
    let (n1, n2) = workload::split_populations(20, 0.1);
    let run = |kind: PolicyKind| {
        let net = ClosedNetwork::new(
            &mu,
            cfg(vec![n1, n2], Distribution::Constant, 8),
        )
        .unwrap();
        net.run(kind.build().as_mut()).unwrap().throughput
    };
    let gap = (run(PolicyKind::Cab) - run(PolicyKind::BestFit)).abs();
    assert!(gap < 1.5, "CAB/BF gap at η=0.1 should be small, got {gap}");
}

#[test]
fn energy_and_edp_scenarios_match_closed_forms() {
    let mu = workload::paper_two_type_mu();
    // Proportional power: E[ℰ] = k (Eq. 23) with constant sizes (exact).
    let mut c = cfg(vec![10, 10], Distribution::Constant, 3);
    c.power = PowerScenario::Proportional;
    c.power_coeff = 2.0;
    let net = ClosedNetwork::new(&mu, c).unwrap();
    let r = net.run(PolicyKind::Cab.build().as_mut()).unwrap();
    assert!((r.mean_energy - 2.0).abs() < 1e-9, "E[ℰ]={}", r.mean_energy);
    // EDP = E[ℰ]·E[T] by construction; check consistency.
    assert!((r.edp - r.mean_energy * r.mean_response).abs() < 1e-9);

    // Constant power: E[ℰ] ≈ l·k/X (Eq. 22) when both processors busy.
    let mut c = cfg(vec![10, 10], Distribution::Constant, 3);
    c.power = PowerScenario::Constant;
    c.power_coeff = 1.5;
    let net = ClosedNetwork::new(&mu, c).unwrap();
    let r = net.run(PolicyKind::Cab.build().as_mut()).unwrap();
    let want = 2.0 * 1.5 / r.throughput;
    let err = (r.mean_energy - want).abs() / want;
    assert!(err < 0.1, "E[ℰ]={} vs 2k/X={want}", r.mean_energy);
}

#[test]
fn multitype_grin_beats_baselines_under_all_distributions() {
    // Figs. 9–12 at reduced scale: 3×3 random system, GrIn vs baselines,
    // Opt as the upper oracle.
    use hetsched::sim::rng::Rng;
    let mut rng = Rng::new(2718);
    let mu = workload::random_mu(&mut rng, 3, 3, 1.0, 25.0).unwrap();
    let pops = vec![5u32, 7, 4];
    for dist in Distribution::all() {
        let run = |kind: PolicyKind| {
            let net = ClosedNetwork::new(&mu, cfg(pops.clone(), dist, 31)).unwrap();
            net.run(kind.build().as_mut()).unwrap().throughput
        };
        let x_grin = run(PolicyKind::GrIn);
        let x_opt = run(PolicyKind::Opt);
        for kind in [PolicyKind::BestFit, PolicyKind::Random, PolicyKind::Jsq, PolicyKind::LoadBalance] {
            let x = run(kind);
            assert!(
                x_grin >= x * 0.97,
                "{} beat GrIn under {}: {x} vs {x_grin}",
                kind.name(),
                dist.name()
            );
        }
        // GrIn within a few percent of Opt (paper: 1.6% average).
        assert!(
            x_grin >= x_opt * 0.93,
            "GrIn {x_grin} far from Opt {x_opt} under {}",
            dist.name()
        );
    }
}

#[test]
fn fcfs_and_lcfs_disciplines_run_all_policies() {
    // Smoke: every policy × every discipline composes.
    let mu = workload::paper_two_type_mu();
    for d in [Discipline::Fcfs, Discipline::Lcfs] {
        for kind in PolicyKind::five_two_type() {
            let mut c = cfg(vec![5, 5], Distribution::Exponential, 17);
            c.discipline = d;
            c.measure = 800;
            let net = ClosedNetwork::new(&mu, c).unwrap();
            let r = net.run(kind.build().as_mut()).unwrap();
            assert!(r.throughput > 0.0);
            assert_eq!(r.completed, 800);
        }
    }
}
