//! Property tests over the policy/solver layer (in-repo testkit).
//!
//! These are the coordinator invariants the paper's correctness rests on:
//! population conservation (Eq. 29), Lemma-8 monotonicity, CAB ≡ GrIn ≡
//! Opt on two types, and deficit steering keeping the system in S_max.

use hetsched::model::state::StateMatrix;
use hetsched::model::throughput::{x_of_state, x_two_type};
use hetsched::policy::cab::Cab;
use hetsched::policy::target::TargetSteering;
use hetsched::policy::{grin, SystemView};
use hetsched::solver::exhaustive::ExhaustiveSolver;
use hetsched::testkit::forall;

#[test]
fn prop_grin_conserves_populations() {
    forall(101, 200, |g| {
        let mu = g.affinity((1, 5), (1, 5));
        let pops = g.populations(mu.types(), 12);
        let sol = grin::solve(&mu, &pops).map_err(|e| e.to_string())?;
        sol.state
            .check_populations(&pops)
            .map_err(|e| format!("row sums broken: {e}"))
    });
}

#[test]
fn prop_grin_never_below_init_and_never_above_opt() {
    forall(102, 60, |g| {
        let mu = g.affinity((2, 3), (2, 3));
        let pops = g.populations(mu.types(), 6);
        let init = grin::initialize(&mu, &pops).map_err(|e| e.to_string())?;
        let sol = grin::solve(&mu, &pops).map_err(|e| e.to_string())?;
        let opt = ExhaustiveSolver.solve(&mu, &pops).map_err(|e| e.to_string())?;
        let xi = x_of_state(&mu, &init);
        if sol.throughput < xi - 1e-9 {
            return Err(format!("GrIn {} below init {xi}", sol.throughput));
        }
        if sol.throughput > opt.throughput + 1e-9 {
            return Err(format!(
                "GrIn {} above exhaustive optimum {}",
                sol.throughput, opt.throughput
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_cab_equals_grin_equals_opt_on_two_types() {
    // Lemma 4 (CAB optimal) + §7's "GrIn gives the same solution as CAB".
    forall(103, 120, |g| {
        let mu = g.affinity_two_type();
        let pops = vec![g.u32_in(1, 12), g.u32_in(1, 12)];
        let (_, cab) = Cab::target_state(&mu, &pops).map_err(|e| e.to_string())?;
        let x_cab = x_of_state(&mu, &cab);
        let x_grin = grin::solve(&mu, &pops).map_err(|e| e.to_string())?.throughput;
        let x_opt = ExhaustiveSolver
            .solve(&mu, &pops)
            .map_err(|e| e.to_string())?
            .throughput;
        if (x_cab - x_opt).abs() > 1e-9 {
            return Err(format!("CAB {x_cab} != Opt {x_opt} for {mu:?} {pops:?}"));
        }
        if (x_grin - x_opt).abs() > 1e-9 {
            return Err(format!("GrIn {x_grin} != Opt {x_opt} for {mu:?} {pops:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cab_smax_dominates_entire_state_grid() {
    // Exhaustive re-verification of Table 1 on random affinity systems.
    forall(104, 60, |g| {
        let mu = g.affinity_two_type();
        let (n1, n2) = (g.u32_in(1, 9), g.u32_in(1, 9));
        let (_, target) = Cab::target_state(&mu, &[n1, n2]).map_err(|e| e.to_string())?;
        let best = x_of_state(&mu, &target);
        for n11 in 0..=n1 {
            for n22 in 0..=n2 {
                let x = x_two_type(&mu, n11, n22, n1, n2).map_err(|e| e.to_string())?;
                if x > best + 1e-9 {
                    return Err(format!(
                        "state ({n11},{n22}) gives {x} > CAB {best} for {mu:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_deficit_steering_is_a_fixed_point() {
    // From the target state, any single departure followed by a policy
    // dispatch of the same type returns exactly to the target.
    forall(105, 150, |g| {
        let mu = g.affinity((2, 4), (2, 4));
        let pops = g.populations(mu.types(), 8);
        let sol = grin::solve(&mu, &pops).map_err(|e| e.to_string())?;
        let steer = TargetSteering::new(sol.state.clone());
        let work = vec![0.0; mu.procs()];
        let mut state = sol.state.clone();
        for _ in 0..40 {
            // Random occupied cell departs.
            let (mut i, mut j);
            loop {
                i = g.usize_in(0, mu.types() - 1);
                j = g.usize_in(0, mu.procs() - 1);
                if state.get(i, j) > 0 {
                    break;
                }
            }
            state.dec(i, j).map_err(|e| e.to_string())?;
            let view = SystemView {
                mu: &mu,
                state: &state,
                work: &work,
                populations: &pops,
            };
            let dest = steer.dispatch(i, &view);
            state.inc(i, dest);
            if state != sol.state {
                return Err(format!(
                    "steering drifted after departure ({i},{j}):\n{state}vs target\n{}",
                    sol.state
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_x_of_state_zero_iff_all_queues_empty() {
    forall(106, 100, |g| {
        let mu = g.affinity((1, 4), (1, 4));
        let pops = g.populations(mu.types(), 6);
        let s = g.state(&pops, mu.procs());
        let x = x_of_state(&mu, &s);
        let total: u32 = pops.iter().sum();
        if total > 0 && x <= 0.0 {
            return Err(format!("non-empty system with X = {x}"));
        }
        let empty = StateMatrix::zeros(mu.types(), mu.procs());
        if x_of_state(&mu, &empty) != 0.0 {
            return Err("empty system with X != 0".into());
        }
        Ok(())
    });
}

#[test]
fn prop_grin_moves_bounded_and_deterministic() {
    forall(107, 80, |g| {
        let mu = g.affinity((2, 4), (2, 4));
        let pops = g.populations(mu.types(), 10);
        let a = grin::solve(&mu, &pops).map_err(|e| e.to_string())?;
        let b = grin::solve(&mu, &pops).map_err(|e| e.to_string())?;
        if a.state != b.state {
            return Err("GrIn is nondeterministic".into());
        }
        let n_total: u32 = pops.iter().sum();
        let cap = 64 + (n_total as usize) * mu.procs() * mu.types() * 4;
        if a.moves >= cap {
            return Err(format!("GrIn hit its move cap ({} moves)", a.moves));
        }
        Ok(())
    });
}
