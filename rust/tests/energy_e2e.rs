//! Energy/EDP objective-axis end-to-end gates (the PR-6 acceptance
//! suite).
//!
//! The unified solve surface ([`SolveRequest`] → [`Policy::prepare`] →
//! [`PreparedTarget`]) must be a strict superset of the pre-redesign
//! throughput paths, and the energy axis must actually buy energy:
//!
//! * throughput-objective `prepare` is bit-identical to the plain
//!   `grin::solve` on random k×l instances;
//! * the incremental [`ObjectiveEval`] agrees with a from-scratch
//!   rebuild within 1e-9 along random greedy-style move walks;
//! * energy-mode GrIn beats the load-balancing split by ≥ 1.08× on
//!   energy per task over the Table-3 general-symmetric system;
//! * the throughput-per-watt objective holds X ≥ min_x_frac·X*;
//! * Eq. 19 energy respects the Lemma-7 α-bounds on random instances;
//! * greedy EDP lands within 5% of the exhaustive two-type optimum;
//! * the energy-objective arm replicates bit-identically across worker
//!   thread counts.

use hetsched::model::energy::{EnergyModel, PowerScenario};
use hetsched::model::objective::{Objective, ObjectiveEval, PowerProfile};
use hetsched::model::state::StateMatrix;
use hetsched::model::throughput::x_of_state;
use hetsched::policy::{grin, Policy, PolicyKind, SolveRequest};
use hetsched::sim::dynamic::{DynamicConfig, ResolveMode};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::rng::Rng;
use hetsched::sim::workload::{self, scenario_phases, ScenarioKind, ScenarioParams};
use hetsched::testkit::prop::forall;

#[test]
fn throughput_objective_prepare_is_bit_identical_to_plain_solve() {
    // The api_redesign invariant: routing the default request through
    // the unified surface changes nothing — state for state, bit for
    // bit on the objective value — across random k×l instances.
    let mut rng = Rng::new(0xE6E1);
    for _ in 0..40 {
        let k = 2 + rng.index(3);
        let l = 2 + rng.index(3);
        let mu = workload::random_mu(&mut rng, k, l, 0.5, 30.0).unwrap();
        let pops = workload::random_populations(&mut rng, k, 12);
        let plain = grin::solve(&mu, &pops).unwrap();
        let mut policy = PolicyKind::GrIn.build();
        let prepared = policy
            .prepare(&SolveRequest::new(&mu, &pops))
            .unwrap();
        assert_eq!(prepared.target.as_ref(), Some(&plain.state));
        assert_eq!(
            prepared.objective_value.unwrap().to_bits(),
            plain.throughput.to_bits(),
            "prepare() drifted from grin::solve on a {k}x{l} instance"
        );
        // The explicit-throughput spelling is the same request.
        let explicit = grin::solve_request(
            &SolveRequest::new(&mu, &pops)
                .with_objective(Objective::Throughput, PowerProfile::default()),
        )
        .unwrap();
        assert_eq!(explicit.state, plain.state);
        assert_eq!(explicit.throughput.to_bits(), plain.throughput.to_bits());
    }
}

#[test]
fn incremental_objective_eval_tracks_full_recompute_within_1e9() {
    // Probe/apply along random move walks vs a from-scratch evaluator
    // and the Eq. 19/21 EnergyModel: ≤ 1e-9 everywhere.
    forall(0xE6E2, 60, |g| {
        let k = g.usize_in(2, 4);
        let l = g.usize_in(2, 4);
        let mu = workload::random_mu(g.rng, k, l, 0.5, 30.0)
            .map_err(|e| e.to_string())?;
        let pops = g.populations(k, 6);
        let mut s = g.state(&pops, l);
        if s.total() == 0 {
            s.set(0, 0, 1);
        }
        let profile = PowerProfile::new(
            g.f64_in(0.5, 3.0),
            PowerScenario::Exponent(g.f64_in(-1.0, 1.0)),
        )
        .with_idle(g.f64_in(0.0, 1.0));
        let objective = match g.usize_in(0, 2) {
            0 => Objective::EnergyPerTask,
            1 => Objective::Edp,
            _ => Objective::ThroughputPerWatt { min_x_frac: 0.5 },
        };
        let mut eval = ObjectiveEval::new(&mu, &s, &profile, objective, 1.0)
            .map_err(|e| e.to_string())?;
        for _ in 0..12 {
            // Random legal move: a populated (p, from) to some other column.
            let p = g.usize_in(0, k - 1);
            let from = g.usize_in(0, l - 1);
            let to = (from + g.usize_in(1, l - 1)) % l;
            if s.get(p, from) == 0 {
                continue;
            }
            let base = eval.base();
            let (px, pp) = eval.probe(p, from, to, base);
            s.move_task(p, from, to).map_err(|e| e.to_string())?;
            eval.apply_move(p, from, to);
            let fresh = ObjectiveEval::new(&mu, &s, &profile, objective, 1.0)
                .map_err(|e| e.to_string())?;
            let (fx, fp) = fresh.base();
            if (px - fx).abs() > 1e-9 || (pp - fp).abs() > 1e-9 {
                return Err(format!(
                    "probe ({px}, {pp}) vs fresh ({fx}, {fp}) after a move"
                ));
            }
            if (eval.score() - fresh.score()).abs() > 1e-9 {
                return Err("incremental score drifted from rebuild".into());
            }
            // With no idle floor the evaluator is exactly Eq. 19/21.
            if profile.idle_power == 0.0 {
                let em = EnergyModel::new(&mu, profile.coeff, profile.scenario)
                    .map_err(|e| e.to_string())?;
                let want = em.energy_per_task(&mu, &s);
                if want.is_finite() && (eval.energy_per_task() - want).abs() > 1e-9 {
                    return Err("evaluator energy drifted from EnergyModel".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn energy_mode_grin_beats_load_balancing_on_energy_per_task() {
    // Table-3 general-symmetric (§7.4) under the α = 0.5 power model:
    // each type is markedly more energy-efficient on its own device
    // (energy per task on a solo cell is μ^{α−1}), so the even
    // load-balancing split wastes ≥ 8% energy vs the energy-mode solve.
    let mu = workload::table3::general_symmetric();
    let pops = [10u32, 10u32];
    let profile = PowerProfile::new(1.0, PowerScenario::Exponent(0.5));
    let em = EnergyModel::new(&mu, profile.coeff, profile.scenario).unwrap();
    let sol =
        grin::solve_objective(&mu, &pops, Objective::EnergyPerTask, &profile).unwrap();
    let e_grin = em.energy_per_task(&mu, &sol.state);
    // Load balancing: each type split evenly across the two devices.
    let balanced = StateMatrix::from_two_type(5, 5, 10, 10).unwrap();
    let e_balanced = em.energy_per_task(&mu, &balanced);
    assert!(
        e_balanced >= 1.08 * e_grin,
        "energy-mode GrIn {e_grin:.5} J/task vs load balancing \
         {e_balanced:.5}: ratio {:.3} < 1.08",
        e_balanced / e_grin
    );
    // And the energy solve never beats itself on throughput for free —
    // sanity: both states carry the full population.
    assert_eq!(sol.state.total(), 20);
    assert!(x_of_state(&mu, &sol.state) > 0.0);
}

#[test]
fn tpw_objective_holds_the_throughput_floor() {
    let mu = workload::table3::general_symmetric();
    let pops = [10u32, 10u32];
    let profile = PowerProfile::new(1.0, PowerScenario::Constant).with_idle(0.5);
    let x_star = grin::solve(&mu, &pops).unwrap().throughput;
    for &frac in &[0.8, 0.9, 1.0] {
        let sol = grin::solve_objective(
            &mu,
            &pops,
            Objective::ThroughputPerWatt { min_x_frac: frac },
            &profile,
        )
        .unwrap();
        let x = x_of_state(&mu, &sol.state);
        assert!(
            x >= frac * x_star - 1e-9,
            "tpw:{frac} landed at X {x:.4} below the floor {:.4}",
            frac * x_star
        );
        assert_eq!(sol.state.total(), 20);
    }
}

#[test]
fn eq19_energy_respects_lemma7_bounds_on_random_instances() {
    // Lemma 7 (μ ≥ 1, α ≤ 1): for α ≤ 0, 0 ≤ E[ℰ] ≤ n_busy·k/X; for
    // 0 < α ≤ 1, n_busy·k/X ≤ E[ℰ] ≤ k.
    forall(0xE6E7, 80, |g| {
        let k = g.usize_in(2, 4);
        let l = g.usize_in(2, 4);
        let mu = workload::random_mu(g.rng, k, l, 1.0, 30.0)
            .map_err(|e| e.to_string())?;
        let pops = g.populations(k, 6);
        let mut s = g.state(&pops, l);
        if s.total() == 0 {
            s.set(0, 0, 1);
        }
        let alpha = g.f64_in(-1.0, 1.0);
        let coeff = g.f64_in(0.5, 4.0);
        let em = EnergyModel::new(&mu, coeff, PowerScenario::Exponent(alpha))
            .map_err(|e| e.to_string())?;
        let x = x_of_state(&mu, &s);
        if x <= 0.0 {
            return Ok(());
        }
        let n_busy = (0..l).filter(|&j| s.col_sum(j) > 0).count();
        let e = em.energy_per_task(&mu, &s);
        let (lo, hi) = em.lemma7_energy_bounds(x, n_busy);
        if e < lo - 1e-9 || e > hi + 1e-9 {
            return Err(format!(
                "α={alpha:.3}, k-coeff={coeff:.3}: E[ℰ]={e:.6} outside [{lo:.6}, {hi:.6}]"
            ));
        }
        Ok(())
    });
}

#[test]
fn greedy_edp_matches_the_exhaustive_two_type_optimum() {
    // Small two-type systems admit exhaustive enumeration of every
    // (n11, n22) split; the greedy EDP solve must land within 5% of
    // that optimum (the greedy loop is a heuristic, not an oracle —
    // Lemma 8 guarantees monotone improvement, not global optimality).
    for (mu, label) in [
        (workload::paper_two_type_mu(), "paper §5"),
        (workload::table3::general_symmetric(), "table-3 general-symmetric"),
    ] {
        for scenario in [PowerScenario::Constant, PowerScenario::Exponent(0.5)] {
            let (n1, n2) = (6u32, 6u32);
            let profile = PowerProfile::new(1.0, scenario);
            let em = EnergyModel::new(&mu, profile.coeff, scenario).unwrap();
            let mut best = f64::INFINITY;
            for n11 in 0..=n1 {
                for n22 in 0..=n2 {
                    let s = StateMatrix::from_two_type(n11, n22, n1, n2).unwrap();
                    if x_of_state(&mu, &s) <= 0.0 {
                        continue;
                    }
                    best = best.min(em.edp(&mu, &s));
                }
            }
            let sol =
                grin::solve_objective(&mu, &[n1, n2], Objective::Edp, &profile).unwrap();
            let got = em.edp(&mu, &sol.state);
            assert!(
                got <= 1.05 * best,
                "{label} / {}: greedy EDP {got:.5} vs exhaustive {best:.5}",
                scenario.name()
            );
        }
    }
}

#[test]
fn energy_objective_cells_replicate_bit_identically_across_thread_counts() {
    // The energy arm through the replication runner: seeded
    // replications at 1 vs 4 worker threads agree bit for bit on every
    // aggregate, the new energy means included.
    let params = ScenarioParams {
        phases: 3,
        completions: 600,
        warmup: 60,
        ..Default::default()
    };
    let mut cfg =
        DynamicConfig::new(scenario_phases(ScenarioKind::SlowDrift, &params).unwrap());
    cfg.resolve = ResolveMode::Adaptive;
    cfg.seed = 0xE6E9;
    cfg.objective = Objective::EnergyPerTask;
    cfg.power = PowerProfile::new(1.0, PowerScenario::Exponent(0.5)).with_idle(0.2);
    let cells = vec![DynCell {
        label: "energy".to_string(),
        mu: workload::paper_two_type_mu(),
        cfg,
        policy: PolicyKind::GrIn,
    }];
    let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 0xACDC };
    let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
    let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
    let (a, b) = (&one[0], &four[0]);
    assert_eq!(a.mean_x.to_bits(), b.mean_x.to_bits());
    assert_eq!(a.ci95_x.to_bits(), b.ci95_x.to_bits());
    assert_eq!(a.mean_energy.to_bits(), b.mean_energy.to_bits());
    assert!(a.mean_x > 0.0 && a.mean_energy > 0.0);
}
