//! Change-point-aware estimation end-to-end — the PR acceptance gates:
//!
//! * **false-alarm gate**: on stationary exponential load the per-cell
//!   CUSUM detector never alarms, across seeds (property test);
//! * **detection-delay gate**: after a 2× rate flip a cell alarms
//!   within a bounded number of its own completions (property test);
//! * on the abrupt regime-flip scenario, CUSUM-triggered adaptive
//!   re-solves at least match the threshold-drift trigger's throughput,
//!   while issuing fewer false re-solves on stationary load;
//! * the sharded control plane under the CUSUM trigger beats a frozen
//!   global solve on the three-class regime flip.

use hetsched::coordinator::RateEstimator;
use hetsched::policy::PolicyKind;
use hetsched::sim::dynamic::{DriftConfig, DynamicConfig, Phase, ResolveMode, Trigger};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::workload::{
    self, scenario_phases, three_class_flip_scale, three_class_mu, ScenarioKind,
    ScenarioParams,
};
use hetsched::testkit::forall;

fn cusum_drift() -> DriftConfig {
    DriftConfig { trigger: Trigger::Cusum, ..Default::default() }
}

#[test]
fn prop_cusum_never_alarms_on_stationary_load() {
    // False-alarm gate: exponential service times at exactly the
    // reference rates, many cells × many seeds — the mini-batched CUSUM
    // must stay silent (with the default h = 4 the per-cell crossing
    // probability is ~e⁻¹²; an alarm here is a real regression, not bad
    // luck).
    forall(1301, 30, |g| {
        let mu = g.affinity((2, 3), (2, 3));
        let (k, l) = (mu.types(), mu.procs());
        let mut est = RateEstimator::from_drift(&mu, &cusum_drift())
            .map_err(|e| e.to_string())?;
        // Round-robin the cells so the staleness clock stays balanced.
        for _ in 0..300 {
            for i in 0..k {
                for j in 0..l {
                    est.observe(i, j, g.rng.exp(mu.rate(i, j)));
                }
            }
        }
        if est.alarm_pending() {
            return Err(format!("false alarm at cells {:?}", est.take_alarms()));
        }
        Ok(())
    });
}

#[test]
fn prop_cusum_alarms_within_bounded_delay_after_2x_flip() {
    // Detection-delay gate: after the cell's true rate halves, the
    // batch residual mean is +1 and each mini-batch adds ~0.75 to g⁺ —
    // crossing h = 4 needs ~6 batches (48 samples).  200 samples (25
    // batches) is a >4σ noise-margin bound; exceeding it means
    // detection broke, not that the dice came up cold.
    forall(1723, 30, |g| {
        let mu = g.affinity_two_type();
        let mut est = RateEstimator::from_drift(&mu, &cusum_drift())
            .map_err(|e| e.to_string())?;
        // Warm stationary stretch first: no alarm.
        for _ in 0..100 {
            est.observe(0, 0, g.rng.exp(mu.rate(0, 0)));
        }
        if est.alarm_pending() {
            return Err("alarmed before the flip".into());
        }
        // Flip: the cell runs 2× slower from here on.
        let flipped = mu.rate(0, 0) / 2.0;
        let mut delay = 0u64;
        while !est.alarm_pending() {
            est.observe(0, 0, g.rng.exp(flipped));
            delay += 1;
            if delay > 200 {
                return Err(format!("no alarm {delay} samples after a 2× flip"));
            }
        }
        let alarms = est.take_alarms();
        if alarms != vec![(0, 0)] {
            return Err(format!("alarmed wrong cells {alarms:?}"));
        }
        Ok(())
    });
}

/// The abrupt regime-flip schedule from the canned scenario builder:
/// one clean phase, then the paper's P1-biased matrix flipped into a
/// P2-biased one for the rest of the run.
fn abrupt_flip_phases() -> Vec<Phase> {
    let params = ScenarioParams {
        phases: 5,
        completions: 2_500,
        warmup: 300,
        ..Default::default()
    };
    scenario_phases(ScenarioKind::AbruptFlip, &params).unwrap()
}

fn adaptive_cell(trigger: Trigger, phases: Vec<Phase>, seed: u64) -> DynCell {
    let mut cfg = DynamicConfig::new(phases);
    cfg.resolve = ResolveMode::Adaptive;
    cfg.drift.trigger = trigger;
    cfg.seed = seed;
    DynCell {
        label: trigger.name().to_string(),
        mu: workload::paper_two_type_mu(),
        cfg,
        policy: PolicyKind::GrIn,
    }
}

#[test]
fn cusum_trigger_matches_threshold_throughput_on_regime_flip() {
    // Acceptance gate: on the abrupt flip the CUSUM trigger must at
    // least match the polled-threshold trigger (it detects within ~200
    // completions; the threshold poll waits for its check_every tick
    // and a refreshed window), and both must clearly beat frozen.
    let mut frozen_cfg = DynamicConfig::new(abrupt_flip_phases());
    frozen_cfg.resolve = ResolveMode::Static;
    frozen_cfg.seed = 4141;
    let cells = vec![
        adaptive_cell(Trigger::Threshold, abrupt_flip_phases(), 4141),
        adaptive_cell(Trigger::Cusum, abrupt_flip_phases(), 4141),
        DynCell {
            label: "static".into(),
            mu: workload::paper_two_type_mu(),
            cfg: frozen_cfg,
            policy: PolicyKind::GrIn,
        },
    ];
    let plan = ReplicationPlan { reps: 4, threads: 0, base_seed: 23 };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let (threshold, cusum, frozen) = (&stats[0], &stats[1], &stats[2]);
    assert!(
        cusum.mean_x >= threshold.mean_x * 0.97,
        "cusum {} vs threshold {} — CUSUM lost throughput on the flip",
        cusum.mean_x,
        threshold.mean_x
    );
    assert!(
        cusum.mean_x >= frozen.mean_x * 1.1,
        "cusum {} vs frozen {} — no adaptation win",
        cusum.mean_x,
        frozen.mean_x
    );
    // The win came from actual CUSUM-triggered re-solves, and the
    // frozen arm never re-solved.
    assert!(cusum.mean_resolves >= 1.0, "{}", cusum.mean_resolves);
    assert_eq!(frozen.mean_resolves, 0.0);
}

#[test]
fn cusum_trigger_issues_fewer_false_resolves_on_stationary_load() {
    // Acceptance gate: on stationary load (no change point anywhere)
    // the CUSUM trigger must re-solve no more often than the threshold
    // trigger — and essentially never — while holding throughput.
    let stationary = vec![Phase::new(vec![10, 10], 300, 6_000)];
    let cells = vec![
        adaptive_cell(Trigger::Threshold, stationary.clone(), 808),
        adaptive_cell(Trigger::Cusum, stationary, 808),
    ];
    let plan = ReplicationPlan { reps: 4, threads: 0, base_seed: 31 };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let (threshold, cusum) = (&stats[0], &stats[1]);
    assert!(
        cusum.mean_resolves <= threshold.mean_resolves,
        "cusum {} re-solves/run vs threshold {} on stationary load",
        cusum.mean_resolves,
        threshold.mean_resolves
    );
    assert!(
        cusum.mean_resolves < 0.75,
        "{} stationary CUSUM re-solves/run",
        cusum.mean_resolves
    );
    // No throughput price for the silence.
    assert!(
        cusum.mean_x >= threshold.mean_x * 0.97,
        "cusum {} vs threshold {} stationary throughput",
        cusum.mean_x,
        threshold.mean_x
    );
}

#[test]
fn sharded_cusum_beats_frozen_on_three_class_regime_flip() {
    // The sharded plane's gather/re-solve loop under per-shard CUSUM
    // detectors: on the three-device-class affinity rotation it must
    // beat the frozen global solve by the same ≥1.1× margin the
    // threshold-trigger sharded arm is held to in sharded_e2e.rs.
    let scale = three_class_flip_scale();
    let mut phases = vec![Phase::new(vec![8, 8, 8], 300, 2_500)];
    for _ in 0..4 {
        phases.push(Phase::new(vec![8, 8, 8], 300, 2_500).with_mu_scale(scale.clone()));
    }
    let cell = |mode: ResolveMode, trigger: Trigger| {
        let mut cfg = DynamicConfig::new(phases.clone());
        cfg.resolve = mode;
        cfg.drift.trigger = trigger;
        cfg.shard.shards = 3;
        cfg.seed = 99;
        DynCell {
            label: format!("{}+{}", mode.name(), trigger.name()),
            mu: three_class_mu(),
            cfg,
            policy: PolicyKind::GrIn,
        }
    };
    let cells = vec![
        cell(ResolveMode::Static, Trigger::Threshold),
        cell(ResolveMode::Sharded, Trigger::Cusum),
    ];
    let plan = ReplicationPlan { reps: 3, threads: 0, base_seed: 17 };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let (frozen, sharded) = (&stats[0], &stats[1]);
    assert!(
        sharded.mean_x >= frozen.mean_x * 1.1,
        "sharded+cusum {} vs frozen {} — no ≥1.1× adaptation win",
        sharded.mean_x,
        frozen.mean_x
    );
    assert!(sharded.mean_resolves >= 1.0, "{}", sharded.mean_resolves);
}

#[test]
fn cusum_replications_are_thread_count_independent() {
    // The determinism claim extends to the CUSUM trigger: identical
    // aggregates regardless of worker count.
    let cells = vec![adaptive_cell(Trigger::Cusum, abrupt_flip_phases(), 55)];
    let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 5 };
    let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
    let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
    assert_eq!(one[0].mean_x.to_bits(), four[0].mean_x.to_bits());
    assert_eq!(one[0].ci95_x.to_bits(), four[0].ci95_x.to_bits());
}
