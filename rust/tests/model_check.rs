//! Bounded model checks of the crate's three concurrent protocols
//! (`cargo test --features model --test model_check`).
//!
//! Each test hands the in-repo DFS explorer
//! ([`hetsched::sync::model::Checker`]) a closure that builds the shared
//! state fresh, spawns 2–3 model threads, and asserts the protocol
//! invariant; the explorer then re-runs it once per distinct bounded
//! interleaving (sequentially consistent schedules, CHESS-style
//! preemption bound).  A passing test means the invariant held in
//! EVERY explored schedule and the bounded space was fully enumerated —
//! not that one lucky run passed.  The negative tests seed a known
//! protocol mutation (epoch published before its payload) and assert
//! the explorer FINDS the violating schedule, which is the gate that
//! the checker actually has teeth.
//!
//! Protocols covered, matching the production code they model:
//! 1. snapshot install vs concurrent routing
//!    (`coordinator::frontend::ConcurrentRouter`, run directly);
//! 2. reconciled-handle delta publish vs completion (occupancy
//!    conservation, run directly);
//! 3. shard install vs global gather (`coordinator::{shard, global}`
//!    epoch protocol, modeled abstractly: per-shard mutexes + a global
//!    epoch published only after every shard installed);
//! 4. `CreditQueue` shutdown (`coordinator::leader`, run directly).

#![cfg(feature = "model")]

use std::time::Duration;

use hetsched::coordinator::{ConcurrentRouter, CreditPop, CreditQueue, RouterConfig, TargetUpdate};
use hetsched::policy::PolicyKind;
use hetsched::sim::workload::table3;
use hetsched::sync::model::{check, spawn, Checker, Report};
use hetsched::sync::{Arc, AtomicU64, Mutex, Ordering};

fn config() -> RouterConfig {
    let mu = table3::p2_biased();
    let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
    RouterConfig::new(mu, omega, vec![10, 10]).with_seed(7)
}

/// μ(0,0) identifies which solve a snapshot came from: 253.0 is the
/// boot matrix ([`table3::p2_biased`]), 928.0 the installed one
/// ([`table3::general_symmetric`]).  Both are exact f64 constants.
const BOOT_RATE: f64 = 253.0;
const INSTALLED_RATE: f64 = 928.0;

/// Protocol 1: a routing thread keeps deciding while the leader
/// installs a new target.  In every interleaving: no torn snapshot
/// (the epoch a handle sees always travels with that epoch's μ),
/// observed epochs are monotone, and occupancy accounts for every
/// route.
#[test]
fn install_vs_route_no_torn_reads_monotone_epochs() {
    check(|| {
        let mut policy = PolicyKind::Cab.build();
        let front = Arc::new(ConcurrentRouter::new(config(), policy.as_mut()).unwrap());
        let f2 = Arc::clone(&front);
        let router = spawn(move || {
            let mut handle = f2.handle();
            let mut last_epoch = 0u64;
            let mut routed = 0i64;
            for _ in 0..2 {
                let j = handle.route(0).unwrap();
                assert!(j < 2, "routed off the fleet");
                routed += 1;
                let snap = handle.snapshot();
                let rate = snap.solved_mu.rate(0, 0);
                match snap.epoch {
                    0 => assert_eq!(rate, BOOT_RATE, "torn snapshot: epoch 0, foreign mu"),
                    1 => assert_eq!(rate, INSTALLED_RATE, "torn snapshot: epoch 1, foreign mu"),
                    e => panic!("impossible epoch {e}"),
                }
                assert!(snap.epoch >= last_epoch, "handle epoch went backwards");
                last_epoch = snap.epoch;
            }
            routed
        });
        let mu2 = table3::general_symmetric();
        let omega2: Vec<f64> = mu2.data().iter().map(|&m| 1.0 / m).collect();
        let update = TargetUpdate::new(mu2, omega2).with_epoch(1);
        front.install(policy.as_mut(), &update).unwrap();
        let routed = router.join().unwrap();
        assert_eq!(front.epoch(), 1);
        assert_eq!(front.inflight(), routed, "occupancy lost a route");
    });
}

/// Protocol 2: a reconciled handle publishes batched deltas while a
/// completion lands concurrently.  After the auto-flush, the published
/// grid must conserve counts (Σ occupancy = routes − completes) in
/// every interleaving — the signed-cell design exists exactly so the
/// transient complete-before-publish orderings stay consistent.
#[test]
fn reconciled_publish_vs_complete_conserves_occupancy() {
    check(|| {
        let mut policy = PolicyKind::Cab.build();
        let front = Arc::new(ConcurrentRouter::new(config(), policy.as_mut()).unwrap());
        // One exact-mode route pins a known in-flight cell to complete.
        let j0 = front.handle().route(0).unwrap();
        let f2 = Arc::clone(&front);
        let completer = spawn(move || f2.complete(0, j0).unwrap());
        let mut handle = front.handle_with_reconcile(2);
        let a = handle.route(0).unwrap();
        let b = handle.route(0).unwrap(); // second decision auto-flushes
        assert!(a < 2 && b < 2);
        completer.join().unwrap();
        // 3 routes − 1 completion, and every handle has flushed.
        assert_eq!(front.inflight(), 2, "flush/complete race broke conservation");
    });
}

/// Abstract model of the shard-install / global-gather epoch protocol:
/// the control plane writes every shard (each under its own lock) and
/// only then publishes the global epoch.  `buggy` inverts the publish
/// order — the seeded mutation the negative test must catch.
fn shard_gather_model(buggy: bool) -> Report {
    Checker::default().run(move || {
        let shards = Arc::new((Mutex::new(0u64), Mutex::new(0u64), AtomicU64::new(0)));
        let s2 = Arc::clone(&shards);
        let installer = spawn(move || {
            let (a, b, epoch) = &*s2;
            if buggy {
                // Seeded mutation: epoch visible before the shards.
                epoch.store(1, Ordering::SeqCst);
                *a.lock().unwrap() = 1;
                *b.lock().unwrap() = 1;
            } else {
                *a.lock().unwrap() = 1;
                *b.lock().unwrap() = 1;
                epoch.store(1, Ordering::SeqCst);
            }
        });
        // Gather: if the global epoch is visible, every shard must
        // already hold that epoch's state.
        let (a, b, epoch) = &*shards;
        let e = epoch.load(Ordering::SeqCst);
        let va = *a.lock().unwrap();
        let vb = *b.lock().unwrap();
        if e == 1 {
            assert_eq!((va, vb), (1, 1), "gather: published epoch with a stale shard");
        }
        installer.join().unwrap();
    })
}

/// Protocol 3, positive: install-then-publish holds in every schedule.
#[test]
fn shard_install_then_publish_is_clean() {
    let report = shard_gather_model(false);
    assert!(report.violation.is_none(), "{:?}", report.violation);
    assert!(report.complete, "schedule space not fully enumerated");
    assert!(report.executions > 1, "nothing was actually explored");
}

/// Protocol 3, negative: publishing the epoch before the shard installs
/// must be caught (this is the test that proves the checker has teeth).
#[test]
fn shard_publish_before_install_is_caught() {
    let report = shard_gather_model(true);
    let v = report.violation.expect("explorer must find the stale-shard schedule");
    assert!(v.message.contains("stale shard"), "unexpected violation: {}", v.message);
    assert!(!v.schedule.is_empty(), "violation must carry a replayable schedule");
}

/// Negative twin at the atomic level: a two-atomic snapshot whose epoch
/// is stored before its payload is torn in some schedule, and the
/// explorer must find it (the frontend avoids this by construction —
/// one immutable allocation behind one epoch — which this test keeps
/// honest).
#[test]
fn torn_two_atomic_snapshot_is_caught() {
    let report = Checker::default().run(|| {
        let snap = Arc::new((AtomicU64::new(0), AtomicU64::new(0)));
        let s2 = Arc::clone(&snap);
        let writer = spawn(move || {
            let (epoch, payload) = &*s2;
            // Seeded mutation: epoch first, payload second.
            epoch.store(1, Ordering::SeqCst);
            payload.store(10, Ordering::SeqCst);
        });
        let (epoch, payload) = &*snap;
        let e = epoch.load(Ordering::SeqCst);
        let p = payload.load(Ordering::SeqCst);
        assert!(
            !(e == 1 && p != 10),
            "torn snapshot: epoch 1 with stale payload"
        );
        writer.join().unwrap();
    });
    let v = report.violation.expect("explorer must find the torn schedule");
    assert!(v.message.contains("torn snapshot"), "unexpected violation: {}", v.message);
}

/// Protocol 4: `CreditQueue` shutdown.  Two consumers park on long
/// timed waits while the producer deposits three credits and closes.
/// In every interleaving: no deadlock (close's `notify_all` reaches
/// every parked waiter), every credit drains exactly once, and both
/// consumers terminate with `Closed`.
#[test]
fn credit_queue_shutdown_is_deadlock_free_in_all_schedules() {
    check(|| {
        let q = Arc::new(CreditQueue::new());
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                spawn(move || {
                    let mut got = 0u32;
                    loop {
                        match q.pop(Duration::from_secs(3600)) {
                            CreditPop::Credit => got += 1,
                            CreditPop::Closed => break,
                            CreditPop::Timeout => {}
                        }
                    }
                    got
                })
            })
            .collect();
        for _ in 0..3 {
            q.push();
        }
        q.close();
        let drained: u32 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(drained, 3, "credits lost or duplicated across shutdown");
    });
}
