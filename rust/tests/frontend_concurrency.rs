//! Concurrency properties of the lock-free serving front end — the PR
//! acceptance gates:
//!
//! * snapshot epochs observed by routing threads never decrease, and a
//!   torn read (one epoch's target with another epoch's weights) is
//!   impossible — each install's weights encode its epoch, so any
//!   mismatch would be caught on the very decision that saw it;
//! * occupancy is conserved across concurrent reconciled handles: once
//!   every handle flushes, each cell equals routes − completes;
//! * exact mode is interleaving-independent route-only: N threads
//!   routing a fixed request multiset land the same per-cell histogram
//!   as one thread routing it sequentially (per-class rows steer
//!   independently and same-class decisions commute, so the CAS
//!   linearization order cannot change the final grid).

use std::sync::atomic::{AtomicBool, Ordering};

use hetsched::coordinator::{ConcurrentRouter, RouterConfig, TargetUpdate};
use hetsched::model::affinity::AffinityMatrix;
use hetsched::policy::PolicyKind;
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;

fn config(mu: AffinityMatrix) -> RouterConfig {
    let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
    RouterConfig::new(mu, omega, vec![24, 24]).with_seed(7)
}

/// Epoch-encoding steering weights for the 2×2 fleet: cell `c` carries
/// `1 + c + epoch`.  Non-uniform, so the front end keeps them verbatim
/// instead of collapsing them to "unweighted".
fn stamped_weights(epoch: u64) -> Vec<f64> {
    (0..4).map(|c| 1.0 + c as f64 + epoch as f64).collect()
}

#[test]
fn epochs_are_monotone_and_snapshots_never_tear() {
    // GrIn: the only policy that honors non-trivial weights, which the
    // torn-read check needs.
    let mut policy = PolicyKind::GrIn.build();
    let front =
        ConcurrentRouter::new(config(workload::table3::p2_biased()), policy.as_mut()).unwrap();
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..3usize {
            let mut handle = front.handle();
            let stop = &stop;
            s.spawn(move || {
                let mut rng = Rng::new(0xE0 ^ t as u64);
                let mut prev = 0u64;
                while !stop.load(Ordering::Acquire) {
                    handle.route(rng.index(2)).unwrap();
                    let snap = handle.snapshot();
                    let e = snap.epoch;
                    assert!(e >= prev, "epoch went backwards: {prev} -> {e}");
                    prev = e;
                    if snap.weights.is_empty() {
                        assert_eq!(e, 0, "only the boot snapshot is unweighted");
                    } else {
                        assert_eq!(
                            snap.weights,
                            stamped_weights(e),
                            "torn snapshot at epoch {e}"
                        );
                    }
                }
            });
        }
        let mu = workload::table3::p2_biased();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        for e in 1..=40u64 {
            let update = TargetUpdate::new(mu.clone(), omega.clone())
                .with_weights(stamped_weights(e))
                .with_epoch(e);
            assert_eq!(front.install(policy.as_mut(), &update).unwrap(), e);
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        stop.store(true, Ordering::Release);
    });
    assert_eq!(front.epoch(), 40);
    assert!(front.routed() > 0, "readers routed nothing under install churn");
}

#[test]
fn reconciled_handles_conserve_occupancy_across_threads() {
    let mut policy = PolicyKind::Cab.build();
    let front = ConcurrentRouter::new(
        config(workload::table3::general_symmetric()),
        policy.as_mut(),
    )
    .unwrap();
    let decisions_per_thread = 600u64;
    let results: Vec<(Vec<i64>, u64)> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..4usize)
            .map(|t| {
                let mut handle = front.handle_with_reconcile(16);
                s.spawn(move || {
                    let mut rng = Rng::new(0xACE ^ t as u64);
                    let mut net = vec![0i64; 4];
                    let mut routed = 0u64;
                    let mut backlog: Vec<(usize, usize)> = Vec::new();
                    for i in 0..decisions_per_thread {
                        let class = rng.index(2);
                        let count = 1 + rng.index(3) as u32;
                        let j = handle.route_batch(class, count).unwrap();
                        net[class * 2 + j] += count as i64;
                        routed += count as u64;
                        backlog.push((class, j));
                        // Complete a random earlier request every few
                        // decisions: decrements race unpublished route
                        // deltas, which the signed cells must absorb.
                        if i % 7 == 6 {
                            let pick = rng.index(backlog.len());
                            let (c, d) = backlog.swap_remove(pick);
                            handle.complete(c, d).unwrap();
                            net[c * 2 + d] -= 1;
                        }
                    }
                    handle.flush();
                    (net, routed)
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let mut expected = vec![0i64; 4];
    let mut routed = 0u64;
    for (net, r) in &results {
        for (cell, d) in expected.iter_mut().zip(net) {
            *cell += d;
        }
        routed += r;
    }
    for i in 0..2 {
        for j in 0..2 {
            assert_eq!(
                front.occupancy(i, j).unwrap(),
                expected[i * 2 + j],
                "cell ({i}, {j}) off after all handles flushed"
            );
        }
    }
    assert_eq!(front.inflight(), expected.iter().sum::<i64>());
    assert_eq!(front.routed(), routed);
    assert_eq!(front.decisions(), 4 * decisions_per_thread);
    // Drain what is still in flight; the books must close at zero.
    for i in 0..2 {
        for j in 0..2 {
            for _ in 0..expected[i * 2 + j] {
                front.complete(i, j).unwrap();
            }
        }
    }
    assert_eq!(front.inflight(), 0);
}

#[test]
fn exact_mode_histogram_is_thread_count_independent() {
    let mut rng = Rng::new(42);
    let seq: Vec<usize> = (0..2000).map(|_| rng.index(2)).collect();

    let mut solo_policy = PolicyKind::Cab.build();
    let solo = ConcurrentRouter::new(
        config(workload::table3::general_symmetric()),
        solo_policy.as_mut(),
    )
    .unwrap();
    let mut handle = solo.handle();
    for &class in &seq {
        handle.route(class).unwrap();
    }

    let mut multi_policy = PolicyKind::Cab.build();
    let multi = ConcurrentRouter::new(
        config(workload::table3::general_symmetric()),
        multi_policy.as_mut(),
    )
    .unwrap();
    std::thread::scope(|s| {
        for chunk in seq.chunks(500) {
            let mut h = multi.handle();
            s.spawn(move || {
                for &class in chunk {
                    h.route(class).unwrap();
                }
            });
        }
    });
    for i in 0..2 {
        for j in 0..2 {
            assert_eq!(
                multi.occupancy(i, j).unwrap(),
                solo.occupancy(i, j).unwrap(),
                "cell ({i}, {j}) differs between 4-thread and 1-thread routing"
            );
        }
    }
    assert_eq!(multi.routed(), solo.routed());
    assert_eq!(multi.decisions(), seq.len() as u64);
}
