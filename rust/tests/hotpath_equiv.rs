//! Hot-path equivalence gates for the simulator overhaul.
//!
//! The reworked core (virtual-time PS, cached FCFS/LCFS, indexed event
//! heap, incremental work aggregates) must change *nothing observable*:
//! this file runs the seed-style scalar processor and the reworked
//! engine side by side on fixed seeds across all three disciplines and
//! asserts identical completion sequences — task id, processor, and
//! time within 1e-9 — plus property-checks the event queue against a
//! linear argmin on random event streams.

use hetsched::model::affinity::AffinityMatrix;
use hetsched::model::state::StateMatrix;
use hetsched::policy::{Policy, PolicyKind, SolveRequest, SystemView};
use hetsched::sim::distribution::Distribution;
use hetsched::sim::engine::{ClosedNetwork, Completion, SimArena, SimConfig};
use hetsched::sim::eventq::EventQueue;
use hetsched::sim::processor::{Discipline, ScalarProcessor};
use hetsched::sim::rng::Rng;
use hetsched::sim::task::Program;
use hetsched::sim::workload;
use hetsched::testkit::forall;

/// The seed engine, verbatim, over [`ScalarProcessor`]: linear argmin
/// over processors, O(n) rescans — the reference trace generator.
fn run_reference(
    mu: &AffinityMatrix,
    cfg: &SimConfig,
    policy: &mut dyn Policy,
) -> Vec<Completion> {
    let (k, l) = (mu.types(), mu.procs());
    policy.prepare(&SolveRequest::new(mu, &cfg.populations)).unwrap();
    let needs_work = policy.needs_work_estimate();
    let mut rng = Rng::new(cfg.seed);
    let mut procs: Vec<ScalarProcessor> =
        (0..l).map(|j| ScalarProcessor::new(j, cfg.discipline)).collect();
    let mut state = StateMatrix::zeros(k, l);
    let mut programs: Vec<Program> = Vec::new();
    for (ttype, &ni) in cfg.populations.iter().enumerate() {
        for _ in 0..ni {
            programs.push(Program::new(programs.len(), ttype));
        }
    }
    let mut order: Vec<usize> = (0..programs.len()).collect();
    rng.shuffle(&mut order);

    let mut next_id = 0u64;
    let mut work = vec![0.0f64; l];
    for &p in &order {
        let ttype = programs[p].ttype;
        let size = cfg.dist.sample(&mut rng);
        let task = programs[p].emit(next_id, 0.0, size);
        next_id += 1;
        if needs_work {
            for (j, pr) in procs.iter().enumerate() {
                work[j] = pr.remaining_work_time();
            }
        }
        let view = SystemView {
            mu,
            state: &state,
            work: &work,
            populations: &cfg.populations,
        };
        let j = policy.dispatch(ttype, &view, &mut rng);
        procs[j].advance(0.0);
        procs[j].push(task, mu.rate(ttype, j), 0.0);
        state.inc(ttype, j);
    }

    let total = cfg.warmup + cfg.measure;
    let mut trace = Vec::with_capacity(total as usize);
    let mut now = 0.0f64;
    let mut completions = 0u64;
    while completions < total {
        let (j, t) = procs
            .iter()
            .enumerate()
            .filter_map(|(j, p)| p.next_completion().map(|t| (j, t)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("closed system never drains");
        now = t;
        procs[j].advance(now);
        let done = procs[j].pop_completed(now).unwrap();
        state.dec(done.ttype, j).unwrap();
        completions += 1;
        trace.push(Completion { id: done.id, proc: j, time: now });

        let prog = done.program;
        let ttype = programs[prog].ttype;
        let size = cfg.dist.sample(&mut rng);
        let task = programs[prog].emit(next_id, now, size);
        next_id += 1;
        if needs_work {
            for (jj, pr) in procs.iter().enumerate() {
                work[jj] = pr.remaining_work_time();
            }
        }
        let view = SystemView {
            mu,
            state: &state,
            work: &work,
            populations: &cfg.populations,
        };
        let dest = policy.dispatch(ttype, &view, &mut rng);
        procs[dest].advance(now);
        procs[dest].push(task, mu.rate(ttype, dest), now);
        state.inc(ttype, dest);
    }
    trace
}

fn equiv_cfg(dist: Distribution, discipline: Discipline, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::paper_default(vec![8, 12]);
    cfg.dist = dist;
    cfg.discipline = discipline;
    cfg.seed = seed;
    cfg.warmup = 100;
    cfg.measure = 1_500;
    cfg
}

#[test]
fn reworked_core_is_trace_identical_to_scalar_reference() {
    // Satellite acceptance gate: the overhauled engine is event-for-event
    // identical to the seed implementation — all three disciplines, two
    // policies (state-target and queue-length driven), two distributions,
    // two seeds.  Continuous size distributions only: under Constant
    // sizes, PS residents can tie exactly on virtual finish time, and the
    // heap resolves ties by arrival seq while the seed's swap_remove'd
    // vec scan resolves them by (scrambled) index — same completion
    // times, types and metrics, but possibly permuted task ids within
    // the tie.
    let mu = workload::paper_two_type_mu();
    let mut arena = SimArena::new();
    for discipline in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
        for kind in [PolicyKind::Cab, PolicyKind::Jsq] {
            for dist in [Distribution::Exponential, Distribution::Uniform] {
                for seed in [7u64, 0xC0FFEE] {
                    let cfg = equiv_cfg(dist, discipline, seed);
                    let reference =
                        run_reference(&mu, &cfg, kind.build().as_mut());
                    let net = ClosedNetwork::new(&mu, cfg.clone()).unwrap();
                    let mut trace = Vec::new();
                    net.run_traced(kind.build().as_mut(), &mut arena, &mut trace)
                        .unwrap();
                    let label = format!(
                        "{} {} {:?} seed={seed}",
                        discipline.name(),
                        kind.name(),
                        dist
                    );
                    assert_eq!(reference.len(), trace.len(), "{label}");
                    for (i, (a, b)) in reference.iter().zip(&trace).enumerate() {
                        assert_eq!(a.id, b.id, "{label}: event {i} task id");
                        assert_eq!(a.proc, b.proc, "{label}: event {i} processor");
                        assert!(
                            (a.time - b.time).abs() < 1e-9,
                            "{label}: event {i} time {} vs {}",
                            a.time,
                            b.time
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_eventq_matches_linear_argmin_on_random_streams() {
    // Satellite acceptance gate: against a mirrored key array, the
    // indexed heap's peek equals the first-minimum linear scan after
    // every random update/remove, including tie keys.
    forall(0xE_4_E_2, 120, |g| {
        let l = g.usize_in(1, 12);
        let mut q = EventQueue::new(l);
        let mut mirror: Vec<Option<f64>> = vec![None; l];
        for step in 0..300 {
            let j = g.usize_in(0, l - 1);
            let key = if g.f64_in(0.0, 1.0) < 0.2 {
                None
            } else {
                // Coarse grid ⇒ frequent exact ties exercise the
                // smaller-index tie-break.
                Some((g.f64_in(0.0, 20.0)).floor())
            };
            q.update(j, key);
            mirror[j] = key;
            let want = mirror
                .iter()
                .enumerate()
                .filter_map(|(jj, k)| k.map(|t| (jj, t)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            if q.peek() != want {
                return Err(format!(
                    "step {step}: heap {:?} vs scan {:?} (mirror {mirror:?})",
                    q.peek(),
                    want
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn traced_run_matches_untraced_metrics() {
    // run_traced is the same simulation plus capture: identical metrics,
    // one trace entry per completion (warm-up included).
    let mu = workload::paper_two_type_mu();
    let cfg = equiv_cfg(Distribution::Exponential, Discipline::Ps, 11);
    let net = ClosedNetwork::new(&mu, cfg.clone()).unwrap();
    let mut arena = SimArena::new();
    let plain = net.run_in(PolicyKind::Cab.build().as_mut(), &mut arena).unwrap();
    let mut trace = Vec::new();
    let traced = net
        .run_traced(PolicyKind::Cab.build().as_mut(), &mut arena, &mut trace)
        .unwrap();
    assert_eq!(trace.len() as u64, cfg.warmup + cfg.measure);
    assert_eq!(plain.throughput.to_bits(), traced.throughput.to_bits());
    assert_eq!(plain.completed, traced.completed);
    // Completion times are non-decreasing.
    for w in trace.windows(2) {
        assert!(w[1].time >= w[0].time - 1e-9);
    }
}
