//! Sharded multi-leader coordination end-to-end — the PR acceptance
//! gates:
//!
//! * the batched warm-start solve (`grin::solve_from_snapshot`) matches
//!   cold-solve quality from arbitrary feasible snapshots;
//! * on stationary load the sharded arm stays within 5% of the
//!   single-leader throughput;
//! * on the three-device-class regime flip the sharded arm beats a
//!   frozen global solve by ≥ 1.1×;
//! * sharded replications are thread-count independent, bit for bit.

use hetsched::model::state::StateMatrix;
use hetsched::model::throughput::x_of_state;
use hetsched::policy::{grin, PolicyKind};
use hetsched::sim::dynamic::{DynamicConfig, Phase, ResolveMode};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::workload::{three_class_flip_scale, three_class_mu};
use hetsched::testkit::forall;

#[test]
fn prop_solve_from_snapshot_matches_cold_solve_quality() {
    // The batched re-solve warm-starts from whatever occupancy the
    // gather assembled: from any feasible snapshot the greedy loop must
    // never regress below the snapshot's own throughput and must stay
    // near the cold (Algorithm-1-seeded) solve's quality.
    forall(911, 80, |g| {
        let mu = g.affinity((2, 4), (2, 4));
        let (k, l) = (mu.types(), mu.procs());
        let pops = g.populations(k, 8);
        let start = g.state(&pops, l);
        let warm = grin::solve_from_snapshot(&mu, &pops, &start).map_err(|e| e.to_string())?;
        let cold = grin::solve(&mu, &pops).map_err(|e| e.to_string())?;
        warm.state.check_populations(&pops).map_err(|e| e.to_string())?;
        if warm.throughput + 1e-9 < x_of_state(&mu, &start) {
            return Err(format!(
                "warm start regressed: {} below snapshot {}",
                warm.throughput,
                x_of_state(&mu, &start)
            ));
        }
        // A different start can land in a different local maximum, but
        // GrIn's single-move maxima are tight (§6 measures 1.6% to the
        // optimum): from any snapshot the warm solve stays within 10%
        // of the cold one.
        if warm.throughput < cold.throughput * 0.9 {
            return Err(format!(
                "warm {} far below cold {}",
                warm.throughput, cold.throughput
            ));
        }
        Ok(())
    });
}

#[test]
fn solve_from_snapshot_rejects_infeasible_snapshots() {
    let mu = three_class_mu();
    let ok = StateMatrix::new(3, 3, vec![8, 0, 0, 0, 8, 0, 0, 0, 8]).unwrap();
    assert!(grin::solve_from_snapshot(&mu, &[8, 8, 8], &ok).is_ok());
    // Wrong populations and wrong shapes are both refused.
    assert!(grin::solve_from_snapshot(&mu, &[8, 8, 9], &ok).is_err());
    let narrow = StateMatrix::zeros(3, 2);
    assert!(grin::solve_from_snapshot(&mu, &[8, 8, 8], &narrow).is_err());
}

/// The three-class drift schedule: one clean phase, then the class
/// affinities rotate (types 0 and 2 swap preferred device classes) for
/// the rest of the run.
fn three_class_flip_phases() -> Vec<Phase> {
    let scale = three_class_flip_scale();
    let mut phases = vec![Phase::new(vec![8, 8, 8], 300, 2_500)];
    for _ in 0..4 {
        phases.push(Phase::new(vec![8, 8, 8], 300, 2_500).with_mu_scale(scale.clone()));
    }
    phases
}

fn cell(mode: ResolveMode, phases: Vec<Phase>, seed: u64) -> DynCell {
    let mut cfg = DynamicConfig::new(phases);
    cfg.resolve = mode;
    cfg.shard.shards = 3; // one shard per device class
    cfg.seed = seed;
    DynCell {
        label: mode.name().to_string(),
        mu: three_class_mu(),
        cfg,
        policy: PolicyKind::GrIn,
    }
}

#[test]
fn sharded_within_5pct_of_single_leader_on_stationary_load() {
    // Acceptance gate 1: on stationary load the two-level (shard →
    // device) deficit steering must hold the same GrIn optimum as the
    // single adaptive leader — within 5% mean throughput over seeded
    // replications.
    let stationary = vec![Phase::new(vec![8, 8, 8], 400, 4_000)];
    let cells = vec![
        cell(ResolveMode::Adaptive, stationary.clone(), 515),
        cell(ResolveMode::Sharded, stationary, 515),
    ];
    let plan = ReplicationPlan { reps: 4, threads: 0, base_seed: 99 };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let (single, sharded) = (&stats[0], &stats[1]);
    assert!(single.mean_x > 0.0 && sharded.mean_x > 0.0);
    assert!(
        sharded.mean_x >= single.mean_x * 0.95,
        "sharded {} vs single-leader {} — more than 5% off on stationary load",
        sharded.mean_x,
        single.mean_x
    );
}

#[test]
fn sharded_beats_frozen_global_solve_on_three_class_regime_flip() {
    // Acceptance gate 2: at k = 3 device classes, the sharded plane
    // (cold-started per-shard estimators + batched GrIn re-solves) must
    // beat a frozen global solve by ≥ 1.1× mean throughput on the
    // regime-flip drift.
    let cells = vec![
        cell(ResolveMode::Static, three_class_flip_phases(), 2031),
        cell(ResolveMode::Sharded, three_class_flip_phases(), 2031),
    ];
    let plan = ReplicationPlan { reps: 3, threads: 0, base_seed: 7 };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let (frozen, sharded) = (&stats[0], &stats[1]);
    assert!(
        sharded.mean_x >= frozen.mean_x * 1.1,
        "sharded {} vs frozen {} — no ≥1.1× adaptation win",
        sharded.mean_x,
        frozen.mean_x
    );
    // The win came from actual batched re-solves, and the frozen arm
    // never re-solved.
    assert!(sharded.mean_resolves >= 1.0, "{}", sharded.mean_resolves);
    assert_eq!(frozen.mean_resolves, 0.0);
}

#[test]
fn sharded_replications_are_thread_count_independent() {
    // PR 2's determinism claim extends to the sharded control plane:
    // identical aggregates regardless of worker count.
    let cells = vec![cell(ResolveMode::Sharded, three_class_flip_phases(), 88)];
    let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 5 };
    let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
    let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
    assert_eq!(one[0].mean_x.to_bits(), four[0].mean_x.to_bits());
    assert_eq!(one[0].ci95_x.to_bits(), four[0].ci95_x.to_bits());
}
