//! Priority/deadline subsystem end-to-end gates.
//!
//! The canned `priority_mix` scenario runs two priority tiers whose
//! offered load flips mid-run over the contended-fast-device matrix
//! ([`workload::priority_mu`]): both classes prefer P1, so the
//! unweighted optimum crowds the low-priority majority onto it and
//! dilutes the high-priority class, while the 4:1 weighted solve
//! reserves P1 — at a small, bounded total-throughput cost.  Gates:
//!
//! * equal-priority weighted solve ≡ unweighted solve (≤ 1e-9);
//! * weighted evaluator ≡ unweighted evaluator at unit weights across
//!   random k×l instances;
//! * on the flip scenario, priority-aware adaptive ≥ 1.15× the
//!   high-priority-class throughput of unweighted adaptive at ≤ 5%
//!   total-throughput cost — in both single-leader and sharded modes;
//! * high-priority deadline-miss rate strictly below unweighted;
//! * the priority arm replicates bit-identically across thread counts.

use hetsched::model::affinity::AffinityMatrix;
use hetsched::model::state::StateMatrix;
use hetsched::model::throughput::{x_of_state, IncrementalX, WeightedIncrementalX};
use hetsched::policy::grin;
use hetsched::policy::PolicyKind;
use hetsched::sim::dynamic::{
    run_dynamic_report, DynamicConfig, DynamicReport, ResolveMode,
};
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::rng::Rng;
use hetsched::sim::workload::{self, scenario_phases, ScenarioKind, ScenarioParams};

/// The gate scenario: 4 phases of the canned priority_mix flip
/// ((4, 16) → (16, 4) at the midpoint) with a 1-second soft deadline on
/// the high-priority class.  The drift threshold is raised so estimator
/// sampling noise cannot flap either arm's target mid-comparison — the
/// axis under test is the weighting, not the change detector.
fn gate_cfg(resolve: ResolveMode, priorities: Vec<u32>) -> DynamicConfig {
    let params = ScenarioParams {
        phases: 4,
        completions: 4_000,
        warmup: 400,
        ..Default::default()
    };
    let mut cfg =
        DynamicConfig::new(scenario_phases(ScenarioKind::PriorityMix, &params).unwrap());
    cfg.resolve = resolve;
    cfg.seed = 0x9817;
    cfg.drift.threshold = 0.4;
    cfg.shard.shards = 2;
    cfg.shard.sync_every = 250;
    cfg.priorities = priorities;
    cfg.deadlines = vec![1.0, 0.0];
    cfg
}

fn run_gate(resolve: ResolveMode, priorities: Vec<u32>) -> DynamicReport {
    let mu = workload::priority_mu();
    let cfg = gate_cfg(resolve, priorities);
    let mut policy = PolicyKind::GrIn.build();
    run_dynamic_report(&mu, &cfg, policy.as_mut()).unwrap()
}

/// Weighted vs unweighted gates for one resolve mode.
fn assert_priority_gates(resolve: ResolveMode, label: &str) {
    let unweighted = run_gate(resolve, Vec::new());
    let weighted = run_gate(resolve, vec![4, 1]);
    let (ux, wx) = (unweighted.mean_throughput(), weighted.mean_throughput());
    let (u0, w0) = (unweighted.class_throughput(0), weighted.class_throughput(0));
    assert!(
        w0 >= 1.15 * u0,
        "{label}: high-priority X {w0:.3} < 1.15× unweighted {u0:.3}"
    );
    assert!(
        wx >= 0.95 * ux,
        "{label}: total X {wx:.3} costs more than 5% of unweighted {ux:.3}"
    );
    let (um, wm) = (
        unweighted.deadline_miss_rate(0),
        weighted.deadline_miss_rate(0),
    );
    assert!(
        wm < um,
        "{label}: weighted miss rate {wm:.4} not strictly below unweighted {um:.4}"
    );
    // The low-priority class pays, but keeps flowing.
    assert!(weighted.class_throughput(1) > 0.0);
}

#[test]
fn equal_priority_weighted_solve_matches_unweighted_within_1e9() {
    // Random k×l instances: with all priorities equal (any absolute
    // level) and full confidence, the weighted solve is the unweighted
    // solve — state for state, within 1e-9 on throughput.
    let mut rng = Rng::new(0x0E9A);
    for _ in 0..40 {
        let k = 2 + rng.index(3);
        let l = 2 + rng.index(3);
        let rows: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
            .collect();
        let mu = AffinityMatrix::from_rows(&rows).unwrap();
        let pops: Vec<u32> = (0..k).map(|_| 1 + rng.below(10) as u32).collect();
        let pri = vec![1 + rng.below(6) as u32; k];
        let weights = grin::priority_weights(&pri, &vec![1.0; k * l], l).unwrap();
        let plain = grin::solve(&mu, &pops).unwrap();
        let weighted = grin::solve_weighted(&mu, &pops, &weights).unwrap();
        assert!(
            (plain.throughput - weighted.throughput).abs() < 1e-9,
            "weighted {} vs unweighted {} on a {k}x{l} instance",
            weighted.throughput,
            plain.throughput
        );
        assert_eq!(plain.state, weighted.state);
    }
}

#[test]
fn weighted_evaluator_matches_incremental_x_at_unit_weights() {
    // WeightedIncrementalX with all-ones weights must agree with
    // IncrementalX within 1e-9 (bitwise, in fact) on X and on every
    // move delta, across random k×l instances and random states.
    let mut rng = Rng::new(0x11AC);
    for _ in 0..40 {
        let k = 2 + rng.index(3);
        let l = 2 + rng.index(4);
        let rows: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..l).map(|_| rng.range_f64(0.5, 30.0)).collect())
            .collect();
        let mu = AffinityMatrix::from_rows(&rows).unwrap();
        let mut n = StateMatrix::zeros(k, l);
        for i in 0..k {
            for j in 0..l {
                n.set(i, j, rng.below(5) as u32);
            }
        }
        let inc = IncrementalX::new(&mu, &n);
        let w = WeightedIncrementalX::new(&mu, &n, &vec![1.0; k * l]).unwrap();
        assert!((w.x() - inc.x()).abs() < 1e-9);
        assert!((w.x() - x_of_state(&mu, &n)).abs() < 1e-9);
        let mut wp = vec![0.0f64; l];
        let mut up = vec![0.0f64; l];
        for p in 0..k {
            w.delta_plus_row(p, &mut wp);
            inc.delta_plus_row(p, &mut up);
            for j in 0..l {
                assert!((wp[j] - up[j]).abs() < 1e-9, "Δ+ row {p} col {j}");
                assert!((w.delta_plus(p, j) - inc.delta_plus(p, j)).abs() < 1e-9);
                if n.get(p, j) > 0 {
                    assert!((w.delta_minus(p, j) - inc.delta_minus(p, j)).abs() < 1e-9);
                }
            }
        }
    }
}

#[test]
fn priority_mix_single_leader_beats_unweighted_for_the_high_class() {
    assert_priority_gates(ResolveMode::Adaptive, "single-leader adaptive");
}

#[test]
fn priority_mix_sharded_beats_unweighted_for_the_high_class() {
    assert_priority_gates(ResolveMode::Sharded, "sharded");
}

#[test]
fn priority_arm_replicates_bit_identically_across_thread_counts() {
    // The priority-aware arm through the replication runner: R seeded
    // replications at 1 vs 4 worker threads must agree bit for bit on
    // every aggregate, per-class stats included.
    let cells = vec![DynCell {
        label: "priority".to_string(),
        mu: workload::priority_mu(),
        cfg: {
            let mut cfg = gate_cfg(ResolveMode::Adaptive, vec![4, 1]);
            // Replication-sized runs: the property is determinism, not
            // throughput quality.
            for ph in &mut cfg.phases {
                ph.completions = 600;
                ph.warmup = 60;
            }
            cfg
        },
        policy: PolicyKind::GrIn,
    }];
    let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 0xBEE };
    let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
    let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
    let (a, b) = (&one[0], &four[0]);
    assert_eq!(a.mean_x.to_bits(), b.mean_x.to_bits());
    assert_eq!(a.ci95_x.to_bits(), b.ci95_x.to_bits());
    for (x, y) in a.mean_class_x.iter().zip(&b.mean_class_x) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in a.mean_miss_rate.iter().zip(&b.mean_miss_rate) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert!(a.mean_x > 0.0 && a.mean_class_x[0] > 0.0);
}
