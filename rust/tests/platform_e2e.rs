//! Platform-rig end-to-end: real PJRT kernels on worker threads, measured
//! rates, CAB vs LB — a miniature of Figs. 15–16 (full runs in the bench).
//!
//! Self-skips without built artifacts.

use hetsched::model::affinity::Regime;
use hetsched::platform::bench_rig::{cases, run_platform, PlatformConfig};
use hetsched::platform::{calibrate, measure_rates, Calibration};
use hetsched::policy::PolicyKind;
use hetsched::runtime::ArtifactDir;

fn have_artifacts() -> bool {
    match ArtifactDir::open_default() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping platform e2e: {e}");
            false
        }
    }
}

fn cal() -> Calibration {
    calibrate(3).expect("kernel calibration")
}

#[test]
fn measured_rates_reproduce_the_intended_regime() {
    if !have_artifacts() {
        return;
    }
    // The cap must exceed every non-capped ideal rep count (~40 at the
    // observed sort/nn cost ratio); 96 keeps wall-clock small.
    let devices = cases::p2_biased(&cal(), 96);
    let rates = measure_rates(&devices, 2).unwrap();
    // P2-biased: NN faster than sort on both devices, NN fastest on GPU.
    assert_eq!(
        rates.mu.classify().unwrap(),
        Regime::P2Biased,
        "measured μ = {:?}",
        rates.mu
    );
}

#[test]
fn cab_beats_lb_on_the_platform() {
    if !have_artifacts() {
        return;
    }
    let devices = cases::p2_biased(&cal(), 96);
    let rates = measure_rates(&devices, 2).unwrap();
    let cfg = PlatformConfig {
        devices: devices.clone(),
        populations: vec![6, 6],
        warmup: 12,
        measure: 36,
        seed: 77,
    };
    let run = |kind: PolicyKind| {
        let mut p = kind.build();
        run_platform(&cfg, &rates, p.as_mut()).unwrap()
    };
    let cab = run(PolicyKind::Cab);
    let lb = run(PolicyKind::LoadBalance);
    assert_eq!(cab.completions, 36);
    assert!(cab.checksum_abs_sum.is_finite() && cab.checksum_abs_sum > 0.0);
    assert!(
        cab.throughput > lb.throughput,
        "CAB {} vs LB {} tasks/s — paper reports 3.27×–9.07×",
        cab.throughput,
        lb.throughput
    );
}

#[test]
fn general_symmetric_case_runs_and_cab_picks_bf() {
    if !have_artifacts() {
        return;
    }
    let devices = cases::general_symmetric(&cal(), 96);
    let rates = measure_rates(&devices, 2).unwrap();
    assert_eq!(rates.mu.classify().unwrap(), Regime::GeneralSymmetric);
    let cfg = PlatformConfig {
        devices,
        populations: vec![5, 5],
        warmup: 10,
        measure: 20,
        seed: 78,
    };
    let mut cab = PolicyKind::Cab.build();
    let r = run_platform(&cfg, &rates, cab.as_mut()).unwrap();
    assert_eq!(r.completions, 20);
    assert!(r.throughput > 0.0);
    assert!(r.mean_response_s > 0.0);
}
