//! Adaptive-scheduling end-to-end: the incremental X(S) evaluator
//! matches the full objective, and the on-line estimate-and-re-solve
//! loop demonstrably beats a frozen GrIn solve on non-stationary
//! workloads.

use hetsched::model::affinity::AffinityMatrix;
use hetsched::model::throughput::{x_df_minus, x_df_plus, x_of_state, IncrementalX};
use hetsched::policy::PolicyKind;
use hetsched::sim::dynamic::{
    run_dynamic_report, DynamicConfig, Phase, ResolveMode,
};
use hetsched::sim::workload::{self, scenario_phases, ScenarioKind, ScenarioParams};
use hetsched::testkit::forall;

#[test]
fn prop_incremental_x_matches_full_evaluation_within_1e9() {
    // Satellite acceptance gate: randomized states + random legal move
    // sequences; the cached evaluator must track the full Eq.-28
    // recomputation within 1e-9 at every step, and its O(1) deltas must
    // equal the O(k) reference deltas.
    forall(401, 150, |g| {
        let mu = g.affinity((1, 5), (1, 5));
        let (k, l) = (mu.types(), mu.procs());
        let pops = g.populations(k, 10);
        let mut s = g.state(&pops, l);
        let mut inc = IncrementalX::new(&mu, &s);
        for step in 0..60 {
            // Delta agreement on a random cell.
            let p = g.usize_in(0, k - 1);
            let j = g.usize_in(0, l - 1);
            let want_plus = x_df_plus(&mu, &s, p, j);
            let got_plus = inc.delta_plus(p, j);
            if (want_plus - got_plus).abs() > 1e-9 {
                return Err(format!(
                    "step {step}: Δ+ {got_plus} vs {want_plus} at ({p},{j})"
                ));
            }
            if s.get(p, j) > 0 {
                let want_minus = x_df_minus(&mu, &s, p, j);
                let got_minus = inc.delta_minus(p, j);
                if (want_minus - got_minus).abs() > 1e-9 {
                    return Err(format!(
                        "step {step}: Δ- {got_minus} vs {want_minus} at ({p},{j})"
                    ));
                }
            }
            // Random legal move (needs ≥ 2 processors and an occupied
            // source cell).
            if l < 2 {
                continue;
            }
            let (mut mi, mut mj);
            let mut tries = 0;
            loop {
                mi = g.usize_in(0, k - 1);
                mj = g.usize_in(0, l - 1);
                if s.get(mi, mj) > 0 {
                    break;
                }
                tries += 1;
                if tries > 200 {
                    return Err("no occupied cell found".into());
                }
            }
            let mut to = g.usize_in(0, l - 1);
            if to == mj {
                to = (to + 1) % l;
            }
            s.move_task(mi, mj, to).map_err(|e| e.to_string())?;
            inc.apply_move(mi, mj, to);
            let full = x_of_state(&mu, &s);
            if (inc.x() - full).abs() > 1e-9 {
                return Err(format!(
                    "step {step}: incremental {} vs full {full}",
                    inc.x()
                ));
            }
        }
        Ok(())
    });
}

/// The drift schedule used by the headline comparison: one clean phase,
/// then the affinity matrix flips regime (the paper's P1-biased matrix
/// drifts into a P2-biased one) for the rest of the run.
fn regime_flip_phases() -> Vec<Phase> {
    let drift = vec![0.4, 0.2, 5.0, 2.5];
    let mut phases = vec![Phase::new(vec![10, 10], 300, 2_500)];
    for _ in 0..4 {
        phases.push(
            Phase::new(vec![10, 10], 300, 2_500).with_mu_scale(drift.clone()),
        );
    }
    phases
}

#[test]
fn adaptive_resolve_beats_static_grin_on_regime_flip() {
    // PR acceptance criterion: the adaptive estimate-and-re-solve loop
    // must demonstrably beat a frozen GrIn solve on a non-stationary
    // scenario, using only observed service times (no oracle rates).
    let mu = workload::paper_two_type_mu();
    let run = |mode: ResolveMode| {
        let mut cfg = DynamicConfig::new(regime_flip_phases());
        cfg.seed = 2027;
        cfg.resolve = mode;
        let mut p = PolicyKind::GrIn.build();
        run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap()
    };
    let frozen = run(ResolveMode::Static);
    let adaptive = run(ResolveMode::Adaptive);
    let oracle = run(ResolveMode::EveryPhase);

    // Clean phase: all three agree (same solve, same seed).
    let x0 = frozen.phases[0].throughput;
    assert!((adaptive.phases[0].throughput - x0).abs() / x0 < 0.05);

    // Once the regime has flipped and the estimator has locked on
    // (phases 2+), adaptive clearly beats frozen...
    for i in 2..5 {
        let a = adaptive.phases[i].throughput;
        let f = frozen.phases[i].throughput;
        assert!(
            a > f * 1.2,
            "phase {i}: adaptive {a} vs frozen {f} — no adaptation win"
        );
        // ...while never beating the oracle by more than noise.
        let o = oracle.phases[i].throughput;
        assert!(a <= o * 1.05, "phase {i}: adaptive {a} above oracle {o}");
    }
    assert!(
        adaptive.mean_throughput() > frozen.mean_throughput() * 1.1,
        "overall: adaptive {} vs frozen {}",
        adaptive.mean_throughput(),
        frozen.mean_throughput()
    );
    // The win came from actual drift-triggered re-solves.
    assert!(adaptive.resolves >= 1);
    assert_eq!(frozen.resolves, 0);
}

#[test]
fn canned_scenarios_run_under_every_resolve_mode() {
    // Smoke the whole scenario surface: 3 kinds × 3 modes, shrunk.
    let mu = workload::paper_two_type_mu();
    let params = ScenarioParams {
        phases: 3,
        completions: 400,
        warmup: 50,
        ..Default::default()
    };
    for kind in ScenarioKind::all() {
        let phases = scenario_phases(kind, &params).unwrap();
        for mode in [ResolveMode::Static, ResolveMode::EveryPhase, ResolveMode::Adaptive] {
            let mut cfg = DynamicConfig::new(phases.clone());
            cfg.resolve = mode;
            cfg.seed = 77;
            let mut p = PolicyKind::GrIn.build();
            let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
            assert_eq!(report.phases.len(), 3, "{kind:?} {mode:?}");
            for r in &report.phases {
                assert!(r.throughput > 0.0, "{kind:?} {mode:?}");
            }
        }
    }
}

#[test]
fn grin_incremental_solve_matches_exhaustive_on_drifted_matrices() {
    // The incremental-evaluator rewiring must not change GrIn's
    // solution quality on the drifted (regime-flipped) matrices the
    // adaptive loop feeds it.
    use hetsched::solver::exhaustive::ExhaustiveSolver;
    let base = workload::paper_two_type_mu();
    for scale in [
        vec![1.0, 1.0],
        vec![0.1, 1.0],
        vec![0.4, 0.2, 5.0, 2.5],
        vec![2.0, 0.5, 0.25, 4.0],
    ] {
        let mu = base.scaled(&scale).unwrap();
        let pops = [7u32, 9];
        let g = hetsched::policy::grin::solve(&mu, &pops).unwrap();
        let opt = ExhaustiveSolver.solve(&mu, &pops).unwrap();
        assert!(
            g.throughput <= opt.throughput + 1e-9,
            "GrIn above Opt on {scale:?}"
        );
        assert!(
            g.throughput >= opt.throughput * 0.97,
            "GrIn {} far from Opt {} on {scale:?}",
            g.throughput,
            opt.throughput
        );
        assert!((x_of_state(&mu, &g.state) - g.throughput).abs() < 1e-9);
    }
}

#[test]
fn estimator_tracks_regime_flip_in_isolation() {
    // Unit-level mirror of the e2e story: feed the estimator the
    // service times of the flipped matrix and check μ̂ crosses over.
    use hetsched::coordinator::RateEstimator;
    let base = workload::paper_two_type_mu();
    let flipped = base.scaled(&[0.4, 0.2, 5.0, 2.5]).unwrap();
    let mut est = RateEstimator::new(&base, 0.05, 64, 8).unwrap();
    for _ in 0..200 {
        for i in 0..2 {
            for j in 0..2 {
                est.observe(i, j, 1.0 / flipped.rate(i, j));
            }
        }
    }
    let mu_hat = est.mu_hat().unwrap();
    for i in 0..2 {
        for j in 0..2 {
            let rel = (mu_hat.rate(i, j) - flipped.rate(i, j)).abs() / flipped.rate(i, j);
            assert!(rel < 0.01, "μ̂({i},{j}) = {}", mu_hat.rate(i, j));
        }
    }
    assert!(est.drift(&base) > 0.5);
    assert!(est.drift(&flipped) < 0.01);
}

#[test]
fn affinity_matrix_is_mu_after_flip() {
    // Guard the numbers the headline test's margins are computed from:
    // the canned drift really lands on [[8, 3], [15, 20]].
    let mu = workload::paper_two_type_mu().scaled(&[0.4, 0.2, 5.0, 2.5]).unwrap();
    let want = AffinityMatrix::two_type(8.0, 3.0, 15.0, 20.0).unwrap();
    for i in 0..2 {
        for j in 0..2 {
            assert!((mu.rate(i, j) - want.rate(i, j)).abs() < 1e-12);
        }
    }
}
