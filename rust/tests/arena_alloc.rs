//! Arena-reuse allocation gate: after warm-up, a replication through a
//! reused [`SimArena`] causes **zero net heap growth** — processors,
//! programs, work buffers, the event heap and the metrics accumulator
//! are all allocated once and reset between runs.
//!
//! Measured with a counting global allocator (this integration test is
//! its own binary, so the allocator override is local to it).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicIsize, Ordering};

use hetsched::policy::PolicyKind;
use hetsched::sim::engine::{ClosedNetwork, SimArena, SimConfig};
use hetsched::sim::processor::Discipline;
use hetsched::sim::workload;

/// Net live bytes (alloc − dealloc) since process start.
static NET_BYTES: AtomicIsize = AtomicIsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            NET_BYTES.fetch_add(layout.size() as isize, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        NET_BYTES.fetch_sub(layout.size() as isize, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            NET_BYTES.fetch_add(new_size as isize - layout.size() as isize, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn one_replication(arena: &mut SimArena, seed: u64, discipline: Discipline) -> f64 {
    let mu = workload::paper_two_type_mu();
    let mut cfg = SimConfig::paper_default(vec![10, 10]);
    cfg.discipline = discipline;
    cfg.warmup = 200;
    cfg.measure = 3_000;
    cfg.seed = seed;
    let net = ClosedNetwork::new(&mu, cfg).unwrap();
    let mut policy = PolicyKind::Cab.build();
    let r = net.run_in(policy.as_mut(), arena).unwrap();
    r.throughput
}

#[test]
fn warm_arena_replications_cause_zero_net_heap_growth() {
    let mut arena = SimArena::new();
    // Warm-up: grow every arena capacity to its steady state — touch all
    // three disciplines, then run the exact replication set once so the
    // measured pass can need no new capacity high-water mark.
    for (i, d) in [Discipline::Fcfs, Discipline::Lcfs].into_iter().enumerate() {
        let x = one_replication(&mut arena, 100 + i as u64, d);
        assert!(x > 0.0);
    }
    for rep in 0..8u64 {
        one_replication(&mut arena, 200 + rep, Discipline::Ps);
    }

    let before = NET_BYTES.load(Ordering::Relaxed);
    let mut acc = 0.0;
    for rep in 0..8u64 {
        acc += one_replication(&mut arena, 200 + rep, Discipline::Ps);
    }
    let after = NET_BYTES.load(Ordering::Relaxed);
    assert!(acc > 0.0);

    let growth = after - before;
    // Every per-replication allocation (policy box, result vectors) must
    // be transient: zero net growth across 8 warm replications.
    assert!(
        growth <= 0,
        "warm replications grew the heap by {growth} bytes"
    );
}
