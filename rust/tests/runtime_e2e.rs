//! Runtime layer end-to-end: every shipped artifact loads, compiles and
//! produces numbers that match the Rust-side oracles.
//!
//! These tests self-skip when `make artifacts` has not run; `make test`
//! always builds artifacts first.

use hetsched::model::affinity::AffinityMatrix;
use hetsched::model::state::StateMatrix;
use hetsched::model::throughput::x_of_state;
use hetsched::runtime::{ArtifactDir, Engine};
use hetsched::sim::rng::Rng;

fn engine() -> Option<Engine> {
    match ArtifactDir::open_default() {
        Ok(a) => Some(Engine::new(a).expect("pjrt cpu client")),
        Err(e) => {
            eprintln!("skipping runtime e2e: {e}");
            None
        }
    }
}

#[test]
fn every_manifest_entry_compiles() {
    let Some(eng) = engine() else { return };
    let art = ArtifactDir::open_default().unwrap();
    assert!(art.entries().len() >= 5, "expected the 5 shipped entries");
    for e in art.entries() {
        // Compiling happens lazily on first run; probe with zero inputs.
        let zero_args: Vec<Vec<f32>> =
            (0..e.arg_shapes.len()).map(|i| vec![0f32; e.arg_elems(i)]).collect();
        let refs: Vec<&[f32]> = zero_args.iter().map(|v| v.as_slice()).collect();
        let outs = eng.run_f32(&e.name, &refs).unwrap_or_else(|err| {
            panic!("entry {} failed: {err}", e.name);
        });
        assert_eq!(outs.len(), e.out_arity, "{}", e.name);
    }
}

#[test]
fn nn2000_matches_rust_matmul_oracle() {
    let Some(eng) = engine() else { return };
    // Small structured case: w = columnwise constant, so
    // y[r, c] = relu(sum(x[r,:])·w_c + b_c) is easy to compute exactly.
    let (m, k, n) = (32usize, 2048usize, 256usize);
    let mut rng = Rng::new(404);
    let x: Vec<f32> = (0..m * k).map(|_| rng.range_f64(-0.01, 0.01) as f32).collect();
    let mut w = vec![0f32; k * n];
    for kk in 0..k {
        for c in 0..n {
            w[kk * n + c] = (c as f32 - 128.0) * 1e-3;
        }
    }
    let b = vec![0.05f32; n];
    let r = eng.nn_task("nn2000", &x, &w, &b).unwrap();
    // Oracle checksum.
    let mut want = 0f64;
    for row in 0..m {
        let s: f64 = x[row * k..(row + 1) * k].iter().map(|&v| v as f64).sum();
        for c in 0..n {
            let y = s * ((c as f64 - 128.0) * 1e-3) + 0.05;
            if y > 0.0 {
                want += y;
            }
        }
    }
    let got = r.checksum as f64;
    assert!(
        (got - want).abs() / want.abs().max(1.0) < 1e-3,
        "checksum {got} vs oracle {want}"
    );
}

#[test]
fn sort_large_sorts_random_rows() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(505);
    let rows: Vec<f32> = (0..16 * 1024).map(|_| rng.range_f64(-100.0, 100.0) as f32).collect();
    let out = eng.sort_task("sort_large", &rows).unwrap();
    for r in 0..16 {
        let row = &out.rows[r * 1024..(r + 1) * 1024];
        assert!(row.windows(2).all(|w| w[0] <= w[1]), "row {r} unsorted");
        // Same multiset as the input row.
        let mut want: Vec<f32> = rows[r * 1024..(r + 1) * 1024].to_vec();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(row, &want[..], "row {r} is not a permutation");
    }
}

#[test]
fn batched_exhaustive_via_pjrt_matches_scalar() {
    // The L1 `throughput_eval` kernel driving the L3 exhaustive solver:
    // the full three-layer integration in one assert.
    let Some(eng) = engine() else { return };
    let mu = AffinityMatrix::from_rows(&[
        vec![10.0, 2.0, 4.0],
        vec![1.0, 8.0, 3.0],
        vec![6.0, 6.0, 9.0],
    ])
    .unwrap();
    let pops = [4u32, 3, 3];
    let (kp, lp, bsz) = (16usize, 16usize, 4096usize);
    let mut mu_p = vec![0f32; kp * lp];
    for i in 0..3 {
        for j in 0..3 {
            mu_p[i * lp + j] = mu.rate(i, j) as f32;
        }
    }
    let scalar = hetsched::solver::exhaustive::ExhaustiveSolver
        .solve(&mu, &pops)
        .unwrap();
    let batched = hetsched::solver::exhaustive::ExhaustiveSolver
        .solve_batched(&mu, &pops, bsz, kp, lp, |buf| {
            eng.throughput_batch(&mu_p, buf)
        })
        .unwrap();
    assert_eq!(batched.evaluated, scalar.evaluated);
    let rel = (batched.throughput - scalar.throughput).abs() / scalar.throughput;
    assert!(rel < 1e-4, "pjrt {} vs rust {}", batched.throughput, scalar.throughput);
    // The argmax states agree in throughput (ties possible in state).
    assert!(
        (x_of_state(&mu, &batched.state) - scalar.throughput).abs() / scalar.throughput
            < 1e-4
    );
}

#[test]
fn executable_cache_no_recompile() {
    let Some(eng) = engine() else { return };
    let x = vec![0f32; 8 * 256];
    let w = vec![0f32; 256 * 256];
    let b = vec![0f32; 256];
    // First call compiles…
    let t0 = std::time::Instant::now();
    eng.nn_task("nn_small", &x, &w, &b).unwrap();
    let cold = t0.elapsed();
    // …subsequent calls must be much faster than compile.
    let t1 = std::time::Instant::now();
    for _ in 0..5 {
        eng.nn_task("nn_small", &x, &w, &b).unwrap();
    }
    let warm = t1.elapsed() / 5;
    assert!(
        warm < cold,
        "warm call ({warm:?}) not faster than cold compile+run ({cold:?})"
    );
}

#[test]
fn zero_state_padding_evaluates_to_zero_throughput() {
    let Some(eng) = engine() else { return };
    let (kp, lp, bsz) = (16usize, 16usize, 4096usize);
    let mu_p = vec![1f32; kp * lp];
    let batch = vec![0f32; bsz * kp * lp];
    let xs = eng.throughput_batch(&mu_p, &batch).unwrap();
    assert!(xs.iter().all(|&x| x == 0.0));
    // And a known state evaluates exactly.
    let mut batch = vec![0f32; bsz * kp * lp];
    let s = StateMatrix::new(2, 2, vec![1, 0, 0, 1]).unwrap();
    batch[..kp * lp].copy_from_slice(&s.to_padded_f32(kp, lp).unwrap());
    let xs = eng.throughput_batch(&mu_p, &batch).unwrap();
    assert!((xs[0] - 2.0).abs() < 1e-5); // two singleton queues at rate 1
}
