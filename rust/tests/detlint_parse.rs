//! Golden tests for the detlint parser (`hetsched::analysis::parse`):
//! feed small, syntactically tricky Rust sources through the shared
//! lexer + item parser and assert exact names, spans, and extracted
//! facts.  These are the constructs that break naive token scanners —
//! raw strings, nested generics, closures, lifetimes, cfg-gated items.

use hetsched::analysis::lexer::{lex, Tok};
use hetsched::analysis::parse::{parse_items, Item, ItemKind};

fn parse(src: &str) -> Vec<Item> {
    parse_items(&lex(src).tokens)
}

fn find<'a>(items: &'a [Item], name: &str) -> &'a Item {
    items
        .iter()
        .find(|it| it.name == name)
        .unwrap_or_else(|| panic!("no item named `{name}` in {items:?}"))
}

#[test]
fn raw_strings_do_not_confuse_item_boundaries() {
    // The raw string contains braces, a fake `fn`, and an unbalanced
    // quote — none of which may affect item structure or spans.
    let src = r####"
pub fn before() {
    let s = r#"fn fake() { " unbalanced } }"#;
    let t = "plain \" escaped";
    s.len() + t.len()
}

pub fn after() {}
"####;
    let items = parse(src);
    assert_eq!(items.len(), 2, "{items:?}");
    let before = find(&items, "before");
    assert_eq!((before.line, before.end_line), (2, 6));
    let after = find(&items, "after");
    assert_eq!(after.line, 8);
    // The raw-string *contents* are still available to fact scans
    // (the plumbing check needs string literals), quotes stripped.
    let strs: Vec<String> = lex(src)
        .tokens
        .iter()
        .filter_map(|t| match &t.tok {
            Tok::Str(s) => Some(s.clone()),
            _ => None,
        })
        .collect();
    assert!(strs.iter().any(|s| s.contains("fn fake()")), "{strs:?}");
}

#[test]
fn nested_generics_parse_without_shift_splitting() {
    // `Vec<Arc<Mutex<T>>>` must not lex as `>>` — the field type and
    // the following field must both round-trip exactly.
    let src = "\
pub struct Holder {
    pub slots: Vec<Arc<Mutex<Vec<u64>>>>,
    pub name: String,
}
";
    let items = parse(src);
    let holder = find(&items, "Holder");
    assert_eq!(holder.kind, ItemKind::Struct);
    assert_eq!(holder.fields.len(), 2, "{:?}", holder.fields);
    let slots = &holder.fields[0];
    assert_eq!(slots.name, "slots");
    assert_eq!(slots.line, 2);
    assert!(slots.public);
    assert_eq!(slots.ty.replace(' ', ""), "Vec<Arc<Mutex<Vec<u64>>>>");
    assert_eq!(holder.fields[1].name, "name");
    assert_eq!(holder.fields[1].line, 3);
}

#[test]
fn closures_and_lifetimes_stay_inside_their_fn() {
    let src = "\
pub fn outer<'a>(xs: &'a [u64]) -> u64 {
    let f = |x: &u64| -> u64 { x.wrapping_add(1) };
    xs.iter().map(|x| f(x)).sum::<u64>()
}

pub struct After<'a> {
    pub r: &'a str,
}
";
    let items = parse(src);
    // The closure bodies must not open new items or shift spans.
    assert_eq!(items.len(), 2, "{items:?}");
    let outer = find(&items, "outer");
    assert_eq!(outer.kind, ItemKind::Fn);
    assert_eq!((outer.line, outer.end_line), (1, 4));
    let body = outer.body.as_ref().expect("fn body");
    // Method facts from inside the chain survive the closure args.
    assert!(body.methods.iter().any(|m| m.name == "sum" && m.turbofish == "u64"));
    assert!(body.methods.iter().any(|m| m.name == "map"));
    let after = find(&items, "After");
    assert_eq!(after.kind, ItemKind::Struct);
    assert_eq!(after.line, 6);
    assert_eq!(after.fields[0].name, "r");
}

#[test]
fn cfg_gated_items_carry_their_predicate() {
    let src = "\
#[cfg(test)]
mod tests {
    pub fn helper() {}
}

#[cfg(feature = \"model\")]
pub fn model_only() {}

#[cfg(not(feature = \"model\"))]
pub fn default_only() {}

pub fn always() {}
";
    let items = parse(src);
    assert_eq!(items.len(), 4, "{items:?}");
    let tests = find(&items, "tests");
    assert_eq!(tests.kind, ItemKind::Mod);
    assert_eq!(tests.cfg, vec!["test".to_string()]);
    assert_eq!(tests.children.len(), 1);
    assert_eq!(tests.children[0].name, "helper");
    let model = find(&items, "model_only");
    assert_eq!(model.cfg, vec!["feature = \"model\"".to_string()]);
    let not_model = find(&items, "default_only");
    assert_eq!(not_model.cfg, vec!["not ( feature = \"model\" )".to_string()]);
    assert!(find(&items, "always").cfg.is_empty());
}

#[test]
fn impl_blocks_round_trip_names_and_traits() {
    let src = "\
impl Engine {
    pub fn run(&mut self) -> u64 { self.step() }
    fn step(&mut self) -> u64 { 0 }
}

impl Iterator for Queue {
    type Item = u64;
    fn next(&mut self) -> Option<u64> { None }
}
";
    let items = parse(src);
    let engine = &items[0];
    assert_eq!(engine.kind, ItemKind::Impl);
    assert_eq!(engine.name, "Engine");
    assert_eq!(engine.trait_name, None);
    let names: Vec<&str> =
        engine.children.iter().map(|c| c.name.as_str()).collect();
    assert_eq!(names, ["run", "step"]);
    assert_eq!(engine.children[0].line, 2);
    let iter = &items[1];
    assert_eq!(iter.name, "Queue");
    assert_eq!(iter.trait_name.as_deref(), Some("Iterator"));
    assert!(iter.children.iter().any(|c| c.name == "next"));
}

#[test]
fn body_facts_have_exact_spans() {
    let src = "\
pub fn work(m: &std::collections::HashMap<u64, f64>, v: &[f64]) -> f64 {
    let first = v[0];
    let small = first as u32;
    for (k, x) in m.iter() {
        log::note(*k);
    }
    first + small as f64
}
";
    let items = parse(src);
    let body = find(&items, "work").body.as_ref().expect("body");
    assert_eq!(body.indexes, vec![2], "{:?}", body.indexes);
    assert_eq!(body.casts.len(), 2);
    assert_eq!((body.casts[0].to.as_str(), body.casts[0].line), ("u32", 3));
    let it = body
        .methods
        .iter()
        .find(|m| m.name == "iter")
        .expect("iter fact");
    assert_eq!((it.base.as_str(), it.line), ("m", 4));
    assert_eq!(body.loops.len(), 1);
    assert_eq!(body.loops[0].line, 4);
    // The HashMap-typed parameter is recognized as a hash local.
    assert!(body.hash_locals.contains(&"m".to_string()), "{:?}", body.hash_locals);
}
