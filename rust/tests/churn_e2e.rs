//! Fault-tolerant serving under device churn — the PR acceptance gates:
//!
//! * **conservation gate** (property test): under random fault plans —
//!   outages with recovery and limp windows — every run completes its
//!   configured completion count with zero lost tasks, across seeds,
//!   all three service disciplines and all single-leader resolve modes;
//! * **margin gate**: on the churn scenario the churn-aware adaptive
//!   and sharded control planes stay within 15% of the
//!   failure-schedule oracle and beat the frozen-target baseline by
//!   ≥ 1.2×;
//! * **limp gate**: a slow-node degradation is never signalled — the
//!   per-cell CUSUM must detect it and the re-solve must steer around
//!   the limping device;
//! * **determinism gate**: churn-cell replications aggregate
//!   bit-identically regardless of worker thread count;
//! * **no-capacity gate**: a fleet with every device down and no
//!   recovery scheduled degrades to a typed [`Error::NoCapacity`],
//!   never a panic or a hang.

use hetsched::error::Error;
use hetsched::model::affinity::AffinityMatrix;
use hetsched::policy::PolicyKind;
use hetsched::sim::dynamic::{
    run_dynamic_report, DynamicConfig, FaultEvent, FaultKind, FaultPlan, Phase,
    ResolveMode, Trigger,
};
use hetsched::sim::processor::Discipline;
use hetsched::sim::replicate::{run_dynamic_cells, DynCell, ReplicationPlan};
use hetsched::sim::workload::{
    self, churn_fault_plan, scenario_phases, ScenarioKind, ScenarioParams,
};
use hetsched::testkit::forall;

/// A fleet where churn-aware re-solves matter: device 0 is fast for
/// both classes, but the clean optimum keeps only a sliver of class-0
/// work there (mixing the near-stalled class 1 into device 1's queue
/// costs less than idling device 0).  When device 0 limps, the optimal
/// response is a full swap — class 0 evacuates to device 1, class 1
/// hides on the crippled device — which a frozen target never finds.
fn churn_sensitive_mu() -> AffinityMatrix {
    AffinityMatrix::two_type(30.0, 22.0, 1.0, 2.0).unwrap()
}

fn churn_params() -> ScenarioParams {
    ScenarioParams {
        phases: 5,
        completions: 2_500,
        warmup: 300,
        churn_down: 0.3,
        churn_limp: 0.1,
        backup_budget: 4,
        ..Default::default()
    }
}

fn churn_cell(label: &str, resolve: ResolveMode, params: &ScenarioParams) -> DynCell {
    let mu = churn_sensitive_mu();
    let mut cfg =
        DynamicConfig::new(scenario_phases(ScenarioKind::Churn, params).unwrap());
    cfg.resolve = resolve;
    cfg.drift.trigger = Trigger::Cusum;
    cfg.seed = 0xC1C;
    cfg.faults = churn_fault_plan(&mu, params).unwrap();
    DynCell { label: label.to_string(), mu, cfg, policy: PolicyKind::GrIn }
}

#[test]
fn prop_no_task_lost_under_random_fault_plans() {
    // Conservation gate: completions = arrivals − residue, i.e. the
    // run-end residual `tasks_lost` is zero and every phase delivers
    // exactly its configured completion count, for random fleets ×
    // random failure/recovery schedules × {PS, FCFS, LCFS} × every
    // single-leader resolve mode.
    forall(0xFA17, 15, |g| {
        let mu = g.affinity((2, 3), (2, 3));
        let l = mu.procs();
        let populations = g.populations(mu.types(), 6);
        let phases =
            vec![Phase::new(populations.clone(), 40, 150), Phase::new(populations, 40, 150)];

        // Sequential non-overlapping fault windows (at most one device
        // degraded at a time, so survivors always exist), each either a
        // full outage with recovery or a limp/restore pair, placed via
        // the optimistic wall-clock estimate so they land mid-run.
        let x_ub: f64 = (0..l)
            .map(|j| {
                (0..mu.types())
                    .map(|i| mu.rate(i, j))
                    .fold(f64::NEG_INFINITY, f64::max)
            })
            .sum();
        let t_total = (2 * (40 + 150)) as f64 / x_ub;
        let mut events = Vec::new();
        let mut cursor = 0.05 * t_total;
        for _ in 0..3 {
            let start = cursor + g.f64_in(0.0, 0.05) * t_total;
            let end = start + g.f64_in(0.05, 0.20) * t_total;
            let device = g.usize_in(0, l - 1);
            if g.usize_in(0, 1) == 0 {
                events.push(FaultEvent { time: start, device, kind: FaultKind::Down });
                events.push(FaultEvent { time: end, device, kind: FaultKind::Up });
            } else {
                let factor = g.f64_in(0.05, 0.5);
                events.push(FaultEvent {
                    time: start,
                    device,
                    kind: FaultKind::Limp(factor),
                });
                events.push(FaultEvent { time: end, device, kind: FaultKind::Limp(1.0) });
            }
            cursor = end + 0.02 * t_total;
        }
        let plan = FaultPlan { events, backup_budget: g.u32_in(0, 3) };
        plan.validate(l).map_err(|e| e.to_string())?;

        let resolve = [ResolveMode::Static, ResolveMode::EveryPhase, ResolveMode::Adaptive]
            [g.usize_in(0, 2)];
        let seed = g.u32_in(1, 1 << 30) as u64;
        for discipline in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut cfg = DynamicConfig::new(phases.clone());
            cfg.discipline = discipline;
            cfg.resolve = resolve;
            cfg.seed = seed;
            cfg.faults = plan.clone();
            let mut p = PolicyKind::GrIn.build();
            let report = run_dynamic_report(&mu, &cfg, p.as_mut())
                .map_err(|e| format!("{discipline:?}/{resolve:?}: {e}"))?;
            if report.tasks_lost != 0 {
                return Err(format!(
                    "{discipline:?}/{resolve:?}: lost {} task(s) under {:?}",
                    report.tasks_lost, plan
                ));
            }
            for (i, r) in report.phases.iter().enumerate() {
                if r.completed != 150 {
                    return Err(format!(
                        "{discipline:?}/{resolve:?}: phase {i} completed {} ≠ 150",
                        r.completed
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn churn_aware_control_tracks_oracle_and_beats_frozen() {
    // Margin gate: frozen / adaptive / sharded / oracle on the same
    // churn schedule.  `run_dynamic_cells` hard-errors if any
    // replication loses a task, so the unwrap doubles as the zero-loss
    // assertion for every arm.
    let params = churn_params();
    let cells = vec![
        churn_cell("frozen", ResolveMode::Static, &params),
        churn_cell("adaptive", ResolveMode::Adaptive, &params),
        churn_cell("sharded", ResolveMode::Sharded, &params),
        churn_cell("oracle", ResolveMode::EveryPhase, &params),
    ];
    let plan = ReplicationPlan { reps: 3, threads: 0, base_seed: 0xFA11 };
    let stats = run_dynamic_cells(&cells, &plan).unwrap();
    let (frozen, adaptive, sharded, oracle) =
        (&stats[0], &stats[1], &stats[2], &stats[3]);

    for (name, arm) in [("adaptive", adaptive), ("sharded", sharded)] {
        assert!(
            arm.mean_x >= 0.85 * oracle.mean_x,
            "{name} {} vs oracle {} — more than 15% behind the \
             failure-schedule oracle",
            arm.mean_x,
            oracle.mean_x
        );
        assert!(
            arm.mean_x >= 1.2 * frozen.mean_x,
            "{name} {} vs frozen {} — no ≥1.2× churn-adaptation win",
            arm.mean_x,
            frozen.mean_x
        );
    }
    // The win came from actual churn reactions: the frozen arm never
    // re-solved, the adaptive arm did, and outages forced re-dispatch
    // and metered downtime on every arm.
    assert_eq!(frozen.mean_resolves, 0.0);
    assert!(adaptive.mean_resolves >= 1.0, "{}", adaptive.mean_resolves);
    assert!(adaptive.mean_redispatched > 0.0, "no task was ever evacuated");
    for arm in &stats {
        assert!(
            arm.mean_downtime_frac > 0.0,
            "{}: outages scheduled but no downtime metered",
            arm.label
        );
    }
}

#[test]
fn cusum_detects_and_steers_around_a_limping_device() {
    // Limp gate: the degradation is deliberately *not* signalled to the
    // control plane — a permanent 10× slow-down of device 0 must be
    // caught by the per-cell CUSUM (resolves ≥ 1) and steered around
    // (≥ 1.2× the frozen throughput).  Limp never evacuates anything,
    // so the re-dispatch counter stays zero.
    let mu = churn_sensitive_mu();
    let faults = FaultPlan::parse_spec("limp:0x0.1@20").unwrap();
    let run = |resolve: ResolveMode| {
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 300, 6_000)]);
        cfg.resolve = resolve;
        cfg.drift.trigger = Trigger::Cusum;
        cfg.seed = 71;
        cfg.faults = faults.clone();
        let mut p = PolicyKind::GrIn.build();
        run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap()
    };
    let frozen = run(ResolveMode::Static);
    let adaptive = run(ResolveMode::Adaptive);
    assert_eq!(frozen.resolves, 0);
    assert!(
        adaptive.resolves >= 1,
        "CUSUM never fired on a 10× limped device"
    );
    assert!(
        adaptive.mean_throughput() >= 1.2 * frozen.mean_throughput(),
        "adaptive {} vs frozen {} — limp detected but not steered around",
        adaptive.mean_throughput(),
        frozen.mean_throughput()
    );
    for r in [&frozen, &adaptive] {
        assert_eq!(r.tasks_lost, 0);
        assert_eq!(r.tasks_redispatched, 0, "limp must not evacuate tasks");
    }
}

#[test]
fn churn_replications_are_thread_count_independent() {
    // Determinism gate: slot-addressed replication keeps churn-cell
    // aggregates — throughput, re-dispatch and downtime metering —
    // bit-identical across worker thread counts.
    let params = ScenarioParams {
        phases: 3,
        completions: 800,
        warmup: 100,
        ..churn_params()
    };
    let cells = vec![
        churn_cell("adaptive", ResolveMode::Adaptive, &params),
        churn_cell("sharded", ResolveMode::Sharded, &params),
    ];
    let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 5 };
    let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
    let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.mean_x.to_bits(), b.mean_x.to_bits(), "{}", a.label);
        assert_eq!(a.ci95_x.to_bits(), b.ci95_x.to_bits(), "{}", a.label);
        assert_eq!(
            a.mean_redispatched.to_bits(),
            b.mean_redispatched.to_bits(),
            "{}",
            a.label
        );
        assert_eq!(
            a.mean_downtime_frac.to_bits(),
            b.mean_downtime_frac.to_bits(),
            "{}",
            a.label
        );
    }
    // The schedule actually exercised the fault machinery.
    assert!(one[0].mean_downtime_frac > 0.0);
}

#[test]
fn all_devices_down_degrades_to_a_typed_error() {
    // No-capacity gate: both devices fail with no recovery scheduled.
    // Every resolve mode must surface `Error::NoCapacity` — not panic,
    // not spin on an empty event queue.
    let mu = workload::paper_two_type_mu();
    let faults = FaultPlan::parse_spec("down:0@1;down:1@1").unwrap();
    for resolve in [
        ResolveMode::Static,
        ResolveMode::EveryPhase,
        ResolveMode::Adaptive,
        ResolveMode::Sharded,
    ] {
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![5, 5], 0, 500)]);
        cfg.resolve = resolve;
        cfg.seed = 9;
        cfg.faults = faults.clone();
        let mut p = PolicyKind::GrIn.build();
        match run_dynamic_report(&mu, &cfg, p.as_mut()) {
            Err(Error::NoCapacity(_)) => {}
            other => panic!("{resolve:?}: expected NoCapacity, got {other:?}"),
        }
    }
    // The replication runner propagates the same typed failure.
    let mut cfg = DynamicConfig::new(vec![Phase::new(vec![5, 5], 0, 500)]);
    cfg.resolve = ResolveMode::Static;
    cfg.faults = faults;
    let cells = vec![DynCell {
        label: "doomed".into(),
        mu,
        cfg,
        policy: PolicyKind::GrIn,
    }];
    let plan = ReplicationPlan { reps: 2, threads: 0, base_seed: 1 };
    assert!(run_dynamic_cells(&cells, &plan).is_err());
}
