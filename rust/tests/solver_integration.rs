//! Solver-layer integration: GrIn vs SLSQP vs exhaustive (Figs. 13–14
//! claims at test scale).

use hetsched::policy::grin;
use hetsched::sim::rng::Rng;
use hetsched::sim::workload;
use hetsched::solver::exhaustive::{CompositionIter, ExhaustiveSolver};
use hetsched::solver::slsqp::{x_continuous, Slsqp};

#[test]
fn grin_average_gap_to_opt_is_small() {
    // §4.2 / §6: GrIn within 1.6% of the exhaustive optimum on average
    // over random 3×3 systems.  At test scale we allow a slightly wider
    // budget (fewer samples); the bench reproduces the full 1000-run
    // figure.
    let mut rng = Rng::new(1313);
    let mut gap_sum = 0.0;
    let runs = 60;
    for _ in 0..runs {
        let mu = workload::random_mu(&mut rng, 3, 3, 0.5, 30.0).unwrap();
        let pops = workload::random_populations(&mut rng, 3, 6);
        let opt = ExhaustiveSolver.solve(&mu, &pops).unwrap();
        let g = grin::solve(&mu, &pops).unwrap();
        gap_sum += 1.0 - g.throughput / opt.throughput;
    }
    let avg_gap = gap_sum / runs as f64;
    assert!(avg_gap < 0.03, "average GrIn gap {avg_gap:.4} (paper: 0.016)");
}

#[test]
fn grin_beats_or_matches_slsqp_on_average() {
    // Fig. 13: GrIn's integer solution beats SLSQP's continuous one on
    // average (SLSQP is a local method on a discontinuous objective).
    let mut rng = Rng::new(1414);
    let mut improvements = Vec::new();
    for _ in 0..40 {
        let mu = workload::random_mu(&mut rng, 4, 4, 0.5, 30.0).unwrap();
        let pops = workload::random_populations(&mut rng, 4, 8);
        let g = grin::solve(&mu, &pops).unwrap();
        let s = Slsqp::default().solve(&mu, &pops).unwrap();
        improvements.push(g.throughput / s.throughput - 1.0);
    }
    let avg = improvements.iter().sum::<f64>() / improvements.len() as f64;
    assert!(
        avg > -0.02,
        "GrIn should be ≥ SLSQP on average, got {avg:.4}"
    );
}

#[test]
fn slsqp_solution_is_feasible_and_stationary_ish() {
    let mut rng = Rng::new(1515);
    for _ in 0..20 {
        let mu = workload::random_mu(&mut rng, 3, 4, 0.5, 30.0).unwrap();
        let pops = workload::random_populations(&mut rng, 3, 9);
        let sol = Slsqp::default().solve(&mu, &pops).unwrap();
        // Feasibility.
        let l = mu.procs();
        for (i, &ni) in pops.iter().enumerate() {
            let row: f64 = (0..l).map(|j| sol.n[i * l + j]).sum();
            assert!((row - ni as f64).abs() < 1e-6);
        }
        assert!(sol.n.iter().all(|&v| v >= -1e-9));
        // Objective consistency.
        assert!((x_continuous(&mu, &sol.n) - sol.throughput).abs() < 1e-9);
    }
}

#[test]
fn composition_counts_match_formula() {
    for (total, parts) in [(0u32, 1usize), (5, 1), (4, 3), (10, 4), (20, 2)] {
        let n = CompositionIter::new(total, parts).count() as u128;
        assert_eq!(n, CompositionIter::count(total, parts), "{total} into {parts}");
    }
    // Π over rows.
    assert_eq!(
        ExhaustiveSolver::state_count(&[4, 4], 3),
        CompositionIter::count(4, 3) * CompositionIter::count(4, 3)
    );
}

#[test]
fn exhaustive_is_invariant_to_row_order() {
    let mu_a = workload::random_mu(&mut Rng::new(7), 3, 3, 1.0, 20.0).unwrap();
    // Permute rows (types) — optimum throughput must be identical.
    let rows: Vec<Vec<f64>> = (0..3).map(|i| mu_a.row(i).to_vec()).collect();
    let mu_b = hetsched::model::affinity::AffinityMatrix::from_rows(&[
        rows[2].clone(),
        rows[0].clone(),
        rows[1].clone(),
    ])
    .unwrap();
    let a = ExhaustiveSolver.solve(&mu_a, &[3, 4, 5]).unwrap();
    let b = ExhaustiveSolver.solve(&mu_b, &[5, 3, 4]).unwrap();
    assert!((a.throughput - b.throughput).abs() < 1e-9);
}

#[test]
fn solver_runtime_ordering_grin_faster_than_slsqp() {
    // Fig. 14's *shape* at test scale: GrIn per-solve wall-clock should
    // not exceed SLSQP's on larger systems (GrIn is O(k·l) per move).
    use std::time::Instant;
    let mut rng = Rng::new(1616);
    let mut grin_total = 0.0;
    let mut slsqp_total = 0.0;
    for _ in 0..15 {
        let mu = workload::random_mu(&mut rng, 8, 8, 0.5, 30.0).unwrap();
        let pops = workload::random_populations(&mut rng, 8, 8);
        let t0 = Instant::now();
        let g = grin::solve(&mu, &pops).unwrap();
        grin_total += t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let s = Slsqp::default().solve(&mu, &pops).unwrap();
        slsqp_total += t1.elapsed().as_secs_f64();
        // Keep the comparison honest: both must produce real solutions.
        assert!(g.throughput > 0.0 && s.throughput > 0.0);
    }
    assert!(
        grin_total < slsqp_total,
        "GrIn ({grin_total:.4}s) should be faster than SLSQP ({slsqp_total:.4}s) at 8×8"
    );
}
