//! Property-testing mini-framework (no `proptest` available offline).
//!
//! Seeded generators + a runner that, on failure, re-reports the failing
//! seed/case so runs reproduce exactly.  Shrinking is deliberately simple
//! (halving retries on integer scalars) — enough for the coordinator
//! invariants this crate checks.

pub mod gen;
pub mod prop;

pub use gen::Gen;
pub use prop::forall;
