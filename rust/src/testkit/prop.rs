//! The property runner.
//!
//! `forall(seed, cases, |gen| -> Result<(), String>)` runs `cases`
//! independent generations; on the first failure it re-runs the same case
//! (deterministic by construction) and panics with the seed + case index
//! so the exact counterexample reproduces with
//! `HETSCHED_PROP_SEED=<seed> HETSCHED_PROP_CASE=<idx>`.

use crate::sim::rng::Rng;

use super::gen::Gen;

/// Run a property over `cases` generated cases.
///
/// The property returns `Err(description)` to signal a counterexample.
pub fn forall<F>(seed: u64, cases: u32, mut prop: F)
where
    F: FnMut(&mut Gen<'_>) -> Result<(), String>,
{
    // Environment override for replaying a specific failure.
    let seed = std::env::var("HETSCHED_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(seed);
    let only_case: Option<u32> = std::env::var("HETSCHED_PROP_CASE")
        .ok()
        .and_then(|s| s.parse().ok());

    let mut base = Rng::new(seed);
    for case in 0..cases {
        let mut rng = base.fork(case as u64);
        if let Some(c) = only_case {
            if case != c {
                continue;
            }
        }
        let mut gen = Gen::new(&mut rng);
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property failed at case {case}/{cases} (seed {seed}): {msg}\n\
                 reproduce with HETSCHED_PROP_SEED={seed} HETSCHED_PROP_CASE={case}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        forall(1, 50, |g| {
            let a = g.u32_in(0, 10);
            if a <= 10 {
                Ok(())
            } else {
                Err(format!("{a} > 10"))
            }
        });
    }

    #[test]
    fn reports_counterexample() {
        let r = std::panic::catch_unwind(|| {
            forall(2, 50, |g| {
                let a = g.u32_in(0, 10);
                if a < 10 {
                    Ok(())
                } else {
                    Err(format!("hit {a}"))
                }
            });
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("HETSCHED_PROP_SEED=2"), "{msg}");
        assert!(msg.contains("hit 10"), "{msg}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut first = Vec::new();
        forall(3, 10, |g| {
            first.push(g.u32_in(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        forall(3, 10, |g| {
            second.push(g.u32_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
