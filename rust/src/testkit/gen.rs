//! Seeded random generators for property tests.

use crate::model::affinity::AffinityMatrix;
use crate::model::state::StateMatrix;
use crate::sim::rng::Rng;

/// A generation context bound to one RNG stream.
pub struct Gen<'a> {
    /// Underlying RNG (public so properties can draw ad-hoc values).
    pub rng: &'a mut Rng,
}

impl<'a> Gen<'a> {
    /// Wrap an RNG.
    pub fn new(rng: &'a mut Rng) -> Self {
        Self { rng }
    }

    /// Uniform usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.index(hi - lo + 1)
    }

    /// Uniform u32 in [lo, hi].
    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        // srclint: allow(as-truncation) — below(n) is strictly less than n, which was widened from u32
        lo + self.rng.below((hi - lo + 1) as u64) as u32
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Random affinity matrix with k×l in the given ranges and rates in
    /// [0.5, 30).
    pub fn affinity(&mut self, k: (usize, usize), l: (usize, usize)) -> AffinityMatrix {
        let k = self.usize_in(k.0, k.1);
        let l = self.usize_in(l.0, l.1);
        let rows: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..l).map(|_| self.f64_in(0.5, 30.0)).collect())
            .collect();
        AffinityMatrix::from_rows(&rows).expect("generated rates are valid")
    }

    /// Random 2×2 matrix satisfying the Eq.-2 affinity constraint.
    pub fn affinity_two_type(&mut self) -> AffinityMatrix {
        loop {
            let m12 = self.f64_in(0.5, 20.0);
            let m11 = m12 + self.f64_in(0.1, 20.0); // μ11 > μ12
            let m21 = self.f64_in(0.5, 20.0);
            let m22 = m21 + self.f64_in(0.1, 20.0); // μ22 > μ21
            let m = AffinityMatrix::two_type(m11, m12, m21, m22).expect("valid");
            // Skip the measure-zero b.4 boundary produced by ties.
            if m.classify().is_ok() {
                return m;
            }
        }
    }

    /// Random populations, each in [1, max_per_type].
    pub fn populations(&mut self, k: usize, max_per_type: u32) -> Vec<u32> {
        (0..k).map(|_| self.u32_in(1, max_per_type)).collect()
    }

    /// Random feasible state for the populations.
    pub fn state(&mut self, populations: &[u32], l: usize) -> StateMatrix {
        let k = populations.len();
        let mut s = StateMatrix::zeros(k, l);
        for (i, &ni) in populations.iter().enumerate() {
            for _ in 0..ni {
                let j = self.usize_in(0, l - 1);
                s.inc(i, j);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_structures_satisfy_invariants() {
        let mut rng = Rng::new(3);
        let mut g = Gen::new(&mut rng);
        for _ in 0..50 {
            let mu = g.affinity((1, 4), (1, 5));
            assert!(mu.types() >= 1 && mu.types() <= 4);
            assert!(mu.procs() >= 1 && mu.procs() <= 5);
            let two = g.affinity_two_type();
            assert!(two.satisfies_two_type_affinity());
            let pops = g.populations(3, 9);
            let s = g.state(&pops, 4);
            s.check_populations(&pops).unwrap();
        }
    }

    #[test]
    fn ranges_are_inclusive() {
        let mut rng = Rng::new(4);
        let mut g = Gen::new(&mut rng);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..200 {
            match g.usize_in(1, 3) {
                1 => seen_lo = true,
                3 => seen_hi = true,
                2 => {}
                _ => panic!("out of range"),
            }
        }
        assert!(seen_lo && seen_hi);
    }
}
