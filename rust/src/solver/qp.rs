//! Convex quadratic programming with linear equalities and lower bounds.
//!
//! ```text
//! min ½ dᵀB d + gᵀd   s.t.  A d = c,   d_i ≥ lb_i (i ∈ bounded)
//! ```
//!
//! Solved by the textbook primal active-set method: equality-constrained
//! subproblems via the KKT system
//!
//! ```text
//! [ B  Aᵀ ] [d]   [−g]
//! [ A  0  ] [λ] = [ c ]
//! ```
//!
//! with bound constraints activated/deactivated by multiplier signs.
//! This is the QP engine inside [`super::slsqp`]; problem sizes are k·l
//! variables (≤ a few hundred), so dense LU is the right tool.

// srclint: allow-file(index-reachable) — KKT system blocks are sized n plus m by construction

use crate::error::{Error, Result};

use super::linalg::{dot, Mat};

/// A QP instance.  `lb[i] = f64::NEG_INFINITY` means unbounded below.
#[derive(Debug, Clone)]
pub struct Qp<'a> {
    /// Hessian (symmetric positive definite) — n×n.
    pub b: &'a Mat,
    /// Linear term — length n.
    pub g: &'a [f64],
    /// Equality matrix — m×n (full row rank).
    pub a: &'a Mat,
    /// Equality right-hand side — length m.
    pub c: &'a [f64],
    /// Lower bounds — length n.
    pub lb: &'a [f64],
}

/// Result of a QP solve.
#[derive(Debug, Clone)]
pub struct QpSolution {
    /// Optimal step d.
    pub d: Vec<f64>,
    /// Equality multipliers.
    pub lambda_eq: Vec<f64>,
    /// Active-set iterations used.
    pub iterations: usize,
}

/// Solve the QP starting from the feasible point `d0` (must satisfy
/// `A d0 = c` and `d0 ≥ lb`).
pub fn solve(qp: &Qp<'_>, d0: &[f64]) -> Result<QpSolution> {
    let n = qp.g.len();
    let m = qp.c.len();
    if qp.b.rows != n || qp.b.cols != n || qp.a.rows != m || qp.a.cols != n
        || qp.lb.len() != n || d0.len() != n
    {
        return Err(Error::Shape("QP dimension mismatch".into()));
    }
    let mut d = d0.to_vec();
    // Active bound set.
    let mut active: Vec<bool> = d
        .iter()
        .zip(qp.lb)
        .map(|(&di, &li)| li.is_finite() && (di - li).abs() < 1e-12)
        .collect();

    let max_iter = 25 * (n + 1);
    for it in 0..max_iter {
        // Equality-constrained subproblem at current point: step p with
        //   B p = -(g + B d),  A p = 0,  p_i = 0 for active i.
        let n_act = active.iter().filter(|&&a| a).count();
        let dim = n + m + n_act;
        let mut kkt = Mat::zeros(dim, dim);
        let mut rhs = vec![0.0; dim];
        // Gradient at d: g + B d.
        let bd = qp.b.matvec(&d)?;
        for i in 0..n {
            for j in 0..n {
                kkt[(i, j)] = qp.b[(i, j)];
            }
            rhs[i] = -(qp.g[i] + bd[i]);
        }
        for r in 0..m {
            for j in 0..n {
                kkt[(n + r, j)] = qp.a[(r, j)];
                kkt[(j, n + r)] = qp.a[(r, j)];
            }
            rhs[n + r] = 0.0; // d is feasible ⇒ A p = 0
        }
        let mut row = n + m;
        let mut act_idx = Vec::with_capacity(n_act);
        for i in 0..n {
            if active[i] {
                kkt[(row, i)] = 1.0;
                kkt[(i, row)] = 1.0;
                rhs[row] = 0.0;
                act_idx.push(i);
                row += 1;
            }
        }
        let sol = kkt.solve(&rhs)?;
        let p = &sol[..n];
        let lambda_eq = sol[n..n + m].to_vec();
        let mu_bounds = &sol[n + m..];

        let p_norm = p.iter().map(|v| v * v).sum::<f64>().sqrt();
        if p_norm < 1e-11 {
            // Stationary on the working set: check bound multipliers.
            // KKT convention: ∇f(d) = −Aᵀλ − Σ μ_i e_i, and the canonical
            // multiplier of d_i ≥ lb_i is ν_i = −μ_i ≥ 0.  A *positive* μ
            // (ν < 0) means releasing the bound decreases the objective,
            // so drop the most positive one.
            let mut worst: Option<(usize, f64)> = None;
            for (t, &i) in act_idx.iter().enumerate() {
                let mu = mu_bounds[t];
                if mu > 1e-10 && worst.map_or(true, |(_, w)| mu > w) {
                    worst = Some((i, mu));
                }
            }
            match worst {
                Some((i, _)) => {
                    active[i] = false;
                    continue;
                }
                None => {
                    return Ok(QpSolution { d, lambda_eq, iterations: it + 1 });
                }
            }
        }

        // Ratio test: largest step α ∈ (0, 1] keeping d + αp ≥ lb.
        let mut alpha = 1.0f64;
        let mut blocking: Option<usize> = None;
        for i in 0..n {
            if !active[i] && qp.lb[i].is_finite() && p[i] < -1e-14 {
                let a_i = (qp.lb[i] - d[i]) / p[i];
                if a_i < alpha {
                    alpha = a_i.max(0.0);
                    blocking = Some(i);
                }
            }
        }
        for i in 0..n {
            d[i] += alpha * p[i];
        }
        if let Some(i) = blocking {
            d[i] = qp.lb[i]; // exact landing
            active[i] = true;
        }
    }
    Err(Error::Solver(format!(
        "active-set QP did not converge in {max_iter} iterations"
    )))
}

/// Objective value ½dᵀBd + gᵀd (for tests and merit functions).
pub fn objective(b: &Mat, g: &[f64], d: &[f64]) -> f64 {
    // srclint: allow(panic-reachable) — B is square in d's dimension by the QP construction
    0.5 * dot(&b.matvec(d).expect("dim"), d) + dot(g, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_quadratic() {
        // min ½dᵀId + gᵀd with A empty ⇒ d = −g.
        let b = Mat::eye(3);
        let g = [1.0, -2.0, 0.5];
        let a = Mat::zeros(0, 3);
        let c: [f64; 0] = [];
        let lb = [f64::NEG_INFINITY; 3];
        let qp = Qp { b: &b, g: &g, a: &a, c: &c, lb: &lb };
        let sol = solve(&qp, &[0.0; 3]).unwrap();
        for (di, gi) in sol.d.iter().zip(g) {
            assert!((di + gi).abs() < 1e-9);
        }
    }

    #[test]
    fn equality_constraint_projects() {
        // min ½‖d‖² s.t. d1 + d2 = 2 ⇒ d = (1, 1).
        let b = Mat::eye(2);
        let g = [0.0, 0.0];
        let a = Mat::from_vec(1, 2, vec![1.0, 1.0]).unwrap();
        let c = [2.0];
        let lb = [f64::NEG_INFINITY; 2];
        let qp = Qp { b: &b, g: &g, a: &a, c: &c, lb: &lb };
        // Start feasible at (2, 0).
        let sol = solve(&qp, &[2.0, 0.0]).unwrap();
        assert!((sol.d[0] - 1.0).abs() < 1e-9);
        assert!((sol.d[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn active_bound_binds() {
        // min ½‖d − (−1, 2)‖² s.t. d ≥ 0 ⇒ d = (0, 2).
        // Rewrite: ½dᵀd + gᵀd with g = (1, −2).
        let b = Mat::eye(2);
        let g = [1.0, -2.0];
        let a = Mat::zeros(0, 2);
        let c: [f64; 0] = [];
        let lb = [0.0, 0.0];
        let qp = Qp { b: &b, g: &g, a: &a, c: &c, lb: &lb };
        let sol = solve(&qp, &[0.5, 0.5]).unwrap();
        assert!(sol.d[0].abs() < 1e-9, "{:?}", sol.d);
        assert!((sol.d[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bound_releases_when_beneficial() {
        // Start with the bound active although the optimum is interior:
        // min ½(d−1)² s.t. d ≥ 0, start at d = 0 (active) ⇒ d* = 1.
        let b = Mat::eye(1);
        let g = [-1.0];
        let a = Mat::zeros(0, 1);
        let c: [f64; 0] = [];
        let lb = [0.0];
        let qp = Qp { b: &b, g: &g, a: &a, c: &c, lb: &lb };
        let sol = solve(&qp, &[0.0]).unwrap();
        assert!((sol.d[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn simplex_like_projection() {
        // min ½‖d − t‖² s.t. Σd = 1, d ≥ 0 with t = (0.9, 0.9, −0.8):
        // the Euclidean projection of t onto the simplex = (0.5, 0.5, 0).
        let b = Mat::eye(3);
        let t = [0.9, 0.9, -0.8];
        let g: Vec<f64> = t.iter().map(|v| -v).collect();
        let a = Mat::from_vec(1, 3, vec![1.0, 1.0, 1.0]).unwrap();
        let c = [1.0];
        let lb = [0.0; 3];
        let qp = Qp { b: &b, g: &g, a: &a, c: &c, lb: &lb };
        let sol = solve(&qp, &[1.0 / 3.0; 3]).unwrap();
        assert!((sol.d[0] - 0.5).abs() < 1e-8, "{:?}", sol.d);
        assert!((sol.d[1] - 0.5).abs() < 1e-8);
        assert!(sol.d[2].abs() < 1e-8);
    }
}
