//! Dense f64 linear-algebra substrate (no external crates offline).
//!
//! Just enough for the SQP stack: matrix arithmetic, LU factorization with
//! partial pivoting, and linear solves — sizes here are tiny (the KKT
//! system of a k·l-variable QP), so simplicity beats blocking.

// srclint: allow-file(index-reachable) — dense matrix kernels; dimensions agree by the caller contract

use crate::error::{Error, Result};

/// Dense row-major f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Rows.
    pub rows: usize,
    /// Columns.
    pub cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "{}x{} matrix from {} values",
                rows,
                cols,
                data.len()
            )));
        }
        Ok(Self { rows, cols, data })
    }

    /// Raw data slice.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.cols {
            return Err(Error::Shape("matvec dimension".into()));
        }
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            y[i] = dot(row, x);
        }
        Ok(y)
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix product.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::Shape("matmul dimension".into()));
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for kk in 0..self.cols {
                let a = self[(i, kk)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(kk, j)];
                }
            }
        }
        Ok(out)
    }

    /// Solve `A x = b` by LU with partial pivoting (A square, consumed as
    /// a copy).  Errors on (numerical) singularity.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if self.rows != self.cols || b.len() != self.rows {
            return Err(Error::Shape("solve needs square A and matching b".into()));
        }
        let n = self.rows;
        let mut a = self.data.clone();
        let mut x: Vec<f64> = b.to_vec();
        let mut piv: Vec<usize> = (0..n).collect();

        for col in 0..n {
            // Partial pivot.
            let mut p = col;
            let mut pmax = a[piv[col] * n + col].abs();
            for r in (col + 1)..n {
                let v = a[piv[r] * n + col].abs();
                if v > pmax {
                    p = r;
                    pmax = v;
                }
            }
            if pmax < 1e-14 {
                return Err(Error::Solver(format!(
                    "singular matrix at column {col} (pivot {pmax:.3e})"
                )));
            }
            piv.swap(col, p);
            let prow = piv[col];
            let d = a[prow * n + col];
            for r in (col + 1)..n {
                let rr = piv[r];
                let f = a[rr * n + col] / d;
                if f == 0.0 {
                    continue;
                }
                a[rr * n + col] = 0.0;
                for c in (col + 1)..n {
                    a[rr * n + c] -= f * a[prow * n + c];
                }
                x[rr] -= f * x[prow];
            }
        }
        // Back substitution.
        let mut out = vec![0.0; n];
        for col in (0..n).rev() {
            let prow = piv[col];
            let mut v = x[prow];
            for c in (col + 1)..n {
                v -= a[prow * n + c] * out[c];
            }
            out[col] = v / a[prow * n + col];
        }
        Ok(out)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lu_solve_known_system() {
        let a = Mat::from_vec(3, 3, vec![2.0, 1.0, 1.0, 1.0, 3.0, 2.0, 1.0, 0.0, 0.0])
            .unwrap();
        let b = [4.0, 5.0, 6.0];
        let x = a.solve(&b).unwrap();
        let ax = a.matvec(&x).unwrap();
        for (got, want) in ax.iter().zip(b) {
            assert!((got - want).abs() < 1e-10);
        }
        // Unique solution: x = (6, 15, -23).
        assert!((x[0] - 6.0).abs() < 1e-10);
        assert!((x[1] - 15.0).abs() < 1e-10);
        assert!((x[2] + 23.0).abs() < 1e-10);
    }

    #[test]
    fn lu_needs_pivoting() {
        // Zero leading pivot: fails without partial pivoting.
        let a = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]).unwrap();
        let x = a.solve(&[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]).unwrap();
        assert!(a.solve(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn matmul_and_transpose() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let at = a.transpose();
        let aat = a.matmul(&at).unwrap();
        assert_eq!(aat.rows, 2);
        assert_eq!(aat.cols, 2);
        assert!((aat[(0, 0)] - 14.0).abs() < 1e-12);
        assert!((aat[(0, 1)] - 32.0).abs() < 1e-12);
        assert!((aat[(1, 1)] - 77.0).abs() < 1e-12);
        assert_eq!(aat[(0, 1)], aat[(1, 0)]);
    }

    #[test]
    fn random_solve_round_trip() {
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(17);
        for n in [1usize, 2, 5, 10, 20] {
            let data: Vec<f64> = (0..n * n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            // Diagonal dominance ⇒ well-conditioned.
            let mut a = Mat::from_vec(n, n, data).unwrap();
            for i in 0..n {
                a[(i, i)] += 4.0 * n as f64;
            }
            let xt: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            let b = a.matvec(&xt).unwrap();
            let x = a.solve(&b).unwrap();
            for (got, want) in x.iter().zip(&xt) {
                assert!((got - want).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn blas_helpers() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
    }
}
