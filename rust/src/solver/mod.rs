//! Solvers for the integer program of Eqs. 28–29.
//!
//! * [`exhaustive`] — the "Opt" oracle of Figs. 9–12: enumerate every
//!   integer composition of the populations over the processors, evaluate
//!   X_sys, keep the argmax.  Supports batched offload of the objective
//!   (the PJRT `throughput_eval` artifact).
//! * [`linalg`] — dense f64 matrix substrate (LU with partial pivoting).
//! * [`qp`] — equality-constrained quadratic programs via KKT systems,
//!   with an active-set outer loop for bound constraints.
//! * [`slsqp`] — Sequential Least-SQuares Programming over the relaxed
//!   (continuous) problem: the paper's comparator [32] for Figs. 13–14.

pub mod exhaustive;
pub mod linalg;
pub mod qp;
pub mod slsqp;
