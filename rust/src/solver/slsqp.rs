//! SLSQP — Sequential Least-SQuares Programming (Kraft [32]) over the
//! relaxed continuous problem, the paper's comparator in Figs. 13–14.
//!
//! maximize X_sys(N) (Eq. 28) over real N_ij ≥ 0 with fixed row sums —
//! solved as `min f = −X_sys` by damped-BFGS SQP: each iteration solves a
//! QP linearization ([`super::qp`]) with the (already linear) equality
//! constraints and bound constraints, then backtracks on an Armijo merit.
//!
//! The objective is discontinuous where a processor column empties
//! (Σ_i N_ij = 0) — the paper calls out exactly this as SLSQP's weak spot
//! ("we do see SLSQP convergence failures") — so the gradient guards the
//! denominator and the solver reports failures honestly in its result.

// srclint: allow-file(index-reachable) — working-set arrays are sized by the problem dims at entry

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;

use super::linalg::{dot, Mat};
use super::qp::{self, Qp};

/// Outcome of an SLSQP run.
#[derive(Debug, Clone)]
pub struct SlsqpSolution {
    /// Continuous task distribution (row-major k×l).
    pub n: Vec<f64>,
    /// X_sys at the solution.
    pub throughput: f64,
    /// Major iterations used.
    pub iterations: usize,
    /// True if the tolerance was met (false = iteration cap or QP failure,
    /// mirroring scipy's "failure to converge" reporting).
    pub converged: bool,
}

/// The solver with its tolerances.
#[derive(Debug, Clone)]
pub struct Slsqp {
    /// Maximum major iterations.
    pub max_iter: usize,
    /// First-order tolerance on the predicted decrease.
    pub tol: f64,
}

impl Default for Slsqp {
    fn default() -> Self {
        Self { max_iter: 200, tol: 1e-10 }
    }
}

/// Denominator guard at the discontinuity Σ_i N_ij → 0.
const DEN_EPS: f64 = 1e-9;

/// X_sys over a continuous state (Eq. 28 relaxed; empty column → 0).
pub fn x_continuous(mu: &AffinityMatrix, n: &[f64]) -> f64 {
    let (k, l) = (mu.types(), mu.procs());
    let mut x = 0.0;
    for j in 0..l {
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..k {
            let nij = n[i * l + j];
            num += mu.rate(i, j) * nij;
            den += nij;
        }
        if den > DEN_EPS {
            x += num / den;
        }
    }
    x
}

/// ∇(−X_sys): ∂X/∂N_pj = (μ_pj − X_j)/S_j.
fn grad_neg_x(mu: &AffinityMatrix, n: &[f64], out: &mut [f64]) {
    let (k, l) = (mu.types(), mu.procs());
    for j in 0..l {
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..k {
            let nij = n[i * l + j];
            num += mu.rate(i, j) * nij;
            den += nij;
        }
        let (xj, sj) = if den > DEN_EPS { (num / den, den) } else { (0.0, DEN_EPS) };
        for i in 0..k {
            out[i * l + j] = -(mu.rate(i, j) - xj) / sj;
        }
    }
}

impl Slsqp {
    /// Solve the relaxed Eq. 28/29 for the given populations.
    pub fn solve(&self, mu: &AffinityMatrix, populations: &[u32]) -> Result<SlsqpSolution> {
        let (k, l) = (mu.types(), mu.procs());
        if populations.len() != k {
            return Err(Error::Shape("population arity".into()));
        }
        let nvar = k * l;

        // Feasible start: spread each population uniformly.
        let mut x: Vec<f64> = Vec::with_capacity(nvar);
        for &ni in populations {
            for _ in 0..l {
                x.push(ni as f64 / l as f64);
            }
        }

        // Equality matrix: row i sums row i of the state (constant).
        let mut a = Mat::zeros(k, nvar);
        for i in 0..k {
            for j in 0..l {
                a[(i, i * l + j)] = 1.0;
            }
        }
        let c_eq = vec![0.0; k]; // steps satisfy A p = 0

        let mut bmat = Mat::eye(nvar);
        let mut g = vec![0.0; nvar];
        grad_neg_x(mu, &x, &mut g);
        let mut f = -x_continuous(mu, &x);

        let mut converged = false;
        let mut iterations = 0usize;
        for it in 0..self.max_iter {
            iterations = it + 1;
            // QP subproblem: min ½pᵀBp + gᵀp, A p = 0, p ≥ −x.
            let lb: Vec<f64> = x.iter().map(|&xi| -xi).collect();
            let qp_prob = Qp { b: &bmat, g: &g, a: &a, c: &c_eq, lb: &lb };
            let p = match qp::solve(&qp_prob, &vec![0.0; nvar]) {
                Ok(sol) => sol.d,
                Err(_) => {
                    // QP failure near the discontinuity: report honestly.
                    return Ok(SlsqpSolution {
                        throughput: x_continuous(mu, &x),
                        n: x,
                        iterations,
                        converged: false,
                    });
                }
            };
            let pred = dot(&g, &p);
            if pred.abs() < self.tol {
                converged = true;
                break;
            }

            // Armijo backtracking on f (constraints hold for any α ∈ (0,1]).
            let mut alpha = 1.0f64;
            let mut accepted = false;
            for _ in 0..40 {
                let xt: Vec<f64> =
                    x.iter().zip(&p).map(|(&xi, &pi)| (xi + alpha * pi).max(0.0)).collect();
                let ft = -x_continuous(mu, &xt);
                if ft <= f + 1e-4 * alpha * pred {
                    // Damped BFGS update with s = α·p, y = ∇f(xt) − ∇f(x).
                    let mut g_new = vec![0.0; nvar];
                    grad_neg_x(mu, &xt, &mut g_new);
                    let s: Vec<f64> = p.iter().map(|&pi| alpha * pi).collect();
                    let y: Vec<f64> = g_new.iter().zip(&g).map(|(a, b)| a - b).collect();
                    bfgs_update(&mut bmat, &s, &y);
                    x = xt;
                    f = ft;
                    g = g_new;
                    accepted = true;
                    break;
                }
                alpha *= 0.5;
            }
            if !accepted {
                // No progress possible along p: treat as converged to the
                // achievable tolerance.
                converged = true;
                break;
            }
        }

        Ok(SlsqpSolution {
            throughput: x_continuous(mu, &x),
            n: x,
            iterations,
            converged,
        })
    }
}

/// Powell-damped BFGS update of B with curvature pair (s, y).
fn bfgs_update(b: &mut Mat, s: &[f64], y: &[f64]) {
    let n = s.len();
    // srclint: allow(panic-reachable) — B is maintained n-square across BFGS updates
    let bs = b.matvec(s).expect("dim");
    let sbs = dot(s, &bs);
    let sy = dot(s, y);
    if sbs <= 1e-14 {
        return;
    }
    // Powell damping: keep the update positive definite.
    let theta = if sy >= 0.2 * sbs { 1.0 } else { (0.8 * sbs) / (sbs - sy) };
    let r: Vec<f64> = (0..n).map(|i| theta * y[i] + (1.0 - theta) * bs[i]).collect();
    let sr = dot(s, &r);
    if sr <= 1e-14 {
        return;
    }
    for i in 0..n {
        for j in 0..n {
            b[(i, j)] += r[i] * r[j] / sr - bs[i] * bs[j] / sbs;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::grin;
    use crate::sim::rng::Rng;
    use crate::sim::workload;

    #[test]
    fn feasibility_is_preserved() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
        ])
        .unwrap();
        let pops = [6u32, 4];
        let sol = Slsqp::default().solve(&mu, &pops).unwrap();
        let l = mu.procs();
        for (i, &ni) in pops.iter().enumerate() {
            let row: f64 = (0..l).map(|j| sol.n[i * l + j]).sum();
            assert!((row - ni as f64).abs() < 1e-7, "row {i} sums to {row}");
        }
        assert!(sol.n.iter().all(|&v| v >= -1e-9));
    }

    #[test]
    fn relaxation_upper_bounds_integer_solutions_usually() {
        // The continuous optimum of the relaxed problem can only exceed or
        // match the best integer state *if SLSQP finds the global optimum*;
        // it's a local method, so just require it beats uniform splitting.
        let mut rng = Rng::new(31);
        for _ in 0..10 {
            let mu = workload::random_mu(&mut rng, 3, 3, 0.5, 30.0).unwrap();
            let pops = workload::random_populations(&mut rng, 3, 8);
            let sol = Slsqp::default().solve(&mu, &pops).unwrap();
            let uniform: Vec<f64> = pops
                .iter()
                .flat_map(|&ni| std::iter::repeat(ni as f64 / 3.0).take(3))
                .collect();
            assert!(sol.throughput >= x_continuous(&mu, &uniform) - 1e-9);
        }
    }

    #[test]
    fn two_type_biased_case_near_cab_optimum() {
        // On the paper's P1-biased matrix the relaxed optimum approaches
        // the AF corner; SLSQP should land within a few percent of the
        // integer optimum (it explores a larger space, per §6).
        let mu = workload::paper_two_type_mu();
        let pops = [10u32, 10];
        let sol = Slsqp::default().solve(&mu, &pops).unwrap();
        let grin_x = grin::solve(&mu, &pops).unwrap().throughput;
        assert!(
            sol.throughput > 0.75 * grin_x,
            "SLSQP {} vs GrIn {}",
            sol.throughput,
            grin_x
        );
    }

    #[test]
    fn deterministic_and_terminates() {
        let mu = workload::paper_two_type_mu();
        let a = Slsqp::default().solve(&mu, &[5, 15]).unwrap();
        let b = Slsqp::default().solve(&mu, &[5, 15]).unwrap();
        assert_eq!(a.n, b.n);
        assert!(a.iterations <= Slsqp::default().max_iter);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0],
            vec![1.0, 8.0],
        ])
        .unwrap();
        let n = vec![2.0, 1.0, 0.5, 3.0];
        let mut g = vec![0.0; 4];
        grad_neg_x(&mu, &n, &mut g);
        let h = 1e-6;
        for v in 0..4 {
            let mut np = n.clone();
            let mut nm = n.clone();
            np[v] += h;
            nm[v] -= h;
            let fd = -(x_continuous(&mu, &np) - x_continuous(&mu, &nm)) / (2.0 * h);
            assert!((g[v] - fd).abs() < 1e-5, "var {v}: {} vs {fd}", g[v]);
        }
    }
}
