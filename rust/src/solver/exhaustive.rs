//! Exhaustive search — the "Opt" oracle (§6, Figs. 9–12).
//!
//! Enumerates every feasible integer state: the Cartesian product over
//! task types of the compositions of N_i into l non-negative parts
//! (|states| = Π_i C(N_i + l − 1, l − 1)).  Exact but exponential — the
//! paper uses it only as the ground-truth baseline, as do we.
//!
//! Two evaluation paths share the same enumerator:
//! * scalar:   `ExhaustiveSolver::solve` (pure Rust, Eq. 28 per state);
//! * batched:  `ExhaustiveSolver::solve_batched` — candidates are packed
//!   into padded f32 tensors and the objective is evaluated by a
//!   caller-supplied batch function (the PJRT `throughput_eval` artifact
//!   in production, a jnp-equivalent closure in tests).

// srclint: allow-file(index-reachable) — allocation grids are enumerated over fixed k by l dims

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::state::StateMatrix;
use crate::model::throughput::x_of_state;

/// Iterator over all compositions of `total` into `parts` non-negative
/// integers (lexicographic odometer).
pub struct CompositionIter {
    current: Vec<u32>,
    total: u32,
    done: bool,
}

impl CompositionIter {
    /// New iterator; the first composition is (total, 0, ..., 0).
    pub fn new(total: u32, parts: usize) -> Self {
        assert!(parts >= 1);
        let mut current = vec![0; parts];
        current[0] = total;
        Self { current, total, done: false }
    }

    /// Number of compositions: C(total + parts − 1, parts − 1).
    pub fn count(total: u32, parts: usize) -> u128 {
        let n = total as u128 + parts as u128 - 1;
        let k = parts as u128 - 1;
        binomial(n, k)
    }
}

/// C(n, k) in u128 (overflow-safe for the sizes the oracle can enumerate).
pub fn binomial(n: u128, k: u128) -> u128 {
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) / (i + 1);
    }
    acc
}

impl Iterator for CompositionIter {
    type Item = Vec<u32>;

    fn next(&mut self) -> Option<Vec<u32>> {
        if self.done {
            return None;
        }
        let out = self.current.clone();
        // Advance: move one unit from the leftmost non-zero prefix cell.
        let parts = self.current.len();
        if parts == 1 {
            self.done = true;
            return Some(out);
        }
        // Standard "next composition" step.
        if self.current[parts - 1] == self.total {
            self.done = true;
            return Some(out);
        }
        let mut i = 0;
        while self.current[i] == 0 {
            i += 1;
        }
        if i + 1 < parts {
            let v = self.current[i];
            self.current[i] = 0;
            self.current[0] = v - 1;
            self.current[i + 1] += 1;
        }
        Some(out)
    }
}

/// Result of an exhaustive solve.
#[derive(Debug, Clone)]
pub struct OptSolution {
    /// The global optimum state.
    pub state: StateMatrix,
    /// X_sys at the optimum.
    pub throughput: f64,
    /// Number of states evaluated.
    pub evaluated: u64,
}

/// The exhaustive oracle.
#[derive(Debug, Default)]
pub struct ExhaustiveSolver;

impl ExhaustiveSolver {
    /// Total state count for the given problem.
    pub fn state_count(populations: &[u32], procs: usize) -> u128 {
        populations
            .iter()
            .map(|&n| CompositionIter::count(n, procs))
            .product()
    }

    /// Enumerate all states, calling `f` with each (reused) state.
    fn for_each_state<F: FnMut(&StateMatrix)>(
        mu: &AffinityMatrix,
        populations: &[u32],
        mut f: F,
    ) -> Result<()> {
        let (k, l) = (mu.types(), mu.procs());
        if populations.len() != k {
            return Err(Error::Shape("population arity".into()));
        }
        // Odometer over rows: materialize each row's compositions once.
        let rows: Vec<Vec<Vec<u32>>> = populations
            .iter()
            .map(|&n| CompositionIter::new(n, l).collect())
            .collect();
        let mut idx = vec![0usize; k];
        let mut state = StateMatrix::zeros(k, l);
        'outer: loop {
            for i in 0..k {
                for j in 0..l {
                    state.set(i, j, rows[i][idx[i]][j]);
                }
            }
            f(&state);
            // Advance odometer.
            let mut d = 0;
            loop {
                idx[d] += 1;
                if idx[d] < rows[d].len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
                if d == k {
                    break 'outer;
                }
            }
        }
        Ok(())
    }

    /// Scalar exhaustive solve (pure Rust objective).
    pub fn solve(&self, mu: &AffinityMatrix, populations: &[u32]) -> Result<OptSolution> {
        let mut best: Option<(StateMatrix, f64)> = None;
        let mut evaluated = 0u64;
        Self::for_each_state(mu, populations, |s| {
            evaluated += 1;
            let x = x_of_state(mu, s);
            if best.as_ref().map_or(true, |(_, bx)| x > *bx) {
                best = Some((s.clone(), x));
            }
        })?;
        let (state, throughput) =
            best.ok_or_else(|| Error::Solver("no states enumerated".into()))?;
        Ok(OptSolution { state, throughput, evaluated })
    }

    /// Batched exhaustive solve: candidates are packed into
    /// `(k_pad × l_pad)` f32 blocks of `batch` candidates and handed to
    /// `eval`, which returns one X_sys per candidate (the PJRT
    /// `throughput_eval` artifact implements exactly this signature).
    /// Ragged tails are padded with all-zero candidates (X_sys = 0).
    pub fn solve_batched<F>(
        &self,
        mu: &AffinityMatrix,
        populations: &[u32],
        batch: usize,
        k_pad: usize,
        l_pad: usize,
        mut eval: F,
    ) -> Result<OptSolution>
    where
        F: FnMut(&[f32]) -> Result<Vec<f32>>,
    {
        let cell = k_pad * l_pad;
        let mut pending: Vec<StateMatrix> = Vec::with_capacity(batch);
        let mut buf = vec![0f32; batch * cell];
        let mut best: Option<(StateMatrix, f64)> = None;
        let mut evaluated = 0u64;

        let mut flush = |pending: &mut Vec<StateMatrix>,
                         buf: &mut Vec<f32>,
                         best: &mut Option<(StateMatrix, f64)>|
         -> Result<()> {
            if pending.is_empty() {
                return Ok(());
            }
            buf.iter_mut().for_each(|v| *v = 0.0);
            for (b, s) in pending.iter().enumerate() {
                let padded = s.to_padded_f32(k_pad, l_pad)?;
                buf[b * cell..(b + 1) * cell].copy_from_slice(&padded);
            }
            let xs = eval(buf)?;
            if xs.len() < pending.len() {
                return Err(Error::Solver(format!(
                    "batch evaluator returned {} values for {} candidates",
                    xs.len(),
                    pending.len()
                )));
            }
            for (b, s) in pending.iter().enumerate() {
                let x = xs[b] as f64;
                if best.as_ref().map_or(true, |(_, bx)| x > *bx) {
                    *best = Some((s.clone(), x));
                }
            }
            pending.clear();
            Ok(())
        };

        let mut err: Option<Error> = None;
        Self::for_each_state(mu, populations, |s| {
            if err.is_some() {
                return;
            }
            evaluated += 1;
            pending.push(s.clone());
            if pending.len() == batch {
                if let Err(e) = flush(&mut pending, &mut buf, &mut best) {
                    err = Some(e);
                }
            }
        })?;
        if let Some(e) = err {
            return Err(e);
        }
        flush(&mut pending, &mut buf, &mut best)?;
        let (state, throughput) =
            best.ok_or_else(|| Error::Solver("no states enumerated".into()))?;
        Ok(OptSolution { state, throughput, evaluated })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn composition_iter_is_complete_and_valid() {
        let all: Vec<Vec<u32>> = CompositionIter::new(4, 3).collect();
        assert_eq!(all.len() as u128, CompositionIter::count(4, 3)); // C(6,2)=15
        for c in &all {
            assert_eq!(c.iter().sum::<u32>(), 4);
        }
        // No duplicates.
        let mut sorted = all.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len());
    }

    #[test]
    fn composition_edge_cases() {
        assert_eq!(CompositionIter::new(0, 3).count(), 1);
        assert_eq!(CompositionIter::new(5, 1).count(), 1);
        assert_eq!(CompositionIter::count(0, 3), 1);
        assert_eq!(binomial(10, 3), 120);
    }

    #[test]
    fn oracle_matches_cab_on_two_types() {
        use crate::policy::cab::Cab;
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let pops = [8u32, 8];
        let opt = ExhaustiveSolver.solve(&mu, &pops).unwrap();
        let (_, cab) = Cab::target_state(&mu, &pops).unwrap();
        assert!((opt.throughput - x_of_state(&mu, &cab)).abs() < 1e-12);
        assert_eq!(opt.evaluated as u128, ExhaustiveSolver::state_count(&pops, 2));
    }

    #[test]
    fn grin_within_gap_of_oracle() {
        use crate::policy::grin;
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(77);
        let mut worst_gap = 0.0f64;
        for _ in 0..20 {
            let rows: Vec<Vec<f64>> = (0..3)
                .map(|_| (0..3).map(|_| rng.range_f64(0.5, 30.0)).collect())
                .collect();
            let mu = AffinityMatrix::from_rows(&rows).unwrap();
            let pops: Vec<u32> = (0..3).map(|_| 1 + rng.below(6) as u32).collect();
            let opt = ExhaustiveSolver.solve(&mu, &pops).unwrap();
            let g = grin::solve(&mu, &pops).unwrap();
            assert!(g.throughput <= opt.throughput + 1e-9);
            worst_gap = worst_gap.max(1.0 - g.throughput / opt.throughput);
        }
        // The paper reports 1.6% *average*; individual gaps stay modest.
        assert!(worst_gap < 0.15, "worst GrIn gap {worst_gap}");
    }

    #[test]
    fn batched_solve_agrees_with_scalar() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0],
            vec![1.0, 8.0, 3.0],
        ])
        .unwrap();
        let pops = [5u32, 4];
        let scalar = ExhaustiveSolver.solve(&mu, &pops).unwrap();
        let (kp, lp) = (4usize, 4usize);
        // Reference batch evaluator: Eq. 28 over the padded layout.
        let mu_c = mu.clone();
        let batched = ExhaustiveSolver
            .solve_batched(&mu, &pops, 7, kp, lp, |buf| {
                let cell = kp * lp;
                let mut out = Vec::new();
                for b in 0..buf.len() / cell {
                    let sl = &buf[b * cell..(b + 1) * cell];
                    let mut x = 0.0f32;
                    for j in 0..lp {
                        let (mut num, mut den) = (0.0f32, 0.0f32);
                        for i in 0..kp {
                            let n = sl[i * lp + j];
                            let r = if i < mu_c.types() && j < mu_c.procs() {
                                mu_c.rate(i, j) as f32
                            } else {
                                0.0
                            };
                            num += r * n;
                            den += n;
                        }
                        if den > 0.0 {
                            x += num / den;
                        }
                    }
                    out.push(x);
                }
                Ok(out)
            })
            .unwrap();
        assert!((batched.throughput - scalar.throughput).abs() < 1e-4);
        assert_eq!(batched.evaluated, scalar.evaluated);
    }
}
