//! `detlint` — AST-level determinism & panic-reachability analysis
//! over `rust/src/`.
//!
//! Usage: `cargo run --bin detlint [-- [<src-root>] [--features a,b]]`
//!
//! Runs the three analyses in `hetsched::analysis` (panic
//! reachability from the hot-path entry points, determinism dataflow,
//! metric-plumbing consistency) and exits non-zero if any finding
//! survives suppression.  `--features` mirrors cargo's flag so the
//! feature-gated cfg (`--features model`) can be analyzed too.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut features: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--features" {
            match args.next() {
                Some(v) => features.extend(v.split(',').map(|s| s.trim().to_string())),
                None => {
                    eprintln!("detlint: --features needs a value (comma-separated)");
                    return ExitCode::FAILURE;
                }
            }
        } else if let Some(v) = a.strip_prefix("--features=") {
            features.extend(v.split(',').map(|s| s.trim().to_string()));
        } else if root.is_none() {
            root = Some(PathBuf::from(a));
        } else {
            eprintln!("detlint: unexpected argument `{a}`");
            return ExitCode::FAILURE;
        }
    }
    let root = root.unwrap_or_else(|| {
        // Work from either the workspace root or rust/.
        for c in ["rust/src", "src"] {
            let p = PathBuf::from(c);
            if p.join("lib.rs").is_file() {
                return p;
            }
        }
        PathBuf::from("rust/src")
    });
    match hetsched::analysis::run(&root, &features) {
        Ok(findings) => {
            for f in &findings {
                eprintln!("{f}");
            }
            if findings.is_empty() {
                let feat = if features.is_empty() {
                    String::new()
                } else {
                    format!(" [features: {}]", features.join(","))
                };
                println!("detlint: clean ({}){feat}", root.display());
                ExitCode::SUCCESS
            } else {
                eprintln!("detlint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("detlint: cannot walk {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}
