//! `srclint` — enforce repo source invariants over `rust/src/`.
//!
//! Usage: `cargo run --bin srclint [-- <src-root>]`
//! Exits non-zero if any finding survives (suppressions need an inline
//! justification: `// srclint: allow(<rule>) — <reason>`).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| {
        // Work from either the workspace root or rust/.
        let cands = ["rust/src", "src"];
        for c in cands {
            let p = PathBuf::from(c);
            if p.join("lib.rs").is_file() {
                return p;
            }
        }
        PathBuf::from("rust/src")
    });
    match hetsched::lint::lint_tree(&root) {
        Ok((findings, files)) => {
            for f in &findings {
                eprintln!("{f}");
            }
            if findings.is_empty() {
                println!("srclint: {files} files clean ({})", root.display());
                ExitCode::SUCCESS
            } else {
                eprintln!("srclint: {} finding(s) in {files} files", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("srclint: cannot walk {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}
