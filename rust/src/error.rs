//! Crate error type (hand-rolled: no `thiserror` available offline).

/// All errors produced by hetsched.
#[derive(Debug)]
pub enum Error {
    /// Dimension / shape mismatch in model math.
    Shape(String),

    /// Invalid configuration or CLI arguments.
    Config(String),

    /// Parse failure (JSON/config/CLI).
    Parse(String),

    /// Solver failed to converge or was given an infeasible problem.
    Solver(String),

    /// Artifact missing / runtime failure around the execution layer.
    Runtime(String),

    /// No routable capacity: every candidate device (or every shard)
    /// is marked down, so a routing decision cannot be made.  Callers
    /// either surface this as a typed error or park the work until a
    /// recovery event restores capacity — never a panic.
    NoCapacity(String),

    /// Underlying XLA/PJRT error (only produced with `--features pjrt`).
    Xla(String),

    /// I/O.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Solver(m) => write!(f, "solver error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::NoCapacity(m) => write!(f, "no capacity: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_carry_context() {
        assert!(Error::Shape("2x2".into()).to_string().contains("shape"));
        assert!(Error::Config("bad".into()).to_string().contains("bad"));
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
        use std::error::Error as _;
        assert!(io.source().is_some());
        assert!(Error::Parse("x".into()).source().is_none());
        let nc = Error::NoCapacity("all devices down".into());
        assert!(nc.to_string().contains("no capacity"));
        assert!(nc.source().is_none());
    }
}
