//! Crate error type.

use thiserror::Error;

/// All errors produced by hetsched.
#[derive(Debug, Error)]
pub enum Error {
    /// Dimension / shape mismatch in model math.
    #[error("shape error: {0}")]
    Shape(String),

    /// Invalid configuration or CLI arguments.
    #[error("config error: {0}")]
    Config(String),

    /// Parse failure (JSON/config/CLI).
    #[error("parse error: {0}")]
    Parse(String),

    /// Solver failed to converge or was given an infeasible problem.
    #[error("solver error: {0}")]
    Solver(String),

    /// Artifact missing / runtime failure around the PJRT layer.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Underlying XLA/PJRT error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;
