//! Discrete-event simulation of the closed batch network (Fig. 2).
//!
//! * [`rng`] — PCG64 + SplitMix64 seeding (no `rand` crate offline).
//! * [`distribution`] — the four §5 task-size distributions, mean-1
//!   normalized: exponential, bounded Pareto, uniform, constant.
//! * [`task`] / [`processor`] — tasks and the PS / FCFS / LCFS service
//!   disciplines (all work-conserving, per Lemma 3), maintained
//!   incrementally: virtual-time PS, O(1) FCFS/LCFS, cached
//!   remaining-work aggregates.
//! * [`eventq`] — indexed binary min-heap over per-processor
//!   next-completion times (O(1) peek, O(log l) re-key).
//! * [`engine`] — the closed network: N programs, one task in flight per
//!   program, policy-driven dispatch on every completion; arena-reusable
//!   via [`engine::SimArena`].
//! * [`metrics`] — throughput, response time, energy, EDP estimators with
//!   warm-up discard (the §5 measurement methodology).
//! * [`workload`] — scenario builders for the paper's sweeps.
//! * [`dynamic`] — piece-wise closed systems (§3.1) with per-phase
//!   policy re-solve (§4.1's "on the fly" GrIn use case).
//! * [`replicate`] — zero-dep `std::thread` replication runner: R seeded
//!   replications × S scenarios fanned across cores with per-thread
//!   reusable arenas, mean/95%-CI per cell.

pub mod distribution;
pub mod dynamic;
pub mod engine;
pub mod eventq;
pub mod metrics;
pub mod processor;
pub mod replicate;
pub mod rng;
pub mod task;
pub mod workload;
