//! Deterministic pseudo-random substrate (no `rand` crate offline).
//!
//! PCG64 (O'Neill's PCG XSL RR 128/64) seeded via SplitMix64 — small,
//! fast, and statistically solid for simulation workloads.  Every sweep in
//! the benches passes an explicit seed so paper figures regenerate
//! bit-identically.

/// PCG XSL RR 128/64.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Rng {
    /// Seed via SplitMix64 expansion of a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next() as u128;
        let s1 = sm.next() as u128;
        let i0 = sm.next() as u128;
        let i1 = sm.next() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        // Standard PCG warm-up.
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// Derive an independent stream (distinct increment ⇒ distinct
    /// sequence) — used to give each program its own stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Rng::new(sm.next())
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) ^ self.state) as u64;
        // srclint: allow(as-truncation) — PCG rotate amount uses only the top 6 bits of state
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1), never exactly 0 (safe for log()).
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift with bias
    /// rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone: low part < threshold may be biased.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with given rate (mean 1/rate), inverse-CDF.
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64_open().ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — seeding-quality generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New stream from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next output.
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = r.f64();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn exp_sampler_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let s: f64 = (0..n).map(|_| r.exp(4.0)).sum();
        let mean = s / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn forked_streams_diverge() {
        let mut base = Rng::new(1);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
