//! Processors with work-conserving service disciplines (Lemma 3).
//!
//! * **PS** — processor sharing: all resident tasks served at μ_ij/n
//!   (Eq. 5), the §5 simulation discipline.
//! * **FCFS** — head-of-line served at full rate, the §7 platform
//!   discipline.
//! * **LCFS** — preemptive-resume last-come-first-serve; included to
//!   demonstrate the Lemma-3 discipline independence.
//!
//! Between events the active-rate profile is constant, so the processor
//! advances lazily: `advance(now)` then mutate.  The seed implementation
//! rescanned every resident on each call (O(n) `advance`, O(n)
//! `next_completion`, O(n) share lookups ⇒ O(n²) event loops); this
//! version maintains everything incrementally:
//!
//! * **PS runs on virtual time**: V advances at 1/n per unit real time,
//!   and each resident's *virtual finish time* F = V(push) + size/rate is
//!   a constant.  Residents sit in a binary min-heap on (F, seq), so
//!   `next_completion` is O(1) (heap root) and arrivals/completions are
//!   O(log n) heap operations — no per-resident work ever.
//! * **FCFS is a ring, LCFS a stack**: only the served head/top is
//!   advanced, so `advance`, `next_completion` and `pop_completed` are
//!   O(1).
//! * **`remaining_work_time` is an aggregate**: Σ remaining/rate is
//!   maintained incrementally (add size/rate on push, subtract dt on
//!   advance — every work-conserving discipline drains exactly one
//!   drain-time unit per unit of busy time), so load-balance dispatch
//!   reads it in O(1) instead of re-summing the queue.
//!
//! [`ScalarProcessor`] preserves the seed's rescan implementation as the
//! reference for the trace-equivalence property tests
//! (`tests/hotpath_equiv.rs`): both produce identical completion
//! sequences on fixed seeds.

// srclint: allow-file(index-reachable) — resident queues are indexed by occupancy counts maintained in lockstep

use super::task::Task;
use crate::error::{Error, Result};

/// Service discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Processor sharing (time slicing), Eq. 5.
    Ps,
    /// First-come-first-serve.
    Fcfs,
    /// Preemptive-resume last-come-first-serve.
    Lcfs,
}

impl Discipline {
    /// Parse a CLI name.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "ps" => Ok(Discipline::Ps),
            "fcfs" => Ok(Discipline::Fcfs),
            "lcfs" => Ok(Discipline::Lcfs),
            other => Err(Error::Parse(format!(
                "unknown discipline '{other}' (ps|fcfs|lcfs)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            Discipline::Ps => "ps",
            Discipline::Fcfs => "fcfs",
            Discipline::Lcfs => "lcfs",
        }
    }
}

/// A task resident on a processor.
#[derive(Debug, Clone)]
struct Resident {
    task: Task,
    /// Full-speed service rate μ_ij for this task on this processor.
    rate: f64,
    /// Progress key.  PS: the *virtual finish time* F = V(push) +
    /// size/rate, constant for the resident's lifetime.  FCFS/LCFS: the
    /// remaining work; only the served head/top is ever decremented.
    key: f64,
    /// Arrival order stamp (discipline ordering, heap tie-break).
    seq: u64,
}

/// One processor (or cluster thereof) with a service discipline.
///
/// The backing store is a single `Vec` interpreted per discipline: a
/// binary min-heap on (key, seq) for PS, a ring starting at `head` for
/// FCFS, a stack for LCFS.  `reset` keeps the allocation, so arenas
/// reuse processors across replications with zero heap churn.
#[derive(Debug, Clone)]
pub struct Processor {
    /// Column index in the affinity matrix.
    pub id: usize,
    discipline: Discipline,
    items: Vec<Resident>,
    /// Ring head (FCFS only; 0 for PS/LCFS).
    head: usize,
    /// PS virtual time; advances at 1/n per unit real time while busy.
    vtime: f64,
    last_update: f64,
    /// Σ remaining/rate over residents, as of `last_update`.
    work_time: f64,
    /// Cumulative time this processor spent busy (occupancy > 0), as of
    /// `last_update` — the idle-power accounting signal.
    busy_time: f64,
    seq: u64,
}

impl Processor {
    /// Empty processor.
    pub fn new(id: usize, discipline: Discipline) -> Self {
        Self {
            id,
            discipline,
            items: Vec::new(),
            head: 0,
            vtime: 0.0,
            last_update: 0.0,
            work_time: 0.0,
            busy_time: 0.0,
            seq: 0,
        }
    }

    /// Clear all state (possibly under a new discipline) while keeping
    /// the resident allocation — the arena-reuse path.
    pub fn reset(&mut self, discipline: Discipline) {
        self.discipline = discipline;
        self.items.clear();
        self.head = 0;
        self.vtime = 0.0;
        self.last_update = 0.0;
        self.work_time = 0.0;
        self.busy_time = 0.0;
        self.seq = 0;
    }

    /// Number of resident tasks.
    #[inline]
    pub fn occupancy(&self) -> usize {
        self.items.len() - self.head
    }

    /// Remaining work in *time* units at full speed — the perfect-info
    /// load-balancing metric of §5 ("task total size in the queue",
    /// measured in drain time), as of the last `advance`.
    #[inline]
    pub fn remaining_work_time(&self) -> f64 {
        self.work_time
    }

    /// Cumulative busy time (occupancy > 0) as of the last `advance` —
    /// idle time over a window is the window length minus the busy-time
    /// delta across it (the idle-power floor's accounting signal).
    #[inline]
    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    /// Progress all active residents to time `now` — O(1) for every
    /// discipline (PS moves the virtual clock, FCFS/LCFS decrement only
    /// the served resident).
    pub fn advance(&mut self, now: f64) {
        let dt = now - self.last_update;
        debug_assert!(dt >= -1e-9, "time went backwards: {dt}");
        if dt > 0.0 {
            let n = self.occupancy();
            if n > 0 {
                match self.discipline {
                    Discipline::Ps => self.vtime += dt / n as f64,
                    Discipline::Fcfs => {
                        let r = &mut self.items[self.head];
                        r.key -= dt * r.rate;
                        if r.key < 0.0 {
                            // Numerical dust only; completions are popped
                            // at their exact event time.
                            debug_assert!(r.key > -1e-6, "{}", r.key);
                            r.key = 0.0;
                        }
                    }
                    Discipline::Lcfs => {
                        // srclint: allow(hot-path-panic) — callers guard on occupancy before taking the last resident.
                        let r = self.items.last_mut().expect("occupancy > 0");
                        r.key -= dt * r.rate;
                        if r.key < 0.0 {
                            debug_assert!(r.key > -1e-6, "{}", r.key);
                            r.key = 0.0;
                        }
                    }
                }
                // Work conservation: any busy discipline drains exactly
                // one drain-time unit per unit of real time.
                self.work_time -= dt;
                if self.work_time < 0.0 {
                    self.work_time = 0.0;
                }
                self.busy_time += dt;
            }
        }
        self.last_update = now;
    }

    /// Admit a task with its full-speed rate; caller must have advanced
    /// the processor to `now` first.
    pub fn push(&mut self, task: Task, rate: f64, now: f64) {
        debug_assert!(rate > 0.0);
        debug_assert!((now - self.last_update).abs() < 1e-9);
        let seq = self.seq;
        self.seq += 1;
        self.work_time += task.size / rate;
        let key = match self.discipline {
            Discipline::Ps => self.vtime + task.size / rate,
            Discipline::Fcfs | Discipline::Lcfs => task.size,
        };
        self.items.push(Resident { task, rate, key, seq });
        if self.discipline == Discipline::Ps {
            self.sift_up(self.items.len() - 1);
        }
    }

    /// Absolute time of the next completion if no further events occur —
    /// O(1): the PS heap root / FCFS head / LCFS top.
    pub fn next_completion(&self) -> Option<f64> {
        let n = self.occupancy();
        if n == 0 {
            return None;
        }
        Some(match self.discipline {
            Discipline::Ps => {
                let r = &self.items[0];
                // remaining = (F − V)·rate, served at rate/n.
                self.last_update + (r.key - self.vtime) * n as f64
            }
            Discipline::Fcfs => {
                let r = &self.items[self.head];
                self.last_update + r.key / r.rate
            }
            Discipline::Lcfs => {
                // srclint: allow(hot-path-panic) — callers guard on occupancy before taking the last resident.
                let r = self.items.last().expect("occupancy > 0");
                self.last_update + r.key / r.rate
            }
        })
    }

    /// Remove and return the resident completing at `now`.  Caller must
    /// `advance(now)` first.
    pub fn pop_completed(&mut self, now: f64) -> Result<Task> {
        debug_assert!((now - self.last_update).abs() < 1e-9);
        if self.occupancy() == 0 {
            return Err(Error::Shape(format!(
                "pop_completed on idle processor {}",
                self.id
            )));
        }
        // Residual work of the completing resident (numerical dust).
        let (rem, rate) = match self.discipline {
            Discipline::Ps => {
                let r = &self.items[0];
                ((r.key - self.vtime) * r.rate, r.rate)
            }
            Discipline::Fcfs => {
                let r = &self.items[self.head];
                (r.key, r.rate)
            }
            Discipline::Lcfs => {
                // srclint: allow(hot-path-panic) — callers guard on occupancy before taking the last resident.
                let r = self.items.last().expect("occupancy > 0");
                (r.key, r.rate)
            }
        };
        if rem > 1e-6 {
            return Err(Error::Shape(format!(
                "no task completing now on processor {} (residual {rem})",
                self.id
            )));
        }
        let resident = match self.discipline {
            Discipline::Ps => self.pop_heap_root(),
            Discipline::Fcfs => {
                let r = self.items[self.head].clone();
                self.head += 1;
                // Amortized O(1) compaction of the consumed prefix.
                if self.head * 2 >= self.items.len() {
                    self.items.drain(..self.head);
                    self.head = 0;
                }
                r
            }
            // srclint: allow(hot-path-panic) — callers guard on occupancy before taking the last resident.
            Discipline::Lcfs => self.items.pop().expect("occupancy > 0"),
        };
        self.work_time -= rem.max(0.0) / rate;
        if self.occupancy() == 0 {
            // Cancel accumulated dust whenever the queue empties, so the
            // aggregates stay exact across arbitrarily long runs.
            self.work_time = 0.0;
            self.vtime = 0.0;
            self.head = 0;
        } else if self.work_time < 0.0 {
            self.work_time = 0.0;
        }
        Ok(resident.task)
    }

    /// Evacuate every resident task with its remaining work — the
    /// device-failure path.  Caller must `advance(now)` first; the
    /// processor is left empty (but keeps its cumulative `busy_time`
    /// and clock, so downtime accounting stays consistent).
    ///
    /// Remaining work per discipline: PS residents carry a constant
    /// virtual finish time F, so remaining = (F − V)·rate; FCFS/LCFS
    /// keys *are* the remaining work (only the served head/top is ever
    /// decremented, and `advance` already brought it current).  Tasks
    /// are returned in arrival (seq) order so re-dispatch is
    /// deterministic and discipline-independent.
    pub fn drain_residents(&mut self, now: f64) -> Vec<(Task, f64)> {
        debug_assert!((now - self.last_update).abs() < 1e-9);
        let mut order: Vec<usize> = (self.head..self.items.len()).collect();
        order.sort_by_key(|&i| self.items[i].seq);
        let drained: Vec<(Task, f64)> = order
            .into_iter()
            .map(|i| {
                let r = &self.items[i];
                let rem = match self.discipline {
                    Discipline::Ps => (r.key - self.vtime) * r.rate,
                    Discipline::Fcfs | Discipline::Lcfs => r.key,
                };
                // Numerical dust only; a resident at exactly zero work
                // re-dispatches as an (immediately completing) ε-task.
                (r.task.clone(), rem.max(1e-12))
            })
            .collect();
        self.items.clear();
        self.head = 0;
        self.vtime = 0.0;
        self.work_time = 0.0;
        drained
    }

    /// Tasks of each type currently resident (invariant checks; compiled
    /// only with debug assertions so release builds pay nothing).
    #[cfg(debug_assertions)]
    pub fn count_type(&self, ttype: usize) -> u32 {
        self.items[self.head..]
            .iter()
            .filter(|r| r.task.ttype == ttype)
            // srclint: allow(as-truncation) — resident counts are bounded by per-processor queue capacity
            .count() as u32
    }

    /// Min-heap order on (virtual finish, seq).  This sift logic is
    /// intentionally kept separate from [`super::eventq::EventQueue`]'s:
    /// that heap is *indexed* (maintains a position map for
    /// decrease-key), this one is intrusive over [`Resident`]s with no
    /// removal-by-id — unifying them generically would complicate both
    /// hot paths.  Both are property-tested against linear references.
    #[inline]
    fn heap_less(a: &Resident, b: &Resident) -> bool {
        a.key < b.key || (a.key == b.key && a.seq < b.seq)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::heap_less(&self.items[i], &self.items[parent]) {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn pop_heap_root(&mut self) -> Resident {
        let root = self.items.swap_remove(0);
        // Sift the swapped-in element down.
        let len = self.items.len();
        let mut i = 0;
        loop {
            let (left, right) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if left < len && Self::heap_less(&self.items[left], &self.items[smallest]) {
                smallest = left;
            }
            if right < len && Self::heap_less(&self.items[right], &self.items[smallest]) {
                smallest = right;
            }
            if smallest == i {
                break;
            }
            self.items.swap(i, smallest);
            i = smallest;
        }
        root
    }
}

/// The seed's rescan-everything processor, preserved verbatim as the
/// reference implementation for trace-equivalence property tests: the
/// reworked [`Processor`] must produce event-for-event identical
/// completion sequences on fixed seeds (`tests/hotpath_equiv.rs`).
#[derive(Debug, Clone)]
pub struct ScalarProcessor {
    /// Column index in the affinity matrix.
    pub id: usize,
    discipline: Discipline,
    residents: Vec<ScalarResident>,
    last_update: f64,
    seq: u64,
}

#[derive(Debug, Clone)]
struct ScalarResident {
    task: Task,
    rate: f64,
    remaining: f64,
    seq: u64,
}

impl ScalarProcessor {
    /// Empty processor.
    pub fn new(id: usize, discipline: Discipline) -> Self {
        Self { id, discipline, residents: Vec::new(), last_update: 0.0, seq: 0 }
    }

    /// Number of resident tasks.
    pub fn occupancy(&self) -> usize {
        self.residents.len()
    }

    /// Σ remaining/rate, recomputed by full scan.
    pub fn remaining_work_time(&self) -> f64 {
        self.residents.iter().map(|r| r.remaining / r.rate).sum()
    }

    fn share(&self, idx: usize) -> f64 {
        let n = self.residents.len();
        match self.discipline {
            Discipline::Ps => 1.0 / n as f64,
            Discipline::Fcfs => {
                let head = self
                    .residents
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, r)| r.seq)
                    .map(|(i, _)| i);
                if head == Some(idx) {
                    1.0
                } else {
                    0.0
                }
            }
            Discipline::Lcfs => {
                let top = self
                    .residents
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, r)| r.seq)
                    .map(|(i, _)| i);
                if top == Some(idx) {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Progress all active residents to time `now` (O(n²) scan).
    pub fn advance(&mut self, now: f64) {
        let dt = now - self.last_update;
        debug_assert!(dt >= -1e-9, "time went backwards: {dt}");
        if dt > 0.0 && !self.residents.is_empty() {
            for idx in 0..self.residents.len() {
                let sh = self.share(idx);
                if sh > 0.0 {
                    let r = &mut self.residents[idx];
                    r.remaining -= dt * sh * r.rate;
                    if r.remaining < 0.0 {
                        debug_assert!(r.remaining > -1e-6, "{}", r.remaining);
                        r.remaining = 0.0;
                    }
                }
            }
        }
        self.last_update = now;
    }

    /// Admit a task (caller advanced to `now` first).
    pub fn push(&mut self, task: Task, rate: f64, now: f64) {
        debug_assert!(rate > 0.0);
        debug_assert!((now - self.last_update).abs() < 1e-9);
        let seq = self.seq;
        self.seq += 1;
        let remaining = task.size;
        self.residents.push(ScalarResident { task, rate, remaining, seq });
    }

    /// Absolute time of the next completion (O(n²) scan).
    pub fn next_completion(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for idx in 0..self.residents.len() {
            let sh = self.share(idx);
            if sh > 0.0 {
                let r = &self.residents[idx];
                let t = self.last_update + r.remaining / (sh * r.rate);
                best = Some(match best {
                    Some(b) => b.min(t),
                    None => t,
                });
            }
        }
        best
    }

    /// Remove the resident completing at `now`.
    pub fn pop_completed(&mut self, now: f64) -> Result<Task> {
        debug_assert!((now - self.last_update).abs() < 1e-9);
        let mut best: Option<(usize, f64)> = None;
        for idx in 0..self.residents.len() {
            if self.share(idx) > 0.0 {
                let rem = self.residents[idx].remaining;
                if best.map_or(true, |(_, b)| rem < b) {
                    best = Some((idx, rem));
                }
            }
        }
        let (idx, rem) = best.ok_or_else(|| {
            Error::Shape(format!("pop_completed on idle processor {}", self.id))
        })?;
        if rem > 1e-6 {
            return Err(Error::Shape(format!(
                "no task completing now on processor {} (residual {rem})",
                self.id
            )));
        }
        Ok(self.residents.swap_remove(idx).task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64, ttype: usize, size: f64) -> Task {
        Task { id, program: id as usize, ttype, size, arrive: 0.0 }
    }

    #[test]
    fn fcfs_serves_in_arrival_order() {
        let mut p = Processor::new(0, Discipline::Fcfs);
        p.push(task(1, 0, 2.0), 1.0, 0.0);
        p.push(task(2, 0, 1.0), 1.0, 0.0);
        // Head (task 1) completes at t=2 despite task 2 being shorter.
        let t = p.next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-12);
        p.advance(t);
        assert_eq!(p.pop_completed(t).unwrap().id, 1);
        // Then task 2 completes 1s later.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ps_shares_capacity_equally() {
        let mut p = Processor::new(0, Discipline::Ps);
        p.push(task(1, 0, 1.0), 1.0, 0.0);
        p.push(task(2, 0, 1.0), 1.0, 0.0);
        // Two equal tasks sharing: both complete at t=2.
        let t = p.next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-12);
        p.advance(t);
        let first = p.pop_completed(t).unwrap();
        assert!(first.id == 1 || first.id == 2);
        // Remaining one is already done too.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ps_rates_differ_by_task_type() {
        let mut p = Processor::new(0, Discipline::Ps);
        p.push(task(1, 0, 1.0), 4.0, 0.0); // fast type
        p.push(task(2, 1, 1.0), 1.0, 0.0); // slow type
        // Shares are 1/2 each: fast completes at 1/(4·0.5)=0.5.
        let t = p.next_completion().unwrap();
        assert!((t - 0.5).abs() < 1e-12);
        p.advance(t);
        assert_eq!(p.pop_completed(t).unwrap().id, 1);
        // Slow task did 0.5·0.5·1.0 = 0.25 work; 0.75 left at full rate 1.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 1.25).abs() < 1e-12);
    }

    #[test]
    fn lcfs_preempts() {
        let mut p = Processor::new(0, Discipline::Lcfs);
        p.push(task(1, 0, 1.0), 1.0, 0.0);
        p.advance(0.5);
        p.push(task(2, 0, 0.2), 1.0, 0.5);
        // Newcomer runs first: completes at 0.7.
        let t = p.next_completion().unwrap();
        assert!((t - 0.7).abs() < 1e-12);
        p.advance(t);
        assert_eq!(p.pop_completed(t).unwrap().id, 2);
        // Task 1 resumes with 0.5 work left: completes at 1.2.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 1.2).abs() < 1e-12);
    }

    #[test]
    fn work_conservation_across_disciplines() {
        // Same task multiset ⇒ same drain time for any discipline (Lemma 3).
        let sizes = [1.5, 0.3, 2.2, 0.7];
        let mut drains = Vec::new();
        for d in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut p = Processor::new(0, d);
            for (i, &s) in sizes.iter().enumerate() {
                p.push(task(i as u64, 0, s), 2.0, 0.0);
            }
            let mut now = 0.0;
            for _ in 0..sizes.len() {
                now = p.next_completion().unwrap();
                p.advance(now);
                p.pop_completed(now).unwrap();
            }
            drains.push(now);
        }
        let total: f64 = sizes.iter().sum::<f64>() / 2.0;
        for d in &drains {
            assert!((d - total).abs() < 1e-9, "{drains:?}");
        }
    }

    #[test]
    fn remaining_work_time_tracks_load() {
        let mut p = Processor::new(0, Discipline::Fcfs);
        assert_eq!(p.remaining_work_time(), 0.0);
        p.push(task(1, 0, 2.0), 2.0, 0.0);
        p.push(task(2, 0, 3.0), 1.0, 0.0);
        assert!((p.remaining_work_time() - 4.0).abs() < 1e-12);
        assert_eq!(p.occupancy(), 2);
        #[cfg(debug_assertions)]
        assert_eq!(p.count_type(0), 2);
        // The aggregate drains at exactly 1 per unit busy time.
        p.advance(0.5);
        assert!((p.remaining_work_time() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn busy_time_accumulates_only_while_occupied() {
        let mut p = Processor::new(0, Discipline::Fcfs);
        assert_eq!(p.busy_time(), 0.0);
        // Idle gap: no busy time accrues.
        p.advance(1.0);
        assert_eq!(p.busy_time(), 0.0);
        p.push(task(1, 0, 2.0), 1.0, 1.0);
        p.advance(2.5);
        assert!((p.busy_time() - 1.5).abs() < 1e-12);
        let t = p.next_completion().unwrap();
        p.advance(t);
        p.pop_completed(t).unwrap();
        assert!((p.busy_time() - 2.0).abs() < 1e-12);
        // Idle again after the queue drains.
        p.advance(t + 3.0);
        assert!((p.busy_time() - 2.0).abs() < 1e-12);
        // reset clears the accumulator.
        p.reset(Discipline::Fcfs);
        assert_eq!(p.busy_time(), 0.0);
    }

    #[test]
    fn drain_residents_returns_remaining_work_in_arrival_order() {
        for d in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut p = Processor::new(0, d);
            p.push(task(1, 0, 2.0), 1.0, 0.0);
            p.push(task(2, 1, 3.0), 1.0, 0.0);
            p.advance(1.0);
            let drained = p.drain_residents(1.0);
            assert_eq!(p.occupancy(), 0);
            assert!(p.next_completion().is_none());
            assert_eq!(p.remaining_work_time(), 0.0);
            let ids: Vec<u64> = drained.iter().map(|(t, _)| t.id).collect();
            assert_eq!(ids, vec![1, 2], "{d:?}: arrival order");
            // One unit of capacity was spent by t=1, split per discipline,
            // but the total remaining work is discipline-independent
            // (work conservation): 5 − 1 = 4.
            let total: f64 = drained.iter().map(|(_, r)| r).sum();
            assert!((total - 4.0).abs() < 1e-9, "{d:?}: {total}");
            // Busy-time accounting survives the drain.
            assert!((p.busy_time() - 1.0).abs() < 1e-12);
            // The emptied processor accepts fresh work normally.
            p.push(task(9, 0, 2.0), 2.0, 1.0);
            assert!((p.next_completion().unwrap() - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn drain_residents_ps_remaining_matches_shares() {
        let mut p = Processor::new(0, Discipline::Ps);
        p.push(task(1, 0, 4.0), 2.0, 0.0); // drains at 2·(1/2)=1 per s
        p.push(task(2, 0, 6.0), 1.0, 0.0); // drains at 0.5 per s
        p.advance(2.0);
        let drained = p.drain_residents(2.0);
        let rem: Vec<f64> = drained.iter().map(|(_, r)| *r).collect();
        assert!((rem[0] - 2.0).abs() < 1e-9, "{rem:?}");
        assert!((rem[1] - 5.0).abs() < 1e-9, "{rem:?}");
    }

    #[test]
    fn pop_on_idle_errors() {
        let mut p = Processor::new(0, Discipline::Ps);
        assert!(p.pop_completed(0.0).is_err());
        assert!(p.next_completion().is_none());
    }

    #[test]
    fn reset_clears_state_for_reuse() {
        let mut p = Processor::new(3, Discipline::Ps);
        p.push(task(1, 0, 1.0), 1.0, 0.0);
        p.advance(0.25);
        p.reset(Discipline::Fcfs);
        assert_eq!(p.occupancy(), 0);
        assert_eq!(p.remaining_work_time(), 0.0);
        assert!(p.next_completion().is_none());
        // Fresh run after reset behaves like a new processor.
        p.push(task(9, 0, 2.0), 1.0, 0.0);
        assert!((p.next_completion().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn scalar_and_fast_agree_on_a_mixed_sequence() {
        // Interleaved pushes/pops at uneven times, every discipline: the
        // reworked processor tracks the seed reference exactly.
        use crate::sim::rng::Rng;
        for d in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut rng = Rng::new(0xBEEF + d as u64);
            let mut fast = Processor::new(0, d);
            let mut slow = ScalarProcessor::new(0, d);
            let mut now = 0.0;
            let mut next_id = 0u64;
            for step in 0..400 {
                let push = fast.occupancy() == 0 || rng.bool_with(0.45);
                if push {
                    // Arrive a bit after `now` — but never beyond the
                    // pending completion: the engine contract is that
                    // `advance` only ever moves to event times.
                    let mut at = now + rng.range_f64(0.0, 0.3);
                    if let Some(tc) = fast.next_completion() {
                        at = at.min(tc);
                    }
                    let sz = rng.range_f64(0.1, 2.0);
                    let rate = rng.range_f64(0.5, 4.0);
                    let tk = task(next_id, (next_id % 2) as usize, sz);
                    next_id += 1;
                    fast.advance(at);
                    slow.advance(at);
                    fast.push(tk.clone(), rate, at);
                    slow.push(tk, rate, at);
                    now = at;
                } else {
                    let tf = fast.next_completion().unwrap();
                    let ts = slow.next_completion().unwrap();
                    assert!((tf - ts).abs() < 1e-9, "{d:?} step {step}: {tf} vs {ts}");
                    fast.advance(tf);
                    slow.advance(ts);
                    let a = fast.pop_completed(tf).unwrap();
                    let b = slow.pop_completed(ts).unwrap();
                    assert_eq!(a.id, b.id, "{d:?} step {step}");
                    now = tf;
                }
                assert_eq!(fast.occupancy(), slow.occupancy());
                assert!(
                    (fast.remaining_work_time() - slow.remaining_work_time()).abs() < 1e-6,
                    "{d:?} step {step}"
                );
            }
        }
    }
}
