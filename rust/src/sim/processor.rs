//! Processors with work-conserving service disciplines (Lemma 3).
//!
//! * **PS** — processor sharing: all resident tasks served at μ_ij/n
//!   (Eq. 5), the §5 simulation discipline.
//! * **FCFS** — head-of-line served at full rate, the §7 platform
//!   discipline.
//! * **LCFS** — preemptive-resume last-come-first-serve; included to
//!   demonstrate the Lemma-3 discipline independence.
//!
//! Between events the active-rate profile is constant, so the processor
//! advances remaining work lazily: `advance(now)` then mutate.

use super::task::Task;
use crate::error::{Error, Result};

/// Service discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Discipline {
    /// Processor sharing (time slicing), Eq. 5.
    Ps,
    /// First-come-first-serve.
    Fcfs,
    /// Preemptive-resume last-come-first-serve.
    Lcfs,
}

impl Discipline {
    /// Parse a CLI name.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "ps" => Ok(Discipline::Ps),
            "fcfs" => Ok(Discipline::Fcfs),
            "lcfs" => Ok(Discipline::Lcfs),
            other => Err(Error::Parse(format!(
                "unknown discipline '{other}' (ps|fcfs|lcfs)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            Discipline::Ps => "ps",
            Discipline::Fcfs => "fcfs",
            Discipline::Lcfs => "lcfs",
        }
    }
}

/// A task resident on a processor.
#[derive(Debug, Clone)]
struct Resident {
    task: Task,
    /// Full-speed service rate μ_ij for this task on this processor.
    rate: f64,
    /// Remaining work units.
    remaining: f64,
    /// Arrival order stamp (discipline ordering).
    seq: u64,
}

/// One processor (or cluster thereof) with a service discipline.
#[derive(Debug, Clone)]
pub struct Processor {
    /// Column index in the affinity matrix.
    pub id: usize,
    discipline: Discipline,
    residents: Vec<Resident>,
    last_update: f64,
    seq: u64,
}

impl Processor {
    /// Empty processor.
    pub fn new(id: usize, discipline: Discipline) -> Self {
        Self { id, discipline, residents: Vec::new(), last_update: 0.0, seq: 0 }
    }

    /// Number of resident tasks.
    #[inline]
    pub fn occupancy(&self) -> usize {
        self.residents.len()
    }

    /// Remaining work in *time* units at full speed — the perfect-info
    /// load-balancing metric of §5 ("task total size in the queue",
    /// measured in drain time).
    pub fn remaining_work_time(&self) -> f64 {
        self.residents.iter().map(|r| r.remaining / r.rate).sum()
    }

    /// Share of the processor each resident currently receives, by index.
    fn share(&self, idx: usize) -> f64 {
        let n = self.residents.len();
        match self.discipline {
            Discipline::Ps => 1.0 / n as f64,
            Discipline::Fcfs => {
                // Oldest seq is served.
                let head = self
                    .residents
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, r)| r.seq)
                    .map(|(i, _)| i);
                if head == Some(idx) {
                    1.0
                } else {
                    0.0
                }
            }
            Discipline::Lcfs => {
                let top = self
                    .residents
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, r)| r.seq)
                    .map(|(i, _)| i);
                if top == Some(idx) {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Progress all active residents to time `now`.
    pub fn advance(&mut self, now: f64) {
        let dt = now - self.last_update;
        debug_assert!(dt >= -1e-9, "time went backwards: {dt}");
        if dt > 0.0 && !self.residents.is_empty() {
            for idx in 0..self.residents.len() {
                let sh = self.share(idx);
                if sh > 0.0 {
                    let r = &mut self.residents[idx];
                    r.remaining -= dt * sh * r.rate;
                    if r.remaining < 0.0 {
                        // Numerical dust only; completions are popped at
                        // their exact event time.
                        debug_assert!(r.remaining > -1e-6, "{}", r.remaining);
                        r.remaining = 0.0;
                    }
                }
            }
        }
        self.last_update = now;
    }

    /// Admit a task with its full-speed rate; caller must have advanced
    /// the processor to `now` first.
    pub fn push(&mut self, task: Task, rate: f64, now: f64) {
        debug_assert!(rate > 0.0);
        debug_assert!((now - self.last_update).abs() < 1e-9);
        let seq = self.seq;
        self.seq += 1;
        self.residents.push(Resident { task, rate, remaining: f64::NAN, seq });
        let r = self.residents.last_mut().unwrap();
        r.remaining = r.task.size;
    }

    /// Absolute time of the next completion if no further events occur.
    pub fn next_completion(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for idx in 0..self.residents.len() {
            let sh = self.share(idx);
            if sh > 0.0 {
                let r = &self.residents[idx];
                let t = self.last_update + r.remaining / (sh * r.rate);
                best = Some(match best {
                    Some(b) => b.min(t),
                    None => t,
                });
            }
        }
        best
    }

    /// Remove and return the resident completing at `now` (the active one
    /// with the least residual).  Caller must `advance(now)` first.
    pub fn pop_completed(&mut self, now: f64) -> Result<Task> {
        debug_assert!((now - self.last_update).abs() < 1e-9);
        let mut best: Option<(usize, f64)> = None;
        for idx in 0..self.residents.len() {
            if self.share(idx) > 0.0 {
                let rem = self.residents[idx].remaining;
                if best.map_or(true, |(_, b)| rem < b) {
                    best = Some((idx, rem));
                }
            }
        }
        let (idx, rem) = best.ok_or_else(|| {
            Error::Shape(format!("pop_completed on idle processor {}", self.id))
        })?;
        if rem > 1e-6 {
            return Err(Error::Shape(format!(
                "no task completing now on processor {} (residual {rem})",
                self.id
            )));
        }
        Ok(self.residents.swap_remove(idx).task)
    }

    /// Tasks of each type currently resident (for invariant checks).
    pub fn count_type(&self, ttype: usize) -> u32 {
        self.residents.iter().filter(|r| r.task.ttype == ttype).count() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64, ttype: usize, size: f64) -> Task {
        Task { id, program: id as usize, ttype, size, arrive: 0.0 }
    }

    #[test]
    fn fcfs_serves_in_arrival_order() {
        let mut p = Processor::new(0, Discipline::Fcfs);
        p.push(task(1, 0, 2.0), 1.0, 0.0);
        p.push(task(2, 0, 1.0), 1.0, 0.0);
        // Head (task 1) completes at t=2 despite task 2 being shorter.
        let t = p.next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-12);
        p.advance(t);
        assert_eq!(p.pop_completed(t).unwrap().id, 1);
        // Then task 2 completes 1s later.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ps_shares_capacity_equally() {
        let mut p = Processor::new(0, Discipline::Ps);
        p.push(task(1, 0, 1.0), 1.0, 0.0);
        p.push(task(2, 0, 1.0), 1.0, 0.0);
        // Two equal tasks sharing: both complete at t=2.
        let t = p.next_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-12);
        p.advance(t);
        let first = p.pop_completed(t).unwrap();
        assert!(first.id == 1 || first.id == 2);
        // Remaining one is already done too.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ps_rates_differ_by_task_type() {
        let mut p = Processor::new(0, Discipline::Ps);
        p.push(task(1, 0, 1.0), 4.0, 0.0); // fast type
        p.push(task(2, 1, 1.0), 1.0, 0.0); // slow type
        // Shares are 1/2 each: fast completes at 1/(4·0.5)=0.5.
        let t = p.next_completion().unwrap();
        assert!((t - 0.5).abs() < 1e-12);
        p.advance(t);
        assert_eq!(p.pop_completed(t).unwrap().id, 1);
        // Slow task did 0.5·0.5·1.0 = 0.25 work; 0.75 left at full rate 1.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 1.25).abs() < 1e-12);
    }

    #[test]
    fn lcfs_preempts() {
        let mut p = Processor::new(0, Discipline::Lcfs);
        p.push(task(1, 0, 1.0), 1.0, 0.0);
        p.advance(0.5);
        p.push(task(2, 0, 0.2), 1.0, 0.5);
        // Newcomer runs first: completes at 0.7.
        let t = p.next_completion().unwrap();
        assert!((t - 0.7).abs() < 1e-12);
        p.advance(t);
        assert_eq!(p.pop_completed(t).unwrap().id, 2);
        // Task 1 resumes with 0.5 work left: completes at 1.2.
        let t2 = p.next_completion().unwrap();
        assert!((t2 - 1.2).abs() < 1e-12);
    }

    #[test]
    fn work_conservation_across_disciplines() {
        // Same task multiset ⇒ same drain time for any discipline (Lemma 3).
        let sizes = [1.5, 0.3, 2.2, 0.7];
        let mut drains = Vec::new();
        for d in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut p = Processor::new(0, d);
            for (i, &s) in sizes.iter().enumerate() {
                p.push(task(i as u64, 0, s), 2.0, 0.0);
            }
            let mut now = 0.0;
            for _ in 0..sizes.len() {
                now = p.next_completion().unwrap();
                p.advance(now);
                p.pop_completed(now).unwrap();
            }
            drains.push(now);
        }
        let total: f64 = sizes.iter().sum::<f64>() / 2.0;
        for d in &drains {
            assert!((d - total).abs() < 1e-9, "{drains:?}");
        }
    }

    #[test]
    fn remaining_work_time_tracks_load() {
        let mut p = Processor::new(0, Discipline::Fcfs);
        assert_eq!(p.remaining_work_time(), 0.0);
        p.push(task(1, 0, 2.0), 2.0, 0.0);
        p.push(task(2, 0, 3.0), 1.0, 0.0);
        assert!((p.remaining_work_time() - 4.0).abs() < 1e-12);
        assert_eq!(p.occupancy(), 2);
        assert_eq!(p.count_type(0), 2);
    }

    #[test]
    fn pop_on_idle_errors() {
        let mut p = Processor::new(0, Discipline::Ps);
        assert!(p.pop_completed(0.0).is_err());
        assert!(p.next_completion().is_none());
    }
}
