//! Parallel replication runner — the §5 methodology at fleet scale.
//!
//! Every figure in the paper is thousands of runs × tens of thousands of
//! completions; the multi-processor-type scenarios of the follow-up work
//! (arXiv:1711.06433, arXiv:1712.03246) need far more simulated
//! configurations still.  This module fans R seeded replications × S
//! scenario cells across cores with nothing but `std::thread`:
//!
//! * **work stealing by atomic counter** — workers pull the next job
//!   index from a shared `AtomicUsize`, so imbalanced cells never idle a
//!   core;
//! * **per-thread arenas** — each worker owns one [`SimArena`];
//!   processors, programs, work buffers and the event heap are allocated
//!   once per thread and reset between runs (zero net allocation per
//!   replication once warm, gated by `tests/arena_alloc.rs`);
//! * **deterministic regardless of thread count** — replication seeds
//!   are derived from (base seed, cell, rep) alone and every result is
//!   written to its own pre-assigned slot, so a 16-thread sweep is
//!   bit-identical to a single-threaded one.
//!
//! Each cell reports mean and a 95% confidence interval over its
//! replications, with Student-t critical values so small replication
//! counts (`--reps 5`) get honestly wide intervals instead of the
//! normal approximation's overconfident ±1.96·se.

// srclint: allow-file(index-reachable) — per-replica slots are preallocated one per job

use crate::sync::{AtomicUsize, Mutex, MutexGuard, Ordering};

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::policy::PolicyKind;

use super::dynamic::{run_dynamic_report, DynamicConfig};
use super::engine::{ClosedNetwork, SimArena, SimConfig};
use super::rng::SplitMix64;

/// How to fan out: replication count, worker threads, base seed.
#[derive(Debug, Clone)]
pub struct ReplicationPlan {
    /// Seeded replications per cell (R).
    pub reps: u32,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Base seed mixed into every replication seed.
    pub base_seed: u64,
}

impl Default for ReplicationPlan {
    fn default() -> Self {
        Self { reps: 16, threads: 0, base_seed: 0x5EED }
    }
}

impl ReplicationPlan {
    /// The worker count actually used.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// One sweep cell: a (system, policy) configuration replicated R times.
#[derive(Debug, Clone)]
pub struct SimCell {
    /// Display label ("eta=0.3 CAB", …).
    pub label: String,
    /// Affinity matrix of this cell.
    pub mu: AffinityMatrix,
    /// Run configuration; `seed` acts as a per-cell salt, the plan's
    /// replication seeds are derived on top of it.
    pub sim: SimConfig,
    /// Policy under test (built fresh per replication).
    pub policy: PolicyKind,
}

/// Aggregated replication statistics for one cell.
#[derive(Debug, Clone)]
pub struct CellStats {
    /// The cell's label.
    pub label: String,
    /// Replications aggregated.
    pub reps: u32,
    /// Mean throughput X̄ across replications.
    pub mean_x: f64,
    /// Sample standard deviation of X.
    pub sd_x: f64,
    /// 95% CI half-width for X̄ (t·sd/√R, Student-t critical value for
    /// R − 1 degrees of freedom; 1.96 beyond df = 30).
    pub ci95_x: f64,
    /// Mean response time E[T] across replications.
    pub mean_response: f64,
    /// 95% CI half-width for E[T] (t-corrected like `ci95_x`).
    pub ci95_response: f64,
    /// Mean per-task energy E[ℰ] across replications (Eq. 19 metering
    /// under the cell's power profile).
    pub mean_energy: f64,
    /// 95% CI half-width for E[ℰ] (t-corrected like `ci95_x`).
    pub ci95_energy: f64,
    /// Mean energy–delay product (Eq. 21) across replications.
    pub mean_edp: f64,
}

/// Deterministic replication seed: depends only on (base, cell salt,
/// cell index, rep index) — never on thread scheduling.
fn rep_seed(base: u64, cell_salt: u64, cell: usize, rep: u32) -> u64 {
    let mut sm = SplitMix64::new(base ^ cell_salt.rotate_left(17));
    let salt = sm.next() ^ (((cell as u64) << 32) | rep as u64);
    SplitMix64::new(salt).next()
}

/// Lock a replication-runner mutex, propagating worker panics.
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // srclint: allow(hot-path-panic) — poisoning re-raises a worker panic, the right failure mode for a sweep.
    m.lock().expect("replication mutex poisoned")
}

/// Run every cell × replication across the plan's worker threads and
/// aggregate per-cell statistics (in cell order).
pub fn run_cells(cells: &[SimCell], plan: &ReplicationPlan) -> Result<Vec<CellStats>> {
    if cells.is_empty() || plan.reps == 0 {
        return Err(Error::Config("replication sweep needs ≥1 cell and ≥1 rep".into()));
    }
    let reps = plan.reps as usize;
    let jobs = cells.len() * reps;
    let threads = plan.effective_threads().clamp(1, jobs);
    let next = AtomicUsize::new(0);
    // (throughput, mean response, energy/task, EDP) per job,
    // slot-addressed so aggregation order — and therefore every fp sum
    // — is independent of scheduling.
    let results: Mutex<Vec<Option<(f64, f64, f64, f64)>>> = Mutex::new(vec![None; jobs]);
    let failure: Mutex<Option<Error>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut arena = SimArena::new();
                loop {
                    // ordering: Relaxed — the counter only hands out unique job
                    // indices; result slots are published by the Mutex below.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs {
                        break;
                    }
                    if locked(&failure).is_some() {
                        break;
                    }
                    // srclint: allow(as-truncation) — i % reps is strictly below the replica count, a u32-scale parameter
                    let (c, r) = (i / reps, (i % reps) as u32);
                    let cell = &cells[c];
                    let mut cfg = cell.sim.clone();
                    cfg.seed = rep_seed(plan.base_seed, cell.sim.seed, c, r);
                    let run = ClosedNetwork::new(&cell.mu, cfg).and_then(|net| {
                        let mut policy = cell.policy.build();
                        net.run_in(policy.as_mut(), &mut arena)
                    });
                    match run {
                        Ok(res) => {
                            locked(&results)[i] = Some((
                                res.throughput,
                                res.mean_response,
                                res.mean_energy,
                                res.edp,
                            ));
                        }
                        Err(e) => {
                            *locked(&failure) = Some(e);
                            break;
                        }
                    }
                }
            });
        }
    });

    // srclint: allow(hot-path-panic) — into_inner after every worker joined; poisoning re-raises a worker panic.
    if let Some(e) = failure.into_inner().expect("failure lock") {
        return Err(e);
    }
    // srclint: allow(hot-path-panic) — same join-then-unwrap pattern as the failure flag above.
    let results = results.into_inner().expect("results lock");
    let mut out = Vec::with_capacity(cells.len());
    for (c, cell) in cells.iter().enumerate() {
        let slice = &results[c * reps..(c + 1) * reps];
        let mut xs = Vec::with_capacity(reps);
        let mut ts = Vec::with_capacity(reps);
        let mut es = Vec::with_capacity(reps);
        let mut ds = Vec::with_capacity(reps);
        for slot in slice {
            let (x, t, e, dp) = slot.ok_or_else(|| {
                Error::Runtime(format!("cell '{}' missing a replication", cell.label))
            })?;
            xs.push(x);
            ts.push(t);
            es.push(e);
            ds.push(dp);
        }
        let (mean_x, sd_x, ci95_x) = mean_sd_ci(&xs);
        let (mean_response, _, ci95_response) = mean_sd_ci(&ts);
        let (mean_energy, _, ci95_energy) = mean_sd_ci(&es);
        let (mean_edp, _, _) = mean_sd_ci(&ds);
        out.push(CellStats {
            label: cell.label.clone(),
            reps: plan.reps,
            mean_x,
            sd_x,
            ci95_x,
            mean_response,
            ci95_response,
            mean_energy,
            ci95_energy,
            mean_edp,
        });
    }
    Ok(out)
}

/// One dynamic-scenario cell: a (system, resolve-mode, policy)
/// configuration replicated R times — the unit of work behind
/// `hetsched scenario --compare`, where the single-leader and sharded
/// arms are A/B'd over identical seeded replications.
#[derive(Debug, Clone)]
pub struct DynCell {
    /// Display label ("adaptive", "sharded", …).
    pub label: String,
    /// Baseline affinity matrix (phases rescale it).
    pub mu: AffinityMatrix,
    /// Dynamic run configuration; its `seed` acts as the per-cell salt,
    /// replication seeds are derived on top of it.
    pub cfg: DynamicConfig,
    /// Policy under test (built fresh per replication; ignored by the
    /// sharded resolve mode, which always steers by batched GrIn).
    pub policy: PolicyKind,
}

/// Aggregated replication statistics for one dynamic cell.
#[derive(Debug, Clone)]
pub struct DynCellStats {
    /// The cell's label.
    pub label: String,
    /// Replications aggregated.
    pub reps: u32,
    /// Mean of the completion-weighted mean throughput across
    /// replications.
    pub mean_x: f64,
    /// Sample standard deviation of that mean throughput.
    pub sd_x: f64,
    /// 95% CI half-width (t·sd/√R, Student-t critical value for R − 1
    /// degrees of freedom; 1.96 beyond df = 30).
    pub ci95_x: f64,
    /// Mean re-solve count per replication.
    pub mean_resolves: f64,
    /// Mean per-class throughput across replications (completion-
    /// weighted within each run, [`DynamicReport::class_throughput`]) —
    /// the per-tier signal of the priority subsystem.
    pub mean_class_x: Vec<f64>,
    /// Mean per-class deadline-miss rate across replications (all zero
    /// when the cell configures no deadlines).
    pub mean_miss_rate: Vec<f64>,
    /// Mean per-task energy across replications
    /// ([`super::dynamic::DynamicReport::mean_energy`] per run) — the
    /// A/B signal of the energy-objective arm.
    pub mean_energy: f64,
    /// Mean count of tasks re-dispatched off failed devices per
    /// replication (0 outside fault-injected cells).
    pub mean_redispatched: f64,
    /// Mean fraction of device-time lost to injected faults
    /// ([`super::dynamic::DynamicReport::mean_downtime_frac`] per run).
    pub mean_downtime_frac: f64,
}

/// Fan R seeded replications of each dynamic cell across the worker
/// pool.  Seeds derive from (base seed, cell salt, cell, rep) exactly
/// as in [`run_cells`] and results land in pre-assigned slots, so the
/// aggregate is thread-count independent bit for bit.
pub fn run_dynamic_cells(cells: &[DynCell], plan: &ReplicationPlan) -> Result<Vec<DynCellStats>> {
    if cells.is_empty() || plan.reps == 0 {
        return Err(Error::Config("replication sweep needs ≥1 cell and ≥1 rep".into()));
    }
    let reps = plan.reps as usize;
    let jobs: Vec<(usize, u32)> = (0..cells.len())
        .flat_map(|c| (0..plan.reps).map(move |r| (c, r)))
        .collect();
    type RunStats = (f64, u64, Vec<f64>, Vec<f64>, f64, u64, f64);
    let runs: Vec<Result<RunStats>> = parallel_map(&jobs, plan.threads, |_, &(c, r)| {
        let cell = &cells[c];
        let mut cfg = cell.cfg.clone();
        cfg.seed = rep_seed(plan.base_seed, cell.cfg.seed, c, r);
        let mut policy = cell.policy.build();
        run_dynamic_report(&cell.mu, &cfg, policy.as_mut()).and_then(|report| {
            // Conservation is a hard invariant of the fault machinery,
            // not a statistic: a replication that lost a task poisons
            // the whole sweep.
            if report.tasks_lost > 0 {
                return Err(Error::Runtime(format!(
                    "cell '{}' rep {r} lost {} task(s) under its fault plan",
                    cell.label, report.tasks_lost
                )));
            }
            let k = cell.mu.types();
            let class_x: Vec<f64> = (0..k).map(|i| report.class_throughput(i)).collect();
            let miss: Vec<f64> = (0..k).map(|i| report.deadline_miss_rate(i)).collect();
            Ok((
                report.mean_throughput(),
                report.resolves,
                class_x,
                miss,
                report.mean_energy(),
                report.tasks_redispatched,
                report.mean_downtime_frac(),
            ))
        })
    });
    let mut it = runs.into_iter();
    let mut out = Vec::with_capacity(cells.len());
    for cell in cells {
        let k = cell.mu.types();
        let mut xs = Vec::with_capacity(reps);
        let mut es = Vec::with_capacity(reps);
        let mut downs = Vec::with_capacity(reps);
        let mut resolve_total = 0u64;
        let mut redispatch_total = 0u64;
        let mut class_x_sum = vec![0.0f64; k];
        let mut miss_sum = vec![0.0f64; k];
        for _ in 0..reps {
            // srclint: allow(hot-path-panic) — parallel_map returns exactly one slot per job by construction.
            let (x, resolves, class_x, miss, energy, redispatched, downtime) =
                it.next().expect("one slot per job")?;
            xs.push(x);
            es.push(energy);
            downs.push(downtime);
            resolve_total += resolves;
            redispatch_total += redispatched;
            for (acc, v) in class_x_sum.iter_mut().zip(&class_x) {
                *acc += v;
            }
            for (acc, v) in miss_sum.iter_mut().zip(&miss) {
                *acc += v;
            }
        }
        let (mean_x, sd_x, ci95_x) = mean_sd_ci(&xs);
        let (mean_energy, _, _) = mean_sd_ci(&es);
        let (mean_downtime_frac, _, _) = mean_sd_ci(&downs);
        out.push(DynCellStats {
            label: cell.label.clone(),
            reps: plan.reps,
            mean_x,
            sd_x,
            ci95_x,
            mean_resolves: resolve_total as f64 / reps as f64,
            mean_class_x: class_x_sum.iter().map(|s| s / reps as f64).collect(),
            mean_miss_rate: miss_sum.iter().map(|s| s / reps as f64).collect(),
            mean_energy,
            mean_redispatched: redispatch_total as f64 / reps as f64,
            mean_downtime_frac,
        });
    }
    Ok(out)
}

/// Two-sided 95% Student-t critical values for df = 1..=30; beyond 30
/// degrees of freedom the normal 1.96 is used (t(0.975, 31) ≈ 2.040,
/// so the cut-over understates the half-width by ≤ 4%, shrinking as R
/// grows).  Small replication counts (`--reps 5`) are the norm for
/// quick sweeps, and the normal value there is badly overconfident
/// (df = 4 needs 2.776, not 1.96 — a 42% wider interval).
const T95: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, //
    2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, //
    2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
];

/// 95% critical value for a CI on the mean of `n` replications.
fn t95(n: usize) -> f64 {
    match n.saturating_sub(1) {
        0 => 0.0,
        df if df <= T95.len() => T95[df - 1],
        _ => 1.96,
    }
}

/// Mean, sample sd and 95% CI half-width (Student-t corrected for small
/// samples) of a replication sample.
fn mean_sd_ci(xs: &[f64]) -> (f64, f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    let sd = var.sqrt();
    (mean, sd, t95(xs.len()) * sd / n.sqrt())
}

/// Fan an arbitrary job list across `threads` workers (0 = one per
/// core), preserving item order in the result.  The generic sibling of
/// [`run_cells`] for heterogeneous work — `hetsched scenario --compare`
/// runs its three resolve modes through it, and the ablation benches
/// their arms.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = (if threads > 0 { threads } else { auto }).clamp(1, items.len());
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..items.len()).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // ordering: Relaxed — hands out unique indices only; slots publish via the Mutex.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                locked(&out)[i] = Some(r);
            });
        }
    });
    out.into_inner()
        // srclint: allow(hot-path-panic) — into_inner after every worker joined; poisoning re-raises a worker panic.
        .expect("parallel_map lock")
        .into_iter()
        // srclint: allow(hot-path-panic) — every index below items len was handed out and filled.
        .map(|slot| slot.expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload;

    fn quick_cells() -> Vec<SimCell> {
        let mu = workload::paper_two_type_mu();
        [PolicyKind::Cab, PolicyKind::Jsq]
            .into_iter()
            .map(|policy| {
                let mut sim = SimConfig::paper_default(vec![10, 10]);
                sim.warmup = 100;
                sim.measure = 1_200;
                SimCell {
                    label: policy.name().to_string(),
                    mu: mu.clone(),
                    sim,
                    policy,
                }
            })
            .collect()
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let cells = quick_cells();
        let mk = |threads| ReplicationPlan { reps: 6, threads, base_seed: 42 };
        let one = run_cells(&cells, &mk(1)).unwrap();
        let four = run_cells(&cells, &mk(4)).unwrap();
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.mean_x.to_bits(), b.mean_x.to_bits(), "{}", a.label);
            assert_eq!(a.ci95_x.to_bits(), b.ci95_x.to_bits(), "{}", a.label);
            // The energy aggregates are slot-ordered too.
            assert_eq!(a.mean_energy.to_bits(), b.mean_energy.to_bits(), "{}", a.label);
            assert_eq!(a.ci95_energy.to_bits(), b.ci95_energy.to_bits(), "{}", a.label);
            assert_eq!(a.mean_edp.to_bits(), b.mean_edp.to_bits(), "{}", a.label);
            assert!(a.mean_energy > 0.0 && a.mean_edp > 0.0, "{}", a.label);
        }
    }

    #[test]
    fn stats_are_sane_and_cab_wins() {
        let cells = quick_cells();
        let plan = ReplicationPlan { reps: 8, threads: 0, base_seed: 7 };
        let stats = run_cells(&cells, &plan).unwrap();
        let (cab, jsq) = (&stats[0], &stats[1]);
        assert_eq!(cab.reps, 8);
        assert!(cab.mean_x > 0.0 && cab.ci95_x >= 0.0);
        // Distinct seeds ⇒ genuine replication spread.
        assert!(cab.sd_x > 0.0, "replications identical?");
        // The CI is t-corrected: for R = 8 the half-width is exactly
        // t(7)·sd/√8, wider than the normal approximation's 1.96·sd/√8.
        let want = 2.365 * cab.sd_x / (8f64).sqrt();
        assert!((cab.ci95_x - want).abs() < 1e-12, "CI {} vs t-corrected {want}", cab.ci95_x);
        assert!(cab.ci95_x > 1.96 * cab.sd_x / (8f64).sqrt());
        assert!(cab.mean_x >= jsq.mean_x * 0.999, "CAB {} vs JSQ {}", cab.mean_x, jsq.mean_x);
        // Smaller samples still aggregate cleanly — R = 2 runs on one
        // degree of freedom, so the t correction (12.706 vs 1.96) is
        // at its most material.
        let wide = run_cells(&cells, &ReplicationPlan { reps: 2, threads: 2, base_seed: 7 })
            .unwrap();
        assert!(wide[0].ci95_x.is_finite() && wide[0].ci95_x >= 0.0);
        let want = 12.706 * wide[0].sd_x / (2f64).sqrt();
        assert!((wide[0].ci95_x - want).abs() < 1e-12);
    }

    #[test]
    fn t_critical_values_cover_small_samples_then_fall_back_to_normal() {
        // n = 1: no CI.  n = 2..=31: the table (df = n − 1).  Beyond:
        // the normal value.
        assert_eq!(t95(0), 0.0);
        assert_eq!(t95(1), 0.0);
        assert_eq!(t95(2), 12.706);
        assert_eq!(t95(5), 2.776);
        assert_eq!(t95(31), 2.042);
        assert_eq!(t95(32), 1.96);
        assert_eq!(t95(1000), 1.96);
        // Monotone decreasing toward the normal limit.
        for n in 2..32 {
            assert!(t95(n) > t95(n + 1) - 1e-12, "t95 not monotone at {n}");
            assert!(t95(n) >= 1.96);
        }
        // mean_sd_ci applies it.
        let (mean, sd, ci) = mean_sd_ci(&[1.0, 3.0]);
        assert_eq!(mean, 2.0);
        assert!((sd - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert!((ci - 12.706 * sd / std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn dynamic_cells_replicate_and_are_thread_count_independent() {
        use crate::sim::dynamic::{DynamicConfig, Phase, ResolveMode};
        let mu = workload::paper_two_type_mu();
        let cells: Vec<DynCell> = [ResolveMode::Adaptive, ResolveMode::Sharded]
            .into_iter()
            .map(|mode| {
                let mut cfg = DynamicConfig::new(vec![
                    Phase::new(vec![6, 6], 50, 600),
                    Phase::new(vec![2, 10], 50, 600),
                ]);
                cfg.resolve = mode;
                cfg.seed = 19;
                DynCell {
                    label: mode.name().to_string(),
                    mu: mu.clone(),
                    cfg,
                    policy: PolicyKind::GrIn,
                }
            })
            .collect();
        let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 11 };
        let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
        let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
        assert_eq!(one.len(), 2);
        for (a, b) in one.iter().zip(&four) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.mean_x.to_bits(), b.mean_x.to_bits(), "{}", a.label);
            assert_eq!(a.ci95_x.to_bits(), b.ci95_x.to_bits(), "{}", a.label);
            assert!(a.mean_x > 0.0);
            // The per-class aggregates are slot-ordered too.
            assert_eq!(a.mean_class_x.len(), 2);
            for (ax, bx) in a.mean_class_x.iter().zip(&b.mean_class_x) {
                assert_eq!(ax.to_bits(), bx.to_bits(), "{}", a.label);
            }
            assert!(a.mean_miss_rate.iter().all(|&m| m == 0.0));
            assert_eq!(a.mean_energy.to_bits(), b.mean_energy.to_bits(), "{}", a.label);
            assert!(a.mean_energy > 0.0, "{}", a.label);
            // No fault plan ⇒ the churn metrics stay exactly zero.
            assert_eq!(a.mean_redispatched, 0.0, "{}", a.label);
            assert_eq!(a.mean_downtime_frac, 0.0, "{}", a.label);
        }
        assert!(run_dynamic_cells(&[], &mk(1)).is_err());
    }

    #[test]
    fn churn_cells_aggregate_fault_metrics_and_stay_deterministic() {
        use crate::sim::dynamic::{DynamicConfig, ResolveMode};
        use crate::sim::workload::{churn_fault_plan, scenario_phases, ScenarioKind, ScenarioParams};
        let mu = workload::paper_two_type_mu();
        let p = ScenarioParams { phases: 3, completions: 600, warmup: 50, ..Default::default() };
        let mut cfg = DynamicConfig::new(scenario_phases(ScenarioKind::Churn, &p).unwrap());
        cfg.resolve = ResolveMode::Adaptive;
        cfg.faults = churn_fault_plan(&mu, &p).unwrap();
        cfg.seed = 23;
        let cells = vec![DynCell {
            label: "churn".into(),
            mu: mu.clone(),
            cfg,
            policy: PolicyKind::GrIn,
        }];
        let mk = |threads| ReplicationPlan { reps: 3, threads, base_seed: 5 };
        let one = run_dynamic_cells(&cells, &mk(1)).unwrap();
        let four = run_dynamic_cells(&cells, &mk(4)).unwrap();
        let (a, b) = (&one[0], &four[0]);
        // The churn aggregates are slot-ordered like everything else:
        // bit-identical regardless of worker count.
        assert_eq!(a.mean_x.to_bits(), b.mean_x.to_bits());
        assert_eq!(a.mean_redispatched.to_bits(), b.mean_redispatched.to_bits());
        assert_eq!(a.mean_downtime_frac.to_bits(), b.mean_downtime_frac.to_bits());
        // The plan's outage really bites: downtime is metered and the
        // evacuated work re-dispatched, never lost (run_dynamic_cells
        // fails the sweep on any lost task).
        assert!(a.mean_downtime_frac > 0.0);
        assert!(a.mean_redispatched > 0.0);
        assert!(a.mean_x > 0.0);
    }

    #[test]
    fn rep_seeds_are_distinct() {
        let mut seen = std::collections::HashSet::new();
        for c in 0..8 {
            for r in 0..16 {
                assert!(seen.insert(rep_seed(1, 99, c, r)), "collision at ({c},{r})");
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..37).collect();
        let out = parallel_map(&items, 4, |i, &x| x * 2 + i as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, items[i] * 2 + i as u64);
        }
        let empty: Vec<u64> = Vec::new();
        assert!(parallel_map(&empty, 4, |_, &x| x).is_empty());
    }

    #[test]
    fn rejects_empty_plans() {
        assert!(run_cells(&[], &ReplicationPlan::default()).is_err());
        let cells = quick_cells();
        let plan = ReplicationPlan { reps: 0, threads: 1, base_seed: 0 };
        assert!(run_cells(&cells, &plan).is_err());
    }
}
