//! Scenario builders for the paper's sweeps.
//!
//! * η-sweeps of the two-type system (Figs. 4–8, 15–16): N = 20 programs,
//!   N1 = η·N of type 1.
//! * random k×l systems (Figs. 9–14): μ entries uniform, random
//!   populations — the paper randomizes both "to show the generality of
//!   GrIn for widely varying task affinities".

use crate::error::Result;
use crate::model::affinity::AffinityMatrix;

use super::rng::Rng;

/// The paper's η grid: 0.1, 0.2, …, 0.9 (§5).
pub fn eta_grid() -> [f64; 9] {
    [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
}

/// Split N programs into (N1, N2) with N1 = round(η·N), clamped so both
/// types stay populated (the paper's η ∈ [0.1, 0.9] guarantees this).
pub fn split_populations(n: u32, eta: f64) -> (u32, u32) {
    let n1 = ((n as f64 * eta).round() as u32).clamp(1, n - 1);
    (n1, n - n1)
}

/// The §5 simulation affinity matrix (P1-biased): μ = [[20, 15], [3, 8]].
pub fn paper_two_type_mu() -> AffinityMatrix {
    AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).expect("static matrix")
}

/// Table-3 derived matrices for the §7 platform cases.
pub mod table3 {
    use super::*;

    /// quicksort-500 + NN-2000 → general-symmetric (§7.4).
    pub fn general_symmetric() -> AffinityMatrix {
        AffinityMatrix::two_type(928.0, 3.61, 587.0, 2398.0).expect("static matrix")
    }

    /// quicksort-1000 + NN-2000 → P2-biased (§7.3).
    pub fn p2_biased() -> AffinityMatrix {
        AffinityMatrix::two_type(253.0, 0.911, 587.0, 2398.0).expect("static matrix")
    }
}

/// A random k×l system: μ entries uniform in [lo, hi).
pub fn random_mu(rng: &mut Rng, k: usize, l: usize, lo: f64, hi: f64) -> Result<AffinityMatrix> {
    let rows: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..l).map(|_| rng.range_f64(lo, hi)).collect())
        .collect();
    AffinityMatrix::from_rows(&rows)
}

/// Random populations: each N_i uniform in [1, max_per_type].
pub fn random_populations(rng: &mut Rng, k: usize, max_per_type: u32) -> Vec<u32> {
    (0..k).map(|_| 1 + rng.below(max_per_type as u64) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;

    #[test]
    fn eta_split_covers_paper_grid() {
        for eta in eta_grid() {
            let (n1, n2) = split_populations(20, eta);
            assert_eq!(n1 + n2, 20);
            assert!(n1 >= 1 && n2 >= 1);
            assert_eq!(n1, (20.0 * eta).round() as u32);
        }
    }

    #[test]
    fn split_clamps_extremes() {
        assert_eq!(split_populations(10, 0.0), (1, 9));
        assert_eq!(split_populations(10, 1.0), (9, 1));
    }

    #[test]
    fn canned_matrices_classify_as_documented() {
        assert_eq!(paper_two_type_mu().classify().unwrap(), Regime::P1Biased);
        assert_eq!(
            table3::general_symmetric().classify().unwrap(),
            Regime::GeneralSymmetric
        );
        assert_eq!(table3::p2_biased().classify().unwrap(), Regime::P2Biased);
    }

    #[test]
    fn random_systems_are_valid() {
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let mu = random_mu(&mut rng, 3, 4, 0.5, 30.0).unwrap();
            assert_eq!(mu.types(), 3);
            assert_eq!(mu.procs(), 4);
            let pops = random_populations(&mut rng, 3, 8);
            assert!(pops.iter().all(|&p| (1..=8).contains(&p)));
        }
    }
}
