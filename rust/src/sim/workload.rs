//! Scenario builders for the paper's sweeps and for the non-stationary
//! extensions.
//!
//! * η-sweeps of the two-type system (Figs. 4–8, 15–16): N = 20 programs,
//!   N1 = η·N of type 1.
//! * random k×l systems (Figs. 9–14): μ entries uniform, random
//!   populations — the paper randomizes both "to show the generality of
//!   GrIn for widely varying task affinities".
//! * non-stationary schedules ([`ScenarioKind`]): phase-shift, burst,
//!   slow-drift and abrupt-flip regimes for the adaptive-scheduling and
//!   change-point-detection experiments (`hetsched scenario`,
//!   `tests/adaptive_e2e.rs`, `tests/cusum_e2e.rs`).

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::throughput::x_max_theoretical;

use super::distribution::Distribution;
use super::dynamic::{FaultEvent, FaultKind, FaultPlan, Phase};
use super::rng::Rng;

/// The paper's η grid: 0.1, 0.2, …, 0.9 (§5).
pub fn eta_grid() -> [f64; 9] {
    [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9]
}

/// Split N programs into (N1, N2) with N1 = round(η·N), clamped so both
/// types stay populated (the paper's η ∈ [0.1, 0.9] guarantees this).
pub fn split_populations(n: u32, eta: f64) -> (u32, u32) {
    // srclint: allow(as-truncation) — the rounded product is clamped to [1, n-1] on the same line
    let n1 = ((n as f64 * eta).round() as u32).clamp(1, n - 1);
    (n1, n - n1)
}

/// The §5 simulation affinity matrix (P1-biased): μ = [[20, 15], [3, 8]].
pub fn paper_two_type_mu() -> AffinityMatrix {
    AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0)
        // srclint: allow(hot-path-panic) — hard-coded paper constants are always a valid matrix.
        .expect("static matrix")
}

/// Table-3 derived matrices for the §7 platform cases.
pub mod table3 {
    use super::*;

    /// quicksort-500 + NN-2000 → general-symmetric (§7.4).
    pub fn general_symmetric() -> AffinityMatrix {
        AffinityMatrix::two_type(928.0, 3.61, 587.0, 2398.0)
        // srclint: allow(hot-path-panic) — hard-coded paper constants are always a valid matrix.
        .expect("static matrix")
    }

    /// quicksort-1000 + NN-2000 → P2-biased (§7.3).
    pub fn p2_biased() -> AffinityMatrix {
        AffinityMatrix::two_type(253.0, 0.911, 587.0, 2398.0)
        // srclint: allow(hot-path-panic) — hard-coded paper constants are always a valid matrix.
        .expect("static matrix")
    }

    /// The general-symmetric rates tiled across `l` devices (device j
    /// gets column j mod 2) — the default fleet for multi-device
    /// serving runs (`hetsched serve --devices L`).
    pub fn general_symmetric_tiled(l: usize) -> Result<AffinityMatrix> {
        let base = general_symmetric();
        let rows: Vec<Vec<f64>> = (0..2)
            .map(|i| (0..l).map(|j| base.rate(i, j % 2)).collect())
            .collect();
        AffinityMatrix::from_rows(&rows)
    }
}

/// Three device classes (big cores / little cores / accelerator) for
/// the k>2 sharded-coordination experiments: each task type has a
/// distinct preferred class.
pub fn three_class_mu() -> AffinityMatrix {
    AffinityMatrix::from_rows(&[
        vec![20.0, 8.0, 2.0],
        vec![5.0, 12.0, 3.0],
        vec![2.0, 4.0, 18.0],
    ])
    // srclint: allow(hot-path-panic) — hard-coded paper constants are always a valid matrix.
    .expect("static matrix")
}

/// Per-cell factors that rotate the class affinity of
/// [`three_class_mu`]: type 0's fast class moves 0 → 2 and type 2's
/// moves 2 → 0 (type 1 is untouched) — the three-class regime flip a
/// frozen global solve cannot see.
pub fn three_class_flip_scale() -> Vec<f64> {
    vec![0.1, 1.0, 9.0, 1.0, 1.0, 1.0, 9.0, 1.0, 0.1]
}

/// The contended-fast-device system of the priority experiments: both
/// task classes are fastest on P1 (class 1 marginally faster, so the
/// unweighted GrIn optimum crowds the low-priority majority onto it and
/// dilutes class 0), while P2 is a reasonable home for class 1
/// (μ = 16) but a terrible one for class 0 (μ = 3.5).  A 4:1
/// priority-weighted solve reserves P1 for class 0 at a ~1–3% total-X
/// cost — the trade `tests/priority_e2e.rs` and
/// `benches/ablation_priority.rs` quantify.
pub fn priority_mu() -> AffinityMatrix {
    AffinityMatrix::two_type(30.0, 3.5, 31.0, 16.0)
        // srclint: allow(hot-path-panic) — hard-coded paper constants are always a valid matrix.
        .expect("static matrix")
}

/// A random k×l system: μ entries uniform in [lo, hi).
pub fn random_mu(rng: &mut Rng, k: usize, l: usize, lo: f64, hi: f64) -> Result<AffinityMatrix> {
    let rows: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..l).map(|_| rng.range_f64(lo, hi)).collect())
        .collect();
    AffinityMatrix::from_rows(&rows)
}

/// Random populations: each N_i uniform in [1, max_per_type].
pub fn random_populations(rng: &mut Rng, k: usize, max_per_type: u32) -> Vec<u32> {
    // srclint: allow(as-truncation) — below(max as u64) is strictly less than a u32 argument
    (0..k).map(|_| 1 + rng.below(max_per_type as u64) as u32).collect()
}

/// The canned non-stationary regimes for the two-type system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// The population mix flips between a low-η and a high-η phase —
    /// abrupt workload composition changes.
    PhaseShift,
    /// Periodic load surges: every third phase multiplies the population
    /// and switches to heavy-tailed (bounded-Pareto) task sizes.
    Burst,
    /// Gradual drift: η and the processing rates interpolate toward a
    /// final regime across the schedule (thermal throttling / affinity
    /// drift), the case where a frozen solve silently decays.
    SlowDrift,
    /// Abrupt change point: one clean phase at the baseline rates, then
    /// the full `drift_to` factors from the second phase on, with the
    /// population mix held fixed — the step change that detection-delay
    /// and false-alarm measurements are made on (`slow_drift` is the
    /// matched gradual control).
    AbruptFlip,
    /// Two priority tiers whose offered load flips mid-run: the first
    /// half of the schedule runs the high-priority class (class 0) at
    /// the `low_eta` share of N, the second half at `high_eta` — the
    /// canned workload of the priority/deadline experiments (rates held
    /// fixed; pair with [`priority_mu`] and `DynamicConfig::priorities`
    /// so the weighted solve has a fast device to reserve).
    PriorityMix,
    /// Device churn: stationary populations and rates, but the fleet
    /// itself is unreliable — long slow-node ("limping") windows on the
    /// class-0 fast device, each ending just before a short full outage
    /// of a rotating survivor, driven by the [`FaultPlan`] that
    /// [`churn_fault_plan`] builds to match the schedule.  The regime
    /// where a frozen target keeps feeding a crippled device and only
    /// churn-aware control (CUSUM limp detection + down-signal
    /// re-solves) holds throughput.
    Churn,
    /// Offered-load ramp past capacity: every phase multiplies the
    /// population by `burst_factor` (> 1), holding rates and the 50/50
    /// mix fixed, until the system is saturated — queues grow, the
    /// bottleneck becomes the dispatch path itself rather than the
    /// placement.  The serving-front-end stress regime: batched routing
    /// (one steering decision per coalesced batch) should sustain
    /// strictly higher served throughput than per-request routing at
    /// the overload point, which `benches/perf_routing.rs` measures and
    /// CI gates.
    Saturation,
}

impl ScenarioKind {
    /// Parse a CLI/config name.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "phase_shift" | "shift" => Ok(ScenarioKind::PhaseShift),
            "burst" => Ok(ScenarioKind::Burst),
            "slow_drift" | "drift" => Ok(ScenarioKind::SlowDrift),
            "abrupt_flip" | "flip" => Ok(ScenarioKind::AbruptFlip),
            "priority_mix" | "priority" => Ok(ScenarioKind::PriorityMix),
            "churn" => Ok(ScenarioKind::Churn),
            "saturation" | "overload" => Ok(ScenarioKind::Saturation),
            other => Err(Error::Parse(format!(
                "unknown scenario '{other}' \
                 (phase_shift|burst|slow_drift|abrupt_flip|priority_mix|churn|saturation)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::PhaseShift => "phase_shift",
            ScenarioKind::Burst => "burst",
            ScenarioKind::SlowDrift => "slow_drift",
            ScenarioKind::AbruptFlip => "abrupt_flip",
            ScenarioKind::PriorityMix => "priority_mix",
            ScenarioKind::Churn => "churn",
            ScenarioKind::Saturation => "saturation",
        }
    }

    /// All canned regimes.
    pub fn all() -> [ScenarioKind; 7] {
        [
            ScenarioKind::PhaseShift,
            ScenarioKind::Burst,
            ScenarioKind::SlowDrift,
            ScenarioKind::AbruptFlip,
            ScenarioKind::PriorityMix,
            ScenarioKind::Churn,
            ScenarioKind::Saturation,
        ]
    }
}

/// Knobs shared by the canned scenarios (two-type systems).
#[derive(Debug, Clone)]
pub struct ScenarioParams {
    /// Baseline total programs N.
    pub n: u32,
    /// Number of phases.
    pub phases: usize,
    /// Measured completions per phase.
    pub completions: u64,
    /// Warm-up completions per phase.
    pub warmup: u64,
    /// Lower η (phase-shift trough / drift start).
    pub low_eta: f64,
    /// Upper η (phase-shift crest / drift end).
    pub high_eta: f64,
    /// Population multiplier of burst phases.
    pub burst_factor: f64,
    /// Per-cell (or per-processor) rate factors reached by the final
    /// slow-drift phase; earlier phases interpolate geometrically.  The
    /// default drifts the paper's P1-biased matrix into a P2-biased one
    /// — the regime flip a frozen solve cannot see.
    pub drift_to: Vec<f64>,
    /// Fraction of a phase each churn outage lasts
    /// ([`ScenarioKind::Churn`]; 0 < f ≤ 0.8 so the device recovers
    /// before the next cycle starts).
    pub churn_down: f64,
    /// Rate factor of churn slow-node cycles (0 < f ≤ 1; 0.25 = the
    /// limping device serves at quarter speed).
    pub churn_limp: f64,
    /// [`FaultPlan::backup_budget`] of churn runs (0 = unmetered
    /// re-dispatch).
    pub backup_budget: u32,
}

impl Default for ScenarioParams {
    fn default() -> Self {
        Self {
            n: 20,
            phases: 6,
            completions: 4_000,
            warmup: 400,
            low_eta: 0.2,
            high_eta: 0.8,
            burst_factor: 2.0,
            drift_to: vec![0.4, 0.2, 5.0, 2.5],
            churn_down: 0.3,
            churn_limp: 0.25,
            backup_budget: 0,
        }
    }
}

/// Build the phase schedule of a canned non-stationary scenario.
pub fn scenario_phases(kind: ScenarioKind, p: &ScenarioParams) -> Result<Vec<Phase>> {
    if p.phases == 0 {
        return Err(Error::Config("scenario needs ≥ 1 phase".into()));
    }
    if p.n < 2 {
        return Err(Error::Config("scenario needs N ≥ 2".into()));
    }
    if !(0.0 < p.low_eta && p.low_eta <= p.high_eta && p.high_eta < 1.0) {
        return Err(Error::Config(format!(
            "need 0 < low_eta ≤ high_eta < 1, got ({}, {})",
            p.low_eta, p.high_eta
        )));
    }
    let phases = match kind {
        ScenarioKind::PhaseShift => (0..p.phases)
            .map(|i| {
                let eta = if i % 2 == 0 { p.low_eta } else { p.high_eta };
                let (n1, n2) = split_populations(p.n, eta);
                Phase::new(vec![n1, n2], p.warmup, p.completions)
            })
            .collect(),
        ScenarioKind::Burst => {
            if p.burst_factor < 1.0 {
                return Err(Error::Config(format!(
                    "burst_factor must be ≥ 1, got {}",
                    p.burst_factor
                )));
            }
            if p.phases < 3 {
                return Err(Error::Config(format!(
                    "burst surges every third phase; {} phases contain none",
                    p.phases
                )));
            }
            (0..p.phases)
                .map(|i| {
                    if i % 3 == 2 {
                        // Surge: more programs, heavy-tailed sizes.
                        // srclint: allow(as-truncation) — surge populations are config-scale, far below u32 range
                        let n = ((p.n as f64 * p.burst_factor).round() as u32).max(2);
                        let (n1, n2) = split_populations(n, 0.5);
                        Phase::new(vec![n1, n2], p.warmup, p.completions)
                            .with_dist(Distribution::default_pareto())
                    } else {
                        let (n1, n2) = split_populations(p.n, 0.5);
                        Phase::new(vec![n1, n2], p.warmup, p.completions)
                    }
                })
                .collect()
        }
        ScenarioKind::AbruptFlip => {
            if p.phases < 2 {
                return Err(Error::Config(
                    "abrupt_flip needs ≥ 2 phases (one clean, one flipped)".into(),
                ));
            }
            if p.drift_to.is_empty() {
                return Err(Error::Config("abrupt_flip needs drift_to factors".into()));
            }
            if p.drift_to.iter().any(|&f| !f.is_finite() || f <= 0.0) {
                return Err(Error::Config("drift_to factors must be > 0".into()));
            }
            // Fixed populations: population changes are directly
            // observable and would re-solve anyway, so holding the mix
            // isolates the rate step the detector has to find.
            let (n1, n2) = split_populations(p.n, 0.5);
            (0..p.phases)
                .map(|i| {
                    let ph = Phase::new(vec![n1, n2], p.warmup, p.completions);
                    if i == 0 {
                        ph
                    } else {
                        ph.with_mu_scale(p.drift_to.clone())
                    }
                })
                .collect()
        }
        ScenarioKind::PriorityMix => {
            if p.phases < 2 {
                return Err(Error::Config(
                    "priority_mix needs ≥ 2 phases (one per load tier)".into(),
                ));
            }
            // First half: the high-priority class is the minority
            // (low_eta share); second half it flips to the majority.
            // Rates never change — the interesting axis is who owns the
            // contended fast device as the tiers' offered load swaps.
            let flip = p.phases / 2;
            (0..p.phases)
                .map(|i| {
                    let eta = if i < flip { p.low_eta } else { p.high_eta };
                    let (n1, n2) = split_populations(p.n, eta);
                    Phase::new(vec![n1, n2], p.warmup, p.completions)
                })
                .collect()
        }
        ScenarioKind::Churn => {
            if p.phases < 2 {
                return Err(Error::Config(
                    "churn needs ≥ 2 phases (one clean, then fault cycles)".into(),
                ));
            }
            validate_churn_params(p)?;
            // Stationary balanced mix: the only non-stationarity is the
            // fleet itself, injected via the matching fault plan.
            let (n1, n2) = split_populations(p.n, 0.5);
            (0..p.phases)
                .map(|_| Phase::new(vec![n1, n2], p.warmup, p.completions))
                .collect()
        }
        ScenarioKind::Saturation => {
            if p.phases < 2 {
                return Err(Error::Config(
                    "saturation needs ≥ 2 phases (baseline, then the ramp)".into(),
                ));
            }
            if p.burst_factor <= 1.0 {
                return Err(Error::Config(format!(
                    "saturation ramps load by burst_factor per phase; \
                     need > 1, got {}",
                    p.burst_factor
                )));
            }
            // Geometric offered-load ramp at fixed rates and mix: phase
            // i runs burst_factor^i × N programs, so by the last phase
            // the fleet is past capacity and the dispatch path itself is
            // the bottleneck.  Capped well under u32::MAX so a hot ramp
            // cannot overflow the population arithmetic.
            (0..p.phases)
                .map(|i| {
                    // srclint: allow(as-truncation) — the phase index is a small loop counter
                    let n = (p.n as f64 * p.burst_factor.powi(i as i32))
                        .min(10_000_000.0)
                        // srclint: allow(as-truncation) — capped at 1e7 on the previous line before rounding
                        .round() as u32;
                    let (n1, n2) = split_populations(n.max(2), 0.5);
                    Phase::new(vec![n1, n2], p.warmup, p.completions)
                })
                .collect()
        }
        ScenarioKind::SlowDrift => {
            if p.drift_to.is_empty() {
                return Err(Error::Config("slow_drift needs drift_to factors".into()));
            }
            if p.drift_to.iter().any(|&f| !f.is_finite() || f <= 0.0) {
                return Err(Error::Config("drift_to factors must be > 0".into()));
            }
            (0..p.phases)
                .map(|i| {
                    let t = if p.phases == 1 {
                        1.0
                    } else {
                        i as f64 / (p.phases - 1) as f64
                    };
                    let eta = p.low_eta + (p.high_eta - p.low_eta) * t;
                    let (n1, n2) = split_populations(p.n, eta);
                    let scale: Vec<f64> =
                        p.drift_to.iter().map(|&f| f.powf(t)).collect();
                    Phase::new(vec![n1, n2], p.warmup, p.completions).with_mu_scale(scale)
                })
                .collect()
        }
    };
    Ok(phases)
}

fn validate_churn_params(p: &ScenarioParams) -> Result<()> {
    if !(p.churn_down > 0.0 && p.churn_down <= 0.8) {
        return Err(Error::Config(format!(
            "churn_down must lie in (0, 0.8], got {}",
            p.churn_down
        )));
    }
    if !(p.churn_limp > 0.0 && p.churn_limp <= 1.0) {
        return Err(Error::Config(format!(
            "churn_limp must lie in (0, 1], got {}",
            p.churn_limp
        )));
    }
    Ok(())
}

/// Build the failure/recovery schedule that pairs with
/// [`ScenarioKind::Churn`]'s phase schedule for the fleet `mu`.
///
/// Phase wall time T is estimated from the theoretical throughput bound
/// (completions arrive at ≈ X_max under any decent policy), and each
/// fault super-cycle spans two phase estimates:
///
/// * a long limp window on device 0 — the class-0 fast device, where a
///   frozen target hurts most — at factor `churn_limp` from `0.10·T`
///   after the cycle start until just before the outage (`Limp(1.0)`
///   restores speed; the device never left the fleet, so detecting both
///   edges is the CUSUM machinery's job);
/// * immediately after the restore, a full outage of one of the other
///   devices for a `churn_down` fraction of T, rotating across the
///   fleet so every survivor eventually fails.  The evacuation floods
///   the remaining devices with full-rate work, so stale beliefs about
///   the just-healed device flush within a few seconds of service;
/// * a short clean tail before the next cycle.
///
/// Cycles start after one clean phase estimate and are tiled to ~3× the
/// nominal schedule length: arms slowed down by the faults themselves
/// (the frozen baseline most of all) stay under churn for their entire
/// run instead of coasting through an accidentally fault-free tail, and
/// events past a run's actual end simply never fire.
///
/// The plan carries `p.backup_budget` and validates against `mu`, so a
/// returned plan is always installable in `DynamicConfig::faults`.
pub fn churn_fault_plan(mu: &AffinityMatrix, p: &ScenarioParams) -> Result<FaultPlan> {
    validate_churn_params(p)?;
    let l = mu.procs();
    if l < 2 {
        return Err(Error::Config(
            "churn needs ≥ 2 devices so survivors can absorb a failure".into(),
        ));
    }
    if p.phases < 2 {
        return Err(Error::Config(
            "churn needs ≥ 2 phases (one clean, then fault cycles)".into(),
        ));
    }
    let (n1, n2) = split_populations(p.n, 0.5);
    let x = match mu.classify() {
        Ok(regime) => x_max_theoretical(mu, regime, n1, n2),
        // Wider-than-2×2 fleets have no closed-form bound; cap
        // throughput by every device serving its fastest class.  An
        // overestimate shortens the time estimate, so fault cycles land
        // early within the run rather than past its end.
        Err(_) => (0..l)
            .map(|j| {
                (0..mu.types())
                    .map(|i| mu.rate(i, j))
                    .fold(f64::NEG_INFINITY, f64::max)
            })
            .sum(),
    };
    if !(x.is_finite() && x > 0.0) {
        return Err(Error::Config(format!(
            "cannot estimate churn phase length: X_max = {x}"
        )));
    }
    let t_phase = (p.warmup + p.completions) as f64 / x;
    // Each super-cycle covers 2·T: limp [0.10, 1.90 − churn_down],
    // outage [1.92 − churn_down, 1.92], clean tail to 2.10 (the next
    // cycle's limp onset).  churn_down ≤ 0.8 keeps every window ordered.
    let cycles = (3 * p.phases + 1) / 2;
    let mut events = Vec::new();
    for m in 0..cycles {
        let base = (1 + 2 * m) as f64 * t_phase;
        events.push(FaultEvent {
            time: base + 0.10 * t_phase,
            device: 0,
            kind: FaultKind::Limp(p.churn_limp),
        });
        events.push(FaultEvent {
            time: base + (1.90 - p.churn_down) * t_phase,
            device: 0,
            kind: FaultKind::Limp(1.0),
        });
        let device = 1 + m % (l - 1);
        events.push(FaultEvent {
            time: base + (1.92 - p.churn_down) * t_phase,
            device,
            kind: FaultKind::Down,
        });
        events.push(FaultEvent {
            time: base + 1.92 * t_phase,
            device,
            kind: FaultKind::Up,
        });
    }
    let plan = FaultPlan { events, backup_budget: p.backup_budget };
    plan.validate(l)?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;

    #[test]
    fn eta_split_covers_paper_grid() {
        for eta in eta_grid() {
            let (n1, n2) = split_populations(20, eta);
            assert_eq!(n1 + n2, 20);
            assert!(n1 >= 1 && n2 >= 1);
            assert_eq!(n1, (20.0 * eta).round() as u32);
        }
    }

    #[test]
    fn split_clamps_extremes() {
        assert_eq!(split_populations(10, 0.0), (1, 9));
        assert_eq!(split_populations(10, 1.0), (9, 1));
    }

    #[test]
    fn canned_matrices_classify_as_documented() {
        assert_eq!(paper_two_type_mu().classify().unwrap(), Regime::P1Biased);
        assert_eq!(
            table3::general_symmetric().classify().unwrap(),
            Regime::GeneralSymmetric
        );
        assert_eq!(table3::p2_biased().classify().unwrap(), Regime::P2Biased);
    }

    #[test]
    fn three_class_flip_rotates_preferred_classes() {
        let base = three_class_mu();
        assert_eq!(base.best_proc(0), 0);
        assert_eq!(base.best_proc(1), 1);
        assert_eq!(base.best_proc(2), 2);
        let flipped = base.scaled(&three_class_flip_scale()).unwrap();
        // Types 0 and 2 swap preferred classes; type 1 keeps its own.
        assert_eq!(flipped.best_proc(0), 2);
        assert_eq!(flipped.best_proc(1), 1);
        assert_eq!(flipped.best_proc(2), 0);
        // The flip is substantial: the frozen placements lose ≥ 2×.
        assert!(flipped.rate(0, 0) * 2.0 < base.rate(0, 0));
        assert!(flipped.rate(2, 2) * 2.0 < base.rate(2, 2));
    }

    #[test]
    fn tiled_general_symmetric_repeats_columns() {
        let t = table3::general_symmetric_tiled(5).unwrap();
        assert_eq!(t.types(), 2);
        assert_eq!(t.procs(), 5);
        let base = table3::general_symmetric();
        for i in 0..2 {
            for j in 0..5 {
                assert_eq!(t.rate(i, j), base.rate(i, j % 2));
            }
        }
    }

    #[test]
    fn scenario_kinds_parse_round_trip() {
        for kind in ScenarioKind::all() {
            assert_eq!(ScenarioKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(ScenarioKind::parse("steady").is_err());
    }

    #[test]
    fn phase_shift_alternates_population_mix() {
        let p = ScenarioParams::default();
        let phases = scenario_phases(ScenarioKind::PhaseShift, &p).unwrap();
        assert_eq!(phases.len(), 6);
        let (lo1, _) = split_populations(20, 0.2);
        let (hi1, _) = split_populations(20, 0.8);
        for (i, ph) in phases.iter().enumerate() {
            let want = if i % 2 == 0 { lo1 } else { hi1 };
            assert_eq!(ph.populations[0], want, "phase {i}");
            assert_eq!(ph.populations.iter().sum::<u32>(), 20);
            assert!(ph.mu_scale.is_empty() && ph.dist.is_none());
        }
    }

    #[test]
    fn burst_surges_population_and_sizes() {
        let p = ScenarioParams { phases: 7, ..Default::default() };
        let phases = scenario_phases(ScenarioKind::Burst, &p).unwrap();
        for (i, ph) in phases.iter().enumerate() {
            let total: u32 = ph.populations.iter().sum();
            if i % 3 == 2 {
                assert_eq!(total, 40, "burst phase {i}");
                assert_eq!(ph.dist, Some(Distribution::default_pareto()));
            } else {
                assert_eq!(total, 20, "calm phase {i}");
                assert!(ph.dist.is_none());
            }
        }
    }

    #[test]
    fn saturation_ramps_load_geometrically() {
        let p = ScenarioParams { phases: 4, ..Default::default() };
        let phases = scenario_phases(ScenarioKind::Saturation, &p).unwrap();
        assert_eq!(phases.len(), 4);
        // 20 → 40 → 80 → 160 at the default ×2 ramp; rates and the
        // 50/50 mix never change — only offered load.
        for (i, ph) in phases.iter().enumerate() {
            let total: u32 = ph.populations.iter().sum();
            assert_eq!(total, 20 << i, "phase {i}");
            assert_eq!(ph.populations[0], total / 2);
            assert!(ph.mu_scale.is_empty() && ph.dist.is_none());
        }
        // A flat "ramp" is rejected — saturation must actually ramp.
        let flat = ScenarioParams { burst_factor: 1.0, ..Default::default() };
        assert!(scenario_phases(ScenarioKind::Saturation, &flat).is_err());
        // One phase is no ramp either.
        let one = ScenarioParams { phases: 1, ..Default::default() };
        assert!(scenario_phases(ScenarioKind::Saturation, &one).is_err());
    }

    #[test]
    fn slow_drift_interpolates_rates_and_mix() {
        let p = ScenarioParams::default();
        let phases = scenario_phases(ScenarioKind::SlowDrift, &p).unwrap();
        // First phase: no drift yet (all factors 1); last: exactly drift_to.
        for &f in &phases[0].mu_scale {
            assert!((f - 1.0).abs() < 1e-12);
        }
        for (a, b) in phases.last().unwrap().mu_scale.iter().zip(&p.drift_to) {
            assert!((a - b).abs() < 1e-12);
        }
        // η climbs monotonically.
        for w in phases.windows(2) {
            assert!(w[1].populations[0] >= w[0].populations[0]);
        }
        // The final effective matrix really flips the paper regime.
        let mu = paper_two_type_mu();
        let last = mu.scaled(&phases.last().unwrap().mu_scale).unwrap();
        assert_eq!(last.classify().unwrap(), Regime::P2Biased);
    }

    #[test]
    fn abrupt_flip_steps_rates_once_and_holds_populations() {
        let p = ScenarioParams::default();
        let phases = scenario_phases(ScenarioKind::AbruptFlip, &p).unwrap();
        assert_eq!(phases.len(), 6);
        let (n1, n2) = split_populations(p.n, 0.5);
        // Phase 0 is clean; every later phase carries the full flip.
        assert!(phases[0].mu_scale.is_empty());
        for ph in &phases[1..] {
            assert_eq!(ph.mu_scale, p.drift_to);
        }
        for ph in &phases {
            assert_eq!(ph.populations, vec![n1, n2]);
            assert!(ph.dist.is_none());
        }
        // The default flip really lands in the other regime — the step
        // the detection-delay gates in tests/cusum_e2e.rs are measured
        // against.
        let mu = paper_two_type_mu();
        let flipped = mu.scaled(&p.drift_to).unwrap();
        assert_eq!(flipped.classify().unwrap(), Regime::P2Biased);
    }

    #[test]
    fn priority_mix_flips_offered_load_mid_run() {
        let p = ScenarioParams::default();
        let phases = scenario_phases(ScenarioKind::PriorityMix, &p).unwrap();
        assert_eq!(phases.len(), 6);
        let (lo1, lo2) = split_populations(20, 0.2);
        let (hi1, hi2) = split_populations(20, 0.8);
        // First half: class 0 is the minority tier; second half the
        // majority.  Rates and distributions never change.
        for (i, ph) in phases.iter().enumerate() {
            let want = if i < 3 { vec![lo1, lo2] } else { vec![hi1, hi2] };
            assert_eq!(ph.populations, want, "phase {i}");
            assert!(ph.mu_scale.is_empty() && ph.dist.is_none());
        }
        // The companion matrix is the contended-fast-device system:
        // class 1 is (marginally) faster everywhere, so the unweighted
        // optimum is accelerate-the-fastest and crowds P1.
        assert_eq!(priority_mu().classify().unwrap(), Regime::P2Biased);
    }

    #[test]
    fn churn_schedule_is_stationary_with_a_matching_fault_plan() {
        let p = ScenarioParams::default();
        let phases = scenario_phases(ScenarioKind::Churn, &p).unwrap();
        assert_eq!(phases.len(), 6);
        let (n1, n2) = split_populations(20, 0.5);
        for ph in &phases {
            assert_eq!(ph.populations, vec![n1, n2]);
            assert!(ph.mu_scale.is_empty() && ph.dist.is_none());
        }

        let mu = paper_two_type_mu();
        let plan = churn_fault_plan(&mu, &p).unwrap();
        assert!(plan.validate(mu.procs()).is_ok());
        assert!(!plan.is_empty());
        // Four events per super-cycle (limp on/off, down, up), cycles
        // tiled to ~3× the nominal schedule, times sorted.
        let cycles = (3 * p.phases + 1) / 2;
        assert_eq!(plan.events.len(), 4 * cycles);
        for w in plan.events.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        // Both failure modes appear: limp windows on device 0 (each one
        // restored), full outages (with recovery) on the other device.
        let limps = plan
            .events
            .iter()
            .filter(|e| e.device == 0 && e.kind == FaultKind::Limp(p.churn_limp))
            .count();
        let restores = plan
            .events
            .iter()
            .filter(|e| e.device == 0 && e.kind == FaultKind::Limp(1.0))
            .count();
        assert_eq!(limps, cycles);
        assert_eq!(restores, cycles, "every limp window is restored");
        assert!(plan
            .events
            .iter()
            .any(|e| e.device == 1 && e.kind == FaultKind::Down));
        let downs = plan.events.iter().filter(|e| e.kind == FaultKind::Down).count();
        let ups = plan.events.iter().filter(|e| e.kind == FaultKind::Up).count();
        assert_eq!(downs, ups, "every outage recovers");
        assert_eq!(downs, cycles);
        assert_eq!(plan.backup_budget, p.backup_budget);

        // Down cycles rotate across the non-limping devices of a wider
        // fleet; device 0 (the limping one) never goes down.
        let wide = three_class_mu();
        let plan3 = churn_fault_plan(&wide, &ScenarioParams { phases: 8, ..p.clone() }).unwrap();
        let downed: Vec<usize> = plan3
            .events
            .iter()
            .filter(|e| e.kind == FaultKind::Down)
            .map(|e| e.device)
            .collect();
        assert_eq!(downed.len(), (3 * 8 + 1) / 2);
        assert_eq!(&downed[..4], &[1, 2, 1, 2]);
        assert!(downed.iter().all(|&d| d != 0));

        // Budget is carried through.
        let budgeted = churn_fault_plan(&mu, &ScenarioParams { backup_budget: 3, ..p }).unwrap();
        assert_eq!(budgeted.backup_budget, 3);
    }

    #[test]
    fn churn_fault_plan_rejects_bad_params() {
        let mu = paper_two_type_mu();
        let ok = ScenarioParams::default();
        let bad: Vec<ScenarioParams> = vec![
            ScenarioParams { churn_down: 0.0, ..ok.clone() },
            ScenarioParams { churn_down: 0.9, ..ok.clone() },
            ScenarioParams { churn_limp: 0.0, ..ok.clone() },
            ScenarioParams { churn_limp: 1.5, ..ok.clone() },
            ScenarioParams { phases: 1, ..ok.clone() },
        ];
        for p in bad {
            assert!(churn_fault_plan(&mu, &p).is_err(), "{p:?}");
        }
        // Single-device fleets have no survivors to absorb a failure.
        let solo = AffinityMatrix::from_rows(&[vec![5.0], vec![3.0]]).unwrap();
        assert!(churn_fault_plan(&solo, &ok).is_err());
    }

    #[test]
    fn scenario_validation_rejects_bad_params() {
        let ok = ScenarioParams::default();
        let cases: Vec<(ScenarioKind, ScenarioParams)> = vec![
            (ScenarioKind::PhaseShift, ScenarioParams { phases: 0, ..ok.clone() }),
            (ScenarioKind::PhaseShift, ScenarioParams { n: 1, ..ok.clone() }),
            (
                ScenarioKind::PhaseShift,
                ScenarioParams { low_eta: 0.9, high_eta: 0.1, ..ok.clone() },
            ),
            (ScenarioKind::Burst, ScenarioParams { burst_factor: 0.5, ..ok.clone() }),
            (ScenarioKind::Burst, ScenarioParams { phases: 2, ..ok.clone() }),
            (ScenarioKind::SlowDrift, ScenarioParams { drift_to: vec![], ..ok.clone() }),
            (ScenarioKind::SlowDrift, ScenarioParams { drift_to: vec![-1.0], ..ok.clone() }),
            (ScenarioKind::AbruptFlip, ScenarioParams { phases: 1, ..ok.clone() }),
            (ScenarioKind::AbruptFlip, ScenarioParams { drift_to: vec![], ..ok.clone() }),
            (ScenarioKind::PriorityMix, ScenarioParams { phases: 1, ..ok.clone() }),
            (ScenarioKind::AbruptFlip, ScenarioParams { drift_to: vec![0.0], ..ok.clone() }),
            (ScenarioKind::Churn, ScenarioParams { phases: 1, ..ok.clone() }),
            (ScenarioKind::Churn, ScenarioParams { churn_down: 0.0, ..ok.clone() }),
            (ScenarioKind::Churn, ScenarioParams { churn_limp: -0.5, ..ok }),
        ];
        for (kind, p) in cases {
            assert!(scenario_phases(kind, &p).is_err(), "{kind:?} {p:?}");
        }
    }

    #[test]
    fn random_systems_are_valid() {
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let mu = random_mu(&mut rng, 3, 4, 0.5, 30.0).unwrap();
            assert_eq!(mu.types(), 3);
            assert_eq!(mu.procs(), 4);
            let pops = random_populations(&mut rng, 3, 8);
            assert!(pops.iter().all(|&p| (1..=8).contains(&p)));
        }
    }
}
