//! Tasks and programs of the closed batch network (Fig. 1).
//!
//! A *program* is an endless sequence of tasks executed strictly in order
//! (data dependencies); exactly one task per program is in the system at
//! any time, so N programs ⇒ N tasks resident (§3.1).

/// One task instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    /// Globally unique id (monotone).
    pub id: u64,
    /// Owning program index.
    pub program: usize,
    /// Task type (row of the affinity matrix).
    pub ttype: usize,
    /// Service requirement in work units (mean-1 distribution draw).
    pub size: f64,
    /// Simulation time at which the task entered the system.
    pub arrive: f64,
}

/// A program: fixed task type (the §5 closed-system setup keeps the
/// per-type populations N_i constant) plus its task counter.
#[derive(Debug, Clone)]
pub struct Program {
    /// Program index.
    pub id: usize,
    /// Task type this program emits.
    pub ttype: usize,
    /// Number of tasks emitted so far.
    pub emitted: u64,
}

impl Program {
    /// New program of the given type.
    pub fn new(id: usize, ttype: usize) -> Self {
        Self { id, ttype, emitted: 0 }
    }

    /// Emit the next task at time `now` with the given drawn size.
    pub fn emit(&mut self, next_id: u64, now: f64, size: f64) -> Task {
        self.emitted += 1;
        Task { id: next_id, program: self.id, ttype: self.ttype, size, arrive: now }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn programs_emit_sequentially() {
        let mut p = Program::new(3, 1);
        let t1 = p.emit(10, 0.0, 1.5);
        let t2 = p.emit(11, 2.5, 0.5);
        assert_eq!(p.emitted, 2);
        assert_eq!(t1.program, 3);
        assert_eq!(t1.ttype, 1);
        assert_eq!(t2.arrive, 2.5);
        assert_ne!(t1.id, t2.id);
    }
}
