//! The closed batch network engine (Fig. 2).
//!
//! N programs, each with exactly one task in flight.  On every completion
//! the owning program immediately emits its next task, the policy picks a
//! processor, and the task joins that processor's queue — no arrival
//! process exists, exactly the paper's closed-system model (§3.1).
//!
//! The event loop is a classic next-completion discrete-event simulation.
//! The seed looped `argmin_j next_completion(j)` per event (O(l) scans of
//! O(n) processors); this version keeps per-processor next-completion
//! times in an indexed min-heap ([`EventQueue`]), so each event is a
//! `peek` (O(1)) plus O(log l) re-keys of the one or two processors the
//! event touched — the §Perf hot-path core.
//!
//! All run-lifetime allocations (processors, programs, the work buffer,
//! the event heap, the metrics accumulator) live in a [`SimArena`] that
//! `run_in` reuses across replications: after the first run, a
//! replication performs no net heap allocation (`tests/arena_alloc.rs`
//! gates this with a counting allocator).

// srclint: allow-file(index-reachable) — event and cell indices come from the validated platform dimensions

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::energy::{EnergyModel, PowerScenario};
use crate::model::objective::{Objective, PowerProfile};
use crate::model::state::StateMatrix;
use crate::policy::{Policy, SolveRequest, SystemView};

use super::distribution::Distribution;
use super::eventq::EventQueue;
use super::metrics::{Metrics, SimResult};
use super::processor::{Discipline, Processor};
use super::rng::Rng;
use super::task::Program;

/// Configuration of one simulation run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Per-type program populations N_i (Σ = N).
    pub populations: Vec<u32>,
    /// Service discipline for every processor (§5 uses PS, §7 FCFS).
    pub discipline: Discipline,
    /// Task-size distribution (mean 1).
    pub dist: Distribution,
    /// Power model coefficient k.
    pub power_coeff: f64,
    /// Power scenario (α).
    pub power: PowerScenario,
    /// Power drawn by an idle processor (the idle-power floor); 0 keeps
    /// the exact pre-objective energy accounting.
    pub idle_power: f64,
    /// What the policy's solve optimizes (threaded into
    /// [`Policy::prepare`]; [`Objective::Throughput`] reproduces every
    /// pre-objective run bit-for-bit).
    pub objective: Objective,
    /// Completions to discard before measuring.
    pub warmup: u64,
    /// Completions to measure.
    pub measure: u64,
    /// RNG seed (figures regenerate bit-identically per seed).
    pub seed: u64,
}

impl SimConfig {
    /// The §5 defaults: N = 20 programs, PS, proportional power,
    /// 2k warm-up and 20k measured completions.
    pub fn paper_default(populations: Vec<u32>) -> Self {
        Self {
            populations,
            discipline: Discipline::Ps,
            dist: Distribution::Exponential,
            power_coeff: 1.0,
            power: PowerScenario::Proportional,
            idle_power: 0.0,
            objective: Objective::Throughput,
            warmup: 2_000,
            measure: 20_000,
            seed: 0xC_A_B,
        }
    }

    /// Total programs N.
    pub fn n_programs(&self) -> u32 {
        self.populations.iter().sum()
    }

    /// The [`PowerProfile`] this run's solve and energy accounting share.
    pub fn power_profile(&self) -> PowerProfile {
        PowerProfile::new(self.power_coeff, self.power).with_idle(self.idle_power)
    }
}

/// One completion event, captured by [`ClosedNetwork::run_traced`] for
/// the trace-equivalence property tests.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    /// Completed task id.
    pub id: u64,
    /// Processor it completed on.
    pub proc: usize,
    /// Absolute completion time.
    pub time: f64,
}

/// Reusable per-thread simulation state: every allocation the engine
/// needs for a run, kept warm across replications (capacities persist
/// through `reset`s, so a warmed arena allocates nothing per run).
#[derive(Debug, Default)]
pub struct SimArena {
    procs: Vec<Processor>,
    programs: Vec<Program>,
    work: Vec<f64>,
    order: Vec<usize>,
    events: EventQueue,
    metrics: Metrics,
}

impl SimArena {
    /// Empty arena; capacities grow on first use and are then reused.
    pub fn new() -> Self {
        Self::default()
    }

    /// Prepare for a run over `l` processors of the given discipline.
    fn reset(&mut self, l: usize, discipline: Discipline) {
        self.procs.truncate(l);
        for p in self.procs.iter_mut() {
            p.reset(discipline);
        }
        while self.procs.len() < l {
            self.procs.push(Processor::new(self.procs.len(), discipline));
        }
        self.programs.clear();
        self.work.clear();
        self.work.resize(l, 0.0);
        self.order.clear();
        self.events.reset(l);
    }
}

/// The closed batch network simulator.
pub struct ClosedNetwork<'a> {
    mu: &'a AffinityMatrix,
    cfg: SimConfig,
}

impl<'a> ClosedNetwork<'a> {
    /// Bind a network to an affinity matrix and a run configuration.
    pub fn new(mu: &'a AffinityMatrix, cfg: SimConfig) -> Result<Self> {
        if cfg.populations.len() != mu.types() {
            return Err(Error::Shape(format!(
                "{} populations for {} task types",
                cfg.populations.len(),
                mu.types()
            )));
        }
        if cfg.n_programs() == 0 {
            return Err(Error::Config("empty system (N = 0)".into()));
        }
        Ok(Self { mu, cfg })
    }

    /// Run one simulation under `policy` and return the §5 metrics.
    pub fn run(&self, policy: &mut dyn Policy) -> Result<SimResult> {
        let mut arena = SimArena::new();
        self.run_in(policy, &mut arena)
    }

    /// Like [`run`](Self::run), but with caller-provided reusable state —
    /// the replication-runner hot path (zero net allocation per run once
    /// the arena is warm).
    pub fn run_in(&self, policy: &mut dyn Policy, arena: &mut SimArena) -> Result<SimResult> {
        self.run_core(policy, arena, None)
    }

    /// Like [`run_in`](Self::run_in), additionally appending every
    /// completion (including warm-up) to `trace`.
    pub fn run_traced(
        &self,
        policy: &mut dyn Policy,
        arena: &mut SimArena,
        trace: &mut Vec<Completion>,
    ) -> Result<SimResult> {
        self.run_core(policy, arena, Some(trace))
    }

    fn run_core(
        &self,
        policy: &mut dyn Policy,
        arena: &mut SimArena,
        mut trace: Option<&mut Vec<Completion>>,
    ) -> Result<SimResult> {
        let mu = self.mu;
        let cfg = &self.cfg;
        let (k, l) = (mu.types(), mu.procs());
        let energy = EnergyModel::new(mu, cfg.power_coeff, cfg.power)?;
        let profile = cfg.power_profile();
        profile.validate()?;
        policy.prepare(
            &SolveRequest::new(mu, &cfg.populations).with_objective(cfg.objective, profile),
        )?;

        let needs_work = policy.needs_work_estimate();
        let mut rng = Rng::new(cfg.seed);
        arena.reset(l, cfg.discipline);
        let mut state = StateMatrix::zeros(k, l);
        for (ttype, &ni) in cfg.populations.iter().enumerate() {
            for _ in 0..ni {
                let id = arena.programs.len();
                arena.programs.push(Program::new(id, ttype));
            }
        }
        // Shuffle initial dispatch order so no policy sees a sorted fill.
        arena.order.extend(0..arena.programs.len());
        rng.shuffle(&mut arena.order);

        let mut next_id = 0u64;
        // Initial fill at t = 0.
        for &p in &arena.order {
            let ttype = arena.programs[p].ttype;
            let size = cfg.dist.sample(&mut rng);
            let task = arena.programs[p].emit(next_id, 0.0, size);
            next_id += 1;
            if needs_work {
                for (j, pr) in arena.procs.iter().enumerate() {
                    arena.work[j] = pr.remaining_work_time();
                }
            }
            let view = SystemView {
                mu,
                state: &state,
                work: &arena.work,
                populations: &cfg.populations,
            };
            let j = policy.dispatch(ttype, &view, &mut rng);
            debug_assert!(j < l, "policy dispatched to invalid processor {j}");
            arena.procs[j].advance(0.0);
            arena.procs[j].push(task, mu.rate(ttype, j), 0.0);
            state.inc(ttype, j);
        }
        for j in 0..l {
            arena.events.update(j, arena.procs[j].next_completion());
        }

        let total = cfg.warmup + cfg.measure;
        arena.metrics.reset(k, l, 0.0);
        let mut measuring = false;
        let mut now = 0.0f64;
        let mut completions = 0u64;
        // Idle-power accounting is strictly gated on a non-zero floor:
        // the mid-run advance-all it needs perturbs the floating-point
        // accumulation order, and default runs must stay bit-identical.
        let track_idle = cfg.idle_power > 0.0;
        let mut busy_at_start: Vec<f64> = Vec::new();

        while completions < total {
            // Next completion across processors: O(1) peek instead of the
            // seed's linear argmin.
            let (j, t) = arena
                .events
                .peek()
                .ok_or_else(|| Error::Solver("deadlock: no runnable task".into()))?;
            debug_assert!(t >= now - 1e-9);
            now = t;
            arena.procs[j].advance(now);
            let done = arena.procs[j].pop_completed(now)?;
            arena.events.update(j, arena.procs[j].next_completion());
            state.dec(done.ttype, j)?;
            completions += 1;

            if !measuring && completions > cfg.warmup {
                measuring = true;
                arena.metrics.reset(k, l, now);
                if track_idle {
                    for p in arena.procs.iter_mut() {
                        p.advance(now);
                    }
                    busy_at_start.extend(arena.procs.iter().map(|p| p.busy_time()));
                }
            }
            if measuring {
                let omega = done.size / mu.rate(done.ttype, j);
                let e = energy.power(done.ttype, j) * omega;
                arena.metrics.record(now, now - done.arrive, e, done.ttype, j);
            }
            if let Some(tr) = trace.as_mut() {
                tr.push(Completion { id: done.id, proc: j, time: now });
            }

            // The program immediately emits its successor task (closed
            // system: one task per program, always).
            let prog = done.program;
            let ttype = arena.programs[prog].ttype;
            let size = cfg.dist.sample(&mut rng);
            let task = arena.programs[prog].emit(next_id, now, size);
            next_id += 1;
            if needs_work {
                for (jj, pr) in arena.procs.iter().enumerate() {
                    arena.work[jj] = pr.remaining_work_time();
                }
            }
            let view = SystemView {
                mu,
                state: &state,
                work: &arena.work,
                populations: &cfg.populations,
            };
            let dest = policy.dispatch(ttype, &view, &mut rng);
            debug_assert!(dest < l);
            arena.procs[dest].advance(now);
            arena.procs[dest].push(task, mu.rate(ttype, dest), now);
            arena.events.update(dest, arena.procs[dest].next_completion());
            state.inc(ttype, dest);

            // Invariant: the closed system always holds exactly N tasks
            // (debug builds only; the O(k·l) scan vanishes in release).
            debug_assert_eq!(state.total(), cfg.n_programs());
        }

        if track_idle && !busy_at_start.is_empty() {
            // Charge the idle floor for each processor's idle share of
            // the measurement window: window length minus its busy-time
            // delta across it.
            for p in arena.procs.iter_mut() {
                p.advance(now);
            }
            let elapsed = arena.metrics.elapsed();
            let mut idle_e = 0.0;
            for (j, p) in arena.procs.iter().enumerate() {
                let busy = p.busy_time() - busy_at_start[j];
                idle_e += (elapsed - busy).max(0.0) * cfg.idle_power;
            }
            arena.metrics.add_idle_energy(idle_e);
        }

        Ok(arena.metrics.finalize(cfg.n_programs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;
    use crate::model::throughput::x_max_theoretical;
    use crate::policy::PolicyKind;

    fn paper_mu() -> AffinityMatrix {
        AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap()
    }

    fn quick_cfg(populations: Vec<u32>) -> SimConfig {
        let mut cfg = SimConfig::paper_default(populations);
        cfg.warmup = 500;
        cfg.measure = 6_000;
        cfg
    }

    #[test]
    fn littles_law_holds_for_every_policy() {
        // X·E[T] = N (Eq. 1) — the bottom-right subplot of Figs. 4–7.
        let mu = paper_mu();
        for kind in PolicyKind::five_two_type() {
            let mut p = kind.build();
            let net = ClosedNetwork::new(&mu, quick_cfg(vec![10, 10])).unwrap();
            let r = net.run(p.as_mut()).unwrap();
            assert!(
                r.little_residual() < 0.05,
                "{}: X·E[T] = {} vs N = 20",
                kind.name(),
                r.little_product
            );
        }
    }

    #[test]
    fn cab_matches_theory_exponential() {
        // Fig. 8: simulated CAB ≈ Eq. 16 theory.
        let mu = paper_mu();
        let (n1, n2) = (10u32, 10u32);
        let theory = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
        let mut cab = PolicyKind::Cab.build();
        let net = ClosedNetwork::new(&mu, quick_cfg(vec![n1, n2])).unwrap();
        let r = net.run(cab.as_mut()).unwrap();
        let err = (r.throughput - theory).abs() / theory;
        assert!(err < 0.05, "sim {} vs theory {theory}", r.throughput);
    }

    #[test]
    fn cab_beats_baselines() {
        let mu = paper_mu();
        let net = ClosedNetwork::new(&mu, quick_cfg(vec![10, 10])).unwrap();
        let mut results = Vec::new();
        for kind in PolicyKind::five_two_type() {
            let mut p = kind.build();
            results.push((kind, net.run(p.as_mut()).unwrap().throughput));
        }
        let cab_x = results[0].1;
        for (kind, x) in &results[1..] {
            assert!(
                cab_x >= *x * 0.999,
                "{} ({x}) beat CAB ({cab_x})",
                kind.name()
            );
        }
    }

    #[test]
    fn proportional_power_energy_is_k() {
        // Eq. 23: E[ℰ] = k·E[size] — exact up to the sample mean of the
        // mean-1 size distribution, for any policy.
        let mu = paper_mu();
        let mut p = PolicyKind::Random.build();
        let net = ClosedNetwork::new(&mu, quick_cfg(vec![10, 10])).unwrap();
        let r = net.run(p.as_mut()).unwrap();
        assert!((r.mean_energy - 1.0).abs() < 0.05, "E[ℰ] = {}", r.mean_energy);
        // And exactly 1 under constant sizes (no sampling noise).
        let mut cfg = quick_cfg(vec![10, 10]);
        cfg.dist = Distribution::Constant;
        let net = ClosedNetwork::new(&mu, cfg).unwrap();
        let r = net.run(PolicyKind::Random.build().as_mut()).unwrap();
        assert!((r.mean_energy - 1.0).abs() < 1e-9, "E[ℰ] = {}", r.mean_energy);
    }

    #[test]
    fn discipline_independence_of_cab_throughput() {
        // Lemma 3 (discipline independence) is exact when CAB's target
        // keeps each queue type-pure — the general-symmetric regime, where
        // BF sends every type to its own processor.  PS/FCFS/LCFS must
        // then agree up to simulation noise.
        let mu = crate::sim::workload::table3::general_symmetric();
        let mut xs = Vec::new();
        for d in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut cfg = quick_cfg(vec![10, 10]);
            cfg.discipline = d;
            let mut p = PolicyKind::Cab.build();
            let net = ClosedNetwork::new(&mu, cfg).unwrap();
            xs.push(net.run(p.as_mut()).unwrap().throughput);
        }
        for w in xs.windows(2) {
            let rel = (w[0] - w[1]).abs() / w[0];
            assert!(rel < 0.03, "discipline changed X: {xs:?}");
        }
    }

    #[test]
    fn fcfs_vs_ps_gap_on_mixed_queues_is_bounded() {
        // On mixed queues (the P1-biased AF state) FCFS trends toward the
        // harmonic mean of the service rates while PS gives the
        // arithmetic mix (Eq. 5) — a real, bounded discipline effect the
        // paper's §7 FCFS experiments absorb into the measured rates.
        let mu = paper_mu();
        let mut xs = Vec::new();
        for d in [Discipline::Ps, Discipline::Fcfs] {
            let mut cfg = quick_cfg(vec![10, 10]);
            cfg.discipline = d;
            let mut p = PolicyKind::Cab.build();
            let net = ClosedNetwork::new(&mu, cfg).unwrap();
            xs.push(net.run(p.as_mut()).unwrap().throughput);
        }
        let rel = (xs[0] - xs[1]).abs() / xs[0];
        assert!(rel < 0.08, "PS vs FCFS gap too large: {xs:?}");
    }

    #[test]
    fn idle_power_floor_charges_the_drained_processor() {
        // One task type, best-fit on processor 0: processor 1 never
        // receives a task, so with an idle floor E[ℰ] grows by exactly
        // idle_power/X (its whole-window idle draw amortized per task).
        let mu = AffinityMatrix::from_rows(&[vec![10.0, 1.0]]).unwrap();
        let mut cfg = quick_cfg(vec![6]);
        cfg.dist = Distribution::Constant;
        let base = ClosedNetwork::new(&mu, cfg.clone())
            .unwrap()
            .run(PolicyKind::BestFit.build().as_mut())
            .unwrap();
        cfg.idle_power = 2.0;
        let idled = ClosedNetwork::new(&mu, cfg)
            .unwrap()
            .run(PolicyKind::BestFit.build().as_mut())
            .unwrap();
        assert_eq!(base.throughput.to_bits(), idled.throughput.to_bits());
        let delta = idled.mean_energy - base.mean_energy;
        assert!(
            (delta - 2.0 / idled.throughput).abs() < 1e-9,
            "idle charge {delta} vs {}",
            2.0 / idled.throughput
        );
    }

    #[test]
    fn energy_objective_threads_through_the_engine() {
        // An energy-objective run solves and simulates end to end; with
        // the throughput objective the config reproduces the default
        // run bit-for-bit (the API-redesign compatibility gate).
        let mu = crate::sim::workload::table3::general_symmetric();
        let mut cfg = quick_cfg(vec![10, 10]);
        cfg.power = PowerScenario::Exponent(0.5);
        let plain = ClosedNetwork::new(&mu, cfg.clone())
            .unwrap()
            .run(PolicyKind::GrIn.build().as_mut())
            .unwrap();
        cfg.objective = Objective::Throughput;
        let explicit = ClosedNetwork::new(&mu, cfg.clone())
            .unwrap()
            .run(PolicyKind::GrIn.build().as_mut())
            .unwrap();
        assert_eq!(plain.throughput.to_bits(), explicit.throughput.to_bits());
        cfg.objective = Objective::EnergyPerTask;
        let energy = ClosedNetwork::new(&mu, cfg.clone())
            .unwrap()
            .run(PolicyKind::GrIn.build().as_mut())
            .unwrap();
        assert!(energy.mean_energy > 0.0 && energy.throughput > 0.0);
        // Objective-blind policies reject the energy objective loudly.
        assert!(ClosedNetwork::new(&mu, cfg)
            .unwrap()
            .run(PolicyKind::Cab.build().as_mut())
            .is_err());
    }

    #[test]
    fn arena_reuse_is_deterministic() {
        // The same seed through a warm arena reproduces the cold-arena
        // run bit-for-bit, across disciplines.
        let mu = paper_mu();
        let mut arena = SimArena::new();
        for d in [Discipline::Ps, Discipline::Fcfs, Discipline::Lcfs] {
            let mut cfg = quick_cfg(vec![10, 10]);
            cfg.discipline = d;
            cfg.measure = 2_000;
            let net = ClosedNetwork::new(&mu, cfg).unwrap();
            let cold = net.run(PolicyKind::Cab.build().as_mut()).unwrap();
            let warm = net
                .run_in(PolicyKind::Cab.build().as_mut(), &mut arena)
                .unwrap();
            assert_eq!(cold.throughput.to_bits(), warm.throughput.to_bits(), "{d:?}");
            assert_eq!(cold.completed, warm.completed);
            assert_eq!(cold.completions_by_cell, warm.completions_by_cell);
        }
    }

    #[test]
    fn rejects_bad_config() {
        let mu = paper_mu();
        assert!(ClosedNetwork::new(&mu, quick_cfg(vec![10])).is_err());
        assert!(ClosedNetwork::new(&mu, quick_cfg(vec![0, 0])).is_err());
    }
}
