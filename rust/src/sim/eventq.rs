//! Indexed binary min-heap over per-processor next-completion times.
//!
//! The closed-network event loop needs one operation per event: *which
//! processor completes next?*  The seed engine answered it with a linear
//! argmin over all l processors per event; this queue answers it in O(1)
//! (`peek`) with O(log l) re-keying of the one or two processors an event
//! actually touches (`update`) — the classic indexed-heap
//! decrease/increase-key structure.
//!
//! Ordering ties break toward the smaller processor index, so `peek`
//! returns exactly what the seed's linear scan returned (Rust's
//! `Iterator::min_by` keeps the *first* minimum), making the reworked
//! engine event-for-event identical to the old one
//! (`tests/hotpath_equiv.rs`).

// srclint: allow-file(index-reachable) — heap parent and child arithmetic stays within the backing vec by construction

/// Sentinel for "processor not in the heap" (idle processor).
const ABSENT: usize = usize::MAX;

/// Indexed min-heap keyed by (time, processor id).
#[derive(Debug, Clone, Default)]
pub struct EventQueue {
    /// Heap entries (key, processor id); `heap[0]` is the minimum.
    heap: Vec<(f64, usize)>,
    /// `pos[j]` = index of processor j's entry in `heap`, or [`ABSENT`].
    pos: Vec<usize>,
}

impl EventQueue {
    /// Empty queue sized for `l` processors.
    pub fn new(l: usize) -> Self {
        Self { heap: Vec::with_capacity(l), pos: vec![ABSENT; l] }
    }

    /// Clear and resize for `l` processors, keeping allocations.
    pub fn reset(&mut self, l: usize) {
        self.heap.clear();
        self.pos.clear();
        self.pos.resize(l, ABSENT);
    }

    /// Number of scheduled (non-idle) processors.
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no processor has a scheduled completion.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The earliest (processor, completion time), if any.
    #[inline]
    pub fn peek(&self) -> Option<(usize, f64)> {
        self.heap.first().map(|&(t, j)| (j, t))
    }

    /// Re-key processor `j`: `Some(t)` schedules (or moves) its next
    /// completion at `t`; `None` removes it (idle processor).
    pub fn update(&mut self, j: usize, key: Option<f64>) {
        debug_assert!(j < self.pos.len(), "processor {j} out of range");
        match key {
            Some(t) => {
                debug_assert!(!t.is_nan(), "NaN completion time for {j}");
                match self.pos[j] {
                    ABSENT => {
                        self.heap.push((t, j));
                        let i = self.heap.len() - 1;
                        self.pos[j] = i;
                        self.sift_up(i);
                    }
                    i => {
                        let old = self.heap[i].0;
                        self.heap[i].0 = t;
                        if t < old {
                            self.sift_up(i);
                        } else {
                            self.sift_down(i);
                        }
                    }
                }
            }
            None => {
                let i = self.pos[j];
                if i == ABSENT {
                    return;
                }
                self.pos[j] = ABSENT;
                let last = self.heap.len() - 1;
                if i != last {
                    self.heap.swap(i, last);
                    self.heap.pop();
                    let moved = self.heap[i].1;
                    self.pos[moved] = i;
                    // The swapped-in entry may need to move either way.
                    self.sift_up(i);
                    self.sift_down(self.pos[moved]);
                } else {
                    self.heap.pop();
                }
            }
        }
    }

    /// Strict heap order: (t, j) lexicographic, smaller j first on ties.
    #[inline]
    fn less(a: (f64, usize), b: (f64, usize)) -> bool {
        a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::less(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                self.pos[self.heap[i].1] = i;
                self.pos[self.heap[parent].1] = parent;
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (left, right) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if left < self.heap.len() && Self::less(self.heap[left], self.heap[smallest]) {
                smallest = left;
            }
            if right < self.heap.len() && Self::less(self.heap[right], self.heap[smallest]) {
                smallest = right;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            self.pos[self.heap[i].1] = i;
            self.pos[self.heap[smallest].1] = smallest;
            i = smallest;
        }
    }

    /// Debug-only structural invariant: heap order holds and `pos` is the
    /// exact inverse of the heap's id column.
    #[cfg(debug_assertions)]
    pub fn check_invariants(&self) {
        for i in 1..self.heap.len() {
            let parent = (i - 1) / 2;
            assert!(
                !Self::less(self.heap[i], self.heap[parent]),
                "heap order violated at {i}"
            );
        }
        for (i, &(_, j)) in self.heap.iter().enumerate() {
            assert_eq!(self.pos[j], i, "pos[{j}] desynced");
        }
        let present = self.pos.iter().filter(|&&p| p != ABSENT).count();
        assert_eq!(present, self.heap.len(), "pos/heap cardinality");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear-argmin reference: first index with the minimal key.
    fn argmin(keys: &[Option<f64>]) -> Option<(usize, f64)> {
        keys.iter()
            .enumerate()
            .filter_map(|(j, k)| k.map(|t| (j, t)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
    }

    #[test]
    fn peek_matches_linear_argmin_on_random_streams() {
        use crate::sim::rng::Rng;
        let mut rng = Rng::new(0xE_4_E);
        for l in [1usize, 2, 3, 8, 17] {
            let mut q = EventQueue::new(l);
            let mut mirror: Vec<Option<f64>> = vec![None; l];
            for step in 0..2_000 {
                let j = rng.index(l);
                let key = if rng.bool_with(0.15) {
                    None
                } else {
                    Some(rng.range_f64(0.0, 100.0))
                };
                q.update(j, key);
                mirror[j] = key;
                q.check_invariants();
                let want = argmin(&mirror);
                let got = q.peek();
                match (want, got) {
                    (None, None) => {}
                    (Some((wj, wt)), Some((gj, gt))) => {
                        assert_eq!(wj, gj, "l={l} step={step}");
                        assert_eq!(wt, gt, "l={l} step={step}");
                    }
                    other => panic!("l={l} step={step}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn ties_break_toward_smaller_index() {
        let mut q = EventQueue::new(4);
        q.update(3, Some(5.0));
        q.update(1, Some(5.0));
        q.update(2, Some(5.0));
        assert_eq!(q.peek(), Some((1, 5.0)));
        q.update(1, None);
        assert_eq!(q.peek(), Some((2, 5.0)));
    }

    #[test]
    fn rekey_moves_both_directions() {
        let mut q = EventQueue::new(3);
        q.update(0, Some(1.0));
        q.update(1, Some(2.0));
        q.update(2, Some(3.0));
        q.update(0, Some(10.0)); // increase-key of the min
        assert_eq!(q.peek(), Some((1, 2.0)));
        q.update(2, Some(0.5)); // decrease-key of the max
        assert_eq!(q.peek(), Some((2, 0.5)));
        q.check_invariants();
    }

    #[test]
    fn remove_absent_is_noop_and_reset_reuses() {
        let mut q = EventQueue::new(2);
        q.update(0, None);
        assert!(q.is_empty());
        q.update(1, Some(1.0));
        assert_eq!(q.len(), 1);
        q.reset(5);
        assert!(q.is_empty());
        q.update(4, Some(2.0));
        assert_eq!(q.peek(), Some((4, 2.0)));
    }
}
