//! The four §5 task-size distributions, all normalized to mean 1.
//!
//! A task's *size* is its service requirement in work units; its service
//! time on processor j is `size / μ_ij` when running alone.  Mean-1
//! normalization makes μ directly the single-task completion rate, exactly
//! the paper's convention (Def. 3).

use super::rng::Rng;
use crate::error::{Error, Result};

/// Task-size distribution (mean 1 unless noted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Distribution {
    /// Exponential(1) — the Markovian case of §3.3.
    Exponential,
    /// Bounded Pareto with tail index `alpha` on [k, h], scaled to mean 1.
    /// The §5 default is α = 1.5 with h/k = 10⁴ (heavy-tailed, the
    /// process-lifetime shape of [12]).
    BoundedPareto { alpha: f64, spread: f64 },
    /// Uniform(0, 2) — mean 1.
    Uniform,
    /// Constant 1 — deterministic sizes.
    Constant,
}

impl Distribution {
    /// The §5 bounded-Pareto default.
    pub fn default_pareto() -> Self {
        Distribution::BoundedPareto { alpha: 1.5, spread: 1e4 }
    }

    /// Parse from a CLI/config name.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "exp" | "exponential" => Ok(Distribution::Exponential),
            "pareto" | "bounded_pareto" => Ok(Self::default_pareto()),
            "uniform" => Ok(Distribution::Uniform),
            "const" | "constant" => Ok(Distribution::Constant),
            other => Err(Error::Parse(format!(
                "unknown distribution '{other}' (exp|pareto|uniform|const)"
            ))),
        }
    }

    /// Canonical name (CLI round-trip).
    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Exponential => "exp",
            Distribution::BoundedPareto { .. } => "pareto",
            Distribution::Uniform => "uniform",
            Distribution::Constant => "const",
        }
    }

    /// All four paper distributions (the Figs. 4–7 sweep).
    pub fn all() -> [Distribution; 4] {
        [
            Distribution::Exponential,
            Distribution::default_pareto(),
            Distribution::Uniform,
            Distribution::Constant,
        ]
    }

    /// Draw one task size.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Distribution::Exponential => rng.exp(1.0),
            Distribution::BoundedPareto { alpha, spread } => {
                let k = pareto_lower(alpha, spread);
                let h = k * spread;
                // Inverse CDF of the bounded Pareto on [k, h].
                let u = rng.f64();
                let ka = k.powf(alpha);
                let ha = h.powf(alpha);
                let x = (1.0 - u * (1.0 - ka / ha)).powf(-1.0 / alpha) * k;
                x.min(h)
            }
            Distribution::Uniform => rng.range_f64(0.0, 2.0),
            Distribution::Constant => 1.0,
        }
    }

    /// Analytic mean (should be 1 for all shipped parameterizations).
    pub fn mean(&self) -> f64 {
        match *self {
            Distribution::Exponential | Distribution::Constant => 1.0,
            Distribution::Uniform => 1.0,
            Distribution::BoundedPareto { alpha, spread } => {
                let k = pareto_lower(alpha, spread);
                bounded_pareto_mean(alpha, k, k * spread)
            }
        }
    }

    /// Squared coefficient of variation (dispersion fingerprint; used by
    /// tests to confirm the heavy tail survived normalization).
    pub fn scv(&self) -> f64 {
        match *self {
            Distribution::Exponential => 1.0,
            Distribution::Constant => 0.0,
            Distribution::Uniform => 1.0 / 3.0,
            Distribution::BoundedPareto { alpha, spread } => {
                let k = pareto_lower(alpha, spread);
                let h = k * spread;
                let m1 = bounded_pareto_mean(alpha, k, h);
                let m2 = bounded_pareto_moment2(alpha, k, h);
                m2 / (m1 * m1) - 1.0
            }
        }
    }
}

/// E[X] of bounded Pareto(α, k, h).
fn bounded_pareto_mean(alpha: f64, k: f64, h: f64) -> f64 {
    debug_assert!(alpha != 1.0);
    let ka = k.powf(alpha);
    let ha = h.powf(alpha);
    ka / (1.0 - ka / ha) * alpha / (alpha - 1.0)
        * (1.0 / k.powf(alpha - 1.0) - 1.0 / h.powf(alpha - 1.0))
}

/// E[X²] of bounded Pareto(α, k, h), α ≠ 2.
fn bounded_pareto_moment2(alpha: f64, k: f64, h: f64) -> f64 {
    let ka = k.powf(alpha);
    let ha = h.powf(alpha);
    ka / (1.0 - ka / ha) * alpha / (alpha - 2.0)
        * (1.0 / k.powf(alpha - 2.0) - 1.0 / h.powf(alpha - 2.0))
}

/// Solve for the lower bound k that gives mean 1 at the given α and h/k
/// spread (closed form via the mean expression's k-linearity).
fn pareto_lower(alpha: f64, spread: f64) -> f64 {
    // mean(α, k, s·k) = k · mean(α, 1, s)  — scale-family property.
    1.0 / bounded_pareto_mean(alpha, 1.0, spread)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for d in Distribution::all() {
            assert_eq!(Distribution::parse(d.name()).unwrap(), d);
        }
        assert!(Distribution::parse("zipf").is_err());
    }

    #[test]
    fn all_means_are_one_analytically() {
        for d in Distribution::all() {
            assert!((d.mean() - 1.0).abs() < 1e-9, "{d:?} mean {}", d.mean());
        }
    }

    #[test]
    fn empirical_means_are_one() {
        let n = 400_000;
        for d in Distribution::all() {
            let mut rng = Rng::new(1234);
            let s: f64 = (0..n).map(|_| d.sample(&mut rng)).sum();
            let mean = s / n as f64;
            // Pareto converges slowly (heavy tail) — wide but meaningful gate.
            let tol = if matches!(d, Distribution::BoundedPareto { .. }) {
                0.08
            } else {
                0.01
            };
            assert!((mean - 1.0).abs() < tol, "{d:?}: mean {mean}");
        }
    }

    #[test]
    fn pareto_is_heavy_tailed() {
        let d = Distribution::default_pareto();
        assert!(d.scv() > 5.0, "scv {}", d.scv());
        // And bounded: samples stay within [k, h].
        let (k, h) = match d {
            Distribution::BoundedPareto { alpha, spread } => {
                let k = super::pareto_lower(alpha, spread);
                (k, k * spread)
            }
            _ => unreachable!(),
        };
        let mut rng = Rng::new(99);
        for _ in 0..50_000 {
            let x = d.sample(&mut rng);
            assert!(x >= k * 0.999 && x <= h * 1.001);
        }
    }

    #[test]
    fn uniform_support() {
        let mut rng = Rng::new(5);
        for _ in 0..10_000 {
            let x = Distribution::Uniform.sample(&mut rng);
            assert!((0.0..2.0).contains(&x));
        }
    }

    #[test]
    fn constant_is_constant() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(Distribution::Constant.sample(&mut rng), 1.0);
        }
    }
}
