//! Measurement methodology of §5: warm-up discard, then count completions.
//!
//! X_sim      = completed / elapsed
//! E[T_sim]   = mean response (entry → completion)
//! E[ℰ_sim]   = mean of 𝒫_ij · ω, ω = size/μ_ij (execution, not response)
//! EDP_sim    = E[ℰ_sim] · E[T_sim]
//! X·E[T]     ≈ N (Little's-Law self-check, bottom-right subplots).
//!
//! Deadline accounting (the priority/deadline subsystem) is opt-in via
//! [`Metrics::track_deadlines`]: per-class response histograms and
//! soft-deadline miss counts, off the hot path — and allocation-free —
//! unless a run configures deadlines.

// srclint: allow-file(index-reachable) — the k by l cell grid is sized at Metrics::new; class ids are validated upstream

use crate::coordinator::stats::LatencyHistogram;

/// Online accumulator for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Completions counted (post-warm-up).
    pub completed: u64,
    /// Sum of response times.
    sum_response: f64,
    /// Sum of per-task energies.
    sum_energy: f64,
    /// Measurement window start.
    t_start: f64,
    /// Last completion time seen.
    t_last: f64,
    /// Per-(type, proc) completion counts, row-major k×l.
    pub completions_by_cell: Vec<u64>,
    /// Per-class soft deadlines in simulated seconds (0 = the class has
    /// no deadline); empty = deadline tracking off.
    deadlines: Vec<f64>,
    /// Per-class deadline misses (response > deadline); sized k only
    /// while tracking.
    misses_by_class: Vec<u64>,
    /// Per-class response histograms (p99 reporting); sized k only
    /// while tracking.
    class_hist: Vec<LatencyHistogram>,
    /// Tasks evacuated from a failed device and re-dispatched to a
    /// survivor during this window (the FEST-style backup counter).
    tasks_redispatched: u64,
    /// Σ_j device-seconds spent down over this window (the fault
    /// injector's accounting; 0 for fault-free runs).
    downtime: f64,
    k: usize,
    l: usize,
}

impl Metrics {
    /// New accumulator opening its window at `t_start`.
    pub fn new(k: usize, l: usize, t_start: f64) -> Self {
        let mut m = Self::default();
        m.reset(k, l, t_start);
        m
    }

    /// Re-open the measurement window at `t_start`, zeroing all
    /// accumulators while keeping the cell-count allocation — the
    /// arena-reuse path (no per-replication allocation).
    pub fn reset(&mut self, k: usize, l: usize, t_start: f64) {
        self.completed = 0;
        self.sum_response = 0.0;
        self.sum_energy = 0.0;
        self.t_start = t_start;
        self.t_last = t_start;
        self.completions_by_cell.clear();
        self.completions_by_cell.resize(k * l, 0);
        self.deadlines.clear();
        self.misses_by_class.clear();
        self.class_hist.clear();
        self.tasks_redispatched = 0;
        self.downtime = 0.0;
        self.k = k;
        self.l = l;
    }

    /// Count one task evacuated from a failed device and re-dispatched
    /// to a survivor.
    pub fn record_redispatch(&mut self) {
        self.tasks_redispatched += 1;
    }

    /// Charge `device_seconds` of accumulated device downtime to this
    /// window (Σ over devices of time spent down).  Call once before
    /// [`finalize`](Self::finalize); fault-free runs never call it and
    /// report a zero `downtime_frac`.
    pub fn add_downtime(&mut self, device_seconds: f64) {
        debug_assert!(device_seconds >= 0.0);
        self.downtime += device_seconds;
    }

    /// Switch on per-class deadline/percentile accounting for this
    /// window: `deadlines[i]` is class i's soft deadline in simulated
    /// seconds (0 = no deadline for that class, responses still feed the
    /// class histogram).  Call after [`new`](Self::new)/[`reset`](Self::reset);
    /// runs that never call it pay nothing on the record path.
    pub fn track_deadlines(&mut self, deadlines: &[f64]) {
        debug_assert_eq!(deadlines.len(), self.k);
        self.deadlines = deadlines.to_vec();
        self.misses_by_class = vec![0; self.k];
        self.class_hist = (0..self.k).map(|_| LatencyHistogram::new()).collect();
    }

    /// Record a completed task.
    ///
    /// `response` = now − arrive; `energy` = 𝒫_ij·size/μ_ij.
    pub fn record(&mut self, now: f64, response: f64, energy: f64, ttype: usize, proc: usize) {
        debug_assert!(response >= 0.0);
        self.completed += 1;
        self.sum_response += response;
        self.sum_energy += energy;
        self.t_last = now;
        self.completions_by_cell[ttype * self.l + proc] += 1;
        if !self.deadlines.is_empty() {
            self.class_hist[ttype].record_s(response);
            let deadline = self.deadlines[ttype];
            if deadline > 0.0 && response > deadline {
                self.misses_by_class[ttype] += 1;
            }
        }
    }

    /// Charge idle-floor energy accrued over the measurement window
    /// (Σ_j idle_power·idle_time_j); it amortizes into E[ℰ_sim] across
    /// the window's completions.  Call once, before
    /// [`finalize`](Self::finalize); runs without an idle-power floor
    /// never call it and keep the exact pre-objective energy accounting.
    pub fn add_idle_energy(&mut self, energy: f64) {
        debug_assert!(energy >= 0.0);
        self.sum_energy += energy;
    }

    /// Elapsed measurement time.
    pub fn elapsed(&self) -> f64 {
        self.t_last - self.t_start
    }

    /// Finalize into a result summary.
    pub fn finalize(&self, n_programs: u32) -> SimResult {
        let el = self.elapsed();
        let x = if el > 0.0 { self.completed as f64 / el } else { 0.0 };
        let mean_t = if self.completed > 0 {
            self.sum_response / self.completed as f64
        } else {
            0.0
        };
        let mean_e = if self.completed > 0 {
            self.sum_energy / self.completed as f64
        } else {
            0.0
        };
        // Fraction of fleet capacity-time lost to downtime: Σ down
        // device-seconds over l·elapsed (clamped: a device can be down
        // for at most the whole window).
        let downtime_frac = if el > 0.0 && self.l > 0 {
            (self.downtime / (self.l as f64 * el)).clamp(0.0, 1.0)
        } else {
            0.0
        };
        SimResult {
            throughput: x,
            mean_response: mean_t,
            mean_energy: mean_e,
            edp: mean_e * mean_t,
            little_product: x * mean_t,
            n_programs,
            completed: self.completed,
            tasks_redispatched: self.tasks_redispatched,
            downtime_frac,
            completions_by_cell: self.completions_by_cell.clone(),
            deadline_misses: self.misses_by_class.clone(),
            p99_by_class: self
                .class_hist
                .iter()
                .map(|h| h.quantile_s(0.99))
                .collect(),
            k: self.k,
            l: self.l,
        }
    }
}

/// Summary of one simulation run (one point of a paper figure).
#[derive(Debug, Clone)]
pub struct SimResult {
    /// X_sim.
    pub throughput: f64,
    /// E[T_sim].
    pub mean_response: f64,
    /// E[ℰ_sim].
    pub mean_energy: f64,
    /// EDP_sim = E[ℰ]·E[T].
    pub edp: f64,
    /// X·E[T] — must ≈ N (Little's Law).
    pub little_product: f64,
    /// N.
    pub n_programs: u32,
    /// Completions measured.
    pub completed: u64,
    /// Tasks evacuated from failed devices and re-dispatched to
    /// survivors during this window (0 for fault-free runs).
    pub tasks_redispatched: u64,
    /// Fraction of fleet capacity-time lost to device downtime over
    /// this window: Σ_j down-seconds / (l · elapsed); 0 when fault-free.
    pub downtime_frac: f64,
    /// Per-(type, proc) completion counts (row-major k×l) — the observed
    /// ρ_ij routing fractions.
    pub completions_by_cell: Vec<u64>,
    /// Per-class soft-deadline misses (empty unless the run called
    /// [`Metrics::track_deadlines`]).
    pub deadline_misses: Vec<u64>,
    /// Per-class p99 response time in seconds (empty unless deadline
    /// tracking was on; bucket-edge resolution, see
    /// [`crate::coordinator::LatencyHistogram::quantile_s`]).
    pub p99_by_class: Vec<f64>,
    k: usize,
    l: usize,
}

impl SimResult {
    /// Fraction of completions of type `i` that ran on processor `j`
    /// (ρ_ij of §3.4 restricted to type i).
    pub fn routing_fraction(&self, i: usize, j: usize) -> f64 {
        let row: u64 = (0..self.l).map(|jj| self.completions_by_cell[i * self.l + jj]).sum();
        if row == 0 {
            return 0.0;
        }
        self.completions_by_cell[i * self.l + j] as f64 / row as f64
    }

    /// Measured completions of class `i` (row sum of the cell counts).
    pub fn class_completions(&self, i: usize) -> u64 {
        (0..self.l).map(|j| self.completions_by_cell[i * self.l + j]).sum()
    }

    /// Class-i throughput X_i = class completions / elapsed — the
    /// per-tier signal the priority subsystem optimizes.  Derived as
    /// X · (class share of completions), so it needs no extra state.
    pub fn class_throughput(&self, i: usize) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.throughput * self.class_completions(i) as f64 / self.completed as f64
    }

    /// Fraction of class-i completions that missed the class's soft
    /// deadline; 0 when the class has no deadline, deadline tracking
    /// was off, or nothing of the class completed.
    pub fn deadline_miss_rate(&self, i: usize) -> f64 {
        let total = self.class_completions(i);
        match self.deadline_misses.get(i) {
            Some(&m) if total > 0 => m as f64 / total as f64,
            _ => 0.0,
        }
    }

    /// Little's-Law residual |X·E[T] − N| / N.
    pub fn little_residual(&self) -> f64 {
        (self.little_product - self.n_programs as f64).abs() / self.n_programs as f64
    }

    /// Task-type count.
    pub fn types(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_finalizes() {
        let mut m = Metrics::new(2, 2, 10.0);
        m.record(12.0, 2.0, 0.5, 0, 0);
        m.record(14.0, 4.0, 1.5, 1, 1);
        let r = m.finalize(20);
        assert_eq!(r.completed, 2);
        assert!((r.throughput - 0.5).abs() < 1e-12); // 2 tasks / 4 s
        assert!((r.mean_response - 3.0).abs() < 1e-12);
        assert!((r.mean_energy - 1.0).abs() < 1e-12);
        assert!((r.edp - 3.0).abs() < 1e-12);
        assert!((r.little_product - 1.5).abs() < 1e-12);
    }

    #[test]
    fn idle_energy_amortizes_into_mean_energy() {
        let mut m = Metrics::new(1, 2, 0.0);
        m.record(1.0, 1.0, 0.5, 0, 0);
        m.record(2.0, 1.0, 0.5, 0, 1);
        m.add_idle_energy(3.0);
        let r = m.finalize(2);
        // (0.5 + 0.5 + 3.0) / 2 completions.
        assert!((r.mean_energy - 2.0).abs() < 1e-12);
        assert!((r.edp - r.mean_energy * r.mean_response).abs() < 1e-12);
    }

    #[test]
    fn fault_stats_flow_into_the_result() {
        let mut m = Metrics::new(2, 2, 0.0);
        m.record(4.0, 1.0, 0.0, 0, 0);
        m.record_redispatch();
        m.record_redispatch();
        // One of two devices down for 2 of the 4 elapsed seconds.
        m.add_downtime(2.0);
        let r = m.finalize(4);
        assert_eq!(r.tasks_redispatched, 2);
        assert!((r.downtime_frac - 0.25).abs() < 1e-12);
        // reset zeroes both fault accumulators.
        m.reset(2, 2, 0.0);
        m.record(1.0, 1.0, 0.0, 0, 0);
        let r = m.finalize(4);
        assert_eq!(r.tasks_redispatched, 0);
        assert_eq!(r.downtime_frac, 0.0);
    }

    #[test]
    fn routing_fractions() {
        let mut m = Metrics::new(2, 2, 0.0);
        m.record(1.0, 1.0, 0.0, 0, 0);
        m.record(2.0, 1.0, 0.0, 0, 0);
        m.record(3.0, 1.0, 0.0, 0, 1);
        m.record(4.0, 1.0, 0.0, 1, 1);
        let r = m.finalize(4);
        assert!((r.routing_fraction(0, 0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.routing_fraction(1, 1) - 1.0).abs() < 1e-12);
        assert_eq!(r.routing_fraction(1, 0), 0.0);
    }

    #[test]
    fn reset_clears_but_reuses() {
        let mut m = Metrics::new(2, 2, 0.0);
        m.record(1.0, 1.0, 0.5, 0, 0);
        m.reset(2, 2, 5.0);
        assert_eq!(m.completed, 0);
        assert_eq!(m.finalize(4).throughput, 0.0);
        m.record(7.0, 2.0, 0.0, 1, 1);
        let r = m.finalize(4);
        assert!((r.throughput - 0.5).abs() < 1e-12); // 1 task / 2 s
        assert_eq!(r.completions_by_cell, vec![0, 0, 0, 1]);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let r = Metrics::new(1, 1, 0.0).finalize(5);
        assert_eq!(r.throughput, 0.0);
        assert_eq!(r.completed, 0);
        // Deadline accounting is opt-in: off by default.
        assert!(r.deadline_misses.is_empty());
        assert_eq!(r.deadline_miss_rate(0), 0.0);
    }

    #[test]
    fn deadline_tracking_counts_misses_per_class() {
        let mut m = Metrics::new(2, 2, 0.0);
        // Class 0 deadline 1.0 s; class 1 has none (0 = untracked).
        m.track_deadlines(&[1.0, 0.0]);
        m.record(1.0, 0.5, 0.0, 0, 0); // hit
        m.record(2.0, 1.5, 0.0, 0, 0); // miss
        m.record(3.0, 2.5, 0.0, 0, 1); // miss
        m.record(4.0, 9.0, 0.0, 1, 1); // class 1: never a miss
        let r = m.finalize(4);
        assert_eq!(r.deadline_misses, vec![2, 0]);
        assert!((r.deadline_miss_rate(0) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.deadline_miss_rate(1), 0.0);
        assert_eq!(r.class_completions(0), 3);
        assert_eq!(r.class_completions(1), 1);
        // Per-class X splits total X by completion share.
        assert!((r.class_throughput(0) - r.throughput * 0.75).abs() < 1e-12);
        // p99 histograms bracket the recorded responses (log buckets).
        assert_eq!(r.p99_by_class.len(), 2);
        assert!(r.p99_by_class[0] >= 2.5 && r.p99_by_class[0] <= 5.1);
        // reset clears the tracking state back to off.
        m.reset(2, 2, 0.0);
        m.record(1.0, 3.0, 0.0, 0, 0);
        let r = m.finalize(4);
        assert!(r.deadline_misses.is_empty());
        assert!(r.p99_by_class.is_empty());
    }
}
