//! Measurement methodology of §5: warm-up discard, then count completions.
//!
//! X_sim      = completed / elapsed
//! E[T_sim]   = mean response (entry → completion)
//! E[ℰ_sim]   = mean of 𝒫_ij · ω, ω = size/μ_ij (execution, not response)
//! EDP_sim    = E[ℰ_sim] · E[T_sim]
//! X·E[T]     ≈ N (Little's-Law self-check, bottom-right subplots).

/// Online accumulator for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Completions counted (post-warm-up).
    pub completed: u64,
    /// Sum of response times.
    sum_response: f64,
    /// Sum of per-task energies.
    sum_energy: f64,
    /// Measurement window start.
    t_start: f64,
    /// Last completion time seen.
    t_last: f64,
    /// Per-(type, proc) completion counts, row-major k×l.
    pub completions_by_cell: Vec<u64>,
    k: usize,
    l: usize,
}

impl Metrics {
    /// New accumulator opening its window at `t_start`.
    pub fn new(k: usize, l: usize, t_start: f64) -> Self {
        let mut m = Self::default();
        m.reset(k, l, t_start);
        m
    }

    /// Re-open the measurement window at `t_start`, zeroing all
    /// accumulators while keeping the cell-count allocation — the
    /// arena-reuse path (no per-replication allocation).
    pub fn reset(&mut self, k: usize, l: usize, t_start: f64) {
        self.completed = 0;
        self.sum_response = 0.0;
        self.sum_energy = 0.0;
        self.t_start = t_start;
        self.t_last = t_start;
        self.completions_by_cell.clear();
        self.completions_by_cell.resize(k * l, 0);
        self.k = k;
        self.l = l;
    }

    /// Record a completed task.
    ///
    /// `response` = now − arrive; `energy` = 𝒫_ij·size/μ_ij.
    pub fn record(&mut self, now: f64, response: f64, energy: f64, ttype: usize, proc: usize) {
        debug_assert!(response >= 0.0);
        self.completed += 1;
        self.sum_response += response;
        self.sum_energy += energy;
        self.t_last = now;
        self.completions_by_cell[ttype * self.l + proc] += 1;
    }

    /// Elapsed measurement time.
    pub fn elapsed(&self) -> f64 {
        self.t_last - self.t_start
    }

    /// Finalize into a result summary.
    pub fn finalize(&self, n_programs: u32) -> SimResult {
        let el = self.elapsed();
        let x = if el > 0.0 { self.completed as f64 / el } else { 0.0 };
        let mean_t = if self.completed > 0 {
            self.sum_response / self.completed as f64
        } else {
            0.0
        };
        let mean_e = if self.completed > 0 {
            self.sum_energy / self.completed as f64
        } else {
            0.0
        };
        SimResult {
            throughput: x,
            mean_response: mean_t,
            mean_energy: mean_e,
            edp: mean_e * mean_t,
            little_product: x * mean_t,
            n_programs,
            completed: self.completed,
            completions_by_cell: self.completions_by_cell.clone(),
            k: self.k,
            l: self.l,
        }
    }
}

/// Summary of one simulation run (one point of a paper figure).
#[derive(Debug, Clone)]
pub struct SimResult {
    /// X_sim.
    pub throughput: f64,
    /// E[T_sim].
    pub mean_response: f64,
    /// E[ℰ_sim].
    pub mean_energy: f64,
    /// EDP_sim = E[ℰ]·E[T].
    pub edp: f64,
    /// X·E[T] — must ≈ N (Little's Law).
    pub little_product: f64,
    /// N.
    pub n_programs: u32,
    /// Completions measured.
    pub completed: u64,
    /// Per-(type, proc) completion counts (row-major k×l) — the observed
    /// ρ_ij routing fractions.
    pub completions_by_cell: Vec<u64>,
    k: usize,
    l: usize,
}

impl SimResult {
    /// Fraction of completions of type `i` that ran on processor `j`
    /// (ρ_ij of §3.4 restricted to type i).
    pub fn routing_fraction(&self, i: usize, j: usize) -> f64 {
        let row: u64 = (0..self.l).map(|jj| self.completions_by_cell[i * self.l + jj]).sum();
        if row == 0 {
            return 0.0;
        }
        self.completions_by_cell[i * self.l + j] as f64 / row as f64
    }

    /// Little's-Law residual |X·E[T] − N| / N.
    pub fn little_residual(&self) -> f64 {
        (self.little_product - self.n_programs as f64).abs() / self.n_programs as f64
    }

    /// Task-type count.
    pub fn types(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_finalizes() {
        let mut m = Metrics::new(2, 2, 10.0);
        m.record(12.0, 2.0, 0.5, 0, 0);
        m.record(14.0, 4.0, 1.5, 1, 1);
        let r = m.finalize(20);
        assert_eq!(r.completed, 2);
        assert!((r.throughput - 0.5).abs() < 1e-12); // 2 tasks / 4 s
        assert!((r.mean_response - 3.0).abs() < 1e-12);
        assert!((r.mean_energy - 1.0).abs() < 1e-12);
        assert!((r.edp - 3.0).abs() < 1e-12);
        assert!((r.little_product - 1.5).abs() < 1e-12);
    }

    #[test]
    fn routing_fractions() {
        let mut m = Metrics::new(2, 2, 0.0);
        m.record(1.0, 1.0, 0.0, 0, 0);
        m.record(2.0, 1.0, 0.0, 0, 0);
        m.record(3.0, 1.0, 0.0, 0, 1);
        m.record(4.0, 1.0, 0.0, 1, 1);
        let r = m.finalize(4);
        assert!((r.routing_fraction(0, 0) - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.routing_fraction(1, 1) - 1.0).abs() < 1e-12);
        assert_eq!(r.routing_fraction(1, 0), 0.0);
    }

    #[test]
    fn reset_clears_but_reuses() {
        let mut m = Metrics::new(2, 2, 0.0);
        m.record(1.0, 1.0, 0.5, 0, 0);
        m.reset(2, 2, 5.0);
        assert_eq!(m.completed, 0);
        assert_eq!(m.finalize(4).throughput, 0.0);
        m.record(7.0, 2.0, 0.0, 1, 1);
        let r = m.finalize(4);
        assert!((r.throughput - 0.5).abs() < 1e-12); // 1 task / 2 s
        assert_eq!(r.completions_by_cell, vec![0, 0, 0, 1]);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let r = Metrics::new(1, 1, 0.0).finalize(5);
        assert_eq!(r.throughput, 0.0);
        assert_eq!(r.completed, 0);
    }
}
