//! Piece-wise closed systems (§3.1) with on-line policy re-solve (§4.1),
//! extended to non-stationary workloads.
//!
//! The paper's closed-system assumption "can be relaxed to include
//! piece-wise closed systems … applications are not launched and
//! terminated very frequently", and GrIn is motivated as fast enough to
//! re-solve "on the fly … when the number of tasks changes".  This
//! engine implements exactly that, plus the serving-reality extensions
//! the ROADMAP asks for:
//!
//! * a run is a sequence of *phases*, each with its own per-type
//!   populations, an optional task-size distribution override, and an
//!   optional processing-rate rescale (`mu_scale`: DVFS/thermal
//!   throttling or per-cell affinity drift);
//! * three [`ResolveMode`]s compare scheduling regimes end-to-end:
//!   **Static** (solve once on the initial matrix, never again),
//!   **EveryPhase** (oracle re-solve with the true per-phase rates) and
//!   **Adaptive** (a [`RateEstimator`] learns μ̂ from observed service
//!   times and GrIn/CAB re-solve when drift exceeds a threshold — no
//!   oracle knowledge).
//!
//! Retirement is graceful: a surplus program finishes its in-flight task
//! and simply does not re-issue — no task is ever killed, matching how
//! real programs terminate.  Tasks in flight across a rate change keep
//! the rate they started with (a real frequency switch drains in-flight
//! work the same way).

// srclint: allow-file(index-reachable) — cell grids, phase tables and per-class vectors are all sized at scenario build

use crate::coordinator::global::ShardedControl;
use crate::coordinator::stats::RateEstimator;
use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, PowerProfile};
use crate::model::state::StateMatrix;
use crate::policy::{Policy, SystemView};

use super::distribution::Distribution;
use super::eventq::EventQueue;
use super::metrics::{Metrics, SimResult};
use super::processor::{Discipline, Processor};
use super::rng::Rng;
use super::task::{Program, Task};

/// One phase of a piece-wise closed run.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Per-type populations during this phase.
    pub populations: Vec<u32>,
    /// Completions to simulate in this phase (measured after `warmup`).
    pub completions: u64,
    /// Completions discarded at the start of the phase.
    pub warmup: u64,
    /// Processing-rate multipliers for this phase: empty = no change,
    /// `procs()` factors = per-processor (throttling), `types()·procs()`
    /// factors = per-cell (affinity drift).  See
    /// [`AffinityMatrix::scaled`].
    pub mu_scale: Vec<f64>,
    /// Task-size distribution override for this phase (burst regimes).
    pub dist: Option<Distribution>,
}

impl Phase {
    /// A stationary phase (no rate change, run-level distribution).
    pub fn new(populations: Vec<u32>, warmup: u64, completions: u64) -> Self {
        Self { populations, completions, warmup, mu_scale: Vec::new(), dist: None }
    }

    /// Builder: attach a rate rescale.
    pub fn with_mu_scale(mut self, scale: Vec<f64>) -> Self {
        self.mu_scale = scale;
        self
    }

    /// Builder: attach a distribution override.
    pub fn with_dist(mut self, dist: Distribution) -> Self {
        self.dist = Some(dist);
        self
    }
}

/// When does the policy re-solve its target?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolveMode {
    /// Solve once against the initial matrix and populations; never
    /// again (the frozen baseline).
    Static,
    /// Re-solve at every phase boundary with the *true* per-phase rates
    /// (oracle knowledge; the paper's piece-wise closed reading).
    EveryPhase,
    /// Estimate μ̂ on line from observed service times and re-solve when
    /// the configured [`Trigger`] fires — polled drift past
    /// [`DriftConfig::threshold`], or a per-cell CUSUM alarm
    /// ([`Trigger::Cusum`]) — plus at population changes, which a real
    /// scheduler observes directly.
    Adaptive,
    /// Multi-leader control plane ([`ShardedControl`]): the fleet is
    /// partitioned into [`ShardConfig::shards`] shards, each with its
    /// own cold-started estimator and local deficit steering; every
    /// [`ShardConfig::sync_every`] completions the global layer gathers
    /// per-shard snapshots and runs one batched GrIn re-solve, pushing
    /// epoch-versioned targets back.  The `policy` argument is ignored
    /// — the control plane always steers by batched GrIn.
    Sharded,
}

impl ResolveMode {
    /// Parse a CLI/config name.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "static" => Ok(ResolveMode::Static),
            "phase" | "every_phase" => Ok(ResolveMode::EveryPhase),
            "adaptive" => Ok(ResolveMode::Adaptive),
            "sharded" => Ok(ResolveMode::Sharded),
            other => Err(Error::Parse(format!(
                "unknown resolve mode '{other}' (static|every_phase|adaptive|sharded)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            ResolveMode::Static => "static",
            ResolveMode::EveryPhase => "every_phase",
            ResolveMode::Adaptive => "adaptive",
            ResolveMode::Sharded => "sharded",
        }
    }

    /// Every mode, in comparison-table order.
    pub fn all() -> [ResolveMode; 4] {
        [
            ResolveMode::Static,
            ResolveMode::EveryPhase,
            ResolveMode::Adaptive,
            ResolveMode::Sharded,
        ]
    }
}

/// What fires an adaptive re-solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// Poll every [`DriftConfig::check_every`] completions and re-solve
    /// when the maximum relative rate deviation of μ̂ from the believed
    /// matrix exceeds [`DriftConfig::threshold`] (the PR-1 behavior).
    Threshold,
    /// Per-cell two-sided CUSUM over service-time residuals
    /// ([`crate::coordinator::RateEstimator`]): re-solve the moment any
    /// cell's cumulative deviation crosses [`DriftConfig::cusum_h`] —
    /// fast on abrupt regime flips, and near-silent on stationary noise
    /// that the global drift metric occasionally mistakes for change.
    Cusum,
}

impl Trigger {
    /// Parse a CLI/config name.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "threshold" | "drift" => Ok(Trigger::Threshold),
            "cusum" => Ok(Trigger::Cusum),
            other => Err(Error::Parse(format!(
                "unknown trigger '{other}' (threshold|cusum)"
            ))),
        }
    }

    /// Canonical name.
    pub fn name(self) -> &'static str {
        match self {
            Trigger::Threshold => "threshold",
            Trigger::Cusum => "cusum",
        }
    }

    /// Both triggers, in comparison order.
    pub fn all() -> [Trigger; 2] {
        [Trigger::Threshold, Trigger::Cusum]
    }
}

/// Adaptive-mode knobs (estimator + change detector).
#[derive(Debug, Clone)]
pub struct DriftConfig {
    /// Relative rate deviation that triggers a re-solve
    /// ([`Trigger::Threshold`]).
    pub threshold: f64,
    /// Completions between drift checks ([`Trigger::Threshold`]).
    pub check_every: u64,
    /// Estimator EWMA coefficient.
    pub ewma_alpha: f64,
    /// Estimator sliding-window length.
    pub window: usize,
    /// Observations before a cell's estimate is trusted.
    pub min_obs: u64,
    /// What fires a re-solve: polled threshold drift or per-cell CUSUM.
    pub trigger: Trigger,
    /// CUSUM drift allowance δ (relative service-time residual units):
    /// deviations below δ per batch are absorbed, not accumulated.
    pub cusum_delta: f64,
    /// CUSUM alarm threshold h: a cell alarms when its cumulative
    /// (δ-discounted) residual crosses h.  The default 4.0 detects a 2×
    /// rate flip in ~6 mini-batches while keeping the stationary
    /// false-alarm probability per cell near e⁻¹² under exponential
    /// service-time noise.
    pub cusum_h: f64,
    /// Completions (estimator-wide) without a fresh sample before a warm
    /// cell is demoted to stale: it stops signalling drift and its
    /// estimate is replaced by the believed rate wherever μ̂ is consumed.
    pub stale_after: u64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        Self {
            threshold: 0.2,
            check_every: 250,
            ewma_alpha: 0.05,
            window: 64,
            min_obs: 8,
            trigger: Trigger::Threshold,
            cusum_delta: 0.25,
            cusum_h: 4.0,
            stale_after: 1_000,
        }
    }
}

/// Sharded-mode knobs (the multi-leader control plane).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Shard count; 0 = one shard per processor (per device class).
    pub shards: usize,
    /// Completions between global gather / batched-re-solve syncs.
    pub sync_every: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        Self { shards: 0, sync_every: 250 }
    }
}

/// What happens to a device at a [`FaultEvent`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The device vanishes: its event-queue entry is removed, resident
    /// tasks are evacuated and re-dispatched to survivors (under the
    /// [`FaultPlan::backup_budget`]), and churn-aware control planes
    /// mask its μ column and re-solve.
    Down,
    /// The device rejoins empty.  Parked work re-dispatches, and
    /// churn-aware control planes restore the column to the boot-time
    /// prior and re-solve (the estimator restarts the column with fresh
    /// CUSUM evidence).
    Up,
    /// Slow-node "limping": the device keeps serving but new pushes run
    /// at `factor ×` the true rate (in-flight tasks keep the rate they
    /// started with, like a real DVFS transition).  Deliberately *not*
    /// signalled to any control plane — detecting the collapse is the
    /// CUSUM machinery's job.  `Limp(1.0)` restores full speed.
    Limp(f64),
}

/// One scheduled fault: `device` changes state at absolute simulation
/// time `time` (seconds since the start of the run, across phases).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Absolute simulation time of the event.
    pub time: f64,
    /// Device (processor column) affected.
    pub device: usize,
    /// What happens.
    pub kind: FaultKind,
}

/// A failure/recovery schedule injected into a dynamic run.
///
/// Events interleave deterministically with the completion stream: a
/// fault at time t fires before any completion at time ≥ t, and if the
/// event queue drains while devices are down the clock jumps to the
/// next recovery event instead of erroring.  `backup_budget` is the
/// FEST-style bound on *concurrently in-flight* re-dispatched (backup)
/// tasks: evacuated work beyond the budget parks and dispatches as
/// earlier backups complete, so re-dispatch is metered, never free —
/// and no task is ever dropped.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Schedule, non-decreasing in time.
    pub events: Vec<FaultEvent>,
    /// Max concurrent re-dispatched tasks (0 = unmetered).
    pub backup_budget: u32,
}

impl FaultPlan {
    /// The empty plan (fault-free run).
    pub fn none() -> Self {
        Self::default()
    }

    /// Is this the empty plan?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Validate against a fleet of `l` devices: events must be sorted by
    /// time, times finite and ≥ 0, devices in range, limp factors finite
    /// and > 0.
    pub fn validate(&self, l: usize) -> Result<()> {
        let mut last = 0.0f64;
        for ev in &self.events {
            if !ev.time.is_finite() || ev.time < 0.0 {
                return Err(Error::Config(format!("fault time {} invalid", ev.time)));
            }
            if ev.time < last {
                return Err(Error::Config(
                    "fault events must be sorted by time".into(),
                ));
            }
            last = ev.time;
            if ev.device >= l {
                return Err(Error::Config(format!(
                    "fault device {} out of range for {} processors",
                    ev.device, l
                )));
            }
            if let FaultKind::Limp(f) = ev.kind {
                if !f.is_finite() || f <= 0.0 {
                    return Err(Error::Config(format!(
                        "limp factor {f} must be finite and > 0"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Parse the CLI/scenario spec format: `;`-separated entries, each
    /// `down:<dev>@<time>`, `up:<dev>@<time>`, `limp:<dev>x<factor>@<time>`
    /// or `budget:<n>`.  Events are sorted by time (stable, so same-time
    /// events keep spec order).
    ///
    /// Example: `down:0@5;up:0@12;limp:1x0.25@20;budget:4`.
    pub fn parse_spec(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::none();
        for entry in spec.split(';').filter(|e| !e.trim().is_empty()) {
            let entry = entry.trim();
            let (kind, rest) = entry.split_once(':').ok_or_else(|| {
                Error::Parse(format!("fault entry '{entry}' needs kind:…"))
            })?;
            if kind == "budget" {
                plan.backup_budget = rest.parse().map_err(|_| {
                    Error::Parse(format!("bad backup budget '{rest}'"))
                })?;
                continue;
            }
            let (dev_part, time_part) = rest.split_once('@').ok_or_else(|| {
                Error::Parse(format!("fault entry '{entry}' needs …@time"))
            })?;
            let time: f64 = time_part.parse().map_err(|_| {
                Error::Parse(format!("bad fault time '{time_part}'"))
            })?;
            let (device, fkind) = match kind {
                "down" | "up" => {
                    let d: usize = dev_part.parse().map_err(|_| {
                        Error::Parse(format!("bad fault device '{dev_part}'"))
                    })?;
                    (d, if kind == "down" { FaultKind::Down } else { FaultKind::Up })
                }
                "limp" => {
                    let (d, f) = dev_part.split_once('x').ok_or_else(|| {
                        Error::Parse(format!(
                            "limp entry '{entry}' needs dev x factor"
                        ))
                    })?;
                    let d: usize = d.parse().map_err(|_| {
                        Error::Parse(format!("bad fault device '{d}'"))
                    })?;
                    let f: f64 = f.parse().map_err(|_| {
                        Error::Parse(format!("bad limp factor '{f}'"))
                    })?;
                    (d, FaultKind::Limp(f))
                }
                other => {
                    return Err(Error::Parse(format!(
                        "unknown fault kind '{other}' (down|up|limp|budget)"
                    )))
                }
            };
            plan.events.push(FaultEvent { time, device, kind: fkind });
        }
        plan.events.sort_by(|a, b| a.time.total_cmp(&b.time));
        Ok(plan)
    }

    /// Canonical spec string ([`Self::parse_spec`] round-trips it).
    pub fn to_spec(&self) -> String {
        let mut parts: Vec<String> = self
            .events
            .iter()
            .map(|ev| match ev.kind {
                FaultKind::Down => format!("down:{}@{}", ev.device, ev.time),
                FaultKind::Up => format!("up:{}@{}", ev.device, ev.time),
                FaultKind::Limp(f) => format!("limp:{}x{}@{}", ev.device, f, ev.time),
            })
            .collect();
        if self.backup_budget > 0 {
            parts.push(format!("budget:{}", self.backup_budget));
        }
        parts.join(";")
    }
}

/// Configuration of a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicConfig {
    /// The phase schedule (≥ 1 phase).
    pub phases: Vec<Phase>,
    /// Service discipline.
    pub discipline: Discipline,
    /// Task-size distribution (phases may override).
    pub dist: Distribution,
    /// Seed.
    pub seed: u64,
    /// Re-solve regime.
    pub resolve: ResolveMode,
    /// Adaptive-mode knobs.
    pub drift: DriftConfig,
    /// Sharded-mode knobs.
    pub shard: ShardConfig,
    /// Per-class integer priorities (each ≥ 1; empty = all classes
    /// equal, the unweighted paths bit for bit).  Non-uniform
    /// priorities steer every solve through the weighted objective
    /// ([`crate::policy::grin::solve_weighted`]) with weights =
    /// normalized priority × per-cell estimator confidence — GrIn only;
    /// other policies reject them through the baseline
    /// [`Policy::prepare`] default.
    pub priorities: Vec<u32>,
    /// Per-class soft deadlines in simulated seconds (0 = no deadline
    /// for that class; empty = deadline accounting off).  Misses and
    /// per-class p99 land in each phase's
    /// [`SimResult`](crate::sim::metrics::SimResult).
    pub deadlines: Vec<f64>,
    /// Scheduling objective every re-solve optimizes
    /// ([`Objective::Throughput`] reproduces the pre-objective runs bit
    /// for bit; other objectives are GrIn-only and reject non-trivial
    /// priorities).
    pub objective: Objective,
    /// Power model: drives objective scoring, per-task energy metering
    /// (completions are charged 𝒫(μ)·ω at the rate they were pushed
    /// with), and — when `idle_power > 0` — a per-phase idle-floor
    /// charge over each measurement window.
    pub power: PowerProfile,
    /// Failure/recovery schedule (empty = fault-free, the pre-churn
    /// runs bit for bit).  See [`FaultPlan`].
    pub faults: FaultPlan,
}

impl DynamicConfig {
    /// Defaults: PS discipline, exponential sizes, oracle per-phase
    /// re-solve (the original piece-wise closed behavior).
    pub fn new(phases: Vec<Phase>) -> Self {
        Self {
            phases,
            discipline: Discipline::Ps,
            dist: Distribution::Exponential,
            seed: 1,
            resolve: ResolveMode::EveryPhase,
            drift: DriftConfig::default(),
            shard: ShardConfig::default(),
            priorities: Vec::new(),
            deadlines: Vec::new(),
            objective: Objective::Throughput,
            power: PowerProfile::default(),
            faults: FaultPlan::none(),
        }
    }
}

/// Outcome of a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicReport {
    /// Per-phase measurements.
    pub phases: Vec<SimResult>,
    /// Re-solves performed (EveryPhase counts phase boundaries after the
    /// first; Adaptive counts drift-triggered target swaps).
    pub resolves: u64,
    /// Tasks evacuated from failed devices and re-dispatched to
    /// survivors over the whole run (warmup included — unlike the
    /// per-phase window counts in [`SimResult`]).
    pub tasks_redispatched: u64,
    /// Conservation residual |emitted − completed − in-system| at run
    /// end; always 0 — re-dispatch never loses or duplicates a task.
    pub tasks_lost: u64,
}

impl DynamicReport {
    /// Completion-weighted mean throughput across phases (total measured
    /// completions / total measured time).
    pub fn mean_throughput(&self) -> f64 {
        let mut completed = 0u64;
        let mut time = 0.0f64;
        for r in &self.phases {
            if r.throughput > 0.0 {
                completed += r.completed;
                time += r.completed as f64 / r.throughput;
            }
        }
        if time > 0.0 {
            completed as f64 / time
        } else {
            0.0
        }
    }

    /// Completion-weighted mean class-`i` throughput across phases —
    /// the per-tier aggregate the priority gates are measured on
    /// (`tests/priority_e2e.rs`).
    pub fn class_throughput(&self, i: usize) -> f64 {
        let mut completed = 0u64;
        let mut time = 0.0f64;
        for r in &self.phases {
            if r.throughput > 0.0 {
                completed += r.class_completions(i);
                time += r.completed as f64 / r.throughput;
            }
        }
        if time > 0.0 {
            completed as f64 / time
        } else {
            0.0
        }
    }

    /// Run-wide class-`i` deadline-miss rate (misses / class
    /// completions, over every measured phase); 0 when deadlines were
    /// not configured.
    pub fn deadline_miss_rate(&self, i: usize) -> f64 {
        let mut miss = 0u64;
        let mut total = 0u64;
        for r in &self.phases {
            if let Some(&m) = r.deadline_misses.get(i) {
                miss += m;
            }
            total += r.class_completions(i);
        }
        if total > 0 {
            miss as f64 / total as f64
        } else {
            0.0
        }
    }

    /// Completion-weighted mean per-task energy across measured phases
    /// (Eq. 20 metering at each task's push-time rate, plus any
    /// idle-floor amortization).
    pub fn mean_energy(&self) -> f64 {
        let mut completed = 0u64;
        let mut joules = 0.0f64;
        for r in &self.phases {
            completed += r.completed;
            joules += r.mean_energy * r.completed as f64;
        }
        if completed > 0 {
            joules / completed as f64
        } else {
            0.0
        }
    }

    /// Time-weighted mean fraction of fleet capacity lost to downtime
    /// across measured phases (Σ downtime-seconds / Σ device-seconds).
    pub fn mean_downtime_frac(&self) -> f64 {
        let mut down = 0.0f64;
        let mut time = 0.0f64;
        for r in &self.phases {
            if r.throughput > 0.0 {
                let el = r.completed as f64 / r.throughput;
                down += r.downtime_frac * el;
                time += el;
            }
        }
        if time > 0.0 {
            down / time
        } else {
            0.0
        }
    }

    /// Run-level energy–delay product: completion-weighted mean energy
    /// × completion-weighted mean response.
    pub fn mean_edp(&self) -> f64 {
        let mut completed = 0u64;
        let mut resp = 0.0f64;
        for r in &self.phases {
            completed += r.completed;
            resp += r.mean_response * r.completed as f64;
        }
        if completed > 0 {
            self.mean_energy() * resp / completed as f64
        } else {
            0.0
        }
    }
}

/// Resolve the priority vector into per-cell weights, then run the
/// solve through the coordinator's shared prepare path
/// ([`crate::coordinator::router::prepare_policy`] — the same
/// [`crate::policy::SolveRequest`] assembly the router's
/// `TargetUpdate::apply` and the
/// concurrent front end's install use, so the simulator and the
/// serving plane cannot drift apart).  Trivial priorities (empty or
/// all-equal — see [`crate::policy::grin::trivial_priorities`]) solve
/// unweighted; otherwise weights = normalized priority × per-cell
/// confidence ([`crate::policy::grin::priority_weights`]).
/// `estimator` supplies the confidence grid on the adaptive path;
/// `None` (oracle paths: static, every-phase, and population-only
/// boundaries before any observation-driven re-solve) means full
/// confidence everywhere.
fn prepare_policy(
    policy: &mut dyn Policy,
    mu: &AffinityMatrix,
    populations: &[u32],
    priorities: &[u32],
    estimator: Option<&RateEstimator>,
    objective: Objective,
    power: PowerProfile,
) -> Result<()> {
    let weights = if crate::policy::grin::trivial_priorities(priorities) {
        Vec::new()
    } else {
        let (k, l) = (mu.types(), mu.procs());
        let confidence = match estimator {
            Some(e) => e.confidences(),
            None => vec![1.0; k * l],
        };
        crate::policy::grin::priority_weights(priorities, &confidence, l)?
    };
    crate::coordinator::router::prepare_policy(
        policy, mu, populations, &weights, objective, power,
    )
    .map(|_| ())
}

/// Physical fallback when routing targets a down device: the up device
/// with the smallest occupancy, ties to the lowest index.  Mirrors what
/// a node-local dispatcher does when its assigned backend stops
/// answering — deterministic, and independent of control-plane state.
fn fallback_device(procs: &[Processor], up: &[bool]) -> Option<usize> {
    let mut best: Option<(usize, usize)> = None;
    for (j, p) in procs.iter().enumerate() {
        if up[j] && best.map_or(true, |(_, occ)| p.occupancy() < occ) {
            best = Some((j, p.occupancy()));
        }
    }
    best.map(|(j, _)| j)
}

/// The true surviving-rate matrix: per-device limp factors applied,
/// then every down column masked to
/// [`DEAD_RATE`](crate::model::affinity::DEAD_RATE) — what the
/// failure-schedule oracle re-solves against.
fn effective_actual(
    actual: &AffinityMatrix,
    up: &[bool],
    limp: &[f64],
) -> Result<AffinityMatrix> {
    let mut m = actual.scaled(limp)?;
    for (j, &u) in up.iter().enumerate() {
        if !u {
            m = m.masked_column(j)?;
        }
    }
    Ok(m)
}

/// Cumulative fleet downtime in device-seconds as of `now`: closed
/// intervals (`acc`) plus the open interval of every still-down device.
fn cum_downtime(acc: f64, now: f64, up: &[bool], down_since: &[f64]) -> f64 {
    let mut d = acc;
    for (j, &u) in up.iter().enumerate() {
        if !u {
            d += now - down_since[j];
        }
    }
    d
}

/// One routing decision, fault-aware: the control plane (which filters
/// dead devices itself) or the policy, with a physical fallback reroute
/// when the policy's believed matrix still points at a down device.
/// `None` means the whole fleet is down — the caller parks the task.
#[allow(clippy::too_many_arguments)]
fn choose_dest(
    control: &mut Option<ShardedControl>,
    policy: &mut dyn Policy,
    needs_work: bool,
    work: &mut [f64],
    procs: &[Processor],
    believed: &AffinityMatrix,
    state: &StateMatrix,
    populations: &[u32],
    ttype: usize,
    rng: &mut Rng,
    up: &[bool],
    faults_on: bool,
) -> Option<usize> {
    if let Some(ctl) = control.as_mut() {
        return ctl.route(ttype).ok();
    }
    if needs_work {
        for (jj, pr) in procs.iter().enumerate() {
            work[jj] = pr.remaining_work_time();
        }
    }
    let view = SystemView { mu: believed, state, work, populations };
    let j = policy.dispatch(ttype, &view, rng);
    if !faults_on || up[j] {
        Some(j)
    } else {
        fallback_device(procs, up)
    }
}

/// Per-phase results of a dynamic run (thin wrapper over
/// [`run_dynamic_report`] for callers that only need the metrics).
pub fn run_dynamic(
    mu: &AffinityMatrix,
    cfg: &DynamicConfig,
    policy: &mut dyn Policy,
) -> Result<Vec<SimResult>> {
    run_dynamic_report(mu, cfg, policy).map(|r| r.phases)
}

/// Run the full dynamic schedule and report per-phase metrics plus the
/// re-solve count.
pub fn run_dynamic_report(
    mu: &AffinityMatrix,
    cfg: &DynamicConfig,
    policy: &mut dyn Policy,
) -> Result<DynamicReport> {
    let (k, l) = (mu.types(), mu.procs());
    if cfg.phases.is_empty() {
        return Err(Error::Config("at least one phase required".into()));
    }
    for ph in &cfg.phases {
        if ph.populations.len() != k {
            return Err(Error::Shape("phase population arity".into()));
        }
        if ph.populations.iter().sum::<u32>() == 0 {
            return Err(Error::Config("empty phase".into()));
        }
    }
    if !cfg.priorities.is_empty() {
        if cfg.priorities.len() != k {
            return Err(Error::Shape(format!(
                "{} priorities for {k} task classes",
                cfg.priorities.len()
            )));
        }
        if cfg.priorities.iter().any(|&p| p == 0) {
            return Err(Error::Config("class priorities must be ≥ 1".into()));
        }
    }
    if !cfg.deadlines.is_empty() {
        if cfg.deadlines.len() != k {
            return Err(Error::Shape(format!(
                "{} deadlines for {k} task classes",
                cfg.deadlines.len()
            )));
        }
        if cfg.deadlines.iter().any(|&d| !d.is_finite() || d < 0.0) {
            return Err(Error::Config("deadlines must be finite and ≥ 0".into()));
        }
    }
    cfg.objective.validate()?;
    cfg.power.validate()?;
    cfg.faults.validate(l)?;
    // The sharded plane never routes through `Policy::prepare`, so the
    // weights-×-objective conflict is rejected here with the same
    // message `grin::solve_request` uses on the single-leader paths.
    if cfg.resolve == ResolveMode::Sharded
        && !cfg.objective.is_throughput()
        && !crate::policy::grin::trivial_priorities(&cfg.priorities)
    {
        return Err(Error::Config(
            "priority weights combine only with the throughput objective".into(),
        ));
    }

    let needs_work = policy.needs_work_estimate();
    let mut rng = Rng::new(cfg.seed);
    let mut procs: Vec<Processor> =
        (0..l).map(|j| Processor::new(j, cfg.discipline)).collect();
    let mut events = EventQueue::new(l);
    let mut state = StateMatrix::zeros(k, l);
    let mut work = vec![0.0f64; l];
    let mut now = 0.0f64;
    let mut next_id = 0u64;

    // What the scheduler believes the rates are (drives the policy and
    // the SystemView); the per-phase `actual` drives the physics.
    let mut believed = mu.clone();
    let mut estimator = RateEstimator::from_drift(mu, &cfg.drift)?;
    let mut resolves = 0u64;
    let mut since_check = 0u64;
    let adaptive = cfg.resolve == ResolveMode::Adaptive;
    let sharded = cfg.resolve == ResolveMode::Sharded;
    // Observed service times feed an estimator in both the single-leader
    // adaptive mode and (per shard) the sharded mode.
    let observes = adaptive || sharded;
    // The idle-power floor perturbs nothing unless it is switched on:
    // the advance-all sweeps it needs change floating-point
    // accumulation order, and default runs must stay bit-identical.
    let track_idle = cfg.power.idle_power > 0.0;
    let mut control: Option<ShardedControl> = if sharded {
        let mut ctl = ShardedControl::new(
            mu,
            &cfg.phases[0].populations,
            cfg.shard.shards,
            &cfg.drift,
            cfg.shard.sync_every,
        )?;
        if !cfg.priorities.is_empty() {
            // Swaps in priority-weighted batched re-solves and steering
            // (one weighted re-install over the boot target).
            ctl.set_priorities(&cfg.priorities)?;
        }
        if !cfg.objective.is_throughput() {
            // Swaps the batched re-solves onto the objective-scored
            // greedy (one re-install over the boot target).
            ctl.set_objective(cfg.objective, cfg.power)?;
        }
        Some(ctl)
    } else {
        None
    };
    // (task id, rate it was pushed at) for the ≤N in-flight tasks — so
    // the energy meter and the estimator both see the service time the
    // task really experienced, even when it straddles a phase
    // boundary's rate change.  Entries are reclaimed at completion,
    // keeping it O(in-flight), not O(completions).
    let mut inflight_rates: Vec<(u64, f64)> = Vec::new();

    // --- fault-injection state (inert when the plan is empty) ---
    let faults_on = !cfg.faults.is_empty();
    let mut fault_idx = 0usize;
    let mut up = vec![true; l];
    let mut limp = vec![1.0f64; l];
    let mut down_since = vec![0.0f64; l];
    let mut downtime_acc = 0.0f64;
    let mut redispatched_total = 0u64;
    let mut completed_all = 0u64;
    // FEST-style backup budget: ids of in-flight re-dispatched tasks.
    let mut backup_ids: Vec<u64> = Vec::new();
    // Tasks waiting for capacity, FIFO: evacuated work blocked on the
    // backup budget (flag `true`), or anything emitted while the whole
    // fleet is down.  Nothing is ever dropped.
    let mut parked: Vec<(Task, bool)> = Vec::new();

    // Program table: alive[i] = ids of active programs per type.
    let mut programs: Vec<Program> = Vec::new();
    let mut retiring: Vec<bool> = Vec::new();
    let mut alive_by_type: Vec<Vec<usize>> = vec![Vec::new(); k];

    let mut results = Vec::with_capacity(cfg.phases.len());

    for (phase_idx, phase) in cfg.phases.iter().enumerate() {
        // --- phase boundary: rates, populations, policy re-solve ---
        let actual = if phase.mu_scale.is_empty() {
            mu.clone()
        } else {
            mu.scaled(&phase.mu_scale)?
        };
        let dist = phase.dist.unwrap_or(cfg.dist);
        match cfg.resolve {
            ResolveMode::Static => {
                if phase_idx == 0 {
                    prepare_policy(
                        policy,
                        &believed,
                        &phase.populations,
                        &cfg.priorities,
                        None,
                        cfg.objective,
                        cfg.power,
                    )?;
                }
            }
            ResolveMode::EveryPhase => {
                if faults_on {
                    // The oracle re-solves with the *surviving* rates:
                    // down columns masked, limp factors applied.  Past
                    // the first phase a failed solve (masked matrix can
                    // be outside a policy's feasible regime) keeps the
                    // old target — fallback routing covers.
                    let oracle = effective_actual(&actual, &up, &limp)?;
                    let prepared = prepare_policy(
                        policy,
                        &oracle,
                        &phase.populations,
                        &cfg.priorities,
                        None,
                        cfg.objective,
                        cfg.power,
                    );
                    if phase_idx == 0 {
                        prepared?;
                        believed = oracle;
                    } else if prepared.is_ok() {
                        believed = oracle;
                        resolves += 1;
                    }
                } else {
                    believed = actual.clone();
                    prepare_policy(
                        policy,
                        &believed,
                        &phase.populations,
                        &cfg.priorities,
                        None,
                        cfg.objective,
                        cfg.power,
                    )?;
                    if phase_idx > 0 {
                        resolves += 1;
                    }
                }
            }
            ResolveMode::Adaptive => {
                // Population changes are directly observable (programs
                // launch/retire through the scheduler), so the policy
                // re-solves for them — but only against the *believed*
                // rates, never the oracle's.  Priority weights carry the
                // live per-cell confidence.
                prepare_policy(
                    policy,
                    &believed,
                    &phase.populations,
                    &cfg.priorities,
                    Some(&estimator),
                    cfg.objective,
                    cfg.power,
                )?;
            }
            ResolveMode::Sharded => {
                // Same observability argument, through the control
                // plane: batched re-solve against its believed rates,
                // epoch-versioned push-back to every shard.
                if phase_idx > 0 {
                    control
                        .as_mut()
                        // srclint: allow(hot-path-panic) — Sharded mode always builds its control plane at setup.
                        .expect("sharded mode constructs its control plane")
                        .set_populations(&phase.populations)?;
                }
            }
        }
        for ttype in 0..k {
            let want = phase.populations[ttype] as usize;
            let have = alive_by_type[ttype].len();
            if want > have {
                for _ in 0..(want - have) {
                    let pid = programs.len();
                    programs.push(Program::new(pid, ttype));
                    retiring.push(false);
                    alive_by_type[ttype].push(pid);
                    // Launch its first task now.
                    let size = dist.sample(&mut rng);
                    let task = programs[pid].emit(next_id, now, size);
                    next_id += 1;
                    match choose_dest(
                        &mut control,
                        policy,
                        needs_work,
                        &mut work,
                        &procs,
                        &believed,
                        &state,
                        &phase.populations,
                        ttype,
                        &mut rng,
                        &up,
                        faults_on,
                    ) {
                        Some(j) => {
                            procs[j].advance(now);
                            let rate = actual.rate(ttype, j) * limp[j];
                            inflight_rates.push((task.id, rate));
                            procs[j].push(task, rate, now);
                            state.inc(ttype, j);
                        }
                        // Whole fleet down: park until a recovery event.
                        None => parked.push((task, false)),
                    }
                }
            } else if want < have {
                // Retire the newest surplus programs gracefully.
                for _ in 0..(have - want) {
                    // srclint: allow(hot-path-panic) — the loop bound is have minus want, so pops cannot exhaust.
                    let pid = alive_by_type[ttype].pop().expect("have > want");
                    retiring[pid] = true;
                }
            }
        }

        // --- phase event loop ---
        // Phase-boundary launches touched arbitrary processors: re-key
        // every entry once (O(l)), then run incrementally.
        for j in 0..l {
            events.update(j, procs[j].next_completion());
        }
        let total = phase.warmup + phase.completions;
        let new_metrics = |t: f64| {
            let mut m = Metrics::new(k, l, t);
            if !cfg.deadlines.is_empty() {
                m.track_deadlines(&cfg.deadlines);
            }
            m
        };
        let mut metrics = new_metrics(now);
        // Fleet downtime already accrued when this phase's measurement
        // window opens; the delta is charged at phase end.
        let mut down_at_start = cum_downtime(downtime_acc, now, &up, &down_since);
        let mut measuring = phase.warmup == 0;
        // Busy-time snapshot at this phase's measurement start; the
        // idle floor is charged over the window at phase end.
        let mut busy_at_start: Vec<f64> = Vec::new();
        if measuring && track_idle {
            for p in procs.iter_mut() {
                p.advance(now);
            }
            busy_at_start.extend(procs.iter().map(|p| p.busy_time()));
        }
        let mut completions = 0u64;
        while completions < total {
            // --- scheduled faults interleave with the completion
            // stream: a fault at time t fires before any completion at
            // ≥ t, and an empty event queue jumps the clock forward to
            // the next fault instead of erroring.
            while fault_idx < cfg.faults.events.len()
                && events
                    .peek()
                    .map_or(true, |(_, t)| cfg.faults.events[fault_idx].time <= t)
            {
                let ev = cfg.faults.events[fault_idx].clone();
                fault_idx += 1;
                // The clock is monotone: a fault whose scheduled time
                // already passed (earlier phases ran long) fires now.
                now = now.max(ev.time);
                match ev.kind {
                    FaultKind::Down if up[ev.device] => {
                        let dev = ev.device;
                        up[dev] = false;
                        down_since[dev] = now;
                        procs[dev].advance(now);
                        let evacuated = procs[dev].drain_residents(now);
                        events.update(dev, None);
                        // Churn-aware control reacts *before* the
                        // evacuated work re-routes, so re-dispatch
                        // already sees the shrunken target.
                        match cfg.resolve {
                            // Frozen: only the physical fallback saves
                            // the frozen target's traffic.
                            ResolveMode::Static => {}
                            ResolveMode::EveryPhase => {
                                let oracle =
                                    effective_actual(&actual, &up, &limp)?;
                                if prepare_policy(
                                    policy,
                                    &oracle,
                                    &phase.populations,
                                    &cfg.priorities,
                                    None,
                                    cfg.objective,
                                    cfg.power,
                                )
                                .is_ok()
                                {
                                    believed = oracle;
                                    resolves += 1;
                                }
                            }
                            ResolveMode::Adaptive => {
                                // Down is directly observable (the
                                // device stops answering), unlike
                                // limping: mask the column, freeze its
                                // estimator cells, re-solve.
                                let cand = believed.masked_column(dev)?;
                                estimator.mark_down(dev);
                                if prepare_policy(
                                    policy,
                                    &cand,
                                    &phase.populations,
                                    &cfg.priorities,
                                    Some(&estimator),
                                    cfg.objective,
                                    cfg.power,
                                )
                                .is_ok()
                                {
                                    believed = cand;
                                    estimator.set_reference(&believed)?;
                                    resolves += 1;
                                }
                            }
                            ResolveMode::Sharded => {
                                let ctl = control
                                    .as_mut()
                                    // srclint: allow(hot-path-panic) — Sharded mode always builds its control plane at setup.
                                    .expect("sharded mode constructs its control plane");
                                if ctl.mark_down(dev)? {
                                    resolves += 1;
                                }
                            }
                        }
                        // Evacuate residents: remaining work preserved,
                        // re-dispatched to survivors under the budget
                        // (the parked-drain below dispatches them).
                        for (mut task, rem) in evacuated {
                            state.dec(task.ttype, dev)?;
                            let pos = inflight_rates
                                .iter()
                                .position(|&(id, _)| id == task.id)
                                // srclint: allow(hot-path-panic) — every dispatch records a rate before the task can evacuate.
                                .expect("evacuated task has a recorded in-flight rate");
                            inflight_rates.swap_remove(pos);
                            task.size = rem;
                            parked.push((task, true));
                        }
                    }
                    FaultKind::Up if !up[ev.device] => {
                        let dev = ev.device;
                        up[dev] = true;
                        downtime_acc += now - down_since[dev];
                        procs[dev].advance(now);
                        match cfg.resolve {
                            ResolveMode::Static => {}
                            ResolveMode::EveryPhase => {
                                let oracle =
                                    effective_actual(&actual, &up, &limp)?;
                                if prepare_policy(
                                    policy,
                                    &oracle,
                                    &phase.populations,
                                    &cfg.priorities,
                                    None,
                                    cfg.objective,
                                    cfg.power,
                                )
                                .is_ok()
                                {
                                    believed = oracle;
                                    resolves += 1;
                                }
                            }
                            ResolveMode::Adaptive => {
                                // Rejoin at the boot-time prior; the
                                // estimator restarts the column with
                                // fresh CUSUM evidence.
                                let cand =
                                    believed.with_column(dev, &mu.column(dev))?;
                                estimator.mark_up(dev);
                                if prepare_policy(
                                    policy,
                                    &cand,
                                    &phase.populations,
                                    &cfg.priorities,
                                    Some(&estimator),
                                    cfg.objective,
                                    cfg.power,
                                )
                                .is_ok()
                                {
                                    believed = cand;
                                    estimator.set_reference(&believed)?;
                                    resolves += 1;
                                }
                            }
                            ResolveMode::Sharded => {
                                let ctl = control
                                    .as_mut()
                                    // srclint: allow(hot-path-panic) — Sharded mode always builds its control plane at setup.
                                    .expect("sharded mode constructs its control plane");
                                if ctl.mark_up(dev, &mu.column(dev))? {
                                    resolves += 1;
                                }
                            }
                        }
                    }
                    FaultKind::Limp(f) => {
                        limp[ev.device] = f;
                        // Only the oracle is told; every other mode must
                        // *detect* the slow node (CUSUM) or eat it.
                        if cfg.resolve == ResolveMode::EveryPhase {
                            let oracle = effective_actual(&actual, &up, &limp)?;
                            if prepare_policy(
                                policy,
                                &oracle,
                                &phase.populations,
                                &cfg.priorities,
                                None,
                                cfg.objective,
                                cfg.power,
                            )
                            .is_ok()
                            {
                                believed = oracle;
                                resolves += 1;
                            }
                        }
                    }
                    // Down on a down device / Up on an up one: no-op.
                    _ => {}
                }
                // A drained queue with dispatchable parked work: stop
                // consuming future faults and let the parked-drain below
                // refill the queue, so the interval between a recovery
                // and the next fault is actually simulated.
                if events.peek().is_none()
                    && !parked.is_empty()
                    && up.iter().any(|&u| u)
                {
                    break;
                }
            }
            // --- dispatch whatever parked work the budget and the
            // fleet now admit (FIFO; budget-blocked backups hold their
            // place while later non-backup tasks may pass).
            if faults_on && !parked.is_empty() {
                let budget = cfg.faults.backup_budget as usize;
                let mut idx = 0;
                while idx < parked.len() {
                    if parked[idx].1 && budget > 0 && backup_ids.len() >= budget {
                        idx += 1;
                        continue;
                    }
                    let ttype = parked[idx].0.ttype;
                    let j = match choose_dest(
                        &mut control,
                        policy,
                        needs_work,
                        &mut work,
                        &procs,
                        &believed,
                        &state,
                        &phase.populations,
                        ttype,
                        &mut rng,
                        &up,
                        faults_on,
                    ) {
                        Some(j) => j,
                        None => break,
                    };
                    let (task, counts) = parked.remove(idx);
                    if counts {
                        backup_ids.push(task.id);
                        redispatched_total += 1;
                        metrics.record_redispatch();
                    }
                    procs[j].advance(now);
                    let rate = actual.rate(ttype, j) * limp[j];
                    inflight_rates.push((task.id, rate));
                    procs[j].push(task, rate, now);
                    events.update(j, procs[j].next_completion());
                    state.inc(ttype, j);
                }
            }
            let (j, t) = match events.peek() {
                Some(e) => e,
                None => {
                    return Err(if up.iter().any(|&u| !u) {
                        Error::NoCapacity(
                            "all devices down with no recovery scheduled".into(),
                        )
                    } else {
                        Error::Solver("dynamic system drained".into())
                    })
                }
            };
            now = t;
            procs[j].advance(now);
            let done = procs[j].pop_completed(now)?;
            events.update(j, procs[j].next_completion());
            state.dec(done.ttype, j)?;
            completions += 1;
            // The meter and the estimator both see what a real system
            // would measure: the task's execution at the rate it was
            // actually pushed with (tasks straddling a rate change keep
            // their old rate).
            let pos = inflight_rates
                .iter()
                .position(|&(id, _)| id == done.id)
                // srclint: allow(hot-path-panic) — every dispatch records a rate before its completion event.
                .expect("completed task has a recorded in-flight rate");
            let (_, rate) = inflight_rates.swap_remove(pos);
            completed_all += 1;
            // A finished backup frees a budget slot.  Its service time
            // is remaining-work at the new device's rate — not a
            // unit-mean size draw — so it is kept out of the estimator
            // (a systematically short, biased sample).
            let mut was_backup = false;
            if faults_on {
                if let Some(p) = backup_ids.iter().position(|&id| id == done.id) {
                    backup_ids.swap_remove(p);
                    was_backup = true;
                }
            }
            if !measuring && completions > phase.warmup {
                measuring = true;
                metrics = new_metrics(now);
                down_at_start = cum_downtime(downtime_acc, now, &up, &down_since);
                if track_idle {
                    for p in procs.iter_mut() {
                        p.advance(now);
                    }
                    busy_at_start.extend(procs.iter().map(|p| p.busy_time()));
                }
            }
            if measuring {
                // Per-task energy at the push-time physics rate:
                // 𝒫(μ)·ω = coeff·μ^α · (size/μ), Eq. 20's integrand.
                let e = cfg.power.task_power(rate) * done.size / rate;
                metrics.record(now, now - done.arrive, e, done.ttype, j);
            }
            if observes {
                let service_s = done.size / rate;
                match control.as_mut() {
                    // The sharded plane syncs (gather + batched
                    // re-solve) on its own cadence inside on_complete.
                    Some(ctl) => {
                        if was_backup {
                            // Occupancy bookkeeping only, no sample.
                            ctl.on_complete_silent(done.ttype, j)?;
                        } else if ctl.on_complete(done.ttype, j, service_s)? {
                            resolves += 1;
                        }
                    }
                    None => {
                        if !was_backup {
                            estimator.observe(done.ttype, j, service_s);
                            since_check += 1;
                        }
                    }
                }
            }
            if adaptive {
                let fire = match cfg.drift.trigger {
                    // Polled: every check_every completions, compare the
                    // worst-cell relative deviation to the threshold.
                    Trigger::Threshold => {
                        if since_check >= cfg.drift.check_every {
                            since_check = 0;
                            estimator.drift(&believed) > cfg.drift.threshold
                        } else {
                            false
                        }
                    }
                    // Event-driven: the per-cell CUSUM alarm flag is
                    // O(1), so it is polled on every completion and the
                    // re-solve lands the moment a change is confirmed.
                    Trigger::Cusum => estimator.alarm_pending(),
                };
                if fire {
                    if cfg.drift.trigger == Trigger::Cusum {
                        // Drain before solving: a failed re-solve then
                        // backs off until the CUSUM re-accumulates.
                        estimator.take_alarms();
                    }
                    // Gated μ̂: stale cells carry the believed rates
                    // forward instead of frozen pre-flip estimates.
                    let mu_hat = estimator.mu_hat_gated()?;
                    // A noisy μ̂ can be momentarily unsolvable (CAB's
                    // Eq.-2 regime check): keep the old target and retry
                    // at the next check.
                    if prepare_policy(
                        policy,
                        &mu_hat,
                        &phase.populations,
                        &cfg.priorities,
                        Some(&estimator),
                        cfg.objective,
                        cfg.power,
                    )
                    .is_ok()
                    {
                        believed = mu_hat;
                        estimator.set_reference(&believed)?;
                        resolves += 1;
                    }
                }
            }
            let pid = done.program;
            if retiring[pid] {
                // Graceful exit: no re-issue.
                continue;
            }
            let ttype = programs[pid].ttype;
            let size = dist.sample(&mut rng);
            let task = programs[pid].emit(next_id, now, size);
            next_id += 1;
            match choose_dest(
                &mut control,
                policy,
                needs_work,
                &mut work,
                &procs,
                &believed,
                &state,
                &phase.populations,
                ttype,
                &mut rng,
                &up,
                faults_on,
            ) {
                Some(dest) => {
                    procs[dest].advance(now);
                    let rate = actual.rate(ttype, dest) * limp[dest];
                    inflight_rates.push((task.id, rate));
                    procs[dest].push(task, rate, now);
                    events.update(dest, procs[dest].next_completion());
                    state.inc(ttype, dest);
                }
                // Whole fleet down: park until a recovery event.
                None => parked.push((task, false)),
            }
        }
        if track_idle && !busy_at_start.is_empty() {
            // Charge the idle floor for each processor's idle share of
            // this phase's measurement window.
            for p in procs.iter_mut() {
                p.advance(now);
            }
            let elapsed = metrics.elapsed();
            let mut idle_e = 0.0;
            for (j, p) in procs.iter().enumerate() {
                let busy = p.busy_time() - busy_at_start[j];
                idle_e += (elapsed - busy).max(0.0) * cfg.power.idle_power;
            }
            metrics.add_idle_energy(idle_e);
        }
        metrics.add_downtime(
            cum_downtime(downtime_acc, now, &up, &down_since) - down_at_start,
        );
        results.push(metrics.finalize(phase.populations.iter().sum()));
        // Retired programs that still hold an in-flight task will drain
        // during the next phase; the state matrix tracks them naturally.
    }
    // Conservation audit: every emitted task either completed or is
    // still in the system (resident on a device or parked) — device
    // churn must never lose or duplicate work.
    let residue = procs.iter().map(|p| p.occupancy() as u64).sum::<u64>()
        + parked.len() as u64;
    let tasks_lost =
        (next_id as i64 - completed_all as i64 - residue as i64).unsigned_abs();
    debug_assert_eq!(tasks_lost, 0, "task conservation violated");
    Ok(DynamicReport {
        phases: results,
        resolves,
        tasks_redispatched: redispatched_total,
        tasks_lost,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;
    use crate::model::throughput::x_max_theoretical;
    use crate::policy::PolicyKind;
    use crate::sim::workload;

    fn phases() -> Vec<Phase> {
        vec![
            Phase::new(vec![10, 10], 500, 5_000),
            Phase::new(vec![2, 18], 500, 5_000),
            Phase::new(vec![15, 5], 500, 5_000),
        ]
    }

    #[test]
    fn cab_tracks_theory_across_phase_changes() {
        // Piece-wise closed: after each population change CAB re-solves
        // and the per-phase throughput matches the per-phase Eq. 16.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(phases());
        cfg.seed = 9;
        let mut p = PolicyKind::Cab.build();
        let rs = run_dynamic(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(rs.len(), 3);
        for (r, ph) in rs.iter().zip(&cfg.phases) {
            let (n1, n2) = (ph.populations[0], ph.populations[1]);
            let theory = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
            let err = (r.throughput - theory).abs() / theory;
            assert!(
                err < 0.08,
                "phase ({n1},{n2}): sim {} vs theory {theory}",
                r.throughput
            );
        }
    }

    #[test]
    fn growing_and_shrinking_preserves_task_conservation() {
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![
            Phase::new(vec![3, 3], 100, 1_000),
            Phase::new(vec![8, 1], 100, 1_000),
            Phase::new(vec![1, 8], 100, 1_000),
        ]);
        cfg.discipline = Discipline::Fcfs;
        cfg.dist = Distribution::Uniform;
        cfg.seed = 5;
        for kind in [PolicyKind::Cab, PolicyKind::GrIn, PolicyKind::Jsq] {
            let mut p = kind.build();
            let rs = run_dynamic(&mu, &cfg, p.as_mut()).unwrap();
            // Little's law per phase (population changed ⇒ N per phase).
            for (i, r) in rs.iter().enumerate() {
                assert!(r.throughput > 0.0, "{} phase {i}", kind.name());
                assert!(
                    r.little_residual() < 0.25,
                    "{} phase {i}: X·E[T] = {} vs N = {}",
                    kind.name(),
                    r.little_product,
                    r.n_programs
                );
            }
        }
    }

    #[test]
    fn rejects_invalid_schedules() {
        let mu = workload::paper_two_type_mu();
        let mut p = PolicyKind::Cab.build();
        let bad = DynamicConfig::new(vec![]);
        assert!(run_dynamic(&mu, &bad, p.as_mut()).is_err());
        let bad = DynamicConfig::new(vec![Phase::new(vec![0, 0], 0, 1)]);
        assert!(run_dynamic(&mu, &bad, p.as_mut()).is_err());
        // Bad mu_scale arity surfaces at the phase boundary.
        let bad = DynamicConfig::new(vec![
            Phase::new(vec![2, 2], 0, 10).with_mu_scale(vec![1.0, 2.0, 3.0]),
        ]);
        assert!(run_dynamic(&mu, &bad, p.as_mut()).is_err());
    }

    #[test]
    fn resolve_mode_parsing_round_trips() {
        for m in ResolveMode::all() {
            assert_eq!(ResolveMode::parse(m.name()).unwrap(), m);
        }
        assert!(ResolveMode::parse("psychic").is_err());
    }

    #[test]
    fn trigger_parsing_round_trips() {
        for t in Trigger::all() {
            assert_eq!(Trigger::parse(t.name()).unwrap(), t);
        }
        assert_eq!(Trigger::parse("drift").unwrap(), Trigger::Threshold);
        assert!(Trigger::parse("vibes").is_err());
    }

    #[test]
    fn cusum_trigger_is_quiet_on_stationary_load() {
        // The headline false-alarm property: on a stationary workload
        // the CUSUM trigger must keep throughput at the theory level
        // while issuing (essentially) no re-solves — the batched
        // mini-batch residuals absorb exponential service-time noise
        // that the polled drift metric occasionally mistakes for change.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 300, 6_000)]);
        cfg.resolve = ResolveMode::Adaptive;
        cfg.drift.trigger = Trigger::Cusum;
        cfg.seed = 33;
        let mut p = PolicyKind::GrIn.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        let theory = x_max_theoretical(&mu, Regime::P1Biased, 10, 10);
        let err = (report.phases[0].throughput - theory).abs() / theory;
        assert!(err < 0.08, "cusum X {} vs theory {theory}", report.phases[0].throughput);
        assert!(report.resolves <= 2, "{} stationary re-solves", report.resolves);
    }

    #[test]
    fn sharded_mode_matches_theory_on_stationary_two_type() {
        // On a stationary workload the sharded control plane (one shard
        // per processor here) must hold the same optimum as the
        // single-leader solve: measured X at the Eq.-16 theory level.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 300, 6_000)]);
        cfg.resolve = ResolveMode::Sharded;
        cfg.seed = 41;
        let mut p = PolicyKind::GrIn.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        let theory = x_max_theoretical(&mu, Regime::P1Biased, 10, 10);
        let err = (report.phases[0].throughput - theory).abs() / theory;
        assert!(err < 0.08, "sharded X {} vs theory {theory}", report.phases[0].throughput);
    }

    #[test]
    fn sharded_mode_survives_population_changes() {
        // Task conservation + positive throughput across grow/shrink
        // phase boundaries under the sharded control plane.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![
            Phase::new(vec![3, 3], 100, 1_000),
            Phase::new(vec![8, 1], 100, 1_000),
            Phase::new(vec![1, 8], 100, 1_000),
        ]);
        cfg.resolve = ResolveMode::Sharded;
        cfg.shard.shards = 2;
        cfg.seed = 13;
        let mut p = PolicyKind::GrIn.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        for (i, r) in report.phases.iter().enumerate() {
            assert!(r.throughput > 0.0, "phase {i}");
            assert!(
                r.little_residual() < 0.25,
                "phase {i}: X·E[T] = {} vs N = {}",
                r.little_product,
                r.n_programs
            );
        }
    }

    #[test]
    fn sharded_mode_rejects_bad_shard_counts() {
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![2, 2], 0, 50)]);
        cfg.resolve = ResolveMode::Sharded;
        cfg.shard.shards = 3; // only 2 processors
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        cfg.shard.shards = 2;
        cfg.shard.sync_every = 0;
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
    }

    #[test]
    fn priority_and_deadline_configs_are_validated() {
        let mu = workload::paper_two_type_mu();
        let base = || DynamicConfig::new(vec![Phase::new(vec![4, 4], 10, 100)]);
        // Arity and zero-priority rejections.
        let mut cfg = base();
        cfg.priorities = vec![1, 2, 3];
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        let mut cfg = base();
        cfg.priorities = vec![0, 1];
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        let mut cfg = base();
        cfg.deadlines = vec![1.0];
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        let mut cfg = base();
        cfg.deadlines = vec![-1.0, 1.0];
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        // Non-uniform priorities need a weight-aware policy: CAB fails
        // loudly instead of silently scheduling unweighted.
        let mut cfg = base();
        cfg.priorities = vec![4, 1];
        let mut p = PolicyKind::Cab.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        // Equal priorities are trivial: they reduce to the plain
        // unweighted prepare — which also means they run fine on
        // weight-blind policies, even as estimator confidences diverge
        // mid-run under the adaptive loop.
        let mut cfg = base();
        cfg.priorities = vec![2, 2];
        let mut p = PolicyKind::GrIn.build();
        run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        let mut cfg = base();
        cfg.priorities = vec![2, 2];
        cfg.resolve = ResolveMode::Adaptive;
        let mut p = PolicyKind::Cab.build();
        run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
    }

    #[test]
    fn deadline_tracking_reports_misses_and_class_throughput() {
        // Every class-0 response is ≫ 1 ms, so a 1 ms deadline must
        // report a ~100% miss rate; a deadline past any plausible
        // response reports ~0.  Class 1 (deadline 0) is never counted.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 100, 2_000)]);
        cfg.seed = 17;
        cfg.deadlines = vec![0.001, 0.0];
        let mut p = PolicyKind::GrIn.build();
        let tight = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert!(tight.deadline_miss_rate(0) > 0.95, "{}", tight.deadline_miss_rate(0));
        assert_eq!(tight.deadline_miss_rate(1), 0.0);
        // Per-class throughputs partition the total.
        let x0 = tight.class_throughput(0);
        let x1 = tight.class_throughput(1);
        assert!(x0 > 0.0 && x1 > 0.0);
        assert!((x0 + x1 - tight.mean_throughput()).abs() < 1e-9);
        // p99 recorded per phase while tracking.
        assert_eq!(tight.phases[0].p99_by_class.len(), 2);
        cfg.deadlines = vec![1e6, 0.0];
        let mut p = PolicyKind::GrIn.build();
        let loose = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(loose.deadline_miss_rate(0), 0.0);
    }

    #[test]
    fn mu_scale_changes_phase_physics() {
        // Same schedule, but the second phase throttles processor 0 to
        // 10%: the oracle re-solver's measured throughput must drop by
        // roughly the optimal-throughput ratio.
        let mu = workload::paper_two_type_mu();
        let mk = |scale: Vec<f64>| {
            let mut cfg = DynamicConfig::new(vec![
                Phase::new(vec![10, 10], 300, 4_000),
                Phase::new(vec![10, 10], 300, 4_000).with_mu_scale(scale),
            ]);
            cfg.seed = 21;
            cfg
        };
        let mut p = PolicyKind::GrIn.build();
        let flat = run_dynamic(&mu, &mk(vec![1.0, 1.0]), p.as_mut()).unwrap();
        let mut p = PolicyKind::GrIn.build();
        let throttled = run_dynamic(&mu, &mk(vec![0.1, 1.0]), p.as_mut()).unwrap();
        // Unthrottled phases agree; throttled phase is clearly slower.
        let rel = (flat[0].throughput - throttled[0].throughput).abs() / flat[0].throughput;
        assert!(rel < 0.05, "phase-0 runs should agree, rel {rel}");
        assert!(
            throttled[1].throughput < flat[1].throughput * 0.8,
            "throttling had no effect: {} vs {}",
            throttled[1].throughput,
            flat[1].throughput
        );
    }

    #[test]
    fn dynamic_runs_meter_real_task_energy() {
        // Proportional power at coeff 1: a task's energy is its size
        // (𝒫·ω = μ·(size/μ)), so E[ℰ] ≈ E[size] = 1 wherever tasks
        // land; the idle floor can only add on top.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 300, 4_000)]);
        cfg.seed = 7;
        let mut p = PolicyKind::GrIn.build();
        let base = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert!(
            (base.mean_energy() - 1.0).abs() < 0.05,
            "E[ℰ] = {}",
            base.mean_energy()
        );
        assert!(base.mean_edp() > 0.0);
        let mut cfg_idle = cfg.clone();
        cfg_idle.power = PowerProfile::default().with_idle(0.5);
        let mut p = PolicyKind::GrIn.build();
        let idled = run_dynamic_report(&mu, &cfg_idle, p.as_mut()).unwrap();
        assert!(idled.mean_energy() >= base.mean_energy() - 1e-9);
    }

    #[test]
    fn energy_objective_threads_through_the_dynamic_loop() {
        use crate::model::energy::PowerScenario;
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 100, 1_500)]);
        cfg.objective = Objective::EnergyPerTask;
        cfg.power = PowerProfile::new(1.0, PowerScenario::Exponent(0.5));
        cfg.seed = 11;
        let mut p = PolicyKind::GrIn.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert!(report.phases[0].throughput > 0.0);
        assert!(report.mean_energy() > 0.0);
        // Objective-blind policies reject the energy objective loudly.
        let mut p = PolicyKind::Cab.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
        // Non-trivial priorities cannot combine with a non-throughput
        // objective — even on the sharded plane, which bypasses
        // `Policy::prepare`.
        cfg.priorities = vec![4, 1];
        cfg.resolve = ResolveMode::Sharded;
        let mut p = PolicyKind::GrIn.build();
        assert!(run_dynamic_report(&mu, &cfg, p.as_mut()).is_err());
    }

    #[test]
    fn adaptive_matches_oracle_on_stationary_workload() {
        // On a stationary workload the adaptive mode must cost nothing:
        // even if estimator noise triggers the odd re-solve, μ̂ ≈ μ so
        // the re-solved target coincides with the optimum and measured
        // throughput stays at the Eq.-16 theory level.
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 300, 6_000)]);
        cfg.resolve = ResolveMode::Adaptive;
        cfg.drift.threshold = 0.5; // generous vs sampling noise
        cfg.seed = 33;
        let mut p = PolicyKind::GrIn.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(report.phases.len(), 1);
        let theory = x_max_theoretical(&mu, Regime::P1Biased, 10, 10);
        let err = (report.phases[0].throughput - theory).abs() / theory;
        assert!(err < 0.08, "adaptive X {} vs theory {theory}", report.phases[0].throughput);
        // Drift checks ran, and the target did not thrash on every one.
        let checks = 6_300 / cfg.drift.check_every;
        assert!(report.resolves < checks, "{} resolves", report.resolves);
        assert!(report.mean_throughput() > 0.0);
    }

    #[test]
    fn fault_plan_spec_round_trips_and_validates() {
        let plan = FaultPlan::parse_spec("down:0@5;up:0@12;limp:1x0.25@20;budget:4").unwrap();
        assert_eq!(plan.events.len(), 3);
        assert_eq!(plan.backup_budget, 4);
        assert_eq!(plan.events[0], FaultEvent { time: 5.0, device: 0, kind: FaultKind::Down });
        assert_eq!(plan.events[1], FaultEvent { time: 12.0, device: 0, kind: FaultKind::Up });
        assert_eq!(
            plan.events[2],
            FaultEvent { time: 20.0, device: 1, kind: FaultKind::Limp(0.25) }
        );
        // Canonical spec round-trips; entries sort by time on parse.
        assert_eq!(FaultPlan::parse_spec(&plan.to_spec()).unwrap(), plan);
        let shuffled = FaultPlan::parse_spec("up:0@12;down:0@5").unwrap();
        assert_eq!(shuffled.events[0].kind, FaultKind::Down);
        plan.validate(2).unwrap();
        // Device out of range for a 1-proc fleet.
        assert!(plan.validate(1).is_err());
        // Unsorted hand-built plans, bad times, bad limp factors.
        let unsorted = FaultPlan {
            events: vec![
                FaultEvent { time: 9.0, device: 0, kind: FaultKind::Down },
                FaultEvent { time: 3.0, device: 0, kind: FaultKind::Up },
            ],
            backup_budget: 0,
        };
        assert!(unsorted.validate(2).is_err());
        let bad_time = FaultPlan {
            events: vec![FaultEvent { time: -1.0, device: 0, kind: FaultKind::Down }],
            backup_budget: 0,
        };
        assert!(bad_time.validate(2).is_err());
        let bad_limp = FaultPlan {
            events: vec![FaultEvent { time: 1.0, device: 0, kind: FaultKind::Limp(0.0) }],
            backup_budget: 0,
        };
        assert!(bad_limp.validate(2).is_err());
        // Parser rejections.
        assert!(FaultPlan::parse_spec("explode:0@5").is_err());
        assert!(FaultPlan::parse_spec("down:0").is_err());
        assert!(FaultPlan::parse_spec("limp:1@5").is_err());
        assert!(FaultPlan::parse_spec("budget:lots").is_err());
        assert!(FaultPlan::parse_spec("").unwrap().is_empty());
    }

    #[test]
    fn fault_injection_conserves_tasks_and_meters_downtime() {
        // One device dies mid-run and recovers later: residents are
        // evacuated and re-dispatched (never lost), and the measured
        // window charges the outage as downtime.
        let mu =
            crate::model::affinity::AffinityMatrix::two_type(10.0, 8.0, 3.0, 9.0).unwrap();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 100, 2_000)]);
        cfg.resolve = ResolveMode::Static;
        cfg.seed = 3;
        cfg.faults = FaultPlan::parse_spec("down:0@5;up:0@25").unwrap();
        let mut p = PolicyKind::Jsq.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(report.tasks_lost, 0);
        assert!(report.tasks_redispatched > 0, "nothing was evacuated");
        assert!(
            report.mean_downtime_frac() > 0.0,
            "outage not metered: {}",
            report.mean_downtime_frac()
        );
        assert!(report.phases[0].throughput > 0.0);
        // The same schedule with a backup budget completes with the
        // same conservation guarantee (evacuations are metered, not
        // dropped).
        cfg.faults = FaultPlan::parse_spec("down:0@5;up:0@25;budget:2").unwrap();
        let mut p = PolicyKind::Jsq.build();
        let budgeted = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(budgeted.tasks_lost, 0);
        assert!(budgeted.tasks_redispatched > 0);
    }

    #[test]
    fn all_devices_down_without_recovery_is_no_capacity() {
        let mu =
            crate::model::affinity::AffinityMatrix::two_type(10.0, 8.0, 3.0, 9.0).unwrap();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![5, 5], 0, 5_000)]);
        cfg.resolve = ResolveMode::Static;
        cfg.seed = 4;
        cfg.faults = FaultPlan::parse_spec("down:0@1;down:1@1").unwrap();
        let mut p = PolicyKind::Jsq.build();
        match run_dynamic_report(&mu, &cfg, p.as_mut()) {
            Err(Error::NoCapacity(_)) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        // With a recovery scheduled, the clock jumps the outage and the
        // run completes — nothing lost.
        cfg.faults = FaultPlan::parse_spec("down:0@1;down:1@1;up:1@3").unwrap();
        let mut p = PolicyKind::Jsq.build();
        let report = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(report.tasks_lost, 0);
        assert!(report.tasks_redispatched > 0);
    }

    #[test]
    fn fault_free_runs_are_unchanged_by_the_fault_machinery() {
        // The inert-plan guarantee: an empty FaultPlan must reproduce
        // the pre-churn runs bit for bit (same completions, throughput,
        // and resolve count).
        let mu = workload::paper_two_type_mu();
        let mut cfg = DynamicConfig::new(vec![Phase::new(vec![10, 10], 100, 2_000)]);
        cfg.seed = 9;
        let mut p = PolicyKind::Cab.build();
        let base = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        cfg.faults = FaultPlan::none();
        let mut p = PolicyKind::Cab.build();
        let again = run_dynamic_report(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(base.phases[0].completed, again.phases[0].completed);
        assert_eq!(base.phases[0].throughput.to_bits(), again.phases[0].throughput.to_bits());
        assert_eq!(base.resolves, again.resolves);
        assert_eq!(again.tasks_redispatched, 0);
        assert_eq!(again.phases[0].downtime_frac, 0.0);
    }
}
