//! Piece-wise closed systems (§3.1) with on-line policy re-solve (§4.1).
//!
//! The paper's closed-system assumption "can be relaxed to include
//! piece-wise closed systems … applications are not launched and
//! terminated very frequently", and GrIn is motivated as fast enough to
//! re-solve "on the fly … when the number of tasks changes".  This
//! engine implements exactly that: the run is a sequence of *phases*,
//! each with its own per-type populations; at every phase boundary
//! programs are launched or retired and the policy's `prepare` runs
//! again (CAB re-classifies, GrIn/Opt re-solve their target state).
//!
//! Retirement is graceful: a surplus program finishes its in-flight task
//! and simply does not re-issue — no task is ever killed, matching how
//! real programs terminate.

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::state::StateMatrix;
use crate::policy::{Policy, SystemView};

use super::distribution::Distribution;
use super::metrics::{Metrics, SimResult};
use super::processor::{Discipline, Processor};
use super::rng::Rng;
use super::task::Program;

/// One phase of a piece-wise closed run.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Per-type populations during this phase.
    pub populations: Vec<u32>,
    /// Completions to simulate in this phase (measured after `warmup`).
    pub completions: u64,
    /// Completions discarded at the start of the phase.
    pub warmup: u64,
}

/// Configuration of a dynamic run.
#[derive(Debug, Clone)]
pub struct DynamicConfig {
    /// The phase schedule (≥ 1 phase).
    pub phases: Vec<Phase>,
    /// Service discipline.
    pub discipline: Discipline,
    /// Task-size distribution.
    pub dist: Distribution,
    /// Seed.
    pub seed: u64,
}

/// Per-phase results of a dynamic run.
pub fn run_dynamic(
    mu: &AffinityMatrix,
    cfg: &DynamicConfig,
    policy: &mut dyn Policy,
) -> Result<Vec<SimResult>> {
    let (k, l) = (mu.types(), mu.procs());
    if cfg.phases.is_empty() {
        return Err(Error::Config("at least one phase required".into()));
    }
    for ph in &cfg.phases {
        if ph.populations.len() != k {
            return Err(Error::Shape("phase population arity".into()));
        }
        if ph.populations.iter().sum::<u32>() == 0 {
            return Err(Error::Config("empty phase".into()));
        }
    }

    let needs_work = policy.needs_work_estimate();
    let mut rng = Rng::new(cfg.seed);
    let mut procs: Vec<Processor> =
        (0..l).map(|j| Processor::new(j, cfg.discipline)).collect();
    let mut state = StateMatrix::zeros(k, l);
    let mut work = vec![0.0f64; l];
    let mut now = 0.0f64;
    let mut next_id = 0u64;

    // Program table: alive[i] = ids of active programs per type.
    let mut programs: Vec<Program> = Vec::new();
    let mut retiring: Vec<bool> = Vec::new();
    let mut alive_by_type: Vec<Vec<usize>> = vec![Vec::new(); k];

    let mut results = Vec::with_capacity(cfg.phases.len());

    for (_phase_idx, phase) in cfg.phases.iter().enumerate() {
        // --- phase boundary: adjust populations, re-prepare the policy ---
        policy.prepare(mu, &phase.populations)?;
        for ttype in 0..k {
            let want = phase.populations[ttype] as usize;
            let have = alive_by_type[ttype].len();
            if want > have {
                for _ in 0..(want - have) {
                    let pid = programs.len();
                    programs.push(Program::new(pid, ttype));
                    retiring.push(false);
                    alive_by_type[ttype].push(pid);
                    // Launch its first task now.
                    let size = cfg.dist.sample(&mut rng);
                    let task = programs[pid].emit(next_id, now, size);
                    next_id += 1;
                    if needs_work {
                        for (j, pr) in procs.iter().enumerate() {
                            work[j] = pr.remaining_work_time();
                        }
                    }
                    let view = SystemView {
                        mu,
                        state: &state,
                        work: &work,
                        populations: &phase.populations,
                    };
                    let j = policy.dispatch(ttype, &view, &mut rng);
                    procs[j].advance(now);
                    procs[j].push(task, mu.rate(ttype, j), now);
                    state.inc(ttype, j);
                }
            } else if want < have {
                // Retire the newest surplus programs gracefully.
                for _ in 0..(have - want) {
                    let pid = alive_by_type[ttype].pop().expect("have > want");
                    retiring[pid] = true;
                }
            }
        }

        // --- phase event loop ---
        let total = phase.warmup + phase.completions;
        let mut metrics = Metrics::new(k, l, now);
        let mut measuring = phase.warmup == 0;
        let mut completions = 0u64;
        while completions < total {
            let (j, t) = procs
                .iter()
                .enumerate()
                .filter_map(|(j, p)| p.next_completion().map(|t| (j, t)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .ok_or_else(|| Error::Solver("dynamic system drained".into()))?;
            now = t;
            procs[j].advance(now);
            let done = procs[j].pop_completed(now)?;
            state.dec(done.ttype, j)?;
            completions += 1;
            if !measuring && completions > phase.warmup {
                measuring = true;
                metrics = Metrics::new(k, l, now);
            }
            if measuring {
                metrics.record(now, now - done.arrive, 0.0, done.ttype, j);
            }
            let pid = done.program;
            if retiring[pid] {
                // Graceful exit: no re-issue.
                continue;
            }
            let ttype = programs[pid].ttype;
            let size = cfg.dist.sample(&mut rng);
            let task = programs[pid].emit(next_id, now, size);
            next_id += 1;
            if needs_work {
                for (jj, pr) in procs.iter().enumerate() {
                    work[jj] = pr.remaining_work_time();
                }
            }
            let view = SystemView {
                mu,
                state: &state,
                work: &work,
                populations: &phase.populations,
            };
            let dest = policy.dispatch(ttype, &view, &mut rng);
            procs[dest].advance(now);
            procs[dest].push(task, mu.rate(ttype, dest), now);
            state.inc(ttype, dest);
        }
        results.push(metrics.finalize(phase.populations.iter().sum()));
        // Retired programs that still hold an in-flight task will drain
        // during the next phase; the state matrix tracks them naturally.
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::affinity::Regime;
    use crate::model::throughput::x_max_theoretical;
    use crate::policy::PolicyKind;
    use crate::sim::workload;

    fn phases() -> Vec<Phase> {
        vec![
            Phase { populations: vec![10, 10], warmup: 500, completions: 5_000 },
            Phase { populations: vec![2, 18], warmup: 500, completions: 5_000 },
            Phase { populations: vec![15, 5], warmup: 500, completions: 5_000 },
        ]
    }

    #[test]
    fn cab_tracks_theory_across_phase_changes() {
        // Piece-wise closed: after each population change CAB re-solves
        // and the per-phase throughput matches the per-phase Eq. 16.
        let mu = workload::paper_two_type_mu();
        let cfg = DynamicConfig {
            phases: phases(),
            discipline: Discipline::Ps,
            dist: Distribution::Exponential,
            seed: 9,
        };
        let mut p = PolicyKind::Cab.build();
        let rs = run_dynamic(&mu, &cfg, p.as_mut()).unwrap();
        assert_eq!(rs.len(), 3);
        for (r, ph) in rs.iter().zip(&cfg.phases) {
            let (n1, n2) = (ph.populations[0], ph.populations[1]);
            let theory = x_max_theoretical(&mu, Regime::P1Biased, n1, n2);
            let err = (r.throughput - theory).abs() / theory;
            assert!(
                err < 0.08,
                "phase ({n1},{n2}): sim {} vs theory {theory}",
                r.throughput
            );
        }
    }

    #[test]
    fn growing_and_shrinking_preserves_task_conservation() {
        let mu = workload::paper_two_type_mu();
        let cfg = DynamicConfig {
            phases: vec![
                Phase { populations: vec![3, 3], warmup: 100, completions: 1_000 },
                Phase { populations: vec![8, 1], warmup: 100, completions: 1_000 },
                Phase { populations: vec![1, 8], warmup: 100, completions: 1_000 },
            ],
            discipline: Discipline::Fcfs,
            dist: Distribution::Uniform,
            seed: 5,
        };
        for kind in [PolicyKind::Cab, PolicyKind::GrIn, PolicyKind::Jsq] {
            let mut p = kind.build();
            let rs = run_dynamic(&mu, &cfg, p.as_mut()).unwrap();
            // Little's law per phase (population changed ⇒ N per phase).
            for (i, r) in rs.iter().enumerate() {
                assert!(r.throughput > 0.0, "{} phase {i}", kind.name());
                assert!(
                    r.little_residual() < 0.25,
                    "{} phase {i}: X·E[T] = {} vs N = {}",
                    kind.name(),
                    r.little_product,
                    r.n_programs
                );
            }
        }
    }

    #[test]
    fn rejects_invalid_schedules() {
        let mu = workload::paper_two_type_mu();
        let bad = DynamicConfig {
            phases: vec![],
            discipline: Discipline::Ps,
            dist: Distribution::Constant,
            seed: 1,
        };
        let mut p = PolicyKind::Cab.build();
        assert!(run_dynamic(&mu, &bad, p.as_mut()).is_err());
        let bad = DynamicConfig {
            phases: vec![Phase { populations: vec![0, 0], warmup: 0, completions: 1 }],
            discipline: Discipline::Ps,
            dist: Distribution::Constant,
            seed: 1,
        };
        assert!(run_dynamic(&mu, &bad, p.as_mut()).is_err());
    }
}
