//! Processing-rate measurement (§7.2, Table 3).
//!
//! "First, we need to measure the average processing rate of each kernel
//! on each processor. We run each kernel 1000 times and calculate the
//! average execution time ω, and therefore, the processing rate μ = 1/ω."
//!
//! We do exactly that through the PJRT engine, per emulated device spec
//! (kernel kind + repetition count).  The measured matrix is what CAB /
//! GrIn consume — the paper stresses only its *ordering* matters.

// srclint: allow-file(index-reachable) — measurement buffers are preallocated to the sample count

use std::time::Instant;

use crate::error::Result;
use crate::model::affinity::AffinityMatrix;
use crate::runtime::Engine;
use crate::sim::rng::Rng;

use super::worker::{DeviceSpec, KernelKind};

/// Baseline single-execution cost of each kernel, measured once before
/// device specs are derived (repetition counts must account for the fact
/// that e.g. the sort network is intrinsically ~25× slower per call than
/// `nn_small`).
#[derive(Debug, Clone)]
pub struct Calibration {
    secs: [f64; 4],
}

impl Calibration {
    /// Mean seconds for one execution of `kind`.
    pub fn secs_of(&self, kind: KernelKind) -> f64 {
        self.secs[Self::idx(kind)]
    }

    fn idx(kind: KernelKind) -> usize {
        match kind {
            KernelKind::SortSmall => 0,
            KernelKind::SortLarge => 1,
            KernelKind::Nn2000 => 2,
            KernelKind::NnSmall => 3,
        }
    }

    /// A synthetic calibration (tests / dry-runs without PJRT).
    pub fn synthetic(sort_small: f64, sort_large: f64, nn2000: f64, nn_small: f64) -> Self {
        Self { secs: [sort_small, sort_large, nn2000, nn_small] }
    }
}

/// Time one execution of every kernel kind (`runs` samples each).
pub fn calibrate(runs: u32) -> Result<Calibration> {
    assert!(runs >= 1);
    let engine = Engine::open_default()?;
    let mut rng = Rng::new(0xCA11);
    let mut buf = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
    };
    let nn2000 = (buf(32 * 2048), buf(2048 * 256), buf(256));
    let nn_small = (buf(8 * 256), buf(256 * 256), buf(256));
    let sort_small = buf(16 * 256);
    let sort_large = buf(16 * 1024);
    let mut secs = [0.0f64; 4];
    for kind in [
        KernelKind::SortSmall,
        KernelKind::SortLarge,
        KernelKind::Nn2000,
        KernelKind::NnSmall,
    ] {
        let once = || -> Result<()> {
            match kind {
                KernelKind::Nn2000 => {
                    engine.nn_task("nn2000", &nn2000.0, &nn2000.1, &nn2000.2)?;
                }
                KernelKind::NnSmall => {
                    engine.nn_task("nn_small", &nn_small.0, &nn_small.1, &nn_small.2)?;
                }
                KernelKind::SortSmall => {
                    engine.sort_task("sort_small", &sort_small)?;
                }
                KernelKind::SortLarge => {
                    engine.sort_task("sort_large", &sort_large)?;
                }
            }
            Ok(())
        };
        once()?; // compile + warm
        // srclint: allow(instant-now) — microbenchmark harness measuring real kernel wall time.
        let t0 = Instant::now();
        for _ in 0..runs {
            once()?;
        }
        secs[Calibration::idx(kind)] = t0.elapsed().as_secs_f64() / runs as f64;
    }
    Ok(Calibration { secs })
}

/// Measured rates for a device set.
#[derive(Debug, Clone)]
pub struct MeasuredRates {
    /// μ[i][j] in tasks/second (task = kernel × reps on that device).
    pub mu: AffinityMatrix,
    /// Mean execution time ω[i][j] in seconds (row-major).
    pub omega: Vec<f64>,
}

/// Time each (task type, device) combination `runs` times.
///
/// Uses a fresh engine on the calling thread (measurement is offline:
/// the paper measures once, before scheduling).
pub fn measure_rates(devices: &[DeviceSpec], runs: u32) -> Result<MeasuredRates> {
    assert!(runs >= 1);
    let engine = Engine::open_default()?;
    let k = devices
        .first()
        .map(|d| d.kernels.len())
        .unwrap_or(0);
    let l = devices.len();
    let mut rng = Rng::new(0xBEEF);
    let mut buf = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
    };
    // Canned inputs (shape-fixed per artifact).
    let nn2000 = (buf(32 * 2048), buf(2048 * 256), buf(256));
    let nn_small = (buf(8 * 256), buf(256 * 256), buf(256));
    let sort_small = buf(16 * 256);
    let sort_large = buf(16 * 1024);

    let run_once = |kind: KernelKind| -> Result<()> {
        match kind {
            KernelKind::Nn2000 => {
                engine.nn_task("nn2000", &nn2000.0, &nn2000.1, &nn2000.2)?;
            }
            KernelKind::NnSmall => {
                engine.nn_task("nn_small", &nn_small.0, &nn_small.1, &nn_small.2)?;
            }
            KernelKind::SortSmall => {
                engine.sort_task("sort_small", &sort_small)?;
            }
            KernelKind::SortLarge => {
                engine.sort_task("sort_large", &sort_large)?;
            }
        }
        Ok(())
    };

    let mut omega = vec![0.0f64; k * l];
    let mut mu_rows = vec![vec![0.0f64; l]; k];
    for (j, dev) in devices.iter().enumerate() {
        for i in 0..k {
            let kind = dev.kernels[i];
            let reps = dev.reps[i];
            run_once(kind)?; // warm the executable cache
            // srclint: allow(instant-now) — microbenchmark harness measuring real kernel wall time.
            let t0 = Instant::now();
            for _ in 0..runs {
                for _ in 0..reps {
                    run_once(kind)?;
                }
            }
            let w = t0.elapsed().as_secs_f64() / runs as f64;
            omega[i * l + j] = w;
            mu_rows[i][j] = 1.0 / w;
        }
    }
    Ok(MeasuredRates { mu: AffinityMatrix::from_rows(&mu_rows)?, omega })
}

#[cfg(test)]
mod tests {
    // Measurement requires built artifacts + a PJRT client; exercised by
    // `tests/platform_e2e.rs` and `benches/table3_rates.rs`.
}
