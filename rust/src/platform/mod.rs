//! The §7 "real platform" — emulated CPU+GPU testbed running real kernels.
//!
//! The paper's testbed is an i7-4790 + GTX 760Ti driving OpenCL kernels
//! (quicksort and a single-layer NN) under FCFS queues.  We reproduce the
//! *system* on CPU-only hardware (DESIGN.md §3 records the substitution):
//!
//! * each processor type becomes a [`worker`] thread pool with its own
//!   FCFS queue and its own PJRT [`crate::runtime::Engine`];
//! * every task executes a *real* AOT-compiled kernel (NN forward or the
//!   sort network) — real compute, real memory traffic, real timing
//!   noise;
//! * heterogeneity (the affinity matrix) is induced by the per-device
//!   repetition count `R_ij ∝ 1/μ_ij`: an i-type task on device j runs
//!   its kernel `R_ij` times, so *measured* rates reproduce μ's ordering
//!   exactly — the only thing CAB needs (§3.3: "it is sufficient to know
//!   their relative ordering");
//! * [`measure`] re-derives Table 3 empirically by timing kernels through
//!   the PJRT engines, 1000 runs per cell in the paper, configurable
//!   here.
//!
//! [`bench_rig`] drives N closed-loop programs over the worker pools and
//! reports experimental throughput — the Figs. 15–16 harness.

pub mod bench_rig;
pub mod measure;
pub mod worker;

pub use bench_rig::{PlatformConfig, PlatformResult, run_platform};
pub use measure::{calibrate, measure_rates, Calibration, MeasuredRates};
pub use worker::{Device, DeviceSpec, KernelKind};
