//! Device workers: one OS thread + one PJRT engine + one FCFS queue per
//! emulated device (§7: "Each context has one single queue to implement
//! the FCFS processing order").

// srclint: allow-file(index-reachable) — device tables are indexed by the worker's own id

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::Engine;
use crate::sim::rng::Rng;

/// Which AOT kernel a task type executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// `sort_small` — quicksort-500 stand-in (CPU-type task).
    SortSmall,
    /// `sort_large` — quicksort-1000 stand-in (CPU-type task).
    SortLarge,
    /// `nn2000` — the NN-2000 benchmark (GPU-type task).
    Nn2000,
    /// `nn_small` — serving-batch NN variant.
    NnSmall,
}

impl KernelKind {
    /// Artifact entry name.
    pub fn entry(self) -> &'static str {
        match self {
            KernelKind::SortSmall => "sort_small",
            KernelKind::SortLarge => "sort_large",
            KernelKind::Nn2000 => "nn2000",
            KernelKind::NnSmall => "nn_small",
        }
    }
}

/// Static description of one emulated device.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Display name ("CPU", "GPU").
    pub name: String,
    /// Kernel of each task type on this device.
    pub kernels: Vec<KernelKind>,
    /// Repetitions per task type: an i-type task runs its kernel
    /// `reps[i]` times here.  `reps ∝ 1/μ` reproduces the affinity
    /// ordering on homogeneous silicon (DESIGN.md §3).
    pub reps: Vec<u32>,
}

// Repetition counts are derived from target rates *and* per-kernel
// calibration by `bench_rig::cases` (kernel baseline costs differ by
// ~2 orders of magnitude, so raw 1/μ scaling would invert orderings).

/// A unit of platform work.
#[derive(Debug, Clone)]
pub struct PlatformTask {
    /// Task id.
    pub id: u64,
    /// Owning program.
    pub program: usize,
    /// Task type (affinity row).
    pub ttype: usize,
    /// Enqueue timestamp.
    pub enqueued: Instant,
}

/// Completion record sent back to the dispatcher.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The finished task.
    pub task: PlatformTask,
    /// Device that ran it.
    pub device: usize,
    /// Wall-clock service time (seconds, kernel reps only).
    pub service_s: f64,
    /// Wall-clock response time (seconds, enqueue → completion).
    pub response_s: f64,
    /// Kernel checksum (numeric liveness probe).
    pub checksum: f32,
}

/// Canned kernel inputs, generated once per worker.
struct KernelInputs {
    nn2000: (Vec<f32>, Vec<f32>, Vec<f32>),
    nn_small: (Vec<f32>, Vec<f32>, Vec<f32>),
    sort_small: Vec<f32>,
    sort_large: Vec<f32>,
}

impl KernelInputs {
    fn generate(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut buf = |n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
        };
        Self {
            nn2000: (buf(32 * 2048), buf(2048 * 256), buf(256)),
            nn_small: (buf(8 * 256), buf(256 * 256), buf(256)),
            sort_small: buf(16 * 256),
            sort_large: buf(16 * 1024),
        }
    }
}

/// Execute one kernel once; returns checksum.
fn run_kernel(engine: &Engine, inputs: &KernelInputs, kind: KernelKind) -> Result<f32> {
    match kind {
        KernelKind::Nn2000 => {
            let (x, w, b) = &inputs.nn2000;
            Ok(engine.nn_task("nn2000", x, w, b)?.checksum)
        }
        KernelKind::NnSmall => {
            let (x, w, b) = &inputs.nn_small;
            Ok(engine.nn_task("nn_small", x, w, b)?.checksum)
        }
        KernelKind::SortSmall => Ok(engine.sort_task("sort_small", &inputs.sort_small)?.checksum),
        KernelKind::SortLarge => Ok(engine.sort_task("sort_large", &inputs.sort_large)?.checksum),
    }
}

/// A running device: FCFS queue + worker thread.
pub struct Device {
    /// Device index (affinity column).
    pub index: usize,
    /// Spec.
    pub spec: DeviceSpec,
    queue: Sender<PlatformTask>,
    handle: Option<JoinHandle<Result<()>>>,
}

impl Device {
    /// Spawn the worker.  Completions flow to `done`.
    pub fn spawn(
        index: usize,
        spec: DeviceSpec,
        done: Sender<Completion>,
    ) -> Result<Self> {
        let (tx, rx): (Sender<PlatformTask>, Receiver<PlatformTask>) = channel();
        let spec_clone = spec.clone();
        let handle = std::thread::Builder::new()
            .name(format!("device-{}", spec.name))
            .spawn(move || -> Result<()> {
                // Engine per worker thread: PJRT executables are !Sync.
                let engine = Engine::open_default()?;
                let inputs = KernelInputs::generate(0x5EED ^ index as u64);
                // Warm the executable cache so measured service excludes
                // compilation.
                for &k in &spec_clone.kernels {
                    run_kernel(&engine, &inputs, k)?;
                }
                while let Ok(task) = rx.recv() {
                    let kind = spec_clone.kernels[task.ttype];
                    let reps = spec_clone.reps[task.ttype];
                    // srclint: allow(instant-now) — worker thread times real kernel service on real devices.
                    let t0 = Instant::now();
                    let mut checksum = 0f32;
                    for _ in 0..reps {
                        checksum = run_kernel(&engine, &inputs, kind)?;
                    }
                    let service = t0.elapsed().as_secs_f64();
                    let response = task.enqueued.elapsed().as_secs_f64();
                    // srclint: allow(discarded-result) — send fails only if the collector hung up at shutdown; dropping the completion is correct then
                    let _ = done.send(Completion {
                        task,
                        device: index,
                        service_s: service,
                        response_s: response,
                        checksum,
                    });
                }
                Ok(())
            })
            .map_err(|e| Error::Runtime(format!("spawn device thread: {e}")))?;
        Ok(Self { index, spec, queue: tx, handle: Some(handle) })
    }

    /// Enqueue a task (FCFS).
    pub fn submit(&self, task: PlatformTask) -> Result<()> {
        self.queue
            .send(task)
            .map_err(|_| Error::Runtime(format!("device {} is gone", self.index)))
    }

    /// Close the queue and join the worker.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.queue);
        if let Some(h) = self.handle.take() {
            h.join()
                .map_err(|_| Error::Runtime("device thread panicked".into()))??;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_entries_map_to_artifacts() {
        assert_eq!(KernelKind::SortSmall.entry(), "sort_small");
        assert_eq!(KernelKind::SortLarge.entry(), "sort_large");
        assert_eq!(KernelKind::Nn2000.entry(), "nn2000");
        assert_eq!(KernelKind::NnSmall.entry(), "nn_small");
    }

    // Thread/engine integration is covered by `tests/platform_e2e.rs`
    // (requires built artifacts); rep derivation by `bench_rig` tests.
}
