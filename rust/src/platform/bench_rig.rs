//! Closed-loop platform driver — the Figs. 15–16 experiment harness.
//!
//! N programs run against the emulated devices exactly as in §7: whenever
//! a program's task completes, its next task is immediately dispatched by
//! the policy under test to some device's FCFS queue.  Throughput is
//! tasks/second of wall-clock over the post-warm-up window.

// srclint: allow-file(index-reachable) — mu and kind tables are sized by the calibrated device set

use std::sync::mpsc::channel;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::model::state::StateMatrix;
use crate::policy::{Policy, SolveRequest, SystemView};
use crate::sim::rng::Rng;

use super::measure::MeasuredRates;
use super::worker::{Completion, Device, DeviceSpec, PlatformTask};

/// Platform experiment configuration.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Emulated devices (affinity columns).
    pub devices: Vec<DeviceSpec>,
    /// Programs per task type (N_i).
    pub populations: Vec<u32>,
    /// Completions to discard (system fill + cache warm).
    pub warmup: u64,
    /// Completions to measure.
    pub measure: u64,
    /// RNG seed (initial fill order).
    pub seed: u64,
}

/// Result of one platform run.
#[derive(Debug, Clone)]
pub struct PlatformResult {
    /// Measured throughput, tasks/second.
    pub throughput: f64,
    /// Mean response time, seconds.
    pub mean_response_s: f64,
    /// Mean service time, seconds.
    pub mean_service_s: f64,
    /// Completions measured.
    pub completions: u64,
    /// Σ|checksum| over measured tasks (numeric liveness probe; NaN-free).
    pub checksum_abs_sum: f64,
}

/// Run one policy against the platform.
pub fn run_platform(
    cfg: &PlatformConfig,
    rates: &MeasuredRates,
    policy: &mut dyn Policy,
) -> Result<PlatformResult> {
    let k = cfg.populations.len();
    let l = cfg.devices.len();
    let mu = &rates.mu;
    if mu.types() != k || mu.procs() != l {
        return Err(Error::Shape("measured rates don't match config".into()));
    }
    policy.prepare(&SolveRequest::new(mu, &cfg.populations))?;

    let (done_tx, done_rx) = channel::<Completion>();
    let mut devices = Vec::with_capacity(l);
    for (j, spec) in cfg.devices.iter().enumerate() {
        devices.push(Device::spawn(j, spec.clone(), done_tx.clone())?);
    }
    drop(done_tx);

    // Program table: type per program.
    let mut ptypes = Vec::new();
    for (t, &n) in cfg.populations.iter().enumerate() {
        for _ in 0..n {
            ptypes.push(t);
        }
    }
    let mut rng = Rng::new(cfg.seed);
    let mut order: Vec<usize> = (0..ptypes.len()).collect();
    rng.shuffle(&mut order);

    let mut state = StateMatrix::zeros(k, l);
    let mut work = vec![0.0f64; l];
    let mut next_id = 0u64;

    let mut dispatch =
        |prog: usize,
         state: &mut StateMatrix,
         work: &mut [f64],
         rng: &mut Rng,
         policy: &mut dyn Policy,
         devices: &[Device]|
         -> Result<()> {
            let ttype = ptypes[prog];
            // Perfect-information work estimate from measured ω.
            for (j, w) in work.iter_mut().enumerate() {
                *w = (0..k)
                    .map(|i| state.get(i, j) as f64 * rates.omega[i * l + j])
                    .sum();
            }
            let view = SystemView {
                mu,
                state,
                work,
                populations: &cfg.populations,
            };
            let j = policy.dispatch(ttype, &view, rng);
            let task = PlatformTask {
                id: next_id,
                program: prog,
                ttype,
                // srclint: allow(instant-now) — the rig measures real end-to-end latency by design.
                enqueued: Instant::now(),
            };
            next_id += 1;
            devices[j].submit(task)?;
            state.inc(ttype, j);
            Ok(())
        };

    // Initial fill.
    for &p in &order {
        dispatch(p, &mut state, &mut work, &mut rng, policy, &devices)?;
    }

    let total = cfg.warmup + cfg.measure;
    let mut completions = 0u64;
    let mut measured = 0u64;
    let mut sum_resp = 0.0f64;
    let mut sum_serv = 0.0f64;
    let mut checksum = 0.0f64;
    let mut window_start: Option<Instant> = None;
    let mut last: Option<Instant> = None;

    while completions < total {
        let c = done_rx
            .recv()
            .map_err(|_| Error::Runtime("all devices died".into()))?;
        completions += 1;
        state.dec(c.task.ttype, c.device)?;
        if completions > cfg.warmup {
            if window_start.is_none() {
                // srclint: allow(instant-now) — the rig measures real end-to-end latency by design.
                window_start = Some(Instant::now());
            }
            // srclint: allow(instant-now) — the rig measures real end-to-end latency by design.
            last = Some(Instant::now());
            measured += 1;
            sum_resp += c.response_s;
            sum_serv += c.service_s;
            if !c.checksum.is_finite() {
                return Err(Error::Runtime(format!(
                    "kernel produced non-finite checksum on device {}",
                    c.device
                )));
            }
            checksum += c.checksum.abs() as f64;
        }
        if completions < total {
            dispatch(c.task.program, &mut state, &mut work, &mut rng, policy, &devices)?;
        }
    }

    for d in devices {
        d.shutdown()?;
    }

    let elapsed = match (window_start, last) {
        (Some(s), Some(e)) => e.duration_since(s).as_secs_f64(),
        _ => 0.0,
    };
    Ok(PlatformResult {
        throughput: if elapsed > 0.0 { measured as f64 / elapsed } else { 0.0 },
        mean_response_s: if measured > 0 { sum_resp / measured as f64 } else { 0.0 },
        mean_service_s: if measured > 0 { sum_serv / measured as f64 } else { 0.0 },
        completions: measured,
        checksum_abs_sum: checksum,
    })
}

/// The two §7 experiment cases as device sets.
pub mod cases {
    use super::super::measure::Calibration;
    use super::super::worker::{DeviceSpec, KernelKind};

    /// Repetition counts from target Table-3 rates, *weighted by each
    /// kernel's calibrated baseline cost*: an (i, j) cell's emulated
    /// service time should be ∝ 1/μ_ij, so
    ///
    ///   reps_ij = round( C / (μ_ij · t_i) ),  C = max_ij μ_ij·t_i
    ///
    /// which puts the fastest-draining cell at exactly 1 repetition.  The
    /// `cap` compresses extreme ratios (the GPU sort is ~250× slower than
    /// the CPU sort in Table 3) to keep wall-clock sane; *orderings* —
    /// the only thing CAB consumes — survive as long as the cap exceeds
    /// every non-capped cell, which [`super::super::measure_rates`]
    /// re-verifies empirically after the fact.
    fn reps_for(
        mu_target: &[[f64; 2]; 2],
        kinds: &[KernelKind; 2],
        cal: &Calibration,
        cap: u32,
    ) -> [Vec<u32>; 2] {
        let mut c = f64::MIN;
        for (i, row) in mu_target.iter().enumerate() {
            for &m in row {
                c = c.max(m * cal.secs_of(kinds[i]));
            }
        }
        let rep = |i: usize, j: usize| -> u32 {
            let ideal = c / (mu_target[i][j] * cal.secs_of(kinds[i]));
            // srclint: allow(as-truncation) — the result is clamped to [1, cap] immediately after
            (ideal.round() as u32).clamp(1, cap)
        };
        [vec![rep(0, 0), rep(1, 0)], vec![rep(0, 1), rep(1, 1)]]
    }

    fn devices(
        sort: KernelKind,
        mu_target: [[f64; 2]; 2],
        cal: &Calibration,
        cap: u32,
    ) -> Vec<DeviceSpec> {
        let kinds = [sort, KernelKind::NnSmall];
        let [cpu, gpu] = reps_for(&mu_target, &kinds, cal, cap);
        vec![
            DeviceSpec { name: "CPU".into(), kernels: kinds.to_vec(), reps: cpu },
            DeviceSpec { name: "GPU".into(), kernels: kinds.to_vec(), reps: gpu },
        ]
    }

    /// §7.4 general-symmetric: quicksort-500 + NN-2000.
    /// Table 3: μ_CPU = (928, 587), μ_GPU = (3.61, 2398).
    pub fn general_symmetric(cal: &Calibration, cap: u32) -> Vec<DeviceSpec> {
        devices(
            KernelKind::SortSmall,
            [[928.0, 3.61], [587.0, 2398.0]],
            cal,
            cap,
        )
    }

    /// §7.3 P2-biased: quicksort-1000 + NN-2000.
    /// Table 3: μ_CPU = (253, 587), μ_GPU = (0.911, 2398).
    pub fn p2_biased(cal: &Calibration, cap: u32) -> Vec<DeviceSpec> {
        devices(
            KernelKind::SortLarge,
            [[253.0, 0.911], [587.0, 2398.0]],
            cal,
            cap,
        )
    }
}

#[cfg(test)]
mod tests {
    // End-to-end platform runs live in `tests/platform_e2e.rs` (they need
    // built artifacts and real threads); `cases` wiring is checked here.
    use super::*;

    #[test]
    fn case_orderings_match_table3() {
        use super::super::measure::Calibration;
        // Synthetic calibration: sort kernels ~25× / ~100× the nn_small
        // cost — the shape observed on the interpret-mode artifacts.
        let cal = Calibration::synthetic(2.5e-3, 1.0e-2, 1.0e-2, 1.0e-4);
        // Emulated rate of cell (i, j) given a spec set.
        let rate = |specs: &[DeviceSpec], i: usize, j: usize| -> f64 {
            let t = match specs[j].kernels[i] {
                super::super::worker::KernelKind::SortSmall => 2.5e-3,
                super::super::worker::KernelKind::SortLarge => 1.0e-2,
                super::super::worker::KernelKind::Nn2000 => 1.0e-2,
                super::super::worker::KernelKind::NnSmall => 1.0e-4,
            };
            1.0 / (specs[j].reps[i] as f64 * t)
        };

        let gs = cases::general_symmetric(&cal, 256);
        // General-symmetric orderings: μ11 > μ21, μ22 > μ12, Eq. 2.
        assert!(rate(&gs, 0, 0) > rate(&gs, 1, 0), "CPU prefers sort");
        assert!(rate(&gs, 1, 1) > rate(&gs, 0, 1), "GPU prefers NN");
        assert!(rate(&gs, 0, 0) > rate(&gs, 0, 1));
        assert!(rate(&gs, 1, 0) < rate(&gs, 1, 1));

        let p2 = cases::p2_biased(&cal, 256);
        // P2-biased: NN faster than sort on *both* devices.
        assert!(rate(&p2, 1, 0) > rate(&p2, 0, 0));
        assert!(rate(&p2, 1, 1) > rate(&p2, 0, 1));
        assert!(rate(&p2, 0, 0) > rate(&p2, 0, 1));
    }
}
