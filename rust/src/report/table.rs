//! Aligned ASCII tables and figure-series blocks.

// srclint: allow-file(index-reachable) — column widths are computed over the same rows being rendered

/// A printable table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (cells stringified by the caller).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate().take(ncol) {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:>w$}", c, w = widths[i]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// A named (x, y) series — one line of a paper figure.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label (e.g. "CAB").
    pub label: String,
    /// Points.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// New empty series.
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new() }
    }

    /// Append a point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// Render several series as a figure block: one row per x, one
    /// column per series — exactly the data behind a paper subplot.
    pub fn render_block(title: &str, x_label: &str, series: &[Series]) -> String {
        let mut headers: Vec<&str> = vec![x_label];
        for s in series {
            headers.push(&s.label);
        }
        let mut t = Table::new(title, &headers);
        if let Some(first) = series.first() {
            for (i, &(x, _)) in first.points.iter().enumerate() {
                let mut row = vec![format!("{x:.3}")];
                for s in series {
                    row.push(
                        s.points
                            .get(i)
                            .map(|&(_, y)| format!("{y:.4}"))
                            .unwrap_or_else(|| "-".into()),
                    );
                }
                t.row(row);
            }
        }
        t.render()
    }
}

/// Compact f64 formatter for table cells.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || v.abs() < 0.01 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["name", "x"]);
        t.row(vec!["CAB".into(), "31.32".into()]);
        t.row(vec!["LB".into(), "14.0".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // Columns right-aligned to equal width.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn series_block_pivots_series_to_columns() {
        let mut a = Series::new("CAB");
        let mut b = Series::new("LB");
        for i in 0..3 {
            a.push(i as f64 / 10.0, 20.0 + i as f64);
            b.push(i as f64 / 10.0, 10.0 + i as f64);
        }
        let s = Series::render_block("Fig X", "eta", &[a, b]);
        assert!(s.contains("CAB"));
        assert!(s.contains("LB"));
        assert!(s.contains("0.200"));
        assert!(s.lines().count() == 6);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert!(fmt(12345.0).contains('e'));
        assert!(fmt(0.001).contains('e'));
        assert_eq!(fmt(3.14159), "3.1416");
    }
}
