//! Wall-clock measurement for the bench harness (criterion stand-in):
//! repeated timed runs with mean/min/max and ns-per-op helpers.

use std::time::{Duration, Instant};

/// Repeated-run stopwatch.
#[derive(Debug, Clone, Default)]
pub struct Stopwatch {
    samples: Vec<Duration>,
}

impl Stopwatch {
    /// New empty stopwatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time one closure invocation and record it; returns its output.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        // srclint: allow(instant-now) — wall-clock timer utility, the one abstraction reports time through.
        let t0 = Instant::now();
        let out = f();
        self.samples.push(t0.elapsed());
        out
    }

    /// Run `f` `n` times, recording each.
    pub fn run_n(&mut self, n: usize, mut f: impl FnMut()) {
        for _ in 0..n {
            self.time(&mut f);
        }
    }

    /// Recorded sample count.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Mean seconds per run.
    pub fn mean_s(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / self.samples.len() as f64
    }

    /// Minimum seconds (the usual bench headline: least noisy).
    pub fn min_s(&self) -> f64 {
        self.samples
            .iter()
            .map(|d| d.as_secs_f64())
            .fold(f64::INFINITY, f64::min)
    }

    /// Maximum seconds.
    pub fn max_s(&self) -> f64 {
        self.samples.iter().map(|d| d.as_secs_f64()).fold(0.0, f64::max)
    }

    /// Mean nanoseconds per operation given `ops` operations per run.
    pub fn ns_per_op(&self, ops: u64) -> f64 {
        self.mean_s() * 1e9 / ops.max(1) as f64
    }

    /// One-line summary.
    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: mean {:.3} ms, min {:.3} ms, max {:.3} ms over {} runs",
            self.mean_s() * 1e3,
            self.min_s() * 1e3,
            self.max_s() * 1e3,
            self.count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut sw = Stopwatch::new();
        let mut acc = 0u64;
        sw.run_n(5, || {
            acc = (0..10_000u64).sum();
        });
        assert_eq!(acc, 49_995_000);
        assert_eq!(sw.count(), 5);
        assert!(sw.mean_s() > 0.0);
        assert!(sw.min_s() <= sw.mean_s());
        assert!(sw.mean_s() <= sw.max_s());
        assert!(sw.ns_per_op(10_000) > 0.0);
        assert!(sw.summary("x").contains("5 runs"));
    }

    #[test]
    fn empty_is_zero() {
        let sw = Stopwatch::new();
        assert_eq!(sw.mean_s(), 0.0);
        assert_eq!(sw.count(), 0);
    }
}
