//! Reporting substrate for benches and the CLI (no `criterion`/plotting
//! crates offline): aligned ASCII tables, figure-style series blocks and
//! wall-clock timers.  Every paper figure/table bench prints through this
//! module so `bench_output.txt` is uniform and diffable.

pub mod table;
pub mod timer;

pub use table::{Series, Table};
pub use timer::Stopwatch;
