//! The leader event loop: closed-loop clients → router → batcher →
//! device workers → completion stream → stats.
//!
//! The leader keeps a fixed number of requests in flight (closed-loop
//! load, the paper's N-programs model transplanted to serving), routes
//! every request with the configured policy, coalesces NN requests into
//! `nn_small` batches per device, and executes sort requests singly —
//! all compute through per-device PJRT engines on worker threads.

// srclint: allow-file(index-reachable) — queue and worker vectors are sized at spawn; indices are worker ids the leader handed out

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::sync::{Arc, Condvar, Mutex};

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, PowerProfile};
use crate::policy::PolicyKind;
use crate::runtime::Engine;
use crate::sim::dynamic::{DriftConfig, Trigger};
use crate::sim::rng::Rng;

use super::batcher::{Batch, DynamicBatcher, FlushReason, Pending};
use super::frontend::{ConcurrentRouter, RouteHandle};
use super::global::ShardedControl;
use super::router::{Router, RouterConfig, TargetUpdate};
use super::stats::{LatencyHistogram, RateEstimator};

/// NN row width of the `nn_small` artifact.
pub const NN_WIDTH: usize = 256;
/// NN batch capacity of the `nn_small` artifact.
pub const NN_BATCH: usize = 8;
/// Sort row count × width of the `sort_small` artifact.
const SORT_ELEMS: usize = 16 * 256;

/// Wall-clock stamp for serving latency/throughput accounting.  The
/// leader serves real traffic on real devices, so end-to-end latency is
/// genuinely wall time; every `Instant::now` in this file funnels
/// through here (simulation paths use an injected Clock instead).
fn wall_now() -> Instant {
    // srclint: allow(instant-now) — sole wall-time source of the serving leader; real latency is its job.
    Instant::now()
}

/// Serving experiment configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Placement policy.
    pub policy: PolicyKind,
    /// Device count (each gets one worker thread + one PJRT engine).
    pub devices: usize,
    /// Closed-loop concurrency (requests kept in flight).
    pub inflight: u32,
    /// Fraction of requests that are sort-class (vs NN-class).
    pub sort_fraction: f64,
    /// Batching deadline for NN requests.
    pub batch_deadline: Duration,
    /// Total requests to serve.
    pub total: u64,
    /// Seed.
    pub seed: u64,
    /// Measured affinity matrix (class × device); defaults to Table-3
    /// general-symmetric when `None`.
    pub mu: Option<AffinityMatrix>,
    /// Adaptive mode: estimate live service rates ([`RateEstimator`]),
    /// detect drift from the matrix the routing target was solved for,
    /// and re-solve/swap the target without stopping traffic.
    pub adaptive: bool,
    /// Completions between drift checks in adaptive mode
    /// ([`Trigger::Threshold`]).
    pub resolve_check: u64,
    /// Relative rate drift that triggers a re-solve
    /// ([`Trigger::Threshold`]).
    pub drift_threshold: f64,
    /// What fires an adaptive re-solve: the polled drift threshold, or
    /// the per-cell CUSUM change detector (alarms checked on every
    /// completion, re-solve lands the moment a change is confirmed).
    pub trigger: Trigger,
    /// CUSUM drift allowance δ per mini-batch (relative residual units).
    pub cusum_delta: f64,
    /// CUSUM alarm threshold h.
    pub cusum_h: f64,
    /// Completions without a fresh sample before a warm estimator cell
    /// demotes to stale (0 disables demotion).
    pub stale_after: u64,
    /// Shard count: 1 = the single-leader path; ≥ 2 partitions the
    /// devices into per-shard [`crate::coordinator::ShardLeader`]s under
    /// a global batched-GrIn re-solve loop (implies adaptive estimation,
    /// per shard and cold-started).
    pub shards: usize,
    /// Completions between global gather/re-solve syncs (sharded mode).
    pub sync_every: u64,
    /// Per-class integer priorities `[sort, nn]` (each ≥ 1; empty =
    /// unweighted).  Non-uniform priorities run every solve through the
    /// weighted objective — GrIn/sharded only, other policies are
    /// rejected rather than silently scheduling unweighted.
    pub priorities: Vec<u32>,
    /// Per-class soft deadlines in seconds `[sort, nn]` (0 = no
    /// deadline for that class; empty = no deadline accounting).
    /// Misses are counted against request latency and reported in
    /// [`ServeReport::deadline_misses`].
    pub deadlines: Vec<f64>,
    /// What every target solve optimizes.  [`Objective::Throughput`]
    /// keeps the pre-objective serving paths bit for bit; other
    /// objectives are GrIn/sharded-only and exclude non-trivial
    /// priorities.
    pub objective: Objective,
    /// Power model: scores non-throughput solves and meters the modeled
    /// per-request energy in [`ServeReport`].
    pub power: PowerProfile,
    /// Concurrent front-end routing threads (0 = the single-threaded
    /// leader routes inline).  ≥ 1 serves through the lock-free
    /// [`ConcurrentRouter`]: routing threads steer against
    /// epoch-versioned target snapshots over atomic occupancy, so
    /// adaptive target installs never block routing.  Needs a
    /// target-solving policy (CAB/GrIn/Opt) and excludes sharding.
    pub frontend_threads: usize,
    /// Router-level batch size (front-end mode): coalesce up to this
    /// many same-class requests behind ONE steering decision, flushed
    /// by [`ServeConfig::batch_deadline`].  0 or 1 routes every request
    /// individually.
    pub router_batch: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            policy: PolicyKind::Cab,
            devices: 2,
            inflight: 16,
            sort_fraction: 0.5,
            batch_deadline: Duration::from_millis(4),
            total: 400,
            seed: 0xC0FFEE,
            mu: None,
            adaptive: false,
            resolve_check: 64,
            drift_threshold: 0.25,
            trigger: Trigger::Threshold,
            cusum_delta: 0.25,
            cusum_h: 4.0,
            stale_after: 1_000,
            shards: 1,
            sync_every: 128,
            priorities: Vec::new(),
            deadlines: Vec::new(),
            objective: Objective::Throughput,
            power: PowerProfile::default(),
            frontend_threads: 0,
            router_batch: 0,
        }
    }
}

/// Serving run report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests served.
    pub served: u64,
    /// Wall-clock seconds.
    pub elapsed_s: f64,
    /// Requests/second.
    pub rps: f64,
    /// Latency histogram, sort class.
    pub sort_latency: LatencyHistogram,
    /// Latency histogram, NN class.
    pub nn_latency: LatencyHistogram,
    /// NN batches launched.
    pub batches: u64,
    /// Mean NN batch fill (requests per launch / capacity).
    pub batch_fill: f64,
    /// Flush-reason counts (full, deadline, drain).
    pub flushes: [u64; 3],
    /// Adaptive re-solves performed (target swaps).
    pub resolves: u64,
    /// Final estimated affinity matrix μ̂ (adaptive mode).
    pub mu_hat: Option<AffinityMatrix>,
    /// Requests served per class `[sort, nn]`.
    pub class_served: [u64; 2],
    /// Soft-deadline misses per class `[sort, nn]` (all zero unless
    /// [`ServeConfig::deadlines`] is set).
    pub deadline_misses: [u64; 2],
    /// Modeled joules per request under [`ServeConfig::power`]:
    /// 𝒫(μ̂(class, device)) × measured kernel seconds, averaged over
    /// every served request.
    pub mean_energy: f64,
    /// Modeled energy–delay product: mean energy × mean request latency.
    pub edp: f64,
    /// Steering decisions made.  One per request on the single-leader
    /// path; on the concurrent front end a router-level batch spends
    /// one decision for all of its requests, so `served /
    /// route_decisions` is the decision amortization batching bought.
    pub route_decisions: u64,
}

impl ServeReport {
    /// Fraction of class-`i` requests that missed the class's soft
    /// deadline (0 when no deadline was configured or nothing served).
    pub fn deadline_miss_rate(&self, class: usize) -> f64 {
        if self.class_served[class] == 0 {
            0.0
        } else {
            self.deadline_misses[class] as f64 / self.class_served[class] as f64
        }
    }
}

enum Work {
    Sort { id: u64, class: usize, arrived: Instant },
    Nn(Batch),
}

struct Done {
    /// Request id (kept for tracing/debug symmetry with `Work::Sort`).
    #[allow(dead_code)]
    id: u64,
    class: usize,
    device: usize,
    arrived: Instant,
    /// Kernel execution seconds attributed to this request (batch time
    /// split evenly across batched requests) — the estimator's signal.
    service_s: f64,
}

/// The serving coordinator.
pub struct Coordinator;

/// Single-leader vs sharded routing plane.
enum Steering {
    Single(Router),
    Sharded(ShardedControl),
}

impl Steering {
    fn route(&mut self, class: usize) -> Result<usize> {
        match self {
            Steering::Single(router) => router.route(class),
            Steering::Sharded(ctl) => ctl.route(class),
        }
    }
}

impl Coordinator {
    /// Run a closed-loop serving experiment.
    pub fn run(cfg: &ServeConfig) -> Result<ServeReport> {
        if cfg.devices < 1 || cfg.inflight == 0 || cfg.total == 0 {
            return Err(Error::Config("devices, inflight, total must be ≥ 1".into()));
        }
        if cfg.adaptive && cfg.resolve_check == 0 {
            return Err(Error::Config("adaptive mode needs resolve_check ≥ 1".into()));
        }
        if cfg.shards == 0 || cfg.shards > cfg.devices {
            return Err(Error::Config(format!(
                "{} shards cannot cover {} devices",
                cfg.shards, cfg.devices
            )));
        }
        if cfg.adaptive && cfg.shards > 1 {
            // Sharded mode always estimates (per shard, cold-started);
            // silently ignoring --adaptive would hide that the single-
            // leader estimator/re-solve path is not the one running.
            return Err(Error::Config(
                "sharded mode implies per-shard adaptive estimation; drop `adaptive`".into(),
            ));
        }
        if cfg.frontend_threads > 0 && cfg.shards > 1 {
            return Err(Error::Config(
                "the concurrent front end drives a single routing plane; \
                 drop either frontend_threads or shards"
                    .into(),
            ));
        }
        if cfg.router_batch > 1 && cfg.frontend_threads == 0 {
            return Err(Error::Config(
                "router-level batching rides the concurrent front end; \
                 set frontend_threads ≥ 1"
                    .into(),
            ));
        }
        if cfg.shards > 1 && cfg.policy != PolicyKind::GrIn {
            // Same honesty rule for the policy: the sharded plane's
            // global re-solve is always batched GrIn.
            return Err(Error::Config(format!(
                "sharded serving steers by batched GrIn; policy {} would be ignored",
                cfg.policy.name()
            )));
        }
        if !cfg.priorities.is_empty() {
            if cfg.priorities.len() != 2 {
                return Err(Error::Config(format!(
                    "{} priorities for the 2 serving classes [sort, nn]",
                    cfg.priorities.len()
                )));
            }
            if cfg.priorities.iter().any(|&p| p == 0) {
                return Err(Error::Config("class priorities must be ≥ 1".into()));
            }
            if cfg.shards == 1
                && cfg.policy != PolicyKind::GrIn
                && !crate::policy::grin::trivial_priorities(&cfg.priorities)
            {
                // Weighted solves are a GrIn extension; refusing beats
                // silently serving unweighted under a priority config.
                // (All-equal vectors reduce to the unweighted solve and
                // run on any policy.)
                return Err(Error::Config(format!(
                    "priorities need the weighted GrIn solve; policy {} cannot honor them",
                    cfg.policy.name()
                )));
            }
        }
        cfg.objective.validate()?;
        cfg.power.validate()?;
        if !cfg.objective.is_throughput()
            && !crate::policy::grin::trivial_priorities(&cfg.priorities)
        {
            return Err(Error::Config(
                "priority weights combine only with the throughput objective".into(),
            ));
        }
        if !cfg.deadlines.is_empty() {
            if cfg.deadlines.len() != 2 {
                return Err(Error::Config(format!(
                    "{} deadlines for the 2 serving classes [sort, nn]",
                    cfg.deadlines.len()
                )));
            }
            if cfg.deadlines.iter().any(|&d| !d.is_finite() || d < 0.0) {
                return Err(Error::Config("deadlines must be finite and ≥ 0".into()));
            }
        }
        let mu = match &cfg.mu {
            Some(m) => m.clone(),
            None if cfg.devices == 2 => crate::sim::workload::table3::general_symmetric(),
            None => crate::sim::workload::table3::general_symmetric_tiled(cfg.devices)?,
        };
        if mu.procs() != cfg.devices || mu.types() != 2 {
            return Err(Error::Config(format!(
                "μ is {}×{}, config wants 2×{}",
                mu.types(),
                mu.procs(),
                cfg.devices
            )));
        }
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        // Streaming μ̂ estimator, seeded with the configured prior.
        let mut estimator = RateEstimator::from_drift(
            &mu,
            &DriftConfig {
                threshold: cfg.drift_threshold,
                check_every: cfg.resolve_check,
                ewma_alpha: 0.1,
                trigger: cfg.trigger,
                cusum_delta: cfg.cusum_delta,
                cusum_h: cfg.cusum_h,
                stale_after: cfg.stale_after,
                ..Default::default()
            },
        )?;
        // Expected in-flight split drives the policy's target solve.
        // srclint: allow(as-truncation) — inflight is u32-scale and sort_fraction is in [0,1], so the product fits
        let n_sort = ((cfg.inflight as f64 * cfg.sort_fraction).round() as u32)
            .clamp(1, cfg.inflight - 1);
        let populations = vec![n_sort, cfg.inflight - n_sort];
        if cfg.frontend_threads > 0 {
            return Self::run_frontend(cfg, mu, omega, populations, estimator);
        }
        let mut steering = if cfg.shards > 1 {
            // check_every is the single-leader cadence knob; the sharded
            // plane syncs on `sync_every` completions instead.
            let drift = DriftConfig {
                threshold: cfg.drift_threshold,
                trigger: cfg.trigger,
                cusum_delta: cfg.cusum_delta,
                cusum_h: cfg.cusum_h,
                stale_after: cfg.stale_after,
                ..Default::default()
            };
            let mut ctl = ShardedControl::new(
                &mu,
                &populations,
                cfg.shards,
                &drift,
                cfg.sync_every,
            )?;
            if !cfg.priorities.is_empty() {
                // Weighted batched re-solves + steering, installed with
                // the boot target under one epoch.
                ctl.set_priorities(&cfg.priorities)?;
            }
            if !cfg.objective.is_throughput() {
                // Objective-scored batched re-solves, one re-install
                // over the boot target.
                ctl.set_objective(cfg.objective, cfg.power)?;
            }
            Steering::Sharded(ctl)
        } else if crate::policy::grin::trivial_priorities(&cfg.priorities) {
            // Empty or all-equal priorities: the plain router, solving
            // for the configured objective (throughput reproduces the
            // pre-objective router exactly).
            Steering::Single(Router::build(
                RouterConfig::new(mu, omega, populations)
                    .with_seed(cfg.seed)
                    .with_objective(cfg.objective, cfg.power),
                cfg.policy.build(),
            )?)
        } else {
            // The boot solve runs under the estimator's (cold, uniform)
            // confidence; adaptive re-solves refresh the weights from
            // the live grid.
            let weights = crate::policy::grin::priority_weights(
                &cfg.priorities,
                &estimator.confidences(),
                mu.procs(),
            )?;
            Steering::Single(Router::build(
                RouterConfig::new(mu, omega, populations)
                    .with_seed(cfg.seed)
                    .with_weights(weights),
                cfg.policy.build(),
            )?)
        };

        // Device workers.
        let (done_rx, work_txs, handles) = Self::spawn_workers(cfg.devices)?;

        let mut batchers: Vec<DynamicBatcher> = (0..cfg.devices)
            .map(|_| DynamicBatcher::new(NN_BATCH, NN_WIDTH, cfg.batch_deadline))
            .collect();
        let mut rng = Rng::new(cfg.seed ^ 0xF00D);
        let mut next_id = 0u64;
        let mut issued = 0u64;
        let mut served = 0u64;
        let mut batches = 0u64;
        let mut batch_fill_sum = 0f64;
        let mut flushes = [0u64; 3];
        let mut sort_latency = LatencyHistogram::new();
        let mut nn_latency = LatencyHistogram::new();
        let mut resolves = 0u64;
        let mut class_served = [0u64; 2];
        let mut deadline_misses = [0u64; 2];
        let mut energy_sum = 0f64;
        let mut latency_sum = 0f64;

        let submit_batch = |j: usize, batch: Batch,
                                batches: &mut u64,
                                fill: &mut f64,
                                flushes: &mut [u64; 3]|
         -> Result<()> {
            *batches += 1;
            *fill += batch.requests.len() as f64 / NN_BATCH as f64;
            flushes[match batch.reason {
                FlushReason::Full => 0,
                FlushReason::Deadline => 1,
                FlushReason::Drain => 2,
            }] += 1;
            work_txs[j]
                .send(Work::Nn(batch))
                .map_err(|_| Error::Runtime("device worker gone".into()))
        };

        let issue = |steering: &mut Steering,
                         batchers: &mut Vec<DynamicBatcher>,
                         rng: &mut Rng,
                         next_id: &mut u64,
                         batches: &mut u64,
                         fill: &mut f64,
                         flushes: &mut [u64; 3]|
         -> Result<()> {
            let class = usize::from(!rng.bool_with(cfg.sort_fraction));
            let id = *next_id;
            *next_id += 1;
            let j = steering.route(class)?;
            if class == 0 {
                work_txs[j]
                    .send(Work::Sort { id, class, arrived: wall_now() })
                    .map_err(|_| Error::Runtime("device worker gone".into()))?;
            } else {
                let row: Vec<f32> =
                    (0..NN_WIDTH).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect();
                let p = Pending { id, row, arrived: wall_now() };
                if let Some(batch) = batchers[j].push(p) {
                    submit_batch(j, batch, batches, fill, flushes)?;
                }
            }
            Ok(())
        };

        let t0 = wall_now();
        // Fill the pipe.
        while issued < cfg.inflight as u64 && issued < cfg.total {
            issue(
                &mut steering, &mut batchers, &mut rng, &mut next_id,
                &mut batches, &mut batch_fill_sum, &mut flushes,
            )?;
            issued += 1;
        }

        while served < cfg.total {
            // Poll deadline flushes.
            for j in 0..cfg.devices {
                if let Some(batch) = batchers[j].poll() {
                    submit_batch(j, batch, &mut batches, &mut batch_fill_sum, &mut flushes)?;
                }
            }
            let wait = batchers
                .iter()
                .filter_map(|b| b.time_to_deadline())
                .min()
                .unwrap_or(Duration::from_millis(50));
            match done_rx.recv_timeout(wait.max(Duration::from_micros(100))) {
                Ok(done) => {
                    match &mut steering {
                        Steering::Single(router) => {
                            router.complete(done.class, done.device)?;
                            if cfg.adaptive {
                                estimator.observe(done.class, done.device, done.service_s);
                            }
                        }
                        // The sharded plane feeds its per-shard
                        // estimators and syncs (gather + batched
                        // re-solve) on its own cadence.
                        Steering::Sharded(ctl) => {
                            if ctl.on_complete(done.class, done.device, done.service_s)? {
                                resolves += 1;
                            }
                        }
                    }
                    let lat = done.arrived.elapsed().as_secs_f64();
                    // Modeled energy: power at the believed rate of the
                    // serving cell × the kernel seconds it actually ran.
                    let rate = match &steering {
                        Steering::Single(router) => {
                            router.mu().rate(done.class, done.device)
                        }
                        Steering::Sharded(ctl) => {
                            ctl.believed().rate(done.class, done.device)
                        }
                    };
                    energy_sum += cfg.power.task_power(rate) * done.service_s;
                    latency_sum += lat;
                    if done.class == 0 {
                        sort_latency.record_s(lat);
                    } else {
                        nn_latency.record_s(lat);
                    }
                    class_served[done.class] += 1;
                    if let Some(&deadline) = cfg.deadlines.get(done.class) {
                        if deadline > 0.0 && lat > deadline {
                            deadline_misses[done.class] += 1;
                        }
                    }
                    served += 1;
                    // Adaptive re-solve (single-leader): when the change
                    // detector fires — polled threshold drift, or a
                    // per-cell CUSUM alarm checked on every completion —
                    // re-run the policy solve against the gated μ̂ and
                    // swap the routing target in place.
                    if cfg.adaptive {
                        if let Steering::Single(router) = &mut steering {
                            let fire = match cfg.trigger {
                                Trigger::Threshold => {
                                    served % cfg.resolve_check == 0
                                        && estimator.drift(router.mu()) > cfg.drift_threshold
                                }
                                Trigger::Cusum => estimator.alarm_pending(),
                            };
                            if fire {
                                if cfg.trigger == Trigger::Cusum {
                                    // Drain now: if the re-solve below
                                    // fails, the detector must
                                    // re-accumulate before re-firing —
                                    // a natural back-off.
                                    estimator.take_alarms();
                                }
                                // Stale cells contribute the believed
                                // rates, not their frozen estimates.
                                let mu_hat = estimator.mu_hat_gated()?;
                                let omega_hat: Vec<f64> =
                                    mu_hat.data().iter().map(|&m| 1.0 / m).collect();
                                // μ̂ may be momentarily unsolvable for the
                                // configured policy (e.g. CAB's Eq.-2 regime
                                // check on a noisy estimate): keep the old
                                // target and retry at the next check.
                                let swapped = if crate::policy::grin::trivial_priorities(
                                    &cfg.priorities,
                                ) {
                                    let update = TargetUpdate::new(mu_hat, omega_hat)
                                        .with_epoch(router.epoch() + 1);
                                    router.apply(&update).is_ok()
                                } else {
                                    // Weights refresh from the live
                                    // confidence grid and swap with the
                                    // target under one epoch.
                                    crate::policy::grin::priority_weights(
                                        &cfg.priorities,
                                        &estimator.confidences(),
                                        mu_hat.procs(),
                                    )
                                    .and_then(|w| {
                                        let update = TargetUpdate::new(mu_hat, omega_hat)
                                            .with_weights(w)
                                            .with_epoch(router.epoch() + 1);
                                        router.apply(&update)
                                    })
                                    .is_ok()
                                };
                                if swapped {
                                    estimator.set_reference(router.mu())?;
                                    resolves += 1;
                                }
                            }
                        }
                    }
                    if issued < cfg.total {
                        issue(
                            &mut steering, &mut batchers, &mut rng, &mut next_id,
                            &mut batches, &mut batch_fill_sum, &mut flushes,
                        )?;
                        issued += 1;
                    } else {
                        // Tail: drain partial batches so stragglers finish.
                        for j in 0..cfg.devices {
                            if let Some(batch) = batchers[j].drain() {
                                submit_batch(
                                    j, batch, &mut batches, &mut batch_fill_sum, &mut flushes,
                                )?;
                            }
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Runtime("all device workers exited".into()));
                }
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();

        drop(work_txs);
        for h in handles {
            h.join().map_err(|_| Error::Runtime("worker panicked".into()))??;
        }

        Ok(ServeReport {
            served,
            elapsed_s: elapsed,
            rps: served as f64 / elapsed,
            sort_latency,
            nn_latency,
            batches,
            batch_fill: if batches > 0 { batch_fill_sum / batches as f64 } else { 0.0 },
            flushes,
            resolves,
            mu_hat: match &steering {
                Steering::Sharded(ctl) => ctl.mu_hat().ok(),
                Steering::Single(_) if cfg.adaptive => estimator.mu_hat().ok(),
                Steering::Single(_) => None,
            },
            class_served,
            deadline_misses,
            mean_energy: if served > 0 { energy_sum / served as f64 } else { 0.0 },
            edp: if served > 0 {
                (energy_sum / served as f64) * (latency_sum / served as f64)
            } else {
                0.0
            },
            // The single leader spends one steering decision per request.
            route_decisions: served,
        })
    }

    /// Spawn one PJRT worker thread per device; returns the completion
    /// stream, the per-device work queues, and the join handles.
    fn spawn_workers(
        devices: usize,
    ) -> Result<(Receiver<Done>, Vec<Sender<Work>>, Vec<JoinHandle<Result<()>>>)> {
        let (done_tx, done_rx): (Sender<Done>, Receiver<Done>) = channel();
        let mut work_txs: Vec<Sender<Work>> = Vec::new();
        let mut handles = Vec::new();
        for d in 0..devices {
            let (tx, rx): (Sender<Work>, Receiver<Work>) = channel();
            work_txs.push(tx);
            let done = done_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-dev{d}"))
                    .spawn(move || -> Result<()> {
                        let engine = Engine::open_default()?;
                        let mut rng = Rng::new(0xD0 + d as u64);
                        let sort_in: Vec<f32> = (0..SORT_ELEMS)
                            .map(|_| rng.range_f64(-1.0, 1.0) as f32)
                            .collect();
                        let mut w = vec![0f32; NN_WIDTH * NN_WIDTH];
                        for i in 0..NN_WIDTH {
                            w[i * NN_WIDTH + i] = 0.5;
                        }
                        let b = vec![0.1f32; NN_WIDTH];
                        while let Ok(work) = rx.recv() {
                            match work {
                                Work::Sort { id, class, arrived } => {
                                    let t0 = wall_now();
                                    engine.sort_task("sort_small", &sort_in)?;
                                    let service_s = t0.elapsed().as_secs_f64();
                                    // srclint: allow(discarded-result) — send fails only if the collector hung up at shutdown; dropping the completion is correct then
                                    let _ = done.send(Done {
                                        id,
                                        class,
                                        device: d,
                                        arrived,
                                        service_s,
                                    });
                                }
                                Work::Nn(batch) => {
                                    let t0 = wall_now();
                                    engine.nn_task("nn_small", &batch.input, &w, &b)?;
                                    let service_s = t0.elapsed().as_secs_f64()
                                        / batch.requests.len().max(1) as f64;
                                    for r in batch.requests {
                                        // srclint: allow(discarded-result) — send fails only if the collector hung up at shutdown; dropping the completion is correct then
                                        let _ = done.send(Done {
                                            id: r.id,
                                            class: 1,
                                            device: d,
                                            arrived: r.arrived,
                                            service_s,
                                        });
                                    }
                                }
                            }
                        }
                        Ok(())
                    })
                    .map_err(|e| Error::Runtime(format!("spawn worker: {e}")))?,
            );
        }
        drop(done_tx);
        Ok((done_rx, work_txs, handles))
    }

    /// The concurrent-front-end serving path (`frontend_threads ≥ 1`):
    /// the same device workers as the single-leader loop, but routing
    /// moves into N front-end threads holding lock-free
    /// [`RouteHandle`]s.  Each thread coalesces same-class requests
    /// into router-level batches (`router_batch`, flushed by
    /// `batch_deadline`) and spends ONE steering decision per batch;
    /// NN rows then fill that thread's per-device kernel batchers at
    /// the chosen device.  The main thread only accounts completions,
    /// feeds the estimator, and lands adaptive re-targets through
    /// [`ConcurrentRouter::install`] — which never blocks routing.
    fn run_frontend(
        cfg: &ServeConfig,
        mu: AffinityMatrix,
        omega: Vec<f64>,
        populations: Vec<u32>,
        mut estimator: RateEstimator,
    ) -> Result<ServeReport> {
        let weights = if crate::policy::grin::trivial_priorities(&cfg.priorities) {
            Vec::new()
        } else {
            crate::policy::grin::priority_weights(
                &cfg.priorities,
                &estimator.confidences(),
                mu.procs(),
            )?
        };
        // The leader keeps the policy: installs re-solve here, off the
        // routing hot path.
        let mut policy = cfg.policy.build();
        let front = Arc::new(ConcurrentRouter::new(
            RouterConfig::new(mu, omega, populations)
                .with_seed(cfg.seed)
                .with_weights(weights)
                .with_objective(cfg.objective, cfg.power),
            policy.as_mut(),
        )?);
        // The μ the energy meter believes; refreshed on every install.
        let mut believed = front.snapshot().solved_mu.clone();

        let (done_rx, work_txs, workers) = Self::spawn_workers(cfg.devices)?;
        let credits = Arc::new(CreditQueue::new());
        let batch_cap = cfg.router_batch.max(1);

        let mut routers = Vec::new();
        for t in 0..cfg.frontend_threads {
            let mut handle = front.handle();
            let credits = Arc::clone(&credits);
            let work_txs = work_txs.clone();
            let devices = cfg.devices;
            let deadline = cfg.batch_deadline;
            let sort_fraction = cfg.sort_fraction;
            let mut rng = Rng::new(cfg.seed ^ (0xF0E0 + t as u64));
            routers.push(
                std::thread::Builder::new()
                    .name(format!("serve-fe{t}"))
                    .spawn(move || -> Result<FrontStats> {
                        // Router-level batchers, one per class.  Sort
                        // rows are 1-wide placeholders (the batch exists
                        // only to share the steering decision); NN rows
                        // are the real activations.
                        let mut class_batchers: Vec<DynamicBatcher> = vec![
                            DynamicBatcher::new(batch_cap, 1, deadline),
                            DynamicBatcher::new(batch_cap, NN_WIDTH, deadline),
                        ];
                        // This thread's per-device NN kernel batchers.
                        let mut nn_batchers: Vec<DynamicBatcher> = (0..devices)
                            .map(|_| DynamicBatcher::new(NN_BATCH, NN_WIDTH, deadline))
                            .collect();
                        let mut stats = FrontStats::default();
                        // Ids are namespaced per thread (tracing only).
                        let mut next_id = (t as u64) << 40;
                        loop {
                            // Deadline flushes: router-level first (they
                            // feed the kernel batchers), then kernels.
                            for class in 0..2 {
                                if let Some(batch) = class_batchers[class].poll() {
                                    dispatch_router_batch(
                                        class, batch, &mut handle, &mut nn_batchers,
                                        &work_txs, &mut stats,
                                    )?;
                                }
                            }
                            for j in 0..devices {
                                if let Some(batch) = nn_batchers[j].poll() {
                                    submit_nn(j, batch, &work_txs, &mut stats)?;
                                }
                            }
                            let wait = class_batchers
                                .iter()
                                .chain(nn_batchers.iter())
                                .filter_map(|b| b.time_to_deadline())
                                .min()
                                .unwrap_or(Duration::from_millis(50));
                            match credits.pop(wait.max(Duration::from_micros(100))) {
                                CreditPop::Credit => {
                                    let class =
                                        usize::from(!rng.bool_with(sort_fraction));
                                    let id = next_id;
                                    next_id += 1;
                                    let row = if class == 0 {
                                        vec![0.0]
                                    } else {
                                        (0..NN_WIDTH)
                                            .map(|_| rng.range_f64(-1.0, 1.0) as f32)
                                            .collect()
                                    };
                                    let p = Pending { id, row, arrived: wall_now() };
                                    if let Some(batch) = class_batchers[class].push(p) {
                                        dispatch_router_batch(
                                            class, batch, &mut handle, &mut nn_batchers,
                                            &work_txs, &mut stats,
                                        )?;
                                    }
                                }
                                CreditPop::Timeout => {}
                                CreditPop::Closed => break,
                            }
                        }
                        // Shutdown: drain partial router batches into the
                        // kernels, then the partial kernels.
                        for class in 0..2 {
                            if let Some(batch) = class_batchers[class].drain() {
                                dispatch_router_batch(
                                    class, batch, &mut handle, &mut nn_batchers,
                                    &work_txs, &mut stats,
                                )?;
                            }
                        }
                        for j in 0..devices {
                            if let Some(batch) = nn_batchers[j].drain() {
                                submit_nn(j, batch, &work_txs, &mut stats)?;
                            }
                        }
                        Ok(stats)
                    })
                    .map_err(|e| Error::Runtime(format!("spawn frontend: {e}")))?,
            );
        }
        // Only the front-end threads submit work.
        drop(work_txs);

        let mut issued = 0u64;
        let mut served = 0u64;
        let mut sort_latency = LatencyHistogram::new();
        let mut nn_latency = LatencyHistogram::new();
        let mut resolves = 0u64;
        let mut class_served = [0u64; 2];
        let mut deadline_misses = [0u64; 2];
        let mut energy_sum = 0f64;
        let mut latency_sum = 0f64;

        let t0 = wall_now();
        // Fill the pipe: one credit per in-flight slot.
        while issued < cfg.inflight as u64 && issued < cfg.total {
            credits.push();
            issued += 1;
        }
        while served < cfg.total {
            match done_rx.recv_timeout(Duration::from_millis(5)) {
                Ok(done) => {
                    front.complete(done.class, done.device)?;
                    if cfg.adaptive {
                        estimator.observe(done.class, done.device, done.service_s);
                    }
                    let lat = done.arrived.elapsed().as_secs_f64();
                    energy_sum +=
                        cfg.power.task_power(believed.rate(done.class, done.device))
                            * done.service_s;
                    latency_sum += lat;
                    if done.class == 0 {
                        sort_latency.record_s(lat);
                    } else {
                        nn_latency.record_s(lat);
                    }
                    class_served[done.class] += 1;
                    if let Some(&deadline) = cfg.deadlines.get(done.class) {
                        if deadline > 0.0 && lat > deadline {
                            deadline_misses[done.class] += 1;
                        }
                    }
                    served += 1;
                    // Adaptive re-solve: same triggers as the single
                    // leader, but the swap is a lock-free install — the
                    // routing threads keep deciding on the old snapshot
                    // while the solve runs, and a failed solve keeps
                    // the old target (natural back-off).
                    if cfg.adaptive {
                        let fire = match cfg.trigger {
                            Trigger::Threshold => {
                                served % cfg.resolve_check == 0
                                    && estimator.drift(&believed) > cfg.drift_threshold
                            }
                            Trigger::Cusum => estimator.alarm_pending(),
                        };
                        if fire {
                            if cfg.trigger == Trigger::Cusum {
                                estimator.take_alarms();
                            }
                            let mu_hat = estimator.mu_hat_gated()?;
                            let omega_hat: Vec<f64> =
                                mu_hat.data().iter().map(|&m| 1.0 / m).collect();
                            let weights_res =
                                if crate::policy::grin::trivial_priorities(&cfg.priorities) {
                                    Ok(Vec::new())
                                } else {
                                    crate::policy::grin::priority_weights(
                                        &cfg.priorities,
                                        &estimator.confidences(),
                                        mu_hat.procs(),
                                    )
                                };
                            let installed = weights_res
                                .and_then(|w| {
                                    let update = TargetUpdate::new(mu_hat, omega_hat)
                                        .with_weights(w)
                                        .with_epoch(front.epoch() + 1);
                                    front.install(policy.as_mut(), &update)
                                })
                                .is_ok();
                            if installed {
                                believed = front.snapshot().solved_mu.clone();
                                estimator.set_reference(&believed)?;
                                resolves += 1;
                            }
                        }
                    }
                    if issued < cfg.total {
                        credits.push();
                        issued += 1;
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Runtime("all device workers exited".into()));
                }
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();

        // Shutdown: retire the front end (its work senders drop), then
        // the workers.
        credits.close();
        let mut batches = 0u64;
        let mut batch_fill_sum = 0f64;
        let mut flushes = [0u64; 3];
        for r in routers {
            let fs = r
                .join()
                .map_err(|_| Error::Runtime("frontend thread panicked".into()))??;
            batches += fs.batches;
            batch_fill_sum += fs.fill_sum;
            for (agg, n) in flushes.iter_mut().zip(fs.flushes) {
                *agg += n;
            }
        }
        for h in workers {
            h.join().map_err(|_| Error::Runtime("worker panicked".into()))??;
        }

        Ok(ServeReport {
            served,
            elapsed_s: elapsed,
            rps: served as f64 / elapsed,
            sort_latency,
            nn_latency,
            batches,
            batch_fill: if batches > 0 { batch_fill_sum / batches as f64 } else { 0.0 },
            flushes,
            resolves,
            mu_hat: if cfg.adaptive { estimator.mu_hat().ok() } else { None },
            class_served,
            deadline_misses,
            mean_energy: if served > 0 { energy_sum / served as f64 } else { 0.0 },
            edp: if served > 0 {
                (energy_sum / served as f64) * (latency_sum / served as f64)
            } else {
                0.0
            },
            route_decisions: front.decisions(),
        })
    }
}

/// Counters a front-end routing thread hands back at shutdown
/// (NN kernel batches it launched).
#[derive(Default)]
struct FrontStats {
    batches: u64,
    fill_sum: f64,
    flushes: [u64; 3],
}

/// Launch one NN kernel batch on device `j`.
fn submit_nn(
    j: usize,
    batch: Batch,
    work_txs: &[Sender<Work>],
    stats: &mut FrontStats,
) -> Result<()> {
    stats.batches += 1;
    stats.fill_sum += batch.requests.len() as f64 / NN_BATCH as f64;
    stats.flushes[match batch.reason {
        FlushReason::Full => 0,
        FlushReason::Deadline => 1,
        FlushReason::Drain => 2,
    }] += 1;
    work_txs[j]
        .send(Work::Nn(batch))
        .map_err(|_| Error::Runtime("device worker gone".into()))
}

/// Spend ONE steering decision on a router-level batch and dispatch
/// its requests to the chosen device: sorts go straight to the worker,
/// NN rows fill this thread's kernel batcher there.
fn dispatch_router_batch(
    class: usize,
    batch: Batch,
    handle: &mut RouteHandle,
    nn_batchers: &mut [DynamicBatcher],
    work_txs: &[Sender<Work>],
    stats: &mut FrontStats,
) -> Result<()> {
    // srclint: allow(as-truncation) — batch sizes are capped by max_batch, far below u32::MAX
    let j = handle.route_batch(class, batch.requests.len() as u32)?;
    if class == 0 {
        for p in batch.requests {
            work_txs[j]
                .send(Work::Sort { id: p.id, class: 0, arrived: p.arrived })
                .map_err(|_| Error::Runtime("device worker gone".into()))?;
        }
    } else {
        for p in batch.requests {
            if let Some(kernel) = nn_batchers[j].push(p) {
                submit_nn(j, kernel, work_txs, stats)?;
            }
        }
    }
    Ok(())
}

/// Closed-loop admission: the main thread deposits one credit per
/// completion (plus the initial in-flight window), front-end threads
/// withdraw one per generated request.  A condvar queue rather than an
/// mpsc channel so N threads can block on it concurrently without
/// serializing behind one receiver.
///
/// Shutdown contract (deadlock freedom, gated by
/// `deadlock-freedom` tests here and the bounded model in
/// `tests/model_check.rs`): [`close`](CreditQueue::close) wakes *all*
/// parked threads; a woken thread always re-reaches a terminal pop
/// outcome because every wait is timed — remaining credits drain even
/// after close, and `Closed` means closed AND empty.
pub struct CreditQueue {
    /// (available credits, closed).
    state: Mutex<(u64, bool)>,
    ready: Condvar,
}

impl Default for CreditQueue {
    fn default() -> Self {
        Self::new()
    }
}

/// Outcome of one [`CreditQueue::pop`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CreditPop {
    /// A credit was withdrawn.
    Credit,
    /// The wait elapsed with no credit and the queue still open.
    Timeout,
    /// Closed and fully drained — the consumer should exit.
    Closed,
}

impl CreditQueue {
    /// An open queue with zero credits.
    pub fn new() -> Self {
        Self { state: Mutex::new((0, false)), ready: Condvar::new() }
    }

    /// Deposit one credit and wake one waiter.
    pub fn push(&self) {
        // srclint: allow(panic-reachable) — lock poisoning means a worker panicked; propagating is the right failure mode
        let mut s = self.state.lock().expect("credit lock poisoned");
        s.0 += 1;
        self.ready.notify_one();
    }

    /// Close the queue and wake every waiter (shutdown path).
    pub fn close(&self) {
        // srclint: allow(panic-reachable) — lock poisoning means a worker panicked; propagating is the right failure mode
        let mut s = self.state.lock().expect("credit lock poisoned");
        s.1 = true;
        self.ready.notify_all();
    }

    /// Withdraw a credit, waiting at most `wait`.  Remaining credits
    /// drain even after close; `Closed` means closed AND empty.
    pub fn pop(&self, wait: Duration) -> CreditPop {
        // srclint: allow(panic-reachable) — lock poisoning means a worker panicked; propagating is the right failure mode
        let mut s = self.state.lock().expect("credit lock poisoned");
        if s.0 > 0 {
            s.0 -= 1;
            return CreditPop::Credit;
        }
        if s.1 {
            return CreditPop::Closed;
        }
        // srclint: allow(panic-reachable) — lock poisoning means a worker panicked; propagating is the right failure mode
        let (mut s, _) = self.ready.wait_timeout(s, wait).expect("credit lock poisoned");
        if s.0 > 0 {
            s.0 -= 1;
            CreditPop::Credit
        } else if s.1 {
            CreditPop::Closed
        } else {
            CreditPop::Timeout
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        let mut cfg = ServeConfig { total: 0, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        cfg.total = 10;
        cfg.devices = 3;
        // An explicit 2×2 μ cannot drive 3 devices.
        cfg.mu = Some(crate::sim::workload::table3::general_symmetric());
        assert!(Coordinator::run(&cfg).is_err());
        // Shard count must be ≥ 1 and cover the devices.
        let cfg = ServeConfig { shards: 0, total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig { shards: 3, devices: 2, total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        // Sharded mode estimates per shard and steers by batched GrIn:
        // the single-leader adaptive flag and any other policy are
        // rejected, not ignored.
        let cfg = ServeConfig {
            shards: 2,
            adaptive: true,
            policy: PolicyKind::GrIn,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg =
            ServeConfig { shards: 2, policy: PolicyKind::Cab, total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        // Priority/deadline validation: arity, zero priorities, and the
        // GrIn-only rule for the single-leader weighted solve.
        let cfg = ServeConfig { priorities: vec![4], total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig {
            priorities: vec![0, 1],
            policy: PolicyKind::GrIn,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig {
            priorities: vec![4, 1],
            policy: PolicyKind::Cab,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig { deadlines: vec![0.5], total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        // Objective rules: weights exclude non-throughput objectives,
        // and objective-blind policies are rejected up front.
        let cfg = ServeConfig {
            priorities: vec![4, 1],
            policy: PolicyKind::GrIn,
            objective: Objective::EnergyPerTask,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig {
            policy: PolicyKind::Cab,
            objective: Objective::Edp,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg =
            ServeConfig { deadlines: vec![-0.5, 0.0], total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        // Front-end rules: no sharding underneath it, router-level
        // batching needs it, and a stateless policy cannot drive its
        // deficit steering (rejected before any worker spawns).
        let cfg = ServeConfig {
            frontend_threads: 2,
            shards: 2,
            policy: PolicyKind::GrIn,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig { router_batch: 8, total: 10, ..Default::default() };
        assert!(Coordinator::run(&cfg).is_err());
        let cfg = ServeConfig {
            frontend_threads: 1,
            policy: PolicyKind::LoadBalance,
            total: 10,
            ..Default::default()
        };
        assert!(Coordinator::run(&cfg).is_err());
    }

    /// Deadlock freedom of the CreditQueue shutdown path: N consumer
    /// threads parked on the condvar (long waits), producer deposits
    /// some credits and closes while they sleep.  Every thread must
    /// come back with `Closed` after draining exactly the deposited
    /// credits — no thread may stay parked (the test would hang and
    /// the harness time out).
    #[test]
    fn credit_queue_shutdown_unparks_all_waiters() {
        let q = Arc::new(CreditQueue::new());
        let consumers = 4;
        let mut threads = Vec::new();
        for _ in 0..consumers {
            let q = Arc::clone(&q);
            threads.push(std::thread::spawn(move || {
                let mut credits = 0u64;
                loop {
                    // A wait far longer than the test: only push/close
                    // wakeups can end it.
                    match q.pop(Duration::from_secs(3600)) {
                        CreditPop::Credit => credits += 1,
                        CreditPop::Timeout => {}
                        CreditPop::Closed => return credits,
                    }
                }
            }));
        }
        // Let the consumers park, then deposit and close while parked.
        std::thread::sleep(Duration::from_millis(20));
        for _ in 0..7 {
            q.push();
        }
        q.close();
        let drained: u64 =
            threads.into_iter().map(|t| t.join().expect("consumer panicked")).sum();
        assert_eq!(drained, 7, "credits deposited before close must drain");
        assert_eq!(q.pop(Duration::ZERO), CreditPop::Closed);
    }

    /// Credits deposited AFTER close still drain (the leader banks the
    /// final completions while front-end threads are shutting down).
    #[test]
    fn credit_queue_drains_after_close() {
        let q = CreditQueue::new();
        q.close();
        q.push();
        q.push();
        assert_eq!(q.pop(Duration::ZERO), CreditPop::Credit);
        assert_eq!(q.pop(Duration::ZERO), CreditPop::Credit);
        assert_eq!(q.pop(Duration::ZERO), CreditPop::Closed);
        assert_eq!(q.pop(Duration::ZERO), CreditPop::Closed, "Closed is terminal");
    }

    /// An open, empty queue times out rather than blocking forever.
    #[test]
    fn credit_queue_times_out_when_open_and_empty() {
        let q = CreditQueue::new();
        assert_eq!(q.pop(Duration::from_millis(1)), CreditPop::Timeout);
    }

    // Full serving runs need artifacts: see `tests/serving_e2e.rs` and
    // `examples/serving_router.rs`.
}
