//! Lock-free serving front end: concurrent routing against an
//! epoch-versioned target snapshot.
//!
//! The single-threaded [`Router`](crate::coordinator::Router) funnels
//! every routing decision through one `&mut self` — fine for one
//! leader thread, a wall for a million-user front end.  The
//! [`ConcurrentRouter`] removes the wall:
//!
//! * **Snapshot reads are wait-free in the steady state.**  The
//!   `(epoch, target, solved_mu, weights)` tuple — the same atomic
//!   install unit as [`crate::coordinator::ShardLeader::install`] and
//!   the same payload as [`TargetUpdate`] — lives in one immutable
//!   [`TargetSnapshot`] behind an `Arc`.  Routing threads keep a
//!   cached `Arc` and compare one atomic epoch load against it per
//!   decision; only when an install actually happened do they take the
//!   snapshot mutex for the pointer clone (the installer holds it only
//!   for the pointer swap).  A torn read — new target with old
//!   weights — is impossible by construction: both live in the same
//!   immutable allocation.
//! * **Occupancy is a grid of atomics.**  Deficit steering
//!   ([`crate::policy::target::TargetSteering`] semantics, same
//!   tie-breaks) runs against per-cell `AtomicI64` counters.  In
//!   **exact** mode every decision validates its chosen cell with a
//!   compare-and-swap; in **reconciled** mode
//!   ([`RouteHandle`] with `reconcile_every > 1`) each thread batches
//!   its own deltas locally and publishes them every N decisions —
//!   relaxed per-decision cost, bounded staleness.
//!
//! Why exact mode replays the single-threaded router bit for bit
//! (route-only): a thread's view of the occupancy row can only
//! *understate* other cells (concurrent routes only increment), while
//! the chosen cell's value is CAS-validated at the linearization
//! point.  Understating a competitor overstates its deficit — so if
//! the chosen cell wins against the inflated competition it also wins
//! against the true row, and both [`pick_by_deficit`] tie-breaks
//! (rate, then index) are interleaving-independent.  Failed CAS means
//! the chosen cell itself moved; the decision retries on fresh state.
//! Completions (decrements) break the monotonicity argument, which is
//! why the equivalence gate in `tests/frontend_concurrency.rs` is
//! route-only and mixed traffic is reconciled-mode territory.

// srclint: allow-file(index-reachable) — per-class tables are sized k and l at router build; class ids are validated at the API edge

use crate::sync::{Arc, AtomicBool, AtomicI64, AtomicU64, Mutex, MutexGuard, Ordering};

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, PowerProfile};
use crate::model::state::StateMatrix;
use crate::policy::target::{pick_by_deficit, pick_by_weighted_deficit, weighted_deficit};
use crate::policy::Policy;

use super::router::{prepare_policy, RouterConfig, TargetUpdate};

/// One immutable epoch of routing truth: everything a decision needs,
/// swapped as a unit.  Readers hold it through an `Arc`, so an install
/// never mutates what a routing thread is looking at.
#[derive(Debug)]
pub struct TargetSnapshot {
    /// Install version (0 = the boot solve).
    pub epoch: u64,
    /// Solved target state S_max the front end steers toward.
    pub target: StateMatrix,
    /// The μ the target was solved for — its rates break steering ties.
    pub solved_mu: AffinityMatrix,
    /// Per-cell steering weights of the solve (row-major k×l; empty =
    /// unweighted).  Travels inside the snapshot so weights can never
    /// be observed with a different epoch's target.
    pub weights: Vec<f64>,
}

/// State shared by the router handle, every routing thread, and the
/// install path.
struct Shared {
    k: usize,
    l: usize,
    /// Last installed epoch; readers poll this (one `Acquire` load per
    /// decision) and refresh their cached snapshot only on change.
    epoch: AtomicU64,
    /// The current snapshot.  The mutex guards only the `Arc` swap /
    /// clone — never a solve, never a decision.
    snapshot: Mutex<Arc<TargetSnapshot>>,
    /// Global occupancy grid, row-major k×l.  Signed: reconciled-mode
    /// completions may transiently land before their route's delta is
    /// published.
    occupancy: Vec<AtomicI64>,
    /// Per-device liveness (see [`ConcurrentRouter::mark_down`]).
    alive: Vec<AtomicBool>,
    /// Total requests routed across all handles.
    routed: AtomicU64,
    /// Total steering decisions (a router-level batch counts once).
    decisions: AtomicU64,
}

impl Shared {
    fn cell(&self, class: usize, device: usize) -> &AtomicI64 {
        &self.occupancy[class * self.l + device]
    }

    /// The snapshot mutex, with poisoning collapsed to a panic in one
    /// place.  Nothing panics while holding this lock (the guarded
    /// section is a pointer clone/swap), so the lock cannot actually be
    /// poisoned; every caller goes through here so the invariant has
    /// exactly one witness.
    fn snapshot_guard(&self) -> MutexGuard<'_, Arc<TargetSnapshot>> {
        // srclint: allow(hot-path-panic) — poisoning is impossible: nothing panics inside the pointer-swap critical section.
        self.snapshot.lock().expect("snapshot lock poisoned")
    }
}

/// The deficit-steering pick against a snapshot row — exactly
/// [`crate::policy::target::TargetSteering::dispatch_among`]: largest
/// (weighted) deficit, ties to the faster (weighted) rate, then the
/// lower index; dead devices are sentinel-masked and an all-dead fleet
/// is `None`.
fn steer(snap: &TargetSnapshot, class: usize, occ: &[i64], alive: &[bool]) -> Option<usize> {
    let l = snap.target.procs();
    let deficit = |j: usize| snap.target.get(class, j) as i64 - occ[j];
    if snap.weights.is_empty() {
        pick_by_deficit((0..l).map(|j| {
            if alive[j] {
                (deficit(j), snap.solved_mu.rate(class, j))
            } else {
                (i64::MIN, f64::NEG_INFINITY)
            }
        }))
    } else {
        pick_by_weighted_deficit((0..l).map(|j| {
            if alive[j] {
                let w = snap.weights[class * l + j];
                (weighted_deficit(w, deficit(j)), w * snap.solved_mu.rate(class, j))
            } else {
                (f64::NEG_INFINITY, f64::NEG_INFINITY)
            }
        }))
    }
    .filter(|&j| alive[j])
}

/// Snapshot weights, with the trivial (absent-or-uniform) case
/// collapsed to "unweighted" — the same reduction GrIn's own steering
/// applies ([`crate::policy::SolveRequest::weights_trivial`]), so the
/// front end and the single-threaded router pick identically under a
/// uniform weight vector.
fn effective_weights(weights: &[f64]) -> Vec<f64> {
    let trivial = weights.is_empty()
        || weights.windows(2).all(|w| (w[0] - w[1]).abs() <= 1e-12);
    if trivial {
        Vec::new()
    } else {
        weights.to_vec()
    }
}

/// Concurrent router: the owner side.  Lives on the leader thread;
/// hands out [`RouteHandle`]s to routing threads, applies
/// [`TargetUpdate`]s, and books completions.
pub struct ConcurrentRouter {
    shared: Arc<Shared>,
    populations: Vec<u32>,
    objective: Objective,
    power: PowerProfile,
}

impl ConcurrentRouter {
    /// Build the front end from one [`RouterConfig`] (the same value
    /// [`Router::build`](crate::coordinator::Router::build) takes): the
    /// policy solves its initial target, which becomes snapshot epoch 0.
    ///
    /// Stateless policies (load balancing, random — anything whose
    /// [`Policy::prepare`] yields no target) are rejected: without a
    /// solved target there is nothing to steer toward lock-free.
    pub fn new(cfg: RouterConfig, policy: &mut dyn Policy) -> Result<Self> {
        let prepared = prepare_policy(
            policy,
            &cfg.mu,
            &cfg.expected_inflight,
            &cfg.weights,
            cfg.objective,
            cfg.power,
        )?;
        let target = prepared.target.ok_or_else(|| {
            Error::Config(format!(
                "policy {} solves no target state; the concurrent front end \
                 steers by target deficit and needs a target-solving policy",
                policy.name()
            ))
        })?;
        let (k, l) = (cfg.mu.types(), cfg.mu.procs());
        if target.types() != k || target.procs() != l {
            return Err(Error::Shape(format!(
                "solved target is {}×{}, config μ is {k}×{l}",
                target.types(),
                target.procs(),
            )));
        }
        let snapshot = TargetSnapshot {
            epoch: 0,
            target,
            solved_mu: cfg.mu,
            weights: effective_weights(&cfg.weights),
        };
        Ok(Self {
            shared: Arc::new(Shared {
                k,
                l,
                epoch: AtomicU64::new(0),
                snapshot: Mutex::new(Arc::new(snapshot)),
                occupancy: (0..k * l).map(|_| AtomicI64::new(0)).collect(),
                alive: (0..l).map(|_| AtomicBool::new(true)).collect(),
                routed: AtomicU64::new(0),
                decisions: AtomicU64::new(0),
            }),
            populations: cfg.expected_inflight,
            objective: cfg.objective,
            power: cfg.power,
        })
    }

    /// Install one [`TargetUpdate`] without blocking routing: the
    /// policy re-solves against the update's μ under its weights (and
    /// the router's objective), and the resulting
    /// `(epoch, target, solved_mu, weights)` snapshot swaps in as a
    /// unit.  Routing threads keep deciding on the old snapshot until
    /// their next epoch check — they never wait on the solve.
    ///
    /// Epochs must strictly increase; a stale or replayed install is a
    /// typed error, so readers can assert monotonicity.  Returns the
    /// installed epoch.
    pub fn install(&self, policy: &mut dyn Policy, update: &TargetUpdate) -> Result<u64> {
        update.validate_shape(self.shared.k, self.shared.l)?;
        let prepared = prepare_policy(
            policy,
            &update.mu,
            &self.populations,
            &update.weights,
            self.objective,
            self.power,
        )?;
        let target = prepared.target.ok_or_else(|| {
            Error::Config(format!("policy {} solves no target state", policy.name()))
        })?;
        if target.types() != self.shared.k || target.procs() != self.shared.l {
            return Err(Error::Shape(format!(
                "solved target is {}×{}, front end runs {}×{}",
                target.types(),
                target.procs(),
                self.shared.k,
                self.shared.l,
            )));
        }
        let snapshot = Arc::new(TargetSnapshot {
            epoch: update.epoch,
            target,
            solved_mu: update.mu.clone(),
            weights: effective_weights(&update.weights),
        });
        let mut slot = self.shared.snapshot_guard();
        if update.epoch <= slot.epoch {
            return Err(Error::Config(format!(
                "target update epoch {} does not advance installed epoch {}",
                update.epoch, slot.epoch
            )));
        }
        *slot = snapshot;
        // Publish while still holding the lock: any reader that
        // observes the new epoch and locks is guaranteed this (or a
        // newer) snapshot.
        // ordering: Release pairs with the Acquire epoch load in
        // RouteHandle::refresh_snapshot / ConcurrentRouter::epoch — a
        // reader that observes the new epoch also observes the swapped
        // snapshot pointer (the store happens-after the swap above).
        self.shared.epoch.store(update.epoch, Ordering::Release);
        Ok(update.epoch)
    }

    /// A routing handle in exact mode: every decision CAS-validates its
    /// cell, replaying the single-threaded router (see module docs).
    pub fn handle(&self) -> RouteHandle {
        self.handle_with_reconcile(1)
    }

    /// A routing handle that publishes its occupancy deltas every
    /// `reconcile_every` decisions (1 = exact).  Decisions between
    /// flushes steer on (last published global state + own local
    /// deltas) — other threads' newest routes are invisible until the
    /// next reconcile, trading strict equivalence for an uncontended
    /// hot path.
    pub fn handle_with_reconcile(&self, reconcile_every: u32) -> RouteHandle {
        let shared = Arc::clone(&self.shared);
        let snap = Arc::clone(&shared.snapshot_guard());
        let cells = shared.k * shared.l;
        let mut handle = RouteHandle {
            snap,
            reconcile_every: reconcile_every.max(1),
            base: vec![0; cells],
            local: vec![0; cells],
            pending: 0,
            routed_pending: 0,
            decisions_pending: 0,
            occ_buf: vec![0; shared.l],
            alive_buf: vec![true; shared.l],
            shared,
        };
        handle.resync_base();
        handle
    }

    /// Completion callback (leader thread): the request routed to
    /// `(class, device)` finished.  Decrements the global cell; in
    /// reconciled mode the decrement may transiently race ahead of the
    /// route's unpublished delta, which is exactly why cells are
    /// signed.
    pub fn complete(&self, class: usize, device: usize) -> Result<()> {
        self.check_cell(class, device)?;
        // ordering: AcqRel — the decrement must be visible to the next
        // Acquire row read / CAS on this cell (Release), and must not
        // move before the completion that caused it (Acquire).
        self.shared.cell(class, device).fetch_sub(1, Ordering::AcqRel);
        Ok(())
    }

    /// Mark `device` down: no further route lands on it (same contract
    /// as [`Router::mark_down`](crate::coordinator::Router::mark_down);
    /// in-flight work keeps draining through
    /// [`complete`](Self::complete)).  Takes effect on a routing
    /// thread's very next decision — liveness is read per pick, not
    /// cached in the snapshot.  Idempotent.
    pub fn mark_down(&self, device: usize) -> Result<()> {
        self.check_device(device)?;
        // ordering: Release pairs with the Acquire liveness read at the
        // top of route_batch — a decision that sees the flag down also
        // sees everything the churn handler did before flipping it.
        self.shared.alive[device].store(false, Ordering::Release);
        Ok(())
    }

    /// Revive `device`.  Idempotent.
    pub fn mark_up(&self, device: usize) -> Result<()> {
        self.check_device(device)?;
        // ordering: Release — same pairing as mark_down.
        self.shared.alive[device].store(true, Ordering::Release);
        Ok(())
    }

    /// Is `device` currently routable?
    pub fn is_alive(&self, device: usize) -> Result<bool> {
        self.check_device(device)?;
        // ordering: Acquire pairs with the Release stores in
        // mark_down / mark_up.
        Ok(self.shared.alive[device].load(Ordering::Acquire))
    }

    /// Last installed epoch.
    pub fn epoch(&self) -> u64 {
        // ordering: Acquire pairs with the Release store in install().
        self.shared.epoch.load(Ordering::Acquire)
    }

    /// Total requests routed across every handle (published ones in
    /// reconciled mode).
    pub fn routed(&self) -> u64 {
        // ordering: Relaxed — pure statistics counter, written with
        // Relaxed fetch_add; no payload is published through it, so an
        // Acquire here would buy nothing (audit PR 9: downgraded).
        self.shared.routed.load(Ordering::Relaxed)
    }

    /// Steering decisions made across every handle (published ones in
    /// reconciled mode).  A router-level batch
    /// ([`RouteHandle::route_batch`]) counts once here while all of its
    /// requests count in [`routed`](Self::routed) — the ratio is the
    /// front end's decision amortization.
    pub fn decisions(&self) -> u64 {
        // ordering: Relaxed — statistics counter, same as routed().
        self.shared.decisions.load(Ordering::Relaxed)
    }

    /// The current snapshot (leader-side introspection).
    pub fn snapshot(&self) -> Arc<TargetSnapshot> {
        Arc::clone(&self.shared.snapshot_guard())
    }

    /// Published global occupancy of `(class, device)`.  Exact once
    /// every handle has flushed; may lag unpublished deltas otherwise.
    pub fn occupancy(&self, class: usize, device: usize) -> Result<i64> {
        self.check_cell(class, device)?;
        // ordering: Acquire pairs with the AcqRel RMWs (route CAS,
        // flush fetch_add, complete fetch_sub) that publish the cell.
        Ok(self.shared.cell(class, device).load(Ordering::Acquire))
    }

    /// Published in-flight total (Σ occupancy).
    pub fn inflight(&self) -> i64 {
        // ordering: Acquire — same pairing as occupancy().
        self.shared.occupancy.iter().map(|c| c.load(Ordering::Acquire)).sum()
    }

    fn check_device(&self, device: usize) -> Result<()> {
        if device >= self.shared.l {
            return Err(Error::Config(format!(
                "unknown device {device} in a {}-device fleet",
                self.shared.l
            )));
        }
        Ok(())
    }

    fn check_cell(&self, class: usize, device: usize) -> Result<()> {
        self.check_device(device)?;
        if class >= self.shared.k {
            return Err(Error::Config(format!(
                "unknown class {class} among {} classes",
                self.shared.k
            )));
        }
        Ok(())
    }
}

/// A per-thread routing handle.  `Send` (move one into each frontend
/// thread); decisions need `&mut self` only for the handle's own
/// scratch and local deltas — nothing a decision touches is shared
/// mutable state under a lock.
pub struct RouteHandle {
    shared: Arc<Shared>,
    /// Cached snapshot; refreshed only when the shared epoch moves.
    snap: Arc<TargetSnapshot>,
    reconcile_every: u32,
    /// Global occupancy as of the last reconcile (reconciled mode).
    base: Vec<i64>,
    /// Own unpublished deltas since the last reconcile.
    local: Vec<i64>,
    /// Decisions since the last reconcile.
    pending: u32,
    /// Requests / decisions not yet published to the shared stats
    /// counters (reconciled mode only; exact mode publishes inline).
    routed_pending: u64,
    decisions_pending: u64,
    /// Scratch: the occupancy row a decision steers on.
    occ_buf: Vec<i64>,
    /// Scratch: liveness observed for this decision.
    alive_buf: Vec<bool>,
}

impl RouteHandle {
    /// Route one request of `class`; returns the chosen device, or
    /// [`Error::NoCapacity`] when every device is down.
    pub fn route(&mut self, class: usize) -> Result<usize> {
        self.route_batch(class, 1)
    }

    /// Route a router-level batch: ONE steering decision covers `count`
    /// coalesced same-class requests, and the chosen cell's occupancy
    /// advances by `count` in the same atomic step — so per-request
    /// completions balance the books exactly.  This is the amortization
    /// `serve --batch N` buys; `count = 1` is the plain route.
    pub fn route_batch(&mut self, class: usize, count: u32) -> Result<usize> {
        if count == 0 {
            return Err(Error::Config("a routed batch needs ≥ 1 request".into()));
        }
        if class >= self.shared.k {
            return Err(Error::Config(format!(
                "unknown class {class} among {} classes",
                self.shared.k
            )));
        }
        self.refresh_snapshot();
        let l = self.shared.l;
        let row = class * l;
        for j in 0..l {
            // ordering: Acquire pairs with the Release liveness stores
            // in mark_down / mark_up.
            self.alive_buf[j] = self.shared.alive[j].load(Ordering::Acquire);
        }
        if self.reconcile_every == 1 {
            // Exact mode: validate the chosen cell with a CAS; retry
            // the whole decision when it moved underneath us.
            loop {
                for j in 0..l {
                    // ordering: Acquire pairs with the AcqRel RMWs that
                    // publish cell updates (CAS / flush / complete).
                    self.occ_buf[j] = self.shared.occupancy[row + j].load(Ordering::Acquire);
                }
                let j = steer(&self.snap, class, &self.occ_buf, &self.alive_buf)
                    .ok_or_else(no_capacity)?;
                let seen = self.occ_buf[j];
                // ordering: AcqRel on success — the linearization point
                // of the decision: Release publishes the increment to
                // later Acquire row reads, Acquire keeps the steering
                // reads above from sinking past it.  Acquire on failure
                // feeds the retry's fresh row read.
                if self.shared.occupancy[row + j]
                    .compare_exchange(
                        seen,
                        seen + count as i64,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    // ordering: Relaxed — statistics counters; readers
                    // use Relaxed loads, no payload rides on them.
                    self.shared.routed.fetch_add(count as u64, Ordering::Relaxed);
                    self.shared.decisions.fetch_add(1, Ordering::Relaxed);
                    return Ok(j);
                }
            }
        } else {
            // Reconciled mode: steer on base + own deltas, publish
            // every `reconcile_every` decisions.
            for j in 0..l {
                self.occ_buf[j] = self.base[row + j] + self.local[row + j];
            }
            let j = steer(&self.snap, class, &self.occ_buf, &self.alive_buf)
                .ok_or_else(no_capacity)?;
            self.local[row + j] += count as i64;
            self.pending += 1;
            // Stats ride the reconcile cadence too: even a relaxed
            // fetch_add per decision is a contended cache line, which is
            // exactly what this mode exists to avoid.
            self.routed_pending += count as u64;
            self.decisions_pending += 1;
            if self.pending >= self.reconcile_every {
                self.flush();
            }
            Ok(j)
        }
    }

    /// Completion callback from this thread: decrement goes straight to
    /// the global grid (completions are off the decision hot path).
    pub fn complete(&self, class: usize, device: usize) -> Result<()> {
        if class >= self.shared.k || device >= self.shared.l {
            return Err(Error::Config(format!(
                "unknown cell ({class}, {device}) in a {}×{} front end",
                self.shared.k, self.shared.l
            )));
        }
        // ordering: AcqRel — same contract as ConcurrentRouter::complete.
        self.shared.cell(class, device).fetch_sub(1, Ordering::AcqRel);
        Ok(())
    }

    /// Publish local deltas into the global grid and re-base on it.
    /// After every handle flushes, the global grid is exact:
    /// Σ cell = routes − completes.
    pub fn flush(&mut self) {
        for (c, d) in self.local.iter_mut().enumerate() {
            if *d != 0 {
                // ordering: AcqRel — publishes this handle's batched
                // deltas to later Acquire row reads on other handles.
                self.shared.occupancy[c].fetch_add(*d, Ordering::AcqRel);
                *d = 0;
            }
        }
        if self.routed_pending != 0 {
            // ordering: Relaxed — statistics counters (see route_batch).
            self.shared.routed.fetch_add(self.routed_pending, Ordering::Relaxed);
            self.routed_pending = 0;
        }
        if self.decisions_pending != 0 {
            // ordering: Relaxed — statistics counters (see route_batch).
            self.shared.decisions.fetch_add(self.decisions_pending, Ordering::Relaxed);
            self.decisions_pending = 0;
        }
        self.pending = 0;
        self.resync_base();
    }

    /// Epoch of the snapshot this handle last decided on — the value
    /// the monotonicity property test watches.
    pub fn epoch(&self) -> u64 {
        self.snap.epoch
    }

    /// The snapshot this handle currently steers by.
    pub fn snapshot(&self) -> &TargetSnapshot {
        &self.snap
    }

    fn refresh_snapshot(&mut self) {
        // ordering: Acquire pairs with the Release store in install();
        // seeing a changed epoch guarantees the locked clone below
        // yields that epoch's (or a newer) snapshot — never a stale one.
        if self.shared.epoch.load(Ordering::Acquire) != self.snap.epoch {
            self.snap = Arc::clone(&self.shared.snapshot_guard());
        }
    }

    fn resync_base(&mut self) {
        for (c, b) in self.base.iter_mut().enumerate() {
            // ordering: Acquire — re-base on fully published cells
            // (pairs with the AcqRel RMWs on the grid).
            *b = self.shared.occupancy[c].load(Ordering::Acquire);
        }
    }
}

fn no_capacity() -> Error {
    Error::NoCapacity("every serving device is down".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Router;
    use crate::policy::PolicyKind;
    use crate::sim::workload;

    fn config() -> RouterConfig {
        let mu = workload::table3::p2_biased();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        RouterConfig::new(mu, omega, vec![10, 10]).with_seed(7)
    }

    #[test]
    fn rejects_stateless_policies() {
        let mut policy = PolicyKind::LoadBalance.build();
        match ConcurrentRouter::new(config(), policy.as_mut()) {
            Err(Error::Config(msg)) => assert!(msg.contains("no target"), "{msg}"),
            other => panic!("expected Config rejection, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn exact_mode_replays_single_threaded_router() {
        // One handle, one thread: the concurrent path must place a
        // seeded request sequence exactly like the Router steering the
        // same target (both are TargetSteering semantics).
        let mut policy = PolicyKind::Cab.build();
        let front = ConcurrentRouter::new(config(), policy.as_mut()).unwrap();
        let mut handle = front.handle();
        let mut router = Router::build(config(), PolicyKind::Cab.build()).unwrap();
        let mut rng = crate::sim::rng::Rng::new(11);
        for _ in 0..40 {
            let class = rng.index(2);
            assert_eq!(handle.route(class).unwrap(), router.route(class).unwrap());
        }
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(
                    front.occupancy(i, j).unwrap(),
                    router.state().get(i, j) as i64
                );
            }
        }
        assert_eq!(front.routed(), router.routed());
        assert_eq!(front.inflight(), router.inflight() as i64);
    }

    #[test]
    fn install_swaps_target_and_enforces_monotone_epochs() {
        let mut policy = PolicyKind::Cab.build();
        let front = ConcurrentRouter::new(config(), policy.as_mut()).unwrap();
        let mut handle = front.handle();
        // Boot target (P2-biased AF): class-0 goes to the CPU.
        assert_eq!(handle.route(0).unwrap(), 0);
        assert_eq!(handle.epoch(), 0);
        let mu2 = workload::table3::general_symmetric();
        let omega2: Vec<f64> = mu2.data().iter().map(|&m| 1.0 / m).collect();
        let update = TargetUpdate::new(mu2.clone(), omega2.clone()).with_epoch(1);
        assert_eq!(front.install(policy.as_mut(), &update).unwrap(), 1);
        assert_eq!(front.epoch(), 1);
        // The handle picks the new epoch up on its next decision; the
        // BF target sends class-1 deficit to the GPU.
        assert_eq!(handle.route(1).unwrap(), 1);
        assert_eq!(handle.epoch(), 1);
        // Replayed and stale epochs are rejected.
        let replay = TargetUpdate::new(mu2.clone(), omega2.clone()).with_epoch(1);
        assert!(front.install(policy.as_mut(), &replay).is_err());
        let stale = TargetUpdate::new(mu2, omega2).with_epoch(0);
        assert!(front.install(policy.as_mut(), &stale).is_err());
        // Shape mismatches are rejected before any solve.
        let bad = crate::model::affinity::AffinityMatrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![3.0, 2.0, 1.0],
        ])
        .unwrap();
        let upd = TargetUpdate::new(bad, vec![1.0; 6]).with_epoch(2);
        assert!(front.install(policy.as_mut(), &upd).is_err());
    }

    #[test]
    fn failed_install_keeps_old_snapshot() {
        let mut policy = PolicyKind::Cab.build();
        let front = ConcurrentRouter::new(config(), policy.as_mut()).unwrap();
        let before = front.snapshot();
        let mu2 = workload::table3::general_symmetric();
        let omega2: Vec<f64> = mu2.data().iter().map(|&m| 1.0 / m).collect();
        let stale = TargetUpdate::new(mu2, omega2).with_epoch(0);
        assert!(front.install(policy.as_mut(), &stale).is_err());
        let after = front.snapshot();
        assert!(Arc::ptr_eq(&before, &after), "failed install must not swap");
    }

    #[test]
    fn down_devices_are_masked_and_all_down_is_no_capacity() {
        let mut policy = PolicyKind::Cab.build();
        let front = ConcurrentRouter::new(config(), policy.as_mut()).unwrap();
        let mut handle = front.handle();
        front.mark_down(0).unwrap();
        assert!(!front.is_alive(0).unwrap());
        for _ in 0..5 {
            assert_eq!(handle.route(0).unwrap(), 1, "routed to a dead device");
        }
        front.mark_down(1).unwrap();
        match handle.route(0) {
            Err(Error::NoCapacity(_)) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        // Drain + revive restores steering.
        front.complete(0, 1).unwrap();
        front.mark_up(0).unwrap();
        assert_eq!(handle.route(0).unwrap(), 0);
        // Out-of-range devices/classes are typed errors.
        assert!(front.mark_down(9).is_err());
        assert!(front.occupancy(5, 0).is_err());
        assert!(handle.route(7).is_err());
        assert!(handle.complete(0, 9).is_err());
    }

    #[test]
    fn batched_route_advances_occupancy_by_count() {
        let mut policy = PolicyKind::Cab.build();
        let front = ConcurrentRouter::new(config(), policy.as_mut()).unwrap();
        let mut handle = front.handle();
        let j = handle.route_batch(0, 5).unwrap();
        assert_eq!(front.occupancy(0, j).unwrap(), 5);
        assert_eq!(front.routed(), 5);
        assert_eq!(front.decisions(), 1, "one decision covered the batch");
        for _ in 0..5 {
            front.complete(0, j).unwrap();
        }
        assert_eq!(front.inflight(), 0);
        assert!(handle.route_batch(0, 0).is_err(), "empty batches are rejected");
    }

    #[test]
    fn reconciled_mode_conserves_counts_after_flush() {
        let mut policy = PolicyKind::Cab.build();
        let front = ConcurrentRouter::new(config(), policy.as_mut()).unwrap();
        let mut handle = front.handle_with_reconcile(8);
        let mut routes = Vec::new();
        for i in 0..11 {
            routes.push((i % 2, handle.route(i % 2).unwrap()));
        }
        // 11 decisions at reconcile_every = 8: one auto-flush happened,
        // 3 deltas are still local.
        let published: i64 = (0..2)
            .flat_map(|i| (0..2).map(move |j| (i, j)))
            .map(|(i, j)| front.occupancy(i, j).unwrap())
            .sum();
        assert_eq!(published, 8);
        handle.flush();
        // Exact after flush: Σ occupancy == routes − completes.
        assert_eq!(front.inflight(), 11);
        for &(class, device) in routes.iter().take(4) {
            front.complete(class, device).unwrap();
        }
        assert_eq!(front.inflight(), 7);
        assert_eq!(front.routed(), 11);
    }
}
