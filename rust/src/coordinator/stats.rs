//! Serving statistics: latency histogram + streaming service-rate
//! estimation for the adaptive leader.
//!
//! * [`LatencyHistogram`] — log-spaced buckets from 1µs to ~67s give <5%
//!   quantile error across the whole range, the standard
//!   serving-telemetry trade-off.
//! * [`RateEstimator`] — per-(class, device) service-time tracking:
//!   an EWMA for fast reaction plus a bounded sliding window for a
//!   noise-robust level estimate.  `mu_hat()` turns the estimates into a
//!   live affinity matrix μ̂ = 1/ω̂ that the leader re-solves GrIn
//!   against; `drift()` quantifies how far μ̂ has moved from the matrix
//!   the current routing target was solved for (non-stationary
//!   workloads: phase shifts, bursts, thermal throttling).

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;

/// Bounded sliding window of the most recent samples (ring buffer).
#[derive(Debug, Clone)]
struct Window {
    buf: Vec<f64>,
    head: usize,
    filled: usize,
}

impl Window {
    fn new(capacity: usize) -> Self {
        Self { buf: vec![0.0; capacity.max(1)], head: 0, filled: 0 }
    }

    fn push(&mut self, v: f64) {
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.buf.len();
        self.filled = (self.filled + 1).min(self.buf.len());
    }

    fn mean(&self) -> Option<f64> {
        if self.filled == 0 {
            return None;
        }
        Some(self.buf[..self.filled].iter().sum::<f64>() / self.filled as f64)
    }
}

/// Streaming per-(class, device) service-rate estimator.
#[derive(Debug, Clone)]
pub struct RateEstimator {
    k: usize,
    l: usize,
    alpha: f64,
    min_obs: u64,
    /// Prior mean service time per cell (1/μ_prior), used until a cell
    /// has seen `min_obs` samples.
    prior_omega: Vec<f64>,
    /// EWMA of observed service seconds per cell.
    ewma: Vec<f64>,
    /// Sliding window per cell.
    windows: Vec<Window>,
    counts: Vec<u64>,
}

impl RateEstimator {
    /// Estimator seeded from the prior affinity matrix (the rates the
    /// scheduler believes before any observation).
    pub fn new(prior: &AffinityMatrix, alpha: f64, window: usize, min_obs: u64) -> Result<Self> {
        if !(0.0..=1.0).contains(&alpha) || alpha == 0.0 {
            return Err(Error::Config(format!("EWMA alpha {alpha} outside (0, 1]")));
        }
        let (k, l) = (prior.types(), prior.procs());
        let prior_omega: Vec<f64> = prior.data().iter().map(|&m| 1.0 / m).collect();
        Ok(Self {
            k,
            l,
            alpha,
            min_obs: min_obs.max(1),
            ewma: prior_omega.clone(),
            prior_omega,
            windows: (0..k * l).map(|_| Window::new(window)).collect(),
            counts: vec![0; k * l],
        })
    }

    /// Record one observed service time (seconds of pure execution, not
    /// queueing) for a `class` task on `device`.
    pub fn observe(&mut self, class: usize, device: usize, service_s: f64) {
        if !(service_s.is_finite() && service_s > 0.0) {
            return; // ignore clock glitches rather than poisoning μ̂
        }
        let c = class * self.l + device;
        self.ewma[c] = (1.0 - self.alpha) * self.ewma[c] + self.alpha * service_s;
        self.windows[c].push(service_s);
        self.counts[c] += 1;
    }

    /// Total observations across all cells.
    pub fn observations(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Observations for one cell.
    pub fn count(&self, class: usize, device: usize) -> u64 {
        self.counts[class * self.l + device]
    }

    /// Has this cell seen at least `min_obs` samples — i.e. is its
    /// estimate trusted enough to contribute to [`drift`](Self::drift)?
    /// Cold cells (shorter windows) never signal drift, which is what
    /// lets sharded leaders boot cold without thrashing the global
    /// re-solve loop.
    pub fn is_warm(&self, class: usize, device: usize) -> bool {
        self.counts[class * self.l + device] >= self.min_obs
    }

    /// Number of cells with at least `min_obs` observations.
    pub fn warm_cells(&self) -> usize {
        self.counts.iter().filter(|&&c| c >= self.min_obs).count()
    }

    /// Current service-time estimate ω̂ for a cell: the window mean once
    /// the cell has `min_obs` samples (EWMA before that), prior when the
    /// cell has never been observed.
    pub fn omega_hat(&self, class: usize, device: usize) -> f64 {
        let c = class * self.l + device;
        if self.counts[c] == 0 {
            return self.prior_omega[c];
        }
        if self.counts[c] >= self.min_obs {
            if let Some(m) = self.windows[c].mean() {
                return m;
            }
        }
        self.ewma[c]
    }

    /// Current rate estimate μ̂ = 1/ω̂ for a cell.
    pub fn rate_hat(&self, class: usize, device: usize) -> f64 {
        1.0 / self.omega_hat(class, device)
    }

    /// The live affinity matrix μ̂.
    pub fn mu_hat(&self) -> Result<AffinityMatrix> {
        let rows: Vec<Vec<f64>> = (0..self.k)
            .map(|i| (0..self.l).map(|j| self.rate_hat(i, j)).collect())
            .collect();
        AffinityMatrix::from_rows(&rows)
    }

    /// Maximum relative rate deviation of μ̂ from `reference`, over the
    /// cells with at least `min_obs` observations (unobserved cells
    /// cannot signal drift).
    pub fn drift(&self, reference: &AffinityMatrix) -> f64 {
        debug_assert_eq!(reference.types(), self.k);
        debug_assert_eq!(reference.procs(), self.l);
        let mut worst = 0.0f64;
        for i in 0..self.k {
            for j in 0..self.l {
                if self.count(i, j) < self.min_obs {
                    continue;
                }
                let rf = reference.rate(i, j);
                worst = worst.max((self.rate_hat(i, j) - rf).abs() / rf);
            }
        }
        worst
    }
}

/// Log-bucketed latency histogram (microsecond resolution floor).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket counts; bucket b covers [2^b, 2^(b+1)) µs.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u128,
    max_us: u64,
}

const NBUCKETS: usize = 27; // 2^26 µs ≈ 67 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self { buckets: vec![0; NBUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency in seconds.
    pub fn record_s(&mut self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(NBUCKETS - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds.
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1e6
    }

    /// Approximate quantile (bucket upper edge), seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (1u64 << (b + 1)) as f64 / 1e6;
            }
        }
        self.max_us as f64 / 1e6
    }

    /// Merge another histogram in.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_s(i as f64 / 1000.0); // 1ms .. 1s
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        // Bucket edges are powers of two: p50 of U(1ms,1s) ≈ 0.5s → edge 0.524s.
        assert!(p50 >= 0.25 && p50 <= 1.1, "p50 {p50}");
        let p99 = h.quantile_s(0.99);
        assert!(p99 >= p50);
        assert!((h.mean_s() - 0.5).abs() < 0.02);
    }

    #[test]
    fn merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_s(0.001);
        b.record_s(1.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.quantile_s(1.0) >= 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.quantile_s(0.99), 0.0);
    }

    #[test]
    fn estimator_starts_at_prior_and_converges_to_observations() {
        let prior = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let mut e = RateEstimator::new(&prior, 0.2, 16, 4).unwrap();
        // No observations: μ̂ = prior exactly.
        assert!((e.rate_hat(0, 0) - 20.0).abs() < 1e-12);
        assert!((e.mu_hat().unwrap().rate(1, 1) - 8.0).abs() < 1e-12);
        assert_eq!(e.drift(&prior), 0.0);
        // Feed a 4× slower reality on cell (0, 0): ω = 1/5 s.
        for _ in 0..64 {
            e.observe(0, 0, 0.2);
        }
        let r = e.rate_hat(0, 0);
        assert!((r - 5.0).abs() < 0.2, "μ̂(0,0) = {r}");
        // Unobserved cells stay at the prior.
        assert!((e.rate_hat(0, 1) - 15.0).abs() < 1e-12);
        // Drift vs the prior reflects the (0, 0) slowdown only.
        let d = e.drift(&prior);
        assert!(d > 0.7 && d < 0.8, "drift = {d}");
        assert_eq!(e.observations(), 64);
        assert_eq!(e.count(0, 0), 64);
    }

    #[test]
    fn estimator_window_dominates_after_min_obs() {
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let mut e = RateEstimator::new(&prior, 0.01, 8, 8).unwrap();
        // Slow EWMA (α = 0.01) but a window of 8 with min_obs 8: after a
        // level shift, the window-mean estimate tracks the new level even
        // though the EWMA lags.
        for _ in 0..8 {
            e.observe(1, 1, 0.5);
        }
        assert!((e.omega_hat(1, 1) - 0.5).abs() < 1e-12);
        // Non-finite and non-positive samples are ignored.
        e.observe(1, 1, f64::NAN);
        e.observe(1, 1, -1.0);
        assert_eq!(e.count(1, 1), 8);
    }

    #[test]
    fn cold_start_windows_never_report_drift() {
        // Guard for the sharded leaders, which each boot with empty
        // windows: while a cell's window is shorter than the trust span
        // (min_obs) it must not report drift, no matter how far the few
        // early samples sit from the prior.
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let mut e = RateEstimator::new(&prior, 0.3, 32, 8).unwrap();
        assert_eq!(e.warm_cells(), 0);
        assert_eq!(e.drift(&prior), 0.0);
        // 7 samples at 10× the prior's service time: still cold.
        for _ in 0..7 {
            e.observe(0, 0, 1.0);
        }
        assert!(!e.is_warm(0, 0));
        assert_eq!(e.drift(&prior), 0.0, "sub-min_obs window signalled drift");
        // The 8th sample warms the cell; the same deviation now counts.
        e.observe(0, 0, 1.0);
        assert!(e.is_warm(0, 0));
        assert_eq!(e.warm_cells(), 1);
        assert!(e.drift(&prior) > 0.5);
        // Other cells remain cold and keep not contributing.
        assert!(!e.is_warm(1, 1));
    }

    #[test]
    fn estimator_rejects_bad_alpha() {
        let prior = AffinityMatrix::two_type(1.0, 1.0, 1.0, 1.0).unwrap();
        assert!(RateEstimator::new(&prior, 0.0, 8, 1).is_err());
        assert!(RateEstimator::new(&prior, 1.5, 8, 1).is_err());
    }
}
