//! Serving statistics: lock-free-ish latency histogram + counters.
//!
//! Log-spaced buckets from 1µs to ~67s give <5% quantile error across the
//! whole range — the standard serving-telemetry trade-off.

/// Log-bucketed latency histogram (microsecond resolution floor).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket counts; bucket b covers [2^b, 2^(b+1)) µs.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u128,
    max_us: u64,
}

const NBUCKETS: usize = 27; // 2^26 µs ≈ 67 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self { buckets: vec![0; NBUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency in seconds.
    pub fn record_s(&mut self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(NBUCKETS - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds.
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1e6
    }

    /// Approximate quantile (bucket upper edge), seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (1u64 << (b + 1)) as f64 / 1e6;
            }
        }
        self.max_us as f64 / 1e6
    }

    /// Merge another histogram in.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_s(i as f64 / 1000.0); // 1ms .. 1s
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        // Bucket edges are powers of two: p50 of U(1ms,1s) ≈ 0.5s → edge 0.524s.
        assert!(p50 >= 0.25 && p50 <= 1.1, "p50 {p50}");
        let p99 = h.quantile_s(0.99);
        assert!(p99 >= p50);
        assert!((h.mean_s() - 0.5).abs() < 0.02);
    }

    #[test]
    fn merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_s(0.001);
        b.record_s(1.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.quantile_s(1.0) >= 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.quantile_s(0.99), 0.0);
    }
}
