//! Serving statistics: latency histogram + streaming service-rate
//! estimation for the adaptive leader.
//!
//! * [`LatencyHistogram`] — log-spaced buckets from 1µs to ~67s give <5%
//!   quantile error across the whole range, the standard
//!   serving-telemetry trade-off.
//! * [`RateEstimator`] — per-(class, device) service-time tracking:
//!   an EWMA for fast reaction plus a bounded sliding window for a
//!   noise-robust level estimate.  `mu_hat()` turns the estimates into a
//!   live affinity matrix μ̂ = 1/ω̂ that the leader re-solves GrIn
//!   against; `drift()` quantifies how far μ̂ has moved from the matrix
//!   the current routing target was solved for (non-stationary
//!   workloads: phase shifts, bursts, thermal throttling).
//!
//! Change-point awareness (the PR-4 subsystem):
//!
//! * **Per-cell two-sided CUSUM** over service-time residuals against
//!   the reference rates the current target was solved for
//!   ([`RateEstimator::set_reference`]).  Residuals are accumulated per
//!   mini-batch of `min_obs` samples (batch means tame the exponential
//!   service-time noise that makes raw-sample CUSUM false-alarm), each
//!   side discounts a drift allowance δ per batch, and a cell alarms
//!   when either side crosses the threshold h — then auto-resets so a
//!   single regime flip raises one alarm, not a storm.
//! * **Per-cell confidence** ([`RateEstimator::confidence`]): how much
//!   to trust a cell's estimate right now — observation count (up to the
//!   `min_obs` trust span) × recency decay (half-life `stale_after`
//!   estimator-wide completions).  A warm cell that stops being
//!   exercised is *demoted* after `stale_after` completions without a
//!   sample: it no longer signals drift ([`RateEstimator::is_warm`],
//!   [`RateEstimator::stale_cells`]) and the gated accessors
//!   ([`RateEstimator::mu_hat_gated`]) substitute the reference rate for
//!   its frozen pre-flip estimate.

// srclint: allow-file(index-reachable) — histogram buckets and cell grids have fixed dimensions; bucket math clamps to range

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::sim::dynamic::DriftConfig;

/// Bounded sliding window of the most recent samples (ring buffer).
#[derive(Debug, Clone)]
struct Window {
    buf: Vec<f64>,
    head: usize,
    filled: usize,
}

impl Window {
    fn new(capacity: usize) -> Self {
        Self { buf: vec![0.0; capacity.max(1)], head: 0, filled: 0 }
    }

    fn push(&mut self, v: f64) {
        self.buf[self.head] = v;
        self.head = (self.head + 1) % self.buf.len();
        self.filled = (self.filled + 1).min(self.buf.len());
    }

    fn mean(&self) -> Option<f64> {
        if self.filled == 0 {
            return None;
        }
        Some(self.buf[..self.filled].iter().sum::<f64>() / self.filled as f64)
    }
}

/// Streaming per-(class, device) service-rate estimator with change-point
/// detection (per-cell two-sided CUSUM) and per-cell confidence.
#[derive(Debug, Clone)]
pub struct RateEstimator {
    k: usize,
    l: usize,
    alpha: f64,
    min_obs: u64,
    /// Prior mean service time per cell (1/μ_prior), used until a cell
    /// has seen `min_obs` samples.
    prior_omega: Vec<f64>,
    /// EWMA of observed service seconds per cell.
    ewma: Vec<f64>,
    /// Sliding window per cell.
    windows: Vec<Window>,
    counts: Vec<u64>,
    /// Reference mean service times the CUSUM residuals are computed
    /// against — the rates the current routing target was solved for.
    /// Starts at the prior; updated via [`set_reference`](Self::set_reference).
    ref_omega: Vec<f64>,
    /// CUSUM slow-down side (service times running above reference).
    g_plus: Vec<f64>,
    /// CUSUM speed-up side (service times running below reference).
    g_minus: Vec<f64>,
    /// Partial mini-batch accumulator per cell (sum of relative residuals).
    batch_sum: Vec<f64>,
    /// Samples in the current mini-batch per cell.
    batch_n: Vec<u64>,
    /// CUSUM drift allowance δ per batch (relative residual units).
    cusum_delta: f64,
    /// CUSUM alarm threshold h.
    cusum_h: f64,
    /// Cells whose CUSUM crossed h since the last reference swap/drain.
    alarmed: Vec<bool>,
    alarm_pending: bool,
    /// Total observations ever recorded (the staleness clock).
    tick: u64,
    /// Clock value of each cell's most recent sample.
    last_obs: Vec<u64>,
    /// Estimator-wide completions without a fresh sample before a warm
    /// cell demotes to stale; 0 disables demotion.
    stale_after: u64,
    /// Devices explicitly marked down ([`mark_down`](Self::mark_down)).
    /// Their cells are *frozen*, not stale: a dead device produces no
    /// samples by definition, so letting the staleness clock run would
    /// decay perfectly good estimates and let half-built mini-batches
    /// re-alarm on zero evidence.
    down: Vec<bool>,
    /// Staleness-clock value captured when each device went down; the
    /// effective clock for a down device's cells.
    down_tick: Vec<u64>,
}

impl RateEstimator {
    /// Estimator seeded from the prior affinity matrix (the rates the
    /// scheduler believes before any observation), with the default
    /// change-detector knobs ([`DriftConfig::default`]).
    pub fn new(prior: &AffinityMatrix, alpha: f64, window: usize, min_obs: u64) -> Result<Self> {
        let d = DriftConfig::default();
        Self::build(prior, alpha, window, min_obs, d.cusum_delta, d.cusum_h, d.stale_after)
    }

    /// Estimator configured from a [`DriftConfig`] (the adaptive/sharded
    /// construction path — one knob set shared by simulator and server).
    pub fn from_drift(prior: &AffinityMatrix, drift: &DriftConfig) -> Result<Self> {
        Self::build(
            prior,
            drift.ewma_alpha,
            drift.window,
            drift.min_obs,
            drift.cusum_delta,
            drift.cusum_h,
            drift.stale_after,
        )
    }

    fn build(
        prior: &AffinityMatrix,
        alpha: f64,
        window: usize,
        min_obs: u64,
        cusum_delta: f64,
        cusum_h: f64,
        stale_after: u64,
    ) -> Result<Self> {
        if !(0.0..=1.0).contains(&alpha) || alpha == 0.0 {
            return Err(Error::Config(format!("EWMA alpha {alpha} outside (0, 1]")));
        }
        if !(cusum_delta.is_finite() && cusum_delta >= 0.0) {
            return Err(Error::Config(format!("CUSUM delta {cusum_delta} must be ≥ 0")));
        }
        if !(cusum_h.is_finite() && cusum_h > 0.0) {
            return Err(Error::Config(format!("CUSUM h {cusum_h} must be > 0")));
        }
        let (k, l) = (prior.types(), prior.procs());
        let prior_omega: Vec<f64> = prior.data().iter().map(|&m| 1.0 / m).collect();
        Ok(Self {
            k,
            l,
            alpha,
            min_obs: min_obs.max(1),
            ewma: prior_omega.clone(),
            ref_omega: prior_omega.clone(),
            prior_omega,
            windows: (0..k * l).map(|_| Window::new(window)).collect(),
            counts: vec![0; k * l],
            g_plus: vec![0.0; k * l],
            g_minus: vec![0.0; k * l],
            batch_sum: vec![0.0; k * l],
            batch_n: vec![0; k * l],
            cusum_delta,
            cusum_h,
            alarmed: vec![false; k * l],
            alarm_pending: false,
            tick: 0,
            last_obs: vec![0; k * l],
            stale_after,
            down: vec![false; l],
            down_tick: vec![0; l],
        })
    }

    /// Staleness clock a cell experiences: global for a live device,
    /// frozen at the failure instant for a down one.
    fn eff_tick(&self, c: usize) -> u64 {
        let dev = c % self.l;
        if self.down[dev] {
            self.down_tick[dev]
        } else {
            self.tick
        }
    }

    /// Clear one device's per-cell CUSUM state: accumulated evidence and
    /// half-built mini-batches describe the *previous* availability
    /// regime and must not alarm across a down/up transition.
    fn reset_cusum_column(&mut self, device: usize) {
        for class in 0..self.k {
            let c = class * self.l + device;
            self.g_plus[c] = 0.0;
            self.g_minus[c] = 0.0;
            self.batch_sum[c] = 0.0;
            self.batch_n[c] = 0;
            self.alarmed[c] = false;
        }
        self.alarm_pending = self.alarmed.iter().any(|&a| a);
    }

    /// Mark a device down: its cells freeze (no staleness decay, no
    /// drift signal, samples ignored) and its CUSUM column resets so a
    /// half-built batch cannot re-alarm on zero evidence.
    pub fn mark_down(&mut self, device: usize) {
        if self.down[device] {
            return;
        }
        self.down[device] = true;
        self.down_tick[device] = self.tick;
        self.reset_cusum_column(device);
    }

    /// Mark a device up again: cells unfreeze with their pre-failure
    /// estimates treated as fresh (the rejoining device must earn a new
    /// CUSUM excursion before it can alarm — recovery is a regime
    /// change, not evidence of drift).
    pub fn mark_up(&mut self, device: usize) {
        if !self.down[device] {
            return;
        }
        self.down[device] = false;
        self.reset_cusum_column(device);
        for class in 0..self.k {
            let c = class * self.l + device;
            if self.counts[c] > 0 {
                self.last_obs[c] = self.tick;
            }
        }
    }

    /// Is this device currently marked down?
    pub fn is_down(&self, device: usize) -> bool {
        self.down[device]
    }

    /// Record one observed service time (seconds of pure execution, not
    /// queueing) for a `class` task on `device`.
    pub fn observe(&mut self, class: usize, device: usize, service_s: f64) {
        if !(service_s.is_finite() && service_s > 0.0) {
            return; // ignore clock glitches rather than poisoning μ̂
        }
        if self.down[device] {
            // A straggler completion racing the down-mark: a dead
            // device has no service rate to estimate.
            return;
        }
        let c = class * self.l + device;
        self.ewma[c] = (1.0 - self.alpha) * self.ewma[c] + self.alpha * service_s;
        self.windows[c].push(service_s);
        self.counts[c] += 1;
        self.tick += 1;
        self.last_obs[c] = self.tick;
        // CUSUM over mini-batch means of the relative residual
        // (s − ω_ref)/ω_ref.  The batch span is min_obs — the same trust
        // span that gates cold cells — which tames exponential
        // service-time noise (batch-mean sd ≈ 1/√min_obs relative)
        // without blunting detection of real level shifts.
        self.batch_sum[c] += (service_s - self.ref_omega[c]) / self.ref_omega[c];
        self.batch_n[c] += 1;
        if self.batch_n[c] >= self.min_obs {
            let r = self.batch_sum[c] / self.batch_n[c] as f64;
            self.batch_sum[c] = 0.0;
            self.batch_n[c] = 0;
            self.g_plus[c] = (self.g_plus[c] + r - self.cusum_delta).max(0.0);
            self.g_minus[c] = (self.g_minus[c] - r - self.cusum_delta).max(0.0);
            if self.g_plus[c] > self.cusum_h || self.g_minus[c] > self.cusum_h {
                // Auto-reset on alarm: one regime flip raises one alarm,
                // and the restarted accumulation measures the *new* level
                // against whatever reference the re-solve installs.
                self.g_plus[c] = 0.0;
                self.g_minus[c] = 0.0;
                self.alarmed[c] = true;
                self.alarm_pending = true;
            }
        }
    }

    /// Total observations across all cells.
    pub fn observations(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Observations for one cell.
    pub fn count(&self, class: usize, device: usize) -> u64 {
        self.counts[class * self.l + device]
    }

    /// Is this cell's estimate trusted enough to contribute to
    /// [`drift`](Self::drift)?  Two conditions: at least `min_obs`
    /// samples (cold cells — shorter windows — never signal drift,
    /// which is what lets sharded leaders boot cold without thrashing
    /// the global re-solve loop) *and* a sample fewer than `stale_after`
    /// estimator-wide completions ago (a cell the routing flip
    /// abandoned must not keep steering on its frozen pre-flip data).
    pub fn is_warm(&self, class: usize, device: usize) -> bool {
        let c = class * self.l + device;
        !self.down[device] && self.counts[c] >= self.min_obs && !self.cell_is_stale(c)
    }

    /// Number of warm cells ([`is_warm`](Self::is_warm)): observed past
    /// `min_obs`, not demoted for staleness, and on a live device.
    pub fn warm_cells(&self) -> usize {
        (0..self.k * self.l)
            .filter(|&c| {
                !self.down[c % self.l]
                    && self.counts[c] >= self.min_obs
                    && !self.cell_is_stale(c)
            })
            .count()
    }

    /// Estimator-wide completions since this cell last saw a sample
    /// (0 for a never-observed cell — it is *cold*, not stale).
    pub fn staleness(&self, class: usize, device: usize) -> u64 {
        let c = class * self.l + device;
        if self.counts[c] == 0 {
            0
        } else {
            self.eff_tick(c) - self.last_obs[c]
        }
    }

    fn cell_is_stale(&self, c: usize) -> bool {
        // ≥, not >: the module contract says a warm cell demotes *after
        // `stale_after` completions without a sample*, so the demotion
        // lands exactly when the staleness clock reaches the knob (the
        // recency half-life), not one completion later.  The old `>`
        // comparison put the boundary off by one against the docs.
        self.stale_after > 0
            && self.counts[c] > 0
            && self.eff_tick(c) - self.last_obs[c] >= self.stale_after
    }

    /// Has this once-observed cell gone `stale_after` estimator-wide
    /// completions without a fresh sample?  True exactly from the
    /// `stale_after`-th sample-free completion on.
    pub fn is_stale(&self, class: usize, device: usize) -> bool {
        self.cell_is_stale(class * self.l + device)
    }

    /// Every stale cell, in row-major (class, device) order.
    pub fn stale_cells(&self) -> Vec<(usize, usize)> {
        (0..self.k * self.l)
            .filter(|&c| self.cell_is_stale(c))
            .map(|c| (c / self.l, c % self.l))
            .collect()
    }

    /// How much to trust this cell's estimate right now, in [0, 1]:
    /// observation count relative to the `min_obs` trust span × recency
    /// decay with half-life `stale_after` (a cell exactly `stale_after`
    /// completions behind the clock has half the confidence of a live
    /// one — and is demoted to stale at that same boundary, see
    /// [`is_stale`](Self::is_stale)).  0 for a never-observed cell.
    pub fn confidence(&self, class: usize, device: usize) -> f64 {
        let c = class * self.l + device;
        if self.counts[c] == 0 {
            return 0.0;
        }
        let count_factor = (self.counts[c] as f64 / self.min_obs as f64).min(1.0);
        let recency = if self.stale_after == 0 {
            1.0
        } else {
            let staleness = (self.eff_tick(c) - self.last_obs[c]) as f64;
            0.5f64.powf(staleness / self.stale_after as f64)
        };
        count_factor * recency
    }

    /// The full confidence grid in row-major (class, device) order —
    /// the weight-assembly input of the priority subsystem
    /// ([`crate::policy::grin::priority_weights`]).
    pub fn confidences(&self) -> Vec<f64> {
        (0..self.k)
            .flat_map(|i| (0..self.l).map(move |j| (i, j)))
            .map(|(i, j)| self.confidence(i, j))
            .collect()
    }

    /// Install the reference rates the CUSUM residuals are measured
    /// against — the matrix the (re-)solved routing target believes.
    /// Resets every cell's CUSUM state, partial batches and pending
    /// alarms: accumulated evidence describes deviation from the *old*
    /// belief and must not leak into the new one.
    ///
    /// Errors on a k×l shape mismatch (a silently mis-indexed reference
    /// would corrupt every residual).
    pub fn set_reference(&mut self, reference: &AffinityMatrix) -> Result<()> {
        if reference.types() != self.k || reference.procs() != self.l {
            return Err(Error::Shape(format!(
                "reference is {}×{}, estimator runs {}×{}",
                reference.types(),
                reference.procs(),
                self.k,
                self.l
            )));
        }
        for (o, &m) in self.ref_omega.iter_mut().zip(reference.data()) {
            *o = 1.0 / m;
        }
        self.g_plus.fill(0.0);
        self.g_minus.fill(0.0);
        self.batch_sum.fill(0.0);
        self.batch_n.fill(0);
        self.alarmed.fill(false);
        self.alarm_pending = false;
        Ok(())
    }

    /// Has any cell's CUSUM alarmed since the last reference swap /
    /// [`take_alarms`](Self::take_alarms) drain?  O(1) — safe to poll on
    /// every completion.
    pub fn alarm_pending(&self) -> bool {
        self.alarm_pending
    }

    /// Drain the alarmed cells (row-major order), clearing the pending
    /// flag.  The caller re-solves against
    /// [`mu_hat_gated`](Self::mu_hat_gated) and, on success, installs
    /// the new belief via [`set_reference`](Self::set_reference); on a
    /// momentarily unsolvable μ̂ the drained alarms act as a natural
    /// back-off — the CUSUM must re-accumulate before re-alarming.
    pub fn take_alarms(&mut self) -> Vec<(usize, usize)> {
        let out: Vec<(usize, usize)> = (0..self.k * self.l)
            .filter(|&c| self.alarmed[c])
            .map(|c| (c / self.l, c % self.l))
            .collect();
        self.alarmed.fill(false);
        self.alarm_pending = false;
        out
    }

    /// Current service-time estimate ω̂ for a cell: the window mean once
    /// the cell has `min_obs` samples (EWMA before that), prior when the
    /// cell has never been observed.
    pub fn omega_hat(&self, class: usize, device: usize) -> f64 {
        let c = class * self.l + device;
        if self.counts[c] == 0 {
            return self.prior_omega[c];
        }
        if self.counts[c] >= self.min_obs {
            if let Some(m) = self.windows[c].mean() {
                return m;
            }
        }
        self.ewma[c]
    }

    /// Current rate estimate μ̂ = 1/ω̂ for a cell.
    pub fn rate_hat(&self, class: usize, device: usize) -> f64 {
        1.0 / self.omega_hat(class, device)
    }

    /// The live affinity matrix μ̂ (raw: every cell reports its own
    /// estimate, however stale — use
    /// [`mu_hat_gated`](Self::mu_hat_gated) for anything that steers).
    pub fn mu_hat(&self) -> Result<AffinityMatrix> {
        let rows: Vec<Vec<f64>> = (0..self.k)
            .map(|i| (0..self.l).map(|j| self.rate_hat(i, j)).collect())
            .collect();
        AffinityMatrix::from_rows(&rows)
    }

    /// Confidence-gated service-time estimate: a stale cell falls back
    /// to the reference belief (its own estimate is frozen pre-flip
    /// data — worse than no information for steering and re-solving).
    pub fn omega_hat_gated(&self, class: usize, device: usize) -> f64 {
        let c = class * self.l + device;
        if self.cell_is_stale(c) {
            self.ref_omega[c]
        } else {
            self.omega_hat(class, device)
        }
    }

    /// Confidence-gated rate estimate μ̂ = 1/ω̂ for a cell.
    pub fn rate_hat_gated(&self, class: usize, device: usize) -> f64 {
        1.0 / self.omega_hat_gated(class, device)
    }

    /// The live affinity matrix μ̂ with stale cells replaced by the
    /// reference belief — what adaptive re-solves and sharded snapshot
    /// gathers consume, so a cell the previous target abandoned cannot
    /// keep steering the fleet on pre-flip rates.
    pub fn mu_hat_gated(&self) -> Result<AffinityMatrix> {
        let rows: Vec<Vec<f64>> = (0..self.k)
            .map(|i| (0..self.l).map(|j| self.rate_hat_gated(i, j)).collect())
            .collect();
        AffinityMatrix::from_rows(&rows)
    }

    /// Maximum relative rate deviation of μ̂ from `reference`, over the
    /// warm cells (unobserved cells cannot signal drift; stale cells
    /// are demoted and stop signalling — see [`is_warm`](Self::is_warm)).
    ///
    /// # Panics
    ///
    /// Panics when `reference` is not k×l, in release builds too: a
    /// shape mismatch would silently compare against the wrong cells,
    /// and every caller holds a same-shape matrix by construction.
    pub fn drift(&self, reference: &AffinityMatrix) -> f64 {
        assert_eq!(
            reference.types(),
            self.k,
            "drift reference has {} task types, estimator runs {}",
            reference.types(),
            self.k
        );
        assert_eq!(
            reference.procs(),
            self.l,
            "drift reference has {} devices, estimator runs {}",
            reference.procs(),
            self.l
        );
        let mut worst = 0.0f64;
        for i in 0..self.k {
            for j in 0..self.l {
                if !self.is_warm(i, j) {
                    continue;
                }
                let rf = reference.rate(i, j);
                worst = worst.max((self.rate_hat(i, j) - rf).abs() / rf);
            }
        }
        worst
    }
}

/// Log-bucketed latency histogram (microsecond resolution floor).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket counts; bucket b covers [2^b, 2^(b+1)) µs.
    buckets: Vec<u64>,
    count: u64,
    sum_us: u128,
    max_us: u64,
}

const NBUCKETS: usize = 27; // 2^26 µs ≈ 67 s

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self { buckets: vec![0; NBUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency in seconds.
    pub fn record_s(&mut self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(NBUCKETS - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us as u128;
        self.max_us = self.max_us.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds.
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64 / 1e6
    }

    /// Approximate quantile (bucket upper edge), seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (1u64 << (b + 1)) as f64 / 1e6;
            }
        }
        self.max_us as f64 / 1e6
    }

    /// Merge another histogram in.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record_s(i as f64 / 1000.0); // 1ms .. 1s
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.5);
        // Bucket edges are powers of two: p50 of U(1ms,1s) ≈ 0.5s → edge 0.524s.
        assert!(p50 >= 0.25 && p50 <= 1.1, "p50 {p50}");
        let p99 = h.quantile_s(0.99);
        assert!(p99 >= p50);
        assert!((h.mean_s() - 0.5).abs() < 0.02);
    }

    #[test]
    fn merge_adds() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_s(0.001);
        b.record_s(1.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.quantile_s(1.0) >= 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.quantile_s(0.99), 0.0);
    }

    #[test]
    fn estimator_starts_at_prior_and_converges_to_observations() {
        let prior = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let mut e = RateEstimator::new(&prior, 0.2, 16, 4).unwrap();
        // No observations: μ̂ = prior exactly.
        assert!((e.rate_hat(0, 0) - 20.0).abs() < 1e-12);
        assert!((e.mu_hat().unwrap().rate(1, 1) - 8.0).abs() < 1e-12);
        assert_eq!(e.drift(&prior), 0.0);
        // Feed a 4× slower reality on cell (0, 0): ω = 1/5 s.
        for _ in 0..64 {
            e.observe(0, 0, 0.2);
        }
        let r = e.rate_hat(0, 0);
        assert!((r - 5.0).abs() < 0.2, "μ̂(0,0) = {r}");
        // Unobserved cells stay at the prior.
        assert!((e.rate_hat(0, 1) - 15.0).abs() < 1e-12);
        // Drift vs the prior reflects the (0, 0) slowdown only.
        let d = e.drift(&prior);
        assert!(d > 0.7 && d < 0.8, "drift = {d}");
        assert_eq!(e.observations(), 64);
        assert_eq!(e.count(0, 0), 64);
    }

    #[test]
    fn estimator_window_dominates_after_min_obs() {
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let mut e = RateEstimator::new(&prior, 0.01, 8, 8).unwrap();
        // Slow EWMA (α = 0.01) but a window of 8 with min_obs 8: after a
        // level shift, the window-mean estimate tracks the new level even
        // though the EWMA lags.
        for _ in 0..8 {
            e.observe(1, 1, 0.5);
        }
        assert!((e.omega_hat(1, 1) - 0.5).abs() < 1e-12);
        // Non-finite and non-positive samples are ignored.
        e.observe(1, 1, f64::NAN);
        e.observe(1, 1, -1.0);
        assert_eq!(e.count(1, 1), 8);
    }

    #[test]
    fn cold_start_windows_never_report_drift() {
        // Guard for the sharded leaders, which each boot with empty
        // windows: while a cell's window is shorter than the trust span
        // (min_obs) it must not report drift, no matter how far the few
        // early samples sit from the prior.
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let mut e = RateEstimator::new(&prior, 0.3, 32, 8).unwrap();
        assert_eq!(e.warm_cells(), 0);
        assert_eq!(e.drift(&prior), 0.0);
        // 7 samples at 10× the prior's service time: still cold.
        for _ in 0..7 {
            e.observe(0, 0, 1.0);
        }
        assert!(!e.is_warm(0, 0));
        assert_eq!(e.drift(&prior), 0.0, "sub-min_obs window signalled drift");
        // The 8th sample warms the cell; the same deviation now counts.
        e.observe(0, 0, 1.0);
        assert!(e.is_warm(0, 0));
        assert_eq!(e.warm_cells(), 1);
        assert!(e.drift(&prior) > 0.5);
        // Other cells remain cold and keep not contributing.
        assert!(!e.is_warm(1, 1));
    }

    #[test]
    fn estimator_rejects_bad_alpha() {
        let prior = AffinityMatrix::two_type(1.0, 1.0, 1.0, 1.0).unwrap();
        assert!(RateEstimator::new(&prior, 0.0, 8, 1).is_err());
        assert!(RateEstimator::new(&prior, 1.5, 8, 1).is_err());
    }

    #[test]
    fn estimator_rejects_bad_cusum_knobs() {
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(1.0, 1.0, 1.0, 1.0).unwrap();
        let bad_h = DriftConfig { cusum_h: 0.0, ..Default::default() };
        assert!(RateEstimator::from_drift(&prior, &bad_h).is_err());
        let bad_delta = DriftConfig { cusum_delta: -0.1, ..Default::default() };
        assert!(RateEstimator::from_drift(&prior, &bad_delta).is_err());
    }

    #[test]
    fn cusum_alarms_on_slowdown_and_auto_resets() {
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig {
            min_obs: 4,
            cusum_delta: 0.25,
            cusum_h: 2.0,
            ..Default::default()
        };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        assert!(!e.alarm_pending());
        // Exact-reference samples: residual 0, never alarms.
        for _ in 0..64 {
            e.observe(0, 0, 0.1);
        }
        assert!(!e.alarm_pending(), "alarmed on zero residual");
        // 2× slowdown on (0, 1): batch residual +1, accumulates 0.75 per
        // 4-sample batch → crosses h = 2 on the 3rd batch (12 samples).
        for _ in 0..12 {
            e.observe(0, 1, 0.2);
        }
        assert!(e.alarm_pending());
        let alarms = e.take_alarms();
        assert_eq!(alarms, vec![(0, 1)]);
        assert!(!e.alarm_pending(), "take_alarms did not drain");
        // Auto-reset: the accumulated excursion was cleared at the alarm,
        // so the very next batch cannot immediately re-alarm...
        for _ in 0..4 {
            e.observe(0, 1, 0.2);
        }
        assert!(!e.alarm_pending(), "no back-off after alarm reset");
        // ...but sustained deviation alarms again.
        for _ in 0..12 {
            e.observe(0, 1, 0.2);
        }
        assert!(e.alarm_pending());
    }

    #[test]
    fn cusum_alarms_on_speedup_via_minus_side() {
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig {
            min_obs: 4,
            cusum_delta: 0.1,
            cusum_h: 1.0,
            ..Default::default()
        };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        // 2× speedup: residual −0.5 per batch, g⁻ grows 0.4 per batch →
        // crosses h = 1 on the 3rd batch.
        for _ in 0..12 {
            e.observe(1, 0, 0.05);
        }
        assert_eq!(e.take_alarms(), vec![(1, 0)]);
    }

    #[test]
    fn set_reference_resets_cusum_and_checks_shape() {
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig { min_obs: 4, cusum_h: 2.0, ..Default::default() };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        for _ in 0..12 {
            e.observe(0, 0, 0.2); // 2× slower than the prior: alarms
        }
        assert!(e.alarm_pending());
        // Installing the new belief (rates at the observed level) clears
        // the alarm and the accumulators...
        let flipped = AffinityMatrix::two_type(5.0, 10.0, 10.0, 10.0).unwrap();
        e.set_reference(&flipped).unwrap();
        assert!(!e.alarm_pending());
        // ...and residuals are now measured against the new reference:
        // the same samples no longer deviate.
        for _ in 0..64 {
            e.observe(0, 0, 0.2);
        }
        assert!(!e.alarm_pending(), "alarmed against the refreshed reference");
        // Shape mismatches are a hard error, not a debug assert.
        let wide = AffinityMatrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![3.0, 2.0, 1.0]])
            .unwrap();
        assert!(e.set_reference(&wide).is_err());
    }

    #[test]
    #[should_panic(expected = "drift reference")]
    fn drift_panics_on_shape_mismatch_in_release_too() {
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let e = RateEstimator::new(&prior, 0.2, 8, 4).unwrap();
        let wide = AffinityMatrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![3.0, 2.0, 1.0],
        ])
        .unwrap();
        e.drift(&wide);
    }

    #[test]
    fn flipped_away_cell_demotes_and_stops_signalling_drift() {
        // Satellite regression gate: a cell that was warm before a
        // regime flip, then never exercised again, must stop
        // contributing its frozen pre-flip rate to drift()/warm_cells().
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig { min_obs: 8, stale_after: 50, ..Default::default() };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        // Cell (0, 0) warms at a 10× slower level: big drift.
        for _ in 0..16 {
            e.observe(0, 0, 1.0);
        }
        assert!(e.is_warm(0, 0));
        assert_eq!(e.warm_cells(), 1);
        assert!(e.drift(&prior) > 0.5);
        let conf_live = e.confidence(0, 0);
        assert!(conf_live > 0.9, "live warm cell confidence {conf_live}");
        // The flip moves all traffic to (1, 1); (0, 0) goes quiet.  One
        // completion short of the boundary it is still warm...
        for _ in 0..49 {
            e.observe(1, 1, 0.1);
        }
        assert!(!e.is_stale(0, 0), "demoted a completion early");
        assert!(e.is_warm(0, 0));
        // ...and the 50th sample-free completion demotes it *exactly* at
        // `stale_after`, per the module contract ("after `stale_after`
        // completions") — the old `>` comparison was off by one here.
        e.observe(1, 1, 0.1);
        assert_eq!(e.staleness(0, 0), 50);
        assert!(e.is_stale(0, 0), "not demoted at the exact boundary");
        assert!(!e.is_warm(0, 0), "stale cell still warm");
        e.observe(1, 1, 0.1);
        assert!(e.is_stale(0, 0), "51 ≥ stale_after completions without a sample");
        assert_eq!(e.stale_cells(), vec![(0, 0)]);
        assert!(e.confidence(0, 0) < 0.5, "confidence did not decay");
        assert!(conf_live > e.confidence(0, 0));
        // Only (1, 1) is warm now, and it matches the prior: no drift.
        assert!(e.drift(&prior) < 0.05, "stale cell kept signalling drift");
        // warm_cells reflects the demotion: (1, 1) alone.
        assert_eq!(e.warm_cells(), 1);
        assert!(e.is_warm(1, 1));
        // The gated matrix substitutes the reference for the stale cell
        // while the live cell keeps its own estimate.
        let gated = e.mu_hat_gated().unwrap();
        assert!((gated.rate(0, 0) - 10.0).abs() < 1e-9, "stale cell not gated");
        assert!((gated.rate(1, 1) - 10.0).abs() < 0.01);
        // The raw matrix still reports the frozen estimate.
        assert!((e.mu_hat().unwrap().rate(0, 0) - 1.0).abs() < 0.01);
        // A fresh sample re-promotes the cell.
        e.observe(0, 0, 1.0);
        assert!(!e.is_stale(0, 0));
        assert!(e.is_warm(0, 0));
    }

    #[test]
    fn down_device_cells_freeze_instead_of_going_stale() {
        // Satellite regression gate (down transition): once a device is
        // explicitly marked down, its warm cells must neither decay to
        // stale nor lose confidence while other cells' completions run
        // the estimator-wide clock — a dead device produces no samples
        // by definition, so absence of samples is not evidence.
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig { min_obs: 8, stale_after: 50, ..Default::default() };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        for _ in 0..16 {
            e.observe(0, 0, 0.1);
        }
        // Build a half-finished slow mini-batch on the device too: the
        // down-mark must discard it, not let it alarm on recovery.
        for _ in 0..4 {
            e.observe(1, 0, 0.9);
        }
        let conf_before = e.confidence(0, 0);
        e.mark_down(0);
        assert!(e.is_down(0));
        assert!(!e.is_warm(0, 0), "down device still signalling drift");
        // Far past stale_after on the global clock...
        for _ in 0..200 {
            e.observe(0, 1, 0.1);
        }
        // ...the frozen cell is neither stale nor decayed: staleness
        // holds at its value when the device went down (4 completions).
        assert!(!e.is_stale(0, 0), "frozen cell decayed to stale");
        assert_eq!(e.staleness(0, 0), 4);
        assert!((e.confidence(0, 0) - conf_before).abs() < 1e-12);
        // Samples racing the down-mark are ignored.
        e.observe(0, 0, 5.0);
        assert_eq!(e.count(0, 0), 16);
        assert!(!e.alarm_pending());
    }

    #[test]
    fn recovered_device_resumes_fresh_without_re_alarming() {
        // Satellite regression gate (up transition): recovery restarts
        // the column with a clean CUSUM — no alarm from pre-failure
        // residue or zero-sample batches — and the cells come back warm
        // (fresh staleness clock) rather than instantly demoted.
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig {
            min_obs: 4,
            cusum_h: 2.0,
            stale_after: 50,
            ..Default::default()
        };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        // Accumulate most of an excursion (2 batches of 2× slowdown:
        // g⁺ = 1.5 of h = 2), then lose the device.
        for _ in 0..8 {
            e.observe(0, 0, 0.2);
        }
        assert!(!e.alarm_pending());
        e.mark_down(0);
        // A long outage elsewhere, then recovery.
        for _ in 0..120 {
            e.observe(0, 1, 0.1);
        }
        e.mark_up(0);
        assert!(!e.is_down(0));
        assert!(!e.alarm_pending(), "recovery itself alarmed");
        assert!(!e.is_stale(0, 0), "rejoined cell instantly stale");
        assert!(e.is_warm(0, 0), "rejoined cell lost its warm status");
        // The pre-failure excursion was discarded: one at-reference
        // batch after recovery stays quiet, and a *sustained* deviation
        // must re-earn the full excursion from zero.
        for _ in 0..4 {
            e.observe(0, 0, 0.1);
        }
        assert!(!e.alarm_pending(), "pre-failure CUSUM residue leaked through");
        for _ in 0..12 {
            e.observe(0, 0, 0.2);
        }
        assert!(e.alarm_pending(), "fresh post-recovery drift went undetected");
        assert_eq!(e.take_alarms(), vec![(0, 0)]);
        // Idempotence: double marks are no-ops.
        e.mark_up(0);
        e.mark_down(1);
        e.mark_down(1);
        assert!(e.is_down(1));
    }

    #[test]
    fn confidence_tracks_count_then_recency() {
        use crate::sim::dynamic::DriftConfig;
        let prior = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let drift = DriftConfig { min_obs: 8, stale_after: 100, ..Default::default() };
        let mut e = RateEstimator::from_drift(&prior, &drift).unwrap();
        assert_eq!(e.confidence(0, 0), 0.0);
        // Half the trust span observed → confidence 0.5.
        for _ in 0..4 {
            e.observe(0, 0, 0.1);
        }
        assert!((e.confidence(0, 0) - 0.5).abs() < 1e-12);
        for _ in 0..4 {
            e.observe(0, 0, 0.1);
        }
        assert!((e.confidence(0, 0) - 1.0).abs() < 1e-12);
        assert_eq!(e.staleness(0, 0), 0);
        // One completion short of the half-life: still live.
        for _ in 0..99 {
            e.observe(1, 1, 0.1);
        }
        assert!(!e.is_stale(0, 0));
        // Exactly one half-life of other-cell completions → confidence
        // 0.5, and the demotion boundary lands here too ("after
        // `stale_after` completions without a sample").
        e.observe(1, 1, 0.1);
        assert_eq!(e.staleness(0, 0), 100);
        assert!((e.confidence(0, 0) - 0.5).abs() < 1e-12);
        assert!(e.is_stale(0, 0));
        // The grid accessor mirrors the scalar one, row-major.
        let grid = e.confidences();
        assert_eq!(grid.len(), 4);
        assert!((grid[0] - e.confidence(0, 0)).abs() < 1e-15);
        assert!((grid[3] - e.confidence(1, 1)).abs() < 1e-15);
    }
}
