//! Global coordination over shard leaders: gather → one batched GrIn
//! re-solve over the assembled k×l view → epoch-versioned push-back.
//!
//! [`ShardedControl`] is the whole control plane in one deterministic
//! object, shared by the live serving coordinator
//! (`hetsched serve --shards N`) and the simulator's
//! [`crate::sim::dynamic::ResolveMode::Sharded`] mode so the two can be
//! A/B'd on identical logic:
//!
//! 1. **Route** (two-level deficit steering): pick the shard with the
//!    largest class deficit against its installed target totals, then
//!    let that [`ShardLeader`] pick the device — O(shards + shard size)
//!    per arrival, no global lock in a real deployment because each
//!    leader only reads its own slice.
//! 2. **Complete**: the owning shard updates occupancy and feeds its
//!    local estimator.
//! 3. **Sync** (every `sync_every` completions): gather
//!    [`ShardSnapshot`]s; if any shard reports drift, assemble the
//!    global μ̂ and occupancy, project the occupancy onto the
//!    configured populations, and run **one batched GrIn re-solve**
//!    warm-started from that snapshot
//!    ([`crate::policy::grin::solve_from_snapshot`] — reusing
//!    `IncrementalX`, typically a handful of moves).  The solution is
//!    split into per-shard slices and installed under a single
//!    incremented epoch, so no arrival anywhere can observe a mix of
//!    old and new targets.
//!
//! The "no global lock in a real deployment" claim in step 1 is made
//! literal by the lock-free front end
//! ([`super::frontend::ConcurrentRouter`], `serve --frontend-threads
//! N`): it publishes the same epoch-versioned install unit as an
//! immutable snapshot behind one atomic epoch, so routing threads
//! never wait on a re-solve at all.
//!
//! Step 3's install/gather ordering (every shard installed before the
//! new epoch becomes observable) is model-checked exhaustively over
//! bounded interleavings in `tests/model_check.rs` (`--features
//! model`), alongside the front end's snapshot-install and
//! reconcile/complete protocols.

// srclint: allow-file(index-reachable) — shard and cell grids are sized at control-plane build; ids are validated on entry

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, PowerProfile};
use crate::model::state::StateMatrix;
use crate::policy::grin::{self, GrInSolution};
use crate::policy::target::{pick_by_deficit, pick_by_weighted_deficit};
use crate::sim::dynamic::DriftConfig;

use super::shard::{mu_columns, partition_devices, ShardLeader, ShardSnapshot};

/// The sharded multi-leader control plane.
#[derive(Debug)]
pub struct ShardedControl {
    shards: Vec<ShardLeader>,
    /// Global device index → owning shard.
    dev_shard: Vec<usize>,
    /// The global rates the installed targets were solved for.
    believed: AffinityMatrix,
    populations: Vec<u32>,
    /// Per-class integer priorities (empty = unweighted).  Only
    /// [`set_priorities`](Self::set_priorities) may change this, and
    /// every change re-solves and re-installs under one new epoch —
    /// the weight-epoch consistency contract.
    priorities: Vec<u32>,
    /// Monotone counter of priority-vector changes.  Re-solves assemble
    /// their weight vector *after* gather from the current priorities
    /// and assert the counter has not moved before installing, so a
    /// target computed under a stale weight vector can never be pushed
    /// (`sync` documents the invariant; the interleaving is impossible
    /// through the public API of this single-threaded object, and the
    /// guard keeps it that way as the plane grows concurrency).
    weight_epoch: u64,
    /// What the batched re-solves optimize.  [`Objective::Throughput`]
    /// keeps every solve on the unweighted/weighted GrIn paths bit for
    /// bit; other objectives swap in the objective-scored greedy
    /// ([`grin::solve_objective`]) and exclude non-trivial priorities.
    objective: Objective,
    /// Power model the objective-scored solves evaluate against.
    power: PowerProfile,
    sync_every: u64,
    since_sync: u64,
    epoch: u64,
    resolves: u64,
    batched_moves: u64,
}

impl ShardedControl {
    /// Partition the `mu.procs()` devices into `shards` leaders
    /// (0 = one shard per device), solve the initial global target and
    /// install it as epoch 1.
    pub fn new(
        mu: &AffinityMatrix,
        populations: &[u32],
        shards: usize,
        drift: &DriftConfig,
        sync_every: u64,
    ) -> Result<Self> {
        if sync_every == 0 {
            return Err(Error::Config("sharded sync_every must be ≥ 1".into()));
        }
        let l = mu.procs();
        let count = if shards == 0 { l } else { shards };
        let parts = partition_devices(l, count)?;
        let mut leaders = Vec::with_capacity(parts.len());
        for (s, devs) in parts.into_iter().enumerate() {
            leaders.push(ShardLeader::new(s, devs, mu, drift)?);
        }
        let mut dev_shard = vec![0usize; l];
        for leader in &leaders {
            for &d in leader.devices() {
                dev_shard[d] = leader.id();
            }
        }
        let mut ctl = Self {
            shards: leaders,
            dev_shard,
            believed: mu.clone(),
            populations: populations.to_vec(),
            priorities: Vec::new(),
            weight_epoch: 0,
            objective: Objective::Throughput,
            power: PowerProfile::default(),
            sync_every,
            since_sync: 0,
            epoch: 0,
            resolves: 0,
            batched_moves: 0,
        };
        let sol = grin::solve(mu, populations)?;
        ctl.install_global(sol.state)?;
        Ok(ctl)
    }

    /// Current target epoch (identical across all shards by
    /// construction).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Drift-triggered batched re-solves performed.
    pub fn resolves(&self) -> u64 {
        self.resolves
    }

    /// Total greedy moves across all batched re-solves (the warm-start
    /// cheapness metric).
    pub fn batched_moves(&self) -> u64 {
        self.batched_moves
    }

    /// The shard leaders.
    pub fn shards(&self) -> &[ShardLeader] {
        &self.shards
    }

    /// The global rates the installed targets were solved for.
    pub fn believed(&self) -> &AffinityMatrix {
        &self.believed
    }

    /// Assembled live global estimate μ̂, confidence-gated per shard
    /// (prior-backed where cold, solved-rate-backed where stale).
    pub fn mu_hat(&self) -> Result<AffinityMatrix> {
        let snaps = self.gather()?;
        Ok(assemble(&self.believed, &snaps)?.0)
    }

    /// The installed per-class priorities (empty = unweighted).
    pub fn priorities(&self) -> &[u32] {
        &self.priorities
    }

    /// Priority-vector changes performed so far (the weight epoch).
    pub fn weight_epoch(&self) -> u64 {
        self.weight_epoch
    }

    /// The objective the batched re-solves optimize.
    pub fn objective(&self) -> Objective {
        self.objective
    }

    /// Route one `class` arrival: shard with the largest class deficit
    /// (ties to the shard offering the fastest solved rate, then the
    /// lower shard id), then deficit steering inside that shard.  Under
    /// priorities the shard pick uses the confidence-weighted deficits
    /// ([`ShardLeader::weighted_class_deficit`]), so a shard whose
    /// estimates for this class went quiet competes at a discount.
    /// Shards with no alive device are masked out of the pick with
    /// sentinel scores (the deficit enumeration must stay index-aligned
    /// with `self.shards`); with every device in the fleet down this is
    /// [`Error::NoCapacity`], never a panic.  Returns the global device
    /// index.
    pub fn route(&mut self, class: usize) -> Result<usize> {
        let best = if grin::trivial_priorities(&self.priorities) {
            pick_by_deficit(self.shards.iter().map(|leader| {
                if leader.has_alive() {
                    (leader.class_deficit(class), leader.best_rate(class))
                } else {
                    (i64::MIN, f64::NEG_INFINITY)
                }
            }))
        } else {
            pick_by_weighted_deficit(self.shards.iter().map(|leader| {
                if leader.has_alive() {
                    (leader.weighted_class_deficit(class), leader.best_rate(class))
                } else {
                    (f64::NEG_INFINITY, f64::NEG_INFINITY)
                }
            }))
        }
        // srclint: allow(panic-reachable) — the control-plane builder guarantees at least one shard
        .expect("control plane has at least one shard");
        if !self.shards[best].has_alive() {
            return Err(Error::NoCapacity(
                "every device in the sharded fleet is down".into(),
            ));
        }
        self.shards[best].route(class).ok_or_else(|| {
            Error::NoCapacity("chosen shard lost its last device mid-route".into())
        })
    }

    /// Completion callback: updates the owning shard and, every
    /// `sync_every` completions, runs the gather/re-solve sync.
    /// Returns `true` when a batched re-solve swapped the targets.
    pub fn on_complete(&mut self, class: usize, device: usize, service_s: f64) -> Result<bool> {
        let s = *self.dev_shard.get(device).ok_or_else(|| {
            Error::Config(format!("unknown device {device} in sharded fleet"))
        })?;
        self.shards[s].complete(class, device, service_s)?;
        self.since_sync += 1;
        if self.since_sync < self.sync_every {
            return Ok(false);
        }
        self.since_sync = 0;
        self.sync()
    }

    /// Completion callback for a backup (re-dispatched) task: balances
    /// the owning shard's occupancy but feeds neither the estimator nor
    /// the sync cadence.  A backup's service sample is the *remaining*
    /// work of an evacuated task served at the survivor's rate — not a
    /// unit-mean size draw — so letting it into μ̂ would bias the very
    /// estimates churn steering depends on.
    pub fn on_complete_silent(&mut self, class: usize, device: usize) -> Result<()> {
        let s = *self.dev_shard.get(device).ok_or_else(|| {
            Error::Config(format!("unknown device {device} in sharded fleet"))
        })?;
        self.shards[s].complete_silent(class, device)
    }

    /// Explicit down-signal: mark `device` dead in its shard (freezing
    /// its estimator cells and clearing its occupancy column), mask the
    /// dead column out of the believed rates, and re-solve + re-install
    /// the shrunken target under one new epoch.  Returns `true` when the
    /// re-solve installed new targets; `Ok(false)` when the shrunken
    /// fleet is momentarily unsolvable (the old targets stand — routing
    /// still avoids the dead device via the liveness masks, and the next
    /// drift sync retries).  Idempotent.
    pub fn mark_down(&mut self, device: usize) -> Result<bool> {
        let s = *self.dev_shard.get(device).ok_or_else(|| {
            Error::Config(format!("unknown device {device} in sharded fleet"))
        })?;
        self.shards[s].mark_down(device)?;
        self.believed = self.believed.masked_column(device)?;
        match self.resolve_full() {
            Ok(sol) => {
                self.install_global(sol.state)?;
                self.resolves += 1;
                Ok(true)
            }
            Err(_) => Ok(false),
        }
    }

    /// Recovery signal: revive `device` in its shard (unfreezing and
    /// resetting its estimator cells), restore its believed column from
    /// `prior_col` (the boot prior — the estimator re-learns the live
    /// rates from scratch), and re-solve + re-install so the recovered
    /// capacity is put back to work.  Same graceful `Ok(false)` contract
    /// as [`mark_down`](Self::mark_down).  Idempotent.
    pub fn mark_up(&mut self, device: usize, prior_col: &[f64]) -> Result<bool> {
        let s = *self.dev_shard.get(device).ok_or_else(|| {
            Error::Config(format!("unknown device {device} in sharded fleet"))
        })?;
        self.shards[s].mark_up(device)?;
        self.believed = self.believed.with_column(device, prior_col)?;
        match self.resolve_full() {
            Ok(sol) => {
                self.install_global(sol.state)?;
                self.resolves += 1;
                Ok(true)
            }
            Err(_) => Ok(false),
        }
    }

    /// Gather snapshots and, if any shard's change detector fired
    /// (threshold drift or CUSUM alarm, per the configured trigger),
    /// run the batched GrIn re-solve and push new epoch targets to
    /// every shard.  The assembled μ̂ is confidence-gated, so stale
    /// cells contribute the currently believed rates — the re-solve
    /// cannot move placements on the word of dead estimates.
    pub fn sync(&mut self) -> Result<bool> {
        // Weight-epoch guard: the weight vector below is assembled from
        // `self.priorities` *after* the gather, and the priority vector
        // cannot change between here and the install (set_priorities is
        // the only writer and this object is single-threaded) — so the
        // installed targets are always the solution of the current
        // weights, never a stale vector's.
        let weight_epoch = self.weight_epoch;
        let snaps = self.gather()?;
        if !snaps.iter().any(|s| s.drifted) {
            return Ok(false);
        }
        let (mu_hat, occupancy, confidence) = assemble(&self.believed, &snaps)?;
        let start = project_to_populations(&mu_hat, &occupancy, &self.populations);
        // μ̂ can be momentarily pathological on noisy estimates: keep
        // the old targets and retry at the next sync.  Drain the shard
        // alarms first so a persistently bad μ̂ cannot re-run the full
        // batched solve on every sync — the CUSUM must re-accumulate,
        // the same back-off the single-leader paths get.
        let warm = if !self.objective.is_throughput() {
            // Non-trivial priorities are excluded by construction
            // (set_priorities / set_objective reject the combination).
            grin::solve_objective_from_snapshot(
                &mu_hat,
                &self.populations,
                self.objective,
                &self.power,
                &start,
            )
        } else if grin::trivial_priorities(&self.priorities) {
            grin::solve_from_snapshot(&mu_hat, &self.populations, &start)
        } else {
            grin::priority_weights(&self.priorities, &confidence, mu_hat.procs()).and_then(
                |w| grin::solve_weighted_from_snapshot(&mu_hat, &self.populations, &w, &start),
            )
        };
        let sol = match warm {
            Ok(sol) => sol,
            Err(_) => {
                for leader in &mut self.shards {
                    leader.reset_alarms();
                }
                return Ok(false);
            }
        };
        debug_assert_eq!(
            weight_epoch, self.weight_epoch,
            "priority vector changed between gather and install"
        );
        self.batched_moves += sol.moves as u64;
        self.believed = mu_hat;
        self.install_global(sol.state)?;
        self.resolves += 1;
        Ok(true)
    }

    /// Population change (programs launched/retired through the
    /// scheduler — directly observable, no estimation needed): re-solve
    /// against the believed rates and push new targets.  A no-op when
    /// the populations are unchanged, so phase boundaries that only
    /// rescale rates cost nothing here (drift syncs handle those).
    pub fn set_populations(&mut self, populations: &[u32]) -> Result<()> {
        if populations.len() != self.believed.types() {
            return Err(Error::Shape("population arity".into()));
        }
        if populations == self.populations.as_slice() {
            return Ok(());
        }
        self.populations = populations.to_vec();
        let sol = self.resolve_full()?;
        self.install_global(sol.state)
    }

    /// Swap the per-class priority vector (empty clears weighting):
    /// bumps the weight epoch, re-solves against the believed rates
    /// under the new weights, and pushes the re-solved targets — with
    /// the new priorities — to every shard under one incremented
    /// epoch.  Targets solved under the old vector are replaced in the
    /// same call, so no route anywhere can mix old weights with new
    /// targets (regression-tested in this module and
    /// `tests/priority_e2e.rs`).
    pub fn set_priorities(&mut self, priorities: &[u32]) -> Result<()> {
        if !priorities.is_empty() {
            if priorities.len() != self.believed.types() {
                return Err(Error::Shape(format!(
                    "{} priorities for {} task classes",
                    priorities.len(),
                    self.believed.types()
                )));
            }
            if priorities.iter().any(|&p| p == 0) {
                return Err(Error::Config("class priorities must be ≥ 1".into()));
            }
            if !self.objective.is_throughput() && !grin::trivial_priorities(priorities) {
                return Err(Error::Config(
                    "priority weights combine only with the throughput objective".into(),
                ));
            }
        }
        if priorities == self.priorities.as_slice() {
            return Ok(());
        }
        self.priorities = priorities.to_vec();
        self.weight_epoch += 1;
        let sol = self.resolve_full()?;
        self.install_global(sol.state)
    }

    /// Swap the objective the batched re-solves optimize: validates,
    /// rejects the combination with a non-trivial priority vector
    /// (weights are a throughput-surface concept), re-solves against
    /// the believed rates and pushes the re-solved targets to every
    /// shard under one incremented epoch.  A no-op when nothing
    /// changed.
    pub fn set_objective(&mut self, objective: Objective, power: PowerProfile) -> Result<()> {
        objective.validate()?;
        power.validate()?;
        if !objective.is_throughput() && !grin::trivial_priorities(&self.priorities) {
            return Err(Error::Config(
                "priority weights combine only with the throughput objective".into(),
            ));
        }
        if objective == self.objective && power == self.power {
            return Ok(());
        }
        self.objective = objective;
        self.power = power;
        let sol = self.resolve_full()?;
        self.install_global(sol.state)
    }

    /// Full (Algorithm-1-seeded) batched solve against the believed
    /// rates under the current priority vector and objective — the
    /// population/priority/objective-swap path.  Non-trivial vectors
    /// gather the live confidence grid for the weights; trivial ones
    /// skip the gather (and its per-shard snapshot clones) entirely.
    fn resolve_full(&self) -> Result<GrInSolution> {
        if !self.objective.is_throughput() {
            return grin::solve_objective(
                &self.believed,
                &self.populations,
                self.objective,
                &self.power,
            );
        }
        if grin::trivial_priorities(&self.priorities) {
            return grin::solve(&self.believed, &self.populations);
        }
        let snaps = self.gather()?;
        let confidence = assemble(&self.believed, &snaps)?.2;
        let weights =
            grin::priority_weights(&self.priorities, &confidence, self.believed.procs())?;
        grin::solve_weighted(&self.believed, &self.populations, &weights)
    }

    fn gather(&self) -> Result<Vec<ShardSnapshot>> {
        self.shards.iter().map(ShardLeader::snapshot).collect()
    }

    /// Split a global target into per-shard slices and install them all
    /// — together with the priority vector they were solved under —
    /// under one incremented epoch (the atomic push-back).
    fn install_global(&mut self, target: StateMatrix) -> Result<()> {
        self.epoch += 1;
        let epoch = self.epoch;
        let k = target.types();
        for leader in &mut self.shards {
            let devs = leader.devices().to_vec();
            let mut local = StateMatrix::zeros(k, devs.len());
            for i in 0..k {
                for (lj, &j) in devs.iter().enumerate() {
                    local.set(i, lj, target.get(i, j));
                }
            }
            let solved = mu_columns(&self.believed, &devs)?;
            leader.install(epoch, local, solved, &self.priorities)?;
        }
        Ok(())
    }
}

/// Stitch per-shard snapshots into the global k×l view: estimator-backed
/// μ̂ columns (boot prior where cold), the occupancy matrix, and the
/// per-cell confidence grid (row-major k×l).
fn assemble(
    believed: &AffinityMatrix,
    snaps: &[ShardSnapshot],
) -> Result<(AffinityMatrix, StateMatrix, Vec<f64>)> {
    let (k, l) = (believed.types(), believed.procs());
    let mut rows = vec![vec![0.0f64; l]; k];
    let mut occ = StateMatrix::zeros(k, l);
    let mut conf = vec![0.0f64; k * l];
    for snap in snaps {
        let ll = snap.devices.len();
        for (lj, &j) in snap.devices.iter().enumerate() {
            for (i, row) in rows.iter_mut().enumerate() {
                row[j] = snap.mu_hat.rate(i, lj);
                occ.set(i, j, snap.occupancy.get(i, lj));
                conf[i * l + j] = snap.confidence[i * ll + lj];
            }
        }
    }
    Ok((AffinityMatrix::from_rows(&rows)?, occ, conf))
}

/// Project a gathered occupancy snapshot onto the configured populations
/// so the warm start is feasible (in-flight counts skew a task or two
/// from the closed-system populations at gather time): drain surpluses
/// from the fullest cells, fill deficits on the fastest column.
fn project_to_populations(
    mu: &AffinityMatrix,
    occ: &StateMatrix,
    populations: &[u32],
) -> StateMatrix {
    let mut n = occ.clone();
    for (i, &want) in populations.iter().enumerate() {
        while n.row_sum(i) > want {
            let j = (0..n.procs())
                .max_by_key(|&j| n.get(i, j))
                // srclint: allow(panic-reachable) — procs() >= 1, so max_by_key over 0..procs() is Some
                .expect("at least one processor");
            // srclint: allow(panic-reachable) — the fullest cell was just selected by max occupancy, so dec succeeds
            n.dec(i, j).expect("fullest cell is non-empty");
        }
        while n.row_sum(i) < want {
            n.inc(i, mu.best_proc(i));
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload;

    fn control(shards: usize) -> ShardedControl {
        let mu = workload::three_class_mu();
        ShardedControl::new(&mu, &[8, 8, 8], shards, &DriftConfig::default(), 100)
            .unwrap()
    }

    #[test]
    fn boot_installs_one_epoch_everywhere() {
        let ctl = control(3);
        assert_eq!(ctl.shards().len(), 3);
        assert_eq!(ctl.epoch(), 1);
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), 1, "torn epoch at boot");
        }
        // The split targets re-assemble to the configured populations.
        let per_class: Vec<u32> = (0..3)
            .map(|i| ctl.shards().iter().map(|s| s.target().row_sum(i)).sum())
            .collect();
        assert_eq!(per_class, vec![8, 8, 8]);
        // shards = 0 means one per device.
        assert_eq!(control(0).shards().len(), 3);
    }

    #[test]
    fn routing_covers_the_fleet_and_completion_round_trips() {
        let mut ctl = control(3);
        let mut routed = vec![0u32; 3];
        let mut placements = Vec::new();
        for class in 0..3 {
            for _ in 0..8 {
                let j = ctl.route(class).unwrap();
                assert!(j < 3);
                routed[j] += 1;
                placements.push((class, j));
            }
        }
        assert_eq!(routed.iter().sum::<u32>(), 24);
        for &(class, j) in &placements {
            ctl.on_complete(class, j, 0.1).unwrap();
        }
        // All occupancy drained.
        for leader in ctl.shards() {
            for i in 0..3 {
                assert_eq!(leader.occupancy().row_sum(i), 0);
            }
        }
        assert!(ctl.on_complete(0, 99, 0.1).is_err());
    }

    #[test]
    fn sync_is_a_noop_without_drift_and_atomic_with_it() {
        let mut ctl = control(3);
        // No observations: no drift, no re-solve.
        assert!(!ctl.sync().unwrap());
        assert_eq!(ctl.resolves(), 0);
        // Feed every cell service times matching the flipped matrix
        // through the normal route/complete cycle until warm.
        let flipped = workload::three_class_mu()
            .scaled(&workload::three_class_flip_scale())
            .unwrap();
        for _ in 0..64 {
            for class in 0..3 {
                let j = ctl.route(class).unwrap();
                ctl.on_complete(class, j, 1.0 / flipped.rate(class, j)).unwrap();
            }
        }
        // By now at least one sync ran (sync_every = 100 < 192
        // completions) and the drifted cells forced a batched re-solve.
        assert!(ctl.resolves() >= 1, "no batched re-solve under drift");
        assert!(ctl.epoch() > 1);
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after sync");
        }
        assert!(ctl.batched_moves() > 0);
    }

    #[test]
    fn cusum_trigger_drives_batched_resolve() {
        use crate::sim::dynamic::Trigger;
        let mu = workload::three_class_mu();
        let drift = DriftConfig {
            min_obs: 4,
            trigger: Trigger::Cusum,
            ..Default::default()
        };
        let mut ctl = ShardedControl::new(&mu, &[8, 8, 8], 3, &drift, 50).unwrap();
        // Service times matching the believed rates: syncs pass, no
        // alarms, no re-solves.
        for _ in 0..30 {
            for class in 0..3 {
                let j = ctl.route(class).unwrap();
                ctl.on_complete(class, j, 1.0 / mu.rate(class, j)).unwrap();
            }
        }
        assert_eq!(ctl.resolves(), 0, "false alarm on on-reference load");
        // Flip the physics: per-cell CUSUM alarms, the next sync
        // re-solves and installs a new epoch everywhere.
        let flipped = mu.scaled(&workload::three_class_flip_scale()).unwrap();
        for _ in 0..40 {
            for class in 0..3 {
                let j = ctl.route(class).unwrap();
                ctl.on_complete(class, j, 1.0 / flipped.rate(class, j)).unwrap();
            }
        }
        assert!(ctl.resolves() >= 1, "no CUSUM-triggered batched re-solve");
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after CUSUM sync");
        }
    }

    #[test]
    fn priority_flip_reinstalls_weighted_targets_atomically() {
        // Weight-epoch consistency regression: flipping the priority
        // vector must (1) bump the weight epoch, (2) re-solve under the
        // *new* weights, and (3) push targets + priorities to every
        // shard under one target epoch — never leaving a shard steering
        // a new target by an old weight vector or vice versa.
        let mu = crate::sim::workload::priority_mu();
        let mut ctl =
            ShardedControl::new(&mu, &[4, 16], 2, &DriftConfig::default(), 100).unwrap();
        assert_eq!(ctl.weight_epoch(), 0);
        let e0 = ctl.epoch();
        ctl.set_priorities(&[4, 1]).unwrap();
        assert_eq!(ctl.weight_epoch(), 1);
        assert_eq!(ctl.epoch(), e0 + 1);
        assert_eq!(ctl.priorities(), &[4, 1]);
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after priority flip");
            // Normalized [1.6, 0.4] arrived with the target.
            assert!((leader.norm_priorities()[0] - 1.6).abs() < 1e-12);
        }
        // The installed targets are the weighted solution: with no
        // observations yet the confidence discount is uniform, so the
        // global target must equal solve_weighted on the believed
        // rates — class 0 owns its fast device P1 outright.
        let target_p1_class0: u32 =
            ctl.shards().iter().map(|s| s.target().get(0, 0)).take(1).sum();
        assert_eq!(target_p1_class0, 4, "weighted re-solve did not run under new weights");
        let target_p1_class1 = ctl.shards()[0].target().get(1, 0);
        assert_eq!(target_p1_class1, 0, "low-priority class still on the reserved device");
        // Re-installing the same vector is a no-op (no epoch churn)...
        let e1 = ctl.epoch();
        ctl.set_priorities(&[4, 1]).unwrap();
        assert_eq!(ctl.epoch(), e1);
        assert_eq!(ctl.weight_epoch(), 1);
        // ...an empty vector clears weighting with a fresh unweighted
        // solve, and bad vectors are rejected before anything moves.
        assert!(ctl.set_priorities(&[1, 2, 3]).is_err());
        assert!(ctl.set_priorities(&[0, 1]).is_err());
        assert_eq!(ctl.weight_epoch(), 1, "rejected vector bumped the weight epoch");
        ctl.set_priorities(&[]).unwrap();
        assert_eq!(ctl.weight_epoch(), 2);
        assert!(ctl.priorities().is_empty());
        for leader in ctl.shards() {
            assert!(leader.norm_priorities().is_empty());
        }
    }

    #[test]
    fn weighted_sync_resolves_with_current_priorities() {
        // A drift-triggered batched re-solve after a priority flip must
        // solve under the current (new) weight vector: the re-installed
        // target keeps the high-priority reservation even though the
        // drifted μ̂ differs from the boot belief.
        let mu = crate::sim::workload::priority_mu();
        let drift = DriftConfig { min_obs: 4, ..Default::default() };
        let mut ctl = ShardedControl::new(&mu, &[4, 16], 2, &drift, 50).unwrap();
        ctl.set_priorities(&[4, 1]).unwrap();
        // Serve 1.5× slower than the belief everywhere: well past the
        // polled drift threshold, no change in who is fastest.
        for _ in 0..40 {
            for class in 0..2 {
                let j = ctl.route(class).unwrap();
                ctl.on_complete(class, j, 1.5 / mu.rate(class, j)).unwrap();
            }
        }
        assert!(ctl.resolves() >= 1, "no drift-triggered batched re-solve");
        assert_eq!(ctl.shards()[0].target().get(1, 0), 0, "sync dropped the reservation");
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after weighted sync");
            assert!((leader.norm_priorities()[0] - 1.6).abs() < 1e-12);
        }
    }

    #[test]
    fn objective_flip_reinstalls_targets_atomically() {
        use crate::model::energy::PowerScenario;
        let mut ctl = control(3);
        let e0 = ctl.epoch();
        let power = PowerProfile::new(1.0, PowerScenario::Exponent(0.5));
        ctl.set_objective(Objective::EnergyPerTask, power).unwrap();
        assert_eq!(ctl.epoch(), e0 + 1);
        assert_eq!(ctl.objective(), Objective::EnergyPerTask);
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after objective flip");
        }
        // The re-assembled targets still hold the populations.
        let per_class: Vec<u32> = (0..3)
            .map(|i| ctl.shards().iter().map(|s| s.target().row_sum(i)).sum())
            .collect();
        assert_eq!(per_class, vec![8, 8, 8]);
        // Re-installing the same objective is a no-op (no epoch churn).
        ctl.set_objective(Objective::EnergyPerTask, power).unwrap();
        assert_eq!(ctl.epoch(), e0 + 1);
        // Priorities and non-throughput objectives are mutually
        // exclusive, in both orders.
        assert!(ctl.set_priorities(&[4, 1, 1]).is_err());
        ctl.set_objective(Objective::Throughput, PowerProfile::default()).unwrap();
        ctl.set_priorities(&[4, 1, 1]).unwrap();
        assert!(ctl.set_objective(Objective::Edp, PowerProfile::default()).is_err());
    }

    #[test]
    fn population_swap_pushes_new_targets_under_new_epoch() {
        let mut ctl = control(3);
        let e0 = ctl.epoch();
        ctl.set_populations(&[2, 2, 20]).unwrap();
        assert_eq!(ctl.epoch(), e0 + 1);
        let per_class: Vec<u32> = (0..3)
            .map(|i| ctl.shards().iter().map(|s| s.target().row_sum(i)).sum())
            .collect();
        assert_eq!(per_class, vec![2, 2, 20]);
        assert!(ctl.set_populations(&[1, 1]).is_err());
    }

    #[test]
    fn mark_down_masks_routes_and_reinstalls_shrunken_target() {
        let mut ctl = control(3);
        let e0 = ctl.epoch();
        // Down-signal: re-solve installs a new epoch and no route ever
        // lands on the dead device again.
        assert!(ctl.mark_down(1).unwrap());
        assert_eq!(ctl.epoch(), e0 + 1);
        assert!(ctl.believed().rate(0, 1) < 1e-6, "dead column not masked from belief");
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after mark_down");
        }
        let per_class: Vec<u32> = (0..3)
            .map(|i| ctl.shards().iter().map(|s| s.target().row_sum(i)).sum())
            .collect();
        assert_eq!(per_class, vec![8, 8, 8], "shrunken target lost population");
        for class in 0..3 {
            for _ in 0..8 {
                let j = ctl.route(class).unwrap();
                assert_ne!(j, 1, "routed a task to a dead device");
                ctl.on_complete_silent(class, j).unwrap();
            }
        }
        // Recovery restores the believed column and routes flow back.
        let mu = workload::three_class_mu();
        let col: Vec<f64> = (0..3).map(|i| mu.rate(i, 1)).collect();
        assert!(ctl.mark_up(1, &col).unwrap());
        assert!((ctl.believed().rate(0, 1) - mu.rate(0, 1)).abs() < 1e-12);
        let mut hit = false;
        for _ in 0..24 {
            if ctl.route(0).unwrap() == 1 {
                hit = true;
            }
        }
        assert!(hit, "recovered device never routed to");
        // Unknown devices are rejected.
        assert!(ctl.mark_down(99).is_err());
    }

    #[test]
    fn all_devices_down_is_no_capacity_not_a_panic() {
        let mut ctl = control(3);
        for dev in 0..3 {
            ctl.mark_down(dev).ok();
        }
        match ctl.route(0) {
            Err(Error::NoCapacity(_)) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        // One recovery is enough to serve again — on the boot prior.
        let mu = workload::three_class_mu();
        let col: Vec<f64> = (0..3).map(|i| mu.rate(i, 2)).collect();
        ctl.mark_up(2, &col).unwrap();
        assert_eq!(ctl.route(0).unwrap(), 2);
    }

    #[test]
    fn projection_restores_populations() {
        let mu = workload::three_class_mu();
        let mut occ = StateMatrix::zeros(3, 3);
        // Row 0 over by one, row 1 under by two, row 2 exact.
        occ.set(0, 0, 5);
        occ.set(0, 1, 4);
        occ.set(1, 1, 6);
        occ.set(2, 2, 8);
        let n = project_to_populations(&mu, &occ, &[8, 8, 8]);
        n.check_populations(&[8, 8, 8]).unwrap();
        // Surplus drained from the fullest cell of row 0.
        assert_eq!(n.get(0, 0), 4);
    }
}
