//! Global coordination over shard leaders: gather → one batched GrIn
//! re-solve over the assembled k×l view → epoch-versioned push-back.
//!
//! [`ShardedControl`] is the whole control plane in one deterministic
//! object, shared by the live serving coordinator
//! (`hetsched serve --shards N`) and the simulator's
//! [`crate::sim::dynamic::ResolveMode::Sharded`] mode so the two can be
//! A/B'd on identical logic:
//!
//! 1. **Route** (two-level deficit steering): pick the shard with the
//!    largest class deficit against its installed target totals, then
//!    let that [`ShardLeader`] pick the device — O(shards + shard size)
//!    per arrival, no global lock in a real deployment because each
//!    leader only reads its own slice.
//! 2. **Complete**: the owning shard updates occupancy and feeds its
//!    local estimator.
//! 3. **Sync** (every `sync_every` completions): gather
//!    [`ShardSnapshot`]s; if any shard reports drift, assemble the
//!    global μ̂ and occupancy, project the occupancy onto the
//!    configured populations, and run **one batched GrIn re-solve**
//!    warm-started from that snapshot
//!    ([`crate::policy::grin::solve_from_snapshot`] — reusing
//!    `IncrementalX`, typically a handful of moves).  The solution is
//!    split into per-shard slices and installed under a single
//!    incremented epoch, so no arrival anywhere can observe a mix of
//!    old and new targets.

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::state::StateMatrix;
use crate::policy::grin;
use crate::policy::target::pick_by_deficit;
use crate::sim::dynamic::DriftConfig;

use super::shard::{mu_columns, partition_devices, ShardLeader, ShardSnapshot};

/// The sharded multi-leader control plane.
#[derive(Debug)]
pub struct ShardedControl {
    shards: Vec<ShardLeader>,
    /// Global device index → owning shard.
    dev_shard: Vec<usize>,
    /// The global rates the installed targets were solved for.
    believed: AffinityMatrix,
    populations: Vec<u32>,
    sync_every: u64,
    since_sync: u64,
    epoch: u64,
    resolves: u64,
    batched_moves: u64,
}

impl ShardedControl {
    /// Partition the `mu.procs()` devices into `shards` leaders
    /// (0 = one shard per device), solve the initial global target and
    /// install it as epoch 1.
    pub fn new(
        mu: &AffinityMatrix,
        populations: &[u32],
        shards: usize,
        drift: &DriftConfig,
        sync_every: u64,
    ) -> Result<Self> {
        if sync_every == 0 {
            return Err(Error::Config("sharded sync_every must be ≥ 1".into()));
        }
        let l = mu.procs();
        let count = if shards == 0 { l } else { shards };
        let parts = partition_devices(l, count)?;
        let mut leaders = Vec::with_capacity(parts.len());
        for (s, devs) in parts.into_iter().enumerate() {
            leaders.push(ShardLeader::new(s, devs, mu, drift)?);
        }
        let mut dev_shard = vec![0usize; l];
        for leader in &leaders {
            for &d in leader.devices() {
                dev_shard[d] = leader.id();
            }
        }
        let mut ctl = Self {
            shards: leaders,
            dev_shard,
            believed: mu.clone(),
            populations: populations.to_vec(),
            sync_every,
            since_sync: 0,
            epoch: 0,
            resolves: 0,
            batched_moves: 0,
        };
        let sol = grin::solve(mu, populations)?;
        ctl.install_global(sol.state)?;
        Ok(ctl)
    }

    /// Current target epoch (identical across all shards by
    /// construction).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Drift-triggered batched re-solves performed.
    pub fn resolves(&self) -> u64 {
        self.resolves
    }

    /// Total greedy moves across all batched re-solves (the warm-start
    /// cheapness metric).
    pub fn batched_moves(&self) -> u64 {
        self.batched_moves
    }

    /// The shard leaders.
    pub fn shards(&self) -> &[ShardLeader] {
        &self.shards
    }

    /// The global rates the installed targets were solved for.
    pub fn believed(&self) -> &AffinityMatrix {
        &self.believed
    }

    /// Assembled live global estimate μ̂, confidence-gated per shard
    /// (prior-backed where cold, solved-rate-backed where stale).
    pub fn mu_hat(&self) -> Result<AffinityMatrix> {
        let snaps = self.gather()?;
        Ok(assemble(&self.believed, &snaps)?.0)
    }

    /// Route one `class` arrival: shard with the largest class deficit
    /// (ties to the shard offering the fastest solved rate, then the
    /// lower shard id), then deficit steering inside that shard.
    /// Returns the global device index.
    pub fn route(&mut self, class: usize) -> usize {
        let best = pick_by_deficit(
            self.shards
                .iter()
                .map(|leader| (leader.class_deficit(class), leader.best_rate(class))),
        );
        self.shards[best].route(class)
    }

    /// Completion callback: updates the owning shard and, every
    /// `sync_every` completions, runs the gather/re-solve sync.
    /// Returns `true` when a batched re-solve swapped the targets.
    pub fn on_complete(&mut self, class: usize, device: usize, service_s: f64) -> Result<bool> {
        let s = *self.dev_shard.get(device).ok_or_else(|| {
            Error::Config(format!("unknown device {device} in sharded fleet"))
        })?;
        self.shards[s].complete(class, device, service_s)?;
        self.since_sync += 1;
        if self.since_sync < self.sync_every {
            return Ok(false);
        }
        self.since_sync = 0;
        self.sync()
    }

    /// Gather snapshots and, if any shard's change detector fired
    /// (threshold drift or CUSUM alarm, per the configured trigger),
    /// run the batched GrIn re-solve and push new epoch targets to
    /// every shard.  The assembled μ̂ is confidence-gated, so stale
    /// cells contribute the currently believed rates — the re-solve
    /// cannot move placements on the word of dead estimates.
    pub fn sync(&mut self) -> Result<bool> {
        let snaps = self.gather()?;
        if !snaps.iter().any(|s| s.drifted) {
            return Ok(false);
        }
        let (mu_hat, occupancy) = assemble(&self.believed, &snaps)?;
        let start = project_to_populations(&mu_hat, &occupancy, &self.populations);
        // μ̂ can be momentarily pathological on noisy estimates: keep
        // the old targets and retry at the next sync.  Drain the shard
        // alarms first so a persistently bad μ̂ cannot re-run the full
        // batched solve on every sync — the CUSUM must re-accumulate,
        // the same back-off the single-leader paths get.
        let sol = match grin::solve_from_snapshot(&mu_hat, &self.populations, &start) {
            Ok(sol) => sol,
            Err(_) => {
                for leader in &mut self.shards {
                    leader.reset_alarms();
                }
                return Ok(false);
            }
        };
        self.batched_moves += sol.moves as u64;
        self.believed = mu_hat;
        self.install_global(sol.state)?;
        self.resolves += 1;
        Ok(true)
    }

    /// Population change (programs launched/retired through the
    /// scheduler — directly observable, no estimation needed): re-solve
    /// against the believed rates and push new targets.  A no-op when
    /// the populations are unchanged, so phase boundaries that only
    /// rescale rates cost nothing here (drift syncs handle those).
    pub fn set_populations(&mut self, populations: &[u32]) -> Result<()> {
        if populations.len() != self.believed.types() {
            return Err(Error::Shape("population arity".into()));
        }
        if populations == self.populations.as_slice() {
            return Ok(());
        }
        self.populations = populations.to_vec();
        let sol = grin::solve(&self.believed, &self.populations)?;
        self.install_global(sol.state)
    }

    fn gather(&self) -> Result<Vec<ShardSnapshot>> {
        self.shards.iter().map(ShardLeader::snapshot).collect()
    }

    /// Split a global target into per-shard slices and install them all
    /// under one incremented epoch (the atomic push-back).
    fn install_global(&mut self, target: StateMatrix) -> Result<()> {
        self.epoch += 1;
        let epoch = self.epoch;
        let k = target.types();
        for leader in &mut self.shards {
            let devs = leader.devices().to_vec();
            let mut local = StateMatrix::zeros(k, devs.len());
            for i in 0..k {
                for (lj, &j) in devs.iter().enumerate() {
                    local.set(i, lj, target.get(i, j));
                }
            }
            let solved = mu_columns(&self.believed, &devs)?;
            leader.install(epoch, local, solved)?;
        }
        Ok(())
    }
}

/// Stitch per-shard snapshots into the global k×l view: estimator-backed
/// μ̂ columns (boot prior where cold) and the occupancy matrix.
fn assemble(
    believed: &AffinityMatrix,
    snaps: &[ShardSnapshot],
) -> Result<(AffinityMatrix, StateMatrix)> {
    let (k, l) = (believed.types(), believed.procs());
    let mut rows = vec![vec![0.0f64; l]; k];
    let mut occ = StateMatrix::zeros(k, l);
    for snap in snaps {
        for (lj, &j) in snap.devices.iter().enumerate() {
            for (i, row) in rows.iter_mut().enumerate() {
                row[j] = snap.mu_hat.rate(i, lj);
                occ.set(i, j, snap.occupancy.get(i, lj));
            }
        }
    }
    Ok((AffinityMatrix::from_rows(&rows)?, occ))
}

/// Project a gathered occupancy snapshot onto the configured populations
/// so the warm start is feasible (in-flight counts skew a task or two
/// from the closed-system populations at gather time): drain surpluses
/// from the fullest cells, fill deficits on the fastest column.
fn project_to_populations(
    mu: &AffinityMatrix,
    occ: &StateMatrix,
    populations: &[u32],
) -> StateMatrix {
    let mut n = occ.clone();
    for (i, &want) in populations.iter().enumerate() {
        while n.row_sum(i) > want {
            let j = (0..n.procs())
                .max_by_key(|&j| n.get(i, j))
                .expect("at least one processor");
            n.dec(i, j).expect("fullest cell is non-empty");
        }
        while n.row_sum(i) < want {
            n.inc(i, mu.best_proc(i));
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload;

    fn control(shards: usize) -> ShardedControl {
        let mu = workload::three_class_mu();
        ShardedControl::new(&mu, &[8, 8, 8], shards, &DriftConfig::default(), 100)
            .unwrap()
    }

    #[test]
    fn boot_installs_one_epoch_everywhere() {
        let ctl = control(3);
        assert_eq!(ctl.shards().len(), 3);
        assert_eq!(ctl.epoch(), 1);
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), 1, "torn epoch at boot");
        }
        // The split targets re-assemble to the configured populations.
        let per_class: Vec<u32> = (0..3)
            .map(|i| ctl.shards().iter().map(|s| s.target().row_sum(i)).sum())
            .collect();
        assert_eq!(per_class, vec![8, 8, 8]);
        // shards = 0 means one per device.
        assert_eq!(control(0).shards().len(), 3);
    }

    #[test]
    fn routing_covers_the_fleet_and_completion_round_trips() {
        let mut ctl = control(3);
        let mut routed = vec![0u32; 3];
        let mut placements = Vec::new();
        for class in 0..3 {
            for _ in 0..8 {
                let j = ctl.route(class);
                assert!(j < 3);
                routed[j] += 1;
                placements.push((class, j));
            }
        }
        assert_eq!(routed.iter().sum::<u32>(), 24);
        for &(class, j) in &placements {
            ctl.on_complete(class, j, 0.1).unwrap();
        }
        // All occupancy drained.
        for leader in ctl.shards() {
            for i in 0..3 {
                assert_eq!(leader.occupancy().row_sum(i), 0);
            }
        }
        assert!(ctl.on_complete(0, 99, 0.1).is_err());
    }

    #[test]
    fn sync_is_a_noop_without_drift_and_atomic_with_it() {
        let mut ctl = control(3);
        // No observations: no drift, no re-solve.
        assert!(!ctl.sync().unwrap());
        assert_eq!(ctl.resolves(), 0);
        // Feed every cell service times matching the flipped matrix
        // through the normal route/complete cycle until warm.
        let flipped = workload::three_class_mu()
            .scaled(&workload::three_class_flip_scale())
            .unwrap();
        for _ in 0..64 {
            for class in 0..3 {
                let j = ctl.route(class);
                ctl.on_complete(class, j, 1.0 / flipped.rate(class, j)).unwrap();
            }
        }
        // By now at least one sync ran (sync_every = 100 < 192
        // completions) and the drifted cells forced a batched re-solve.
        assert!(ctl.resolves() >= 1, "no batched re-solve under drift");
        assert!(ctl.epoch() > 1);
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after sync");
        }
        assert!(ctl.batched_moves() > 0);
    }

    #[test]
    fn cusum_trigger_drives_batched_resolve() {
        use crate::sim::dynamic::Trigger;
        let mu = workload::three_class_mu();
        let drift = DriftConfig {
            min_obs: 4,
            trigger: Trigger::Cusum,
            ..Default::default()
        };
        let mut ctl = ShardedControl::new(&mu, &[8, 8, 8], 3, &drift, 50).unwrap();
        // Service times matching the believed rates: syncs pass, no
        // alarms, no re-solves.
        for _ in 0..30 {
            for class in 0..3 {
                let j = ctl.route(class);
                ctl.on_complete(class, j, 1.0 / mu.rate(class, j)).unwrap();
            }
        }
        assert_eq!(ctl.resolves(), 0, "false alarm on on-reference load");
        // Flip the physics: per-cell CUSUM alarms, the next sync
        // re-solves and installs a new epoch everywhere.
        let flipped = mu.scaled(&workload::three_class_flip_scale()).unwrap();
        for _ in 0..40 {
            for class in 0..3 {
                let j = ctl.route(class);
                ctl.on_complete(class, j, 1.0 / flipped.rate(class, j)).unwrap();
            }
        }
        assert!(ctl.resolves() >= 1, "no CUSUM-triggered batched re-solve");
        for leader in ctl.shards() {
            assert_eq!(leader.epoch(), ctl.epoch(), "torn epoch after CUSUM sync");
        }
    }

    #[test]
    fn population_swap_pushes_new_targets_under_new_epoch() {
        let mut ctl = control(3);
        let e0 = ctl.epoch();
        ctl.set_populations(&[2, 2, 20]).unwrap();
        assert_eq!(ctl.epoch(), e0 + 1);
        let per_class: Vec<u32> = (0..3)
            .map(|i| ctl.shards().iter().map(|s| s.target().row_sum(i)).sum())
            .collect();
        assert_eq!(per_class, vec![2, 2, 20]);
        assert!(ctl.set_populations(&[1, 1]).is_err());
    }

    #[test]
    fn projection_restores_populations() {
        let mu = workload::three_class_mu();
        let mut occ = StateMatrix::zeros(3, 3);
        // Row 0 over by one, row 1 under by two, row 2 exact.
        occ.set(0, 0, 5);
        occ.set(0, 1, 4);
        occ.set(1, 1, 6);
        occ.set(2, 2, 8);
        let n = project_to_populations(&mu, &occ, &[8, 8, 8]);
        n.check_populations(&[8, 8, 8]).unwrap();
        // Surplus drained from the fullest cell of row 0.
        assert_eq!(n.get(0, 0), 4);
    }
}
