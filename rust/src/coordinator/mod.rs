//! Serving-style coordinator: request router + dynamic batcher + leader.
//!
//! The paper's policies decide *where* work runs; this module embeds them
//! in a live serving loop (the L3 mandate): open- or closed-loop clients
//! submit requests of different classes (sort-type / NN-type), the
//! [`router`] applies any [`crate::policy::Policy`] against live queue
//! state, the [`batcher`] coalesces NN requests into PJRT-batch-sized
//! kernel launches (`nn_small` executes 8 rows per call), and [`stats`]
//! reports throughput + latency percentiles.
//!
//! Python never appears here: workers execute AOT artifacts through
//! [`crate::runtime::Engine`].
//!
//! For fleets with more than two device classes the single leader
//! shards: [`shard`] holds the per-device-class [`ShardLeader`]s (local
//! routing, occupancy, cold-started estimation) and [`global`] the
//! gather / batched-GrIn-re-solve / epoch-versioned push-back loop that
//! steers them ([`ShardedControl`]), used by both `hetsched serve
//! --shards N` and the simulator's `sharded` resolve mode.
//!
//! For heavy front-end traffic the routing hot path itself goes
//! concurrent: [`frontend`] holds the [`ConcurrentRouter`] — routing
//! threads steer against epoch-versioned [`TargetSnapshot`]s (the
//! `(epoch, target, solved_mu, weights)` tuple swapped as one unit,
//! exactly the [`router::TargetUpdate`] payload the single-threaded
//! [`Router`] applies) over a grid of atomic occupancy counters, so
//! target installs never block routing (`serve --frontend-threads N`).
//! [`batcher`] doubles as the router-level request coalescer
//! (`serve --batch N --batch-deadline`), deadline-driven by an injected
//! [`batcher::Clock`].

pub mod batcher;
pub mod frontend;
pub mod global;
pub mod leader;
pub mod router;
pub mod shard;
pub mod stats;

pub use batcher::{Batch, Clock, DynamicBatcher, MonotonicClock, VirtualClock};
pub use frontend::{ConcurrentRouter, RouteHandle, TargetSnapshot};
pub use global::ShardedControl;
pub use leader::{Coordinator, CreditPop, CreditQueue, ServeConfig, ServeReport};
pub use router::{Router, RouterConfig, TargetUpdate};
pub use shard::{ShardLeader, ShardSnapshot};
pub use stats::{LatencyHistogram, RateEstimator};
