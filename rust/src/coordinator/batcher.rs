//! Dynamic batching of requests into fixed-size launches.
//!
//! Two serving layers share this batcher:
//!
//! * the NN kernel path — the `nn_small` artifact executes a fixed
//!   8-row batch per call, so single NN requests (one row each) are
//!   coalesced until either the batch fills or the oldest request
//!   exceeds the batching deadline — the classic serving
//!   throughput/latency knob (vLLM-style).  Unfilled slots are
//!   zero-padded (the kernel is shape-static).
//! * the router front end ([`crate::coordinator::ConcurrentRouter`]) —
//!   class-keyed request coalescing so one steering decision covers a
//!   whole batch (`serve --batch N --batch-deadline`).
//!
//! Deadlines are measured on an injected [`Clock`], not wall-clock
//! `Instant`: serving runs on the [`MonotonicClock`], while tests, the
//! simulator and the routing bench drive a [`VirtualClock`] so flush
//! order (`Full` vs `Deadline` vs `Drain`) is deterministic and
//! replayable under load.

// srclint: allow-file(index-reachable) — ring slots are addressed modulo the fixed capacity, always in range

use crate::sync::{Arc, AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Time source for batching deadlines, in seconds from an arbitrary
/// origin.  Monotone non-decreasing; only differences are meaningful.
pub trait Clock {
    /// Current time in seconds.
    fn now_s(&self) -> f64;
}

/// Real time: seconds since the clock was created (monotonic, never
/// wall-clock — immune to NTP steps).
#[derive(Debug, Clone)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    /// A clock whose origin is now.
    pub fn new() -> Self {
        // srclint: allow(instant-now) — this constructor IS the real Clock origin; consumers inject a Clock.
        Self { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_s(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

/// Simulated time: a shared, manually advanced clock.  Clones share
/// the same instant (an `Arc` over the f64 bits), so every batcher in
/// a test or sim run observes one consistent virtual now.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now_bits: Arc<AtomicU64>,
}

impl VirtualClock {
    /// A virtual clock at t = 0.
    pub fn new() -> Self {
        Self::default()
    }

    /// Jump to an absolute virtual time (seconds).
    pub fn set(&self, now_s: f64) {
        // ordering: Release pairs with the Acquire load in now_s — a
        // reader that sees the new instant sees everything the advancer
        // did before moving time.
        self.now_bits.store(now_s.to_bits(), Ordering::Release);
    }

    /// Advance by `dt` seconds.
    pub fn advance(&self, dt: f64) {
        self.set(self.now_s() + dt);
    }
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        // ordering: Acquire pairs with the Release store in set().
        f64::from_bits(self.now_bits.load(Ordering::Acquire))
    }
}

/// One pending request inside the batcher.
#[derive(Debug, Clone)]
pub struct Pending {
    /// Request id.
    pub id: u64,
    /// One row of activations (length = row width).
    pub row: Vec<f32>,
    /// Arrival time (wall latency accounting in the serving leader).
    pub arrived: Instant,
}

/// A flushed batch ready for kernel launch.
#[derive(Debug, Clone)]
pub struct Batch {
    /// The requests filling the batch (≤ capacity).
    pub requests: Vec<Pending>,
    /// Row-major input tensor (capacity × width, zero-padded).
    pub input: Vec<f32>,
    /// Why the batch flushed.
    pub reason: FlushReason,
}

/// What triggered a flush.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The batch is full.
    Full,
    /// The oldest pending request hit the deadline.
    Deadline,
    /// Explicit drain (shutdown).
    Drain,
}

/// Size/deadline-driven batcher over an injected [`Clock`].  The
/// default clock is the monotonic one, so `DynamicBatcher::new` keeps
/// its serving semantics; [`with_clock`](DynamicBatcher::with_clock)
/// swaps in a [`VirtualClock`] for deterministic tests and sims.
#[derive(Debug)]
pub struct DynamicBatcher<C: Clock = MonotonicClock> {
    capacity: usize,
    width: usize,
    deadline: Duration,
    pending: Vec<Pending>,
    clock: C,
    /// Clock stamp of the oldest pending request (the deadline anchor);
    /// `None` when empty.  FIFO: only the head can hit the deadline.
    oldest_s: Option<f64>,
}

impl DynamicBatcher<MonotonicClock> {
    /// `capacity` rows of `width` f32 each; flush after `deadline` at the
    /// latest, measured on a fresh monotonic clock.
    pub fn new(capacity: usize, width: usize, deadline: Duration) -> Self {
        Self::with_clock(capacity, width, deadline, MonotonicClock::new())
    }
}

impl<C: Clock> DynamicBatcher<C> {
    /// [`new`](DynamicBatcher::new) on an explicit time source.
    pub fn with_clock(capacity: usize, width: usize, deadline: Duration, clock: C) -> Self {
        assert!(capacity >= 1 && width >= 1);
        Self {
            capacity,
            width,
            deadline,
            pending: Vec::with_capacity(capacity),
            clock,
            oldest_s: None,
        }
    }

    /// Number of pending requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True if nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Offer a request; returns a batch if this push filled it.
    pub fn push(&mut self, p: Pending) -> Option<Batch> {
        debug_assert_eq!(p.row.len(), self.width);
        if self.pending.is_empty() {
            self.oldest_s = Some(self.clock.now_s());
        }
        self.pending.push(p);
        if self.pending.len() >= self.capacity {
            Some(self.flush(FlushReason::Full))
        } else {
            None
        }
    }

    /// Flush if the oldest pending request is past the deadline.
    pub fn poll(&mut self) -> Option<Batch> {
        match self.oldest_s {
            Some(t0) if self.clock.now_s() - t0 >= self.deadline.as_secs_f64() => {
                Some(self.flush(FlushReason::Deadline))
            }
            _ => None,
        }
    }

    /// Time until the current oldest request hits the deadline.
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.oldest_s.map(|t0| {
            let left = self.deadline.as_secs_f64() - (self.clock.now_s() - t0);
            Duration::try_from_secs_f64(left.max(0.0)).unwrap_or(Duration::ZERO)
        })
    }

    /// Drain whatever is pending (shutdown path).
    pub fn drain(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.flush(FlushReason::Drain))
        }
    }

    fn flush(&mut self, reason: FlushReason) -> Batch {
        self.oldest_s = None;
        let requests: Vec<Pending> = self.pending.drain(..).collect();
        let mut input = vec![0f32; self.capacity * self.width];
        for (i, r) in requests.iter().enumerate() {
            input[i * self.width..(i + 1) * self.width].copy_from_slice(&r.row);
        }
        Batch { requests, input, reason }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, width: usize) -> Pending {
        Pending { id, row: vec![id as f32; width], arrived: Instant::now() }
    }

    #[test]
    fn fills_then_flushes() {
        let mut b = DynamicBatcher::new(4, 8, Duration::from_millis(100));
        assert!(b.push(pending(0, 8)).is_none());
        assert!(b.push(pending(1, 8)).is_none());
        assert!(b.push(pending(2, 8)).is_none());
        let batch = b.push(pending(3, 8)).expect("full");
        assert_eq!(batch.reason, FlushReason::Full);
        assert_eq!(batch.requests.len(), 4);
        assert!(b.is_empty());
        // Row placement: request i occupies row i.
        assert_eq!(batch.input[0], 0.0);
        assert_eq!(batch.input[8], 1.0);
        assert_eq!(batch.input[3 * 8], 3.0);
    }

    #[test]
    fn deadline_flushes_partial_with_padding() {
        let mut b = DynamicBatcher::new(4, 2, Duration::from_millis(0));
        b.push(pending(7, 2));
        let batch = b.poll().expect("deadline hit");
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.input, vec![7.0, 7.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn poll_respects_deadline() {
        let mut b = DynamicBatcher::new(4, 2, Duration::from_secs(60));
        b.push(pending(1, 2));
        assert!(b.poll().is_none());
        assert!(b.time_to_deadline().unwrap() > Duration::from_secs(59));
    }

    #[test]
    fn drain_empties() {
        let mut b = DynamicBatcher::new(4, 2, Duration::from_secs(60));
        assert!(b.drain().is_none());
        b.push(pending(1, 2));
        let batch = b.drain().expect("drain");
        assert_eq!(batch.reason, FlushReason::Drain);
        assert!(b.is_empty());
    }

    #[test]
    fn virtual_deadline_is_deterministic() {
        // On the virtual clock the deadline boundary is exact — no
        // wall-clock slop.  Dyadic instants (powers of two) make every
        // f64 step representable, so the assertions are equalities.
        let clock = VirtualClock::new();
        let mut b =
            DynamicBatcher::with_clock(4, 2, Duration::from_millis(500), clock.clone());
        clock.set(1.0);
        b.push(pending(1, 2));
        assert!(b.poll().is_none());
        clock.advance(0.25);
        assert!(b.poll().is_none(), "250ms early must not flush");
        assert_eq!(b.time_to_deadline().unwrap(), Duration::from_millis(250));
        clock.advance(0.25);
        let batch = b.poll().expect("exact deadline flushes");
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(b.time_to_deadline(), None);
    }

    #[test]
    fn deadline_anchors_to_oldest_across_pushes() {
        // Later pushes must not reset the deadline anchor: the oldest
        // request's age decides, FIFO.
        let clock = VirtualClock::new();
        let mut b =
            DynamicBatcher::with_clock(4, 2, Duration::from_millis(500), clock.clone());
        b.push(pending(1, 2));
        clock.advance(0.375);
        b.push(pending(2, 2)); // young, but the head is 375ms old
        clock.advance(0.125);
        let batch = b.poll().expect("head aged out");
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(batch.requests.len(), 2);
        // A flush re-anchors: the next push starts a fresh deadline.
        b.push(pending(3, 2));
        assert!(b.poll().is_none());
        assert_eq!(b.time_to_deadline().unwrap(), Duration::from_millis(500));
    }

    #[test]
    fn flush_reason_ordering_full_deadline_drain() {
        // The canonical lifecycle order under load: a filling push wins
        // over an elapsed deadline (push is checked at arrival, before
        // any poll), the next partial batch ages out as Deadline, and
        // shutdown drains the remainder — [Full, Deadline, Drain],
        // deterministically, because time only moves when advanced.
        let clock = VirtualClock::new();
        let mut b =
            DynamicBatcher::with_clock(2, 1, Duration::from_millis(1), clock.clone());
        let mut reasons = Vec::new();
        b.push(pending(1, 1));
        clock.advance(1.0); // way past the deadline …
        if let Some(batch) = b.push(pending(2, 1)) {
            reasons.push(batch.reason); // … but the fill flushes first
        }
        b.push(pending(3, 1));
        clock.advance(1.0);
        if let Some(batch) = b.poll() {
            reasons.push(batch.reason);
        }
        b.push(pending(4, 1));
        if let Some(batch) = b.drain() {
            reasons.push(batch.reason);
        }
        assert_eq!(
            reasons,
            vec![FlushReason::Full, FlushReason::Deadline, FlushReason::Drain]
        );
    }

    #[test]
    fn virtual_clock_is_shared_across_clones() {
        let clock = VirtualClock::new();
        let handle = clock.clone();
        clock.set(42.0);
        assert_eq!(handle.now_s(), 42.0);
        handle.advance(8.0);
        assert_eq!(clock.now_s(), 50.0);
    }
}
