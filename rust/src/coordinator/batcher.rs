//! Dynamic batching of NN requests into PJRT-batch-sized launches.
//!
//! The `nn_small` artifact executes a fixed 8-row batch per call; single
//! NN requests (one row each) are coalesced until either the batch fills
//! or the oldest request exceeds the batching deadline — the classic
//! serving throughput/latency knob (vLLM-style).  Unfilled slots are
//! zero-padded (the kernel is shape-static).

use std::time::{Duration, Instant};

/// One pending request inside the batcher.
#[derive(Debug, Clone)]
pub struct Pending {
    /// Request id.
    pub id: u64,
    /// One row of activations (length = row width).
    pub row: Vec<f32>,
    /// Arrival time.
    pub arrived: Instant,
}

/// A flushed batch ready for kernel launch.
#[derive(Debug, Clone)]
pub struct Batch {
    /// The requests filling the batch (≤ capacity).
    pub requests: Vec<Pending>,
    /// Row-major input tensor (capacity × width, zero-padded).
    pub input: Vec<f32>,
    /// Why the batch flushed.
    pub reason: FlushReason,
}

/// What triggered a flush.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The batch is full.
    Full,
    /// The oldest pending request hit the deadline.
    Deadline,
    /// Explicit drain (shutdown).
    Drain,
}

/// Size/deadline-driven batcher.
#[derive(Debug)]
pub struct DynamicBatcher {
    capacity: usize,
    width: usize,
    deadline: Duration,
    pending: Vec<Pending>,
}

impl DynamicBatcher {
    /// `capacity` rows of `width` f32 each; flush after `deadline` at the
    /// latest.
    pub fn new(capacity: usize, width: usize, deadline: Duration) -> Self {
        assert!(capacity >= 1 && width >= 1);
        Self { capacity, width, deadline, pending: Vec::with_capacity(capacity) }
    }

    /// Number of pending requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True if nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Offer a request; returns a batch if this push filled it.
    pub fn push(&mut self, p: Pending) -> Option<Batch> {
        debug_assert_eq!(p.row.len(), self.width);
        self.pending.push(p);
        if self.pending.len() >= self.capacity {
            Some(self.flush(FlushReason::Full))
        } else {
            None
        }
    }

    /// Flush if the oldest pending request is past the deadline.
    pub fn poll(&mut self, now: Instant) -> Option<Batch> {
        match self.pending.first() {
            Some(oldest) if now.duration_since(oldest.arrived) >= self.deadline => {
                Some(self.flush(FlushReason::Deadline))
            }
            _ => None,
        }
    }

    /// Time until the current oldest request hits the deadline.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending.first().map(|p| {
            self.deadline
                .checked_sub(now.duration_since(p.arrived))
                .unwrap_or(Duration::ZERO)
        })
    }

    /// Drain whatever is pending (shutdown path).
    pub fn drain(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.flush(FlushReason::Drain))
        }
    }

    fn flush(&mut self, reason: FlushReason) -> Batch {
        let requests: Vec<Pending> = self.pending.drain(..).collect();
        let mut input = vec![0f32; self.capacity * self.width];
        for (i, r) in requests.iter().enumerate() {
            input[i * self.width..(i + 1) * self.width].copy_from_slice(&r.row);
        }
        Batch { requests, input, reason }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, width: usize) -> Pending {
        Pending { id, row: vec![id as f32; width], arrived: Instant::now() }
    }

    #[test]
    fn fills_then_flushes() {
        let mut b = DynamicBatcher::new(4, 8, Duration::from_millis(100));
        assert!(b.push(pending(0, 8)).is_none());
        assert!(b.push(pending(1, 8)).is_none());
        assert!(b.push(pending(2, 8)).is_none());
        let batch = b.push(pending(3, 8)).expect("full");
        assert_eq!(batch.reason, FlushReason::Full);
        assert_eq!(batch.requests.len(), 4);
        assert!(b.is_empty());
        // Row placement: request i occupies rows i.
        assert_eq!(batch.input[0], 0.0);
        assert_eq!(batch.input[8], 1.0);
        assert_eq!(batch.input[3 * 8], 3.0);
    }

    #[test]
    fn deadline_flushes_partial_with_padding() {
        let mut b = DynamicBatcher::new(4, 2, Duration::from_millis(0));
        b.push(pending(7, 2));
        let batch = b.poll(Instant::now()).expect("deadline hit");
        assert_eq!(batch.reason, FlushReason::Deadline);
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.input, vec![7.0, 7.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn poll_respects_deadline() {
        let mut b = DynamicBatcher::new(4, 2, Duration::from_secs(60));
        b.push(pending(1, 2));
        assert!(b.poll(Instant::now()).is_none());
        assert!(b.time_to_deadline(Instant::now()).unwrap() > Duration::from_secs(59));
    }

    #[test]
    fn drain_empties() {
        let mut b = DynamicBatcher::new(4, 2, Duration::from_secs(60));
        assert!(b.drain().is_none());
        b.push(pending(1, 2));
        let batch = b.drain().expect("drain");
        assert_eq!(batch.reason, FlushReason::Drain);
        assert!(b.is_empty());
    }
}
