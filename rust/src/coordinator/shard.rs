//! Shard leaders — the per-device-class control plane of the sharded
//! serving subsystem.
//!
//! One [`ShardLeader`] owns a slice of the device fleet (a device class,
//! or a cell of one): it routes arrivals within its slice by deficit
//! steering against an **epoch-versioned** local target, tracks local
//! occupancy, and runs its own [`RateEstimator`] (cold-started — cells
//! below `min_obs` observations never signal drift, see
//! `stats.rs`).  The global layer ([`super::global`]) periodically
//! gathers [`ShardSnapshot`]s, runs one batched GrIn re-solve over the
//! assembled k×l view, and pushes new targets back through
//! [`ShardLeader::install`].
//!
//! Change detection is per-shard and trigger-configurable
//! ([`crate::sim::dynamic::Trigger`]): the PR-1 polled drift threshold,
//! or the per-cell CUSUM detector that alarms within a bounded number
//! of completions of an abrupt rate flip.  Either way the snapshot's
//! `mu_hat` is **confidence-gated**: cells whose estimates went stale
//! (no sample for `stale_after` completions) report the rates the
//! current target was solved for instead of their frozen pre-flip
//! estimates, so the batched re-solve and both deficit-steering levels
//! never steer on dead data.
//!
//! **Epoch semantics:** a leader's `(epoch, target, solved_mu,
//! priorities)` tuple only ever changes together, in one `install`
//! call.  A route issued before the install steers wholly by the old
//! policy, one issued after wholly by the new — in-flight tasks never
//! observe a torn (half-old, half-new) target, and weighted steering
//! never mixes an old priority vector with a new target (the
//! weight-epoch consistency contract the global layer's
//! [`super::global::ShardedControl::sync`] relies on).  Occupancy is
//! keyed by (class, device) alone, so completions of tasks routed under
//! an earlier epoch still decrement correctly after any number of
//! swaps.
//!
//! The lock-free front end ([`super::frontend::ConcurrentRouter`])
//! reifies exactly this tuple as its immutable
//! [`super::frontend::TargetSnapshot`] — same atomicity contract,
//! enforced structurally (one `Arc` swap) instead of by a `&mut self`
//! install, so concurrent routing threads get it for free.
//!
//! The install-before-publish ordering this module's epoch semantics
//! rely on is model-checked: `tests/model_check.rs`
//! (`--features model`) exhaustively explores bounded interleavings of
//! shard installs against a concurrent gather and proves a gatherer
//! that observes the new global epoch never sees a stale shard — and
//! that inverting the publish order IS caught by the explorer.

// srclint: allow-file(index-reachable) — shard-local tables are sized at construction; indices are task slots the shard owns

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::state::StateMatrix;
use crate::policy::target::{pick_by_deficit, pick_by_weighted_deficit, weighted_deficit};
use crate::sim::dynamic::{DriftConfig, Trigger};

use super::stats::RateEstimator;

/// Partition `l` devices into `shards` contiguous, near-equal slices
/// (the first `l % shards` shards get the extra device).
pub fn partition_devices(l: usize, shards: usize) -> Result<Vec<Vec<usize>>> {
    if shards == 0 || shards > l {
        return Err(Error::Config(format!(
            "cannot split {l} devices into {shards} shards"
        )));
    }
    let base = l / shards;
    let extra = l % shards;
    let mut out = Vec::with_capacity(shards);
    let mut next = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push((next..next + len).collect());
        next += len;
    }
    Ok(out)
}

/// Extract the listed columns of `mu` into a shard-local matrix.
pub fn mu_columns(mu: &AffinityMatrix, cols: &[usize]) -> Result<AffinityMatrix> {
    let rows: Vec<Vec<f64>> = (0..mu.types())
        .map(|i| cols.iter().map(|&j| mu.rate(i, j)).collect())
        .collect();
    AffinityMatrix::from_rows(&rows)
}

/// What a shard reports to the global coordinator at gather time.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// The reporting shard.
    pub shard: usize,
    /// Epoch of the targets the shard is currently steering by.
    pub epoch: u64,
    /// Global device indices the shard owns (column order of the local
    /// matrices below).
    pub devices: Vec<usize>,
    /// Live local rate estimate μ̂, confidence-gated: prior-backed where
    /// cold, solved-rate-backed where stale.
    pub mu_hat: AffinityMatrix,
    /// Local occupancy (class × local device).
    pub occupancy: StateMatrix,
    /// Has the shard's change detector fired — threshold drift past the
    /// configured level, or a pending CUSUM alarm, per the configured
    /// [`crate::sim::dynamic::Trigger`]?
    pub drifted: bool,
    /// Local cells currently demoted to stale (local column indices).
    pub stale: Vec<(usize, usize)>,
    /// Per-cell estimate confidence (row-major class × local device,
    /// [`RateEstimator::confidences`]) — the weight-assembly input for
    /// the global layer's priority-weighted batched re-solve.
    pub confidence: Vec<f64>,
}

/// One shard's leader: local routing, occupancy, estimation.
#[derive(Debug)]
pub struct ShardLeader {
    id: usize,
    /// Global device indices owned by this shard (defines local column
    /// order).
    devices: Vec<usize>,
    /// The local columns of the rate matrix the current target was
    /// solved for (drift reference + routing tie-break).
    solved_mu: AffinityMatrix,
    estimator: RateEstimator,
    occupancy: StateMatrix,
    target: StateMatrix,
    epoch: u64,
    /// Change-detector configuration (trigger kind + knobs).
    drift: DriftConfig,
    /// Mean-normalized class priorities the installed target was solved
    /// under (empty = unweighted).  Swapped atomically with the target
    /// in [`install`](Self::install), so weighted deficit steering and
    /// the target always agree on the weight vector.
    norm_pri: Vec<f64>,
    /// Per-local-device liveness (churn): routing never picks a dead
    /// column, and snapshots mask dead columns so the global re-solve
    /// cannot place load on them.
    alive: Vec<bool>,
}

impl ShardLeader {
    /// A leader over `devices`, estimator seeded from the prior's local
    /// columns, steering target empty until the first
    /// [`install`](Self::install).
    pub fn new(
        id: usize,
        devices: Vec<usize>,
        prior: &AffinityMatrix,
        drift: &DriftConfig,
    ) -> Result<Self> {
        if devices.is_empty() {
            return Err(Error::Config(format!("shard {id} owns no devices")));
        }
        if devices.iter().any(|&d| d >= prior.procs()) {
            return Err(Error::Config(format!(
                "shard {id} device out of range (fleet has {})",
                prior.procs()
            )));
        }
        let local = mu_columns(prior, &devices)?;
        let estimator = RateEstimator::from_drift(&local, drift)?;
        let (k, ll) = (prior.types(), devices.len());
        Ok(Self {
            id,
            devices,
            solved_mu: local,
            estimator,
            occupancy: StateMatrix::zeros(k, ll),
            target: StateMatrix::zeros(k, ll),
            epoch: 0,
            drift: drift.clone(),
            norm_pri: Vec::new(),
            alive: vec![true; ll],
        })
    }

    /// Does the shard own at least one live device?
    pub fn has_alive(&self) -> bool {
        self.alive.iter().any(|&a| a)
    }

    /// Is this (global) device currently live?  Errors when the shard
    /// does not own it.
    pub fn is_alive(&self, device: usize) -> Result<bool> {
        Ok(self.alive[self.local_index(device)?])
    }

    /// Device-churn down signal: the (global) device stops routing, its
    /// estimator cells freeze ([`RateEstimator::mark_down`]), and its
    /// occupancy column clears — the simulator evacuates the resident
    /// tasks and re-routes them through [`route`](Self::route), which
    /// re-increments wherever they land, so completions keep balancing.
    pub fn mark_down(&mut self, device: usize) -> Result<()> {
        let lj = self.local_index(device)?;
        if !self.alive[lj] {
            return Ok(());
        }
        self.alive[lj] = false;
        self.estimator.mark_down(lj);
        for class in 0..self.occupancy.types() {
            while self.occupancy.get(class, lj) > 0 {
                self.occupancy.dec(class, lj)?;
            }
        }
        Ok(())
    }

    /// Device-churn recovery signal: the (global) device routes again
    /// and its estimator cells unfreeze with a clean CUSUM
    /// ([`RateEstimator::mark_up`]).
    pub fn mark_up(&mut self, device: usize) -> Result<()> {
        let lj = self.local_index(device)?;
        if self.alive[lj] {
            return Ok(());
        }
        self.alive[lj] = true;
        self.estimator.mark_up(lj);
        Ok(())
    }

    /// Shard id.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Epoch of the installed target.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Global device indices owned by this shard.
    pub fn devices(&self) -> &[usize] {
        &self.devices
    }

    /// The shard's streaming estimator.
    pub fn estimator(&self) -> &RateEstimator {
        &self.estimator
    }

    /// Local occupancy (class × local device).
    pub fn occupancy(&self) -> &StateMatrix {
        &self.occupancy
    }

    /// The installed local target.
    pub fn target(&self) -> &StateMatrix {
        &self.target
    }

    /// Shard-level class deficit (target row total − occupancy row
    /// total) — the global dispatch signal.
    pub fn class_deficit(&self, class: usize) -> i64 {
        self.target.row_sum(class) as i64 - self.occupancy.row_sum(class) as i64
    }

    /// Priority/confidence-weighted shard-level class deficit
    /// Σ_j w_ij·(N*_ij − N_ij), w_ij = normalized priority ×
    /// confidence discount — the global dispatch signal when
    /// priorities are installed: a deficit the shard's estimator barely
    /// trusts counts for less than one it has fresh data on.  Equals
    /// the plain [`class_deficit`](Self::class_deficit) (as f64) when
    /// no priorities are installed and every cell is fully confident.
    pub fn weighted_class_deficit(&self, class: usize) -> f64 {
        let pri = self.norm_pri.get(class).copied().unwrap_or(1.0);
        (0..self.devices.len())
            .map(|lj| {
                let w = pri * (1.0 + self.estimator.confidence(class, lj)) / 2.0;
                let d = self.target.get(class, lj) as i64
                    - self.occupancy.get(class, lj) as i64;
                // Claims are discounted; overflow counts at full size
                // (see `policy::target::weighted_deficit`).
                weighted_deficit(w, d)
            })
            .sum()
    }

    /// The mean-normalized priorities installed with the current target
    /// (empty = unweighted).
    pub fn norm_priorities(&self) -> &[f64] {
        &self.norm_pri
    }

    /// Fastest solved rate the shard offers `class` (global tie-break).
    pub fn best_rate(&self, class: usize) -> f64 {
        self.solved_mu
            .row(class)
            .iter()
            .fold(f64::NEG_INFINITY, |a, &b| a.max(b))
    }

    /// Has the shard's change detector fired?  Under
    /// [`Trigger::Threshold`] this is the polled drift metric against
    /// the rates the current target was solved for; under
    /// [`Trigger::Cusum`] it is a pending per-cell alarm.  Cold cells
    /// (below `min_obs` observations) never contribute either way — a
    /// freshly booted shard reports no change until its windows warm up.
    pub fn drifted(&self) -> bool {
        match self.drift.trigger {
            Trigger::Threshold => {
                self.estimator.drift(&self.solved_mu) > self.drift.threshold
            }
            Trigger::Cusum => self.estimator.alarm_pending(),
        }
    }

    /// Route one `class` arrival within the shard: largest local target
    /// deficit, ties to the faster solved rate then the lower device
    /// index.  Under installed priorities both deficit and rate are
    /// scaled by w_ij = normalized priority × confidence discount, so a
    /// deficit on a cell whose estimate went quiet is discounted
    /// against one the estimator actually trusts.  Down devices never
    /// win (sentinel scores no live column can lose to); `None` means
    /// every device in the shard is down — the caller routes elsewhere
    /// or surfaces [`crate::error::Error::NoCapacity`], never panics.
    /// Returns the chosen *global* device index.
    pub fn route(&mut self, class: usize) -> Option<usize> {
        let ll = self.devices.len();
        let deficit = |lj: usize| {
            self.target.get(class, lj) as i64 - self.occupancy.get(class, lj) as i64
        };
        let best = if self.norm_pri.is_empty() {
            pick_by_deficit((0..ll).map(|lj| {
                if self.alive[lj] {
                    (deficit(lj), self.solved_mu.rate(class, lj))
                } else {
                    (i64::MIN, f64::NEG_INFINITY)
                }
            }))
        } else {
            let pri = self.norm_pri[class];
            pick_by_weighted_deficit((0..ll).map(|lj| {
                if self.alive[lj] {
                    let w = pri * (1.0 + self.estimator.confidence(class, lj)) / 2.0;
                    (weighted_deficit(w, deficit(lj)), w * self.solved_mu.rate(class, lj))
                } else {
                    (f64::NEG_INFINITY, f64::NEG_INFINITY)
                }
            }))
        }
        .filter(|&lj| self.alive[lj])?;
        self.occupancy.inc(class, best);
        Some(self.devices[best])
    }

    /// Completion callback: `device` is the global index the task ran
    /// on, `service_s` its pure execution time (the estimator's signal).
    pub fn complete(&mut self, class: usize, device: usize, service_s: f64) -> Result<()> {
        let lj = self.local_index(device)?;
        self.occupancy.dec(class, lj)?;
        self.estimator.observe(class, lj, service_s);
        Ok(())
    }

    /// Completion of a re-dispatched (backup) task: occupancy
    /// bookkeeping only.  Its service time is remaining-work at the new
    /// device's rate — a systematically short, biased sample the
    /// estimator must not learn from.
    pub fn complete_silent(&mut self, class: usize, device: usize) -> Result<()> {
        let lj = self.local_index(device)?;
        self.occupancy.dec(class, lj)
    }

    /// Atomically swap the shard's routing policy: the (epoch, target,
    /// solved-rates, priorities) tuple changes in one call.
    /// `priorities` is the class-priority vector the target was solved
    /// under (empty = unweighted) — passing it here rather than through
    /// a separate setter is what makes a weight flip and its re-solved
    /// target indivisible: no route can ever steer a new target by an
    /// old weight vector or vice versa.
    pub fn install(
        &mut self,
        epoch: u64,
        target: StateMatrix,
        solved_mu: AffinityMatrix,
        priorities: &[u32],
    ) -> Result<()> {
        let (k, ll) = (self.occupancy.types(), self.devices.len());
        if !priorities.is_empty() {
            if priorities.len() != k {
                return Err(Error::Shape(format!(
                    "shard {} got {} priorities for {k} classes",
                    self.id,
                    priorities.len()
                )));
            }
            if priorities.iter().any(|&p| p == 0) {
                return Err(Error::Config("class priorities must be ≥ 1".into()));
            }
        }
        if target.types() != k || target.procs() != ll {
            return Err(Error::Shape(format!(
                "shard {} target is {}×{}, wants {k}×{ll}",
                self.id,
                target.types(),
                target.procs()
            )));
        }
        if solved_mu.types() != k || solved_mu.procs() != ll {
            return Err(Error::Shape(format!(
                "shard {} solved μ is {}×{}, wants {k}×{ll}",
                self.id,
                solved_mu.types(),
                solved_mu.procs()
            )));
        }
        // The CUSUM residuals (and the stale-cell fallback) follow the
        // newly installed belief; accumulated deviation from the *old*
        // solved rates is consumed by the swap.  A swap that does not
        // change the believed rates (population-only re-solves) keeps
        // the accumulated evidence — wiping it would restart detection
        // of a real flip that straddles population churn.
        if solved_mu.data() != self.solved_mu.data() {
            self.estimator.set_reference(&solved_mu)?;
        }
        self.target = target;
        self.solved_mu = solved_mu;
        // A trivial (empty or all-equal) vector clears weighting: the
        // equal-priorities ≡ unweighted contract extends to steering,
        // which also keeps confidence jitter out of unprioritized runs.
        self.norm_pri = if crate::policy::grin::trivial_priorities(priorities) {
            Vec::new()
        } else {
            let mean =
                priorities.iter().map(|&p| p as f64).sum::<f64>() / priorities.len() as f64;
            priorities.iter().map(|&p| p as f64 / mean).collect()
        };
        self.epoch = epoch;
        Ok(())
    }

    /// Drain pending CUSUM alarms without installing a new target —
    /// called by the global layer when a re-solve attempt failed, so
    /// the detector must re-accumulate before re-firing (the same
    /// back-off the single-leader paths get by draining before
    /// solving).
    pub fn reset_alarms(&mut self) {
        self.estimator.take_alarms();
    }

    /// The shard's report to the global gather.  `mu_hat` is
    /// confidence-gated (stale cells report the solved rates instead of
    /// their frozen estimates) and availability-masked: down columns
    /// report [`crate::model::affinity::DEAD_RATE`], so the batched
    /// re-solve keeps steering the fleet around dead devices on every
    /// sync, not just the one that reacted to the down signal.
    pub fn snapshot(&self) -> Result<ShardSnapshot> {
        let mut mu_hat = self.estimator.mu_hat_gated()?;
        for (lj, &a) in self.alive.iter().enumerate() {
            if !a {
                mu_hat = mu_hat.masked_column(lj)?;
            }
        }
        Ok(ShardSnapshot {
            shard: self.id,
            epoch: self.epoch,
            devices: self.devices.clone(),
            mu_hat,
            occupancy: self.occupancy.clone(),
            drifted: self.drifted(),
            stale: self.estimator.stale_cells(),
            confidence: self.estimator.confidences(),
        })
    }

    fn local_index(&self, device: usize) -> Result<usize> {
        self.devices
            .iter()
            .position(|&d| d == device)
            .ok_or_else(|| {
                Error::Config(format!("device {device} not in shard {}", self.id))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drift_cfg() -> DriftConfig {
        DriftConfig { min_obs: 8, ..Default::default() }
    }

    #[test]
    fn partition_is_contiguous_and_covers_fleet() {
        let parts = partition_devices(7, 3).unwrap();
        assert_eq!(parts, vec![vec![0, 1, 2], vec![3, 4], vec![5, 6]]);
        let flat: Vec<usize> = parts.into_iter().flatten().collect();
        assert_eq!(flat, (0..7).collect::<Vec<_>>());
        assert_eq!(partition_devices(3, 3).unwrap().len(), 3);
        assert!(partition_devices(2, 3).is_err());
        assert!(partition_devices(2, 0).is_err());
    }

    #[test]
    fn routes_by_deficit_within_shard_and_tracks_occupancy() {
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0, 7.0],
            vec![1.0, 8.0, 3.0, 2.0],
        ])
        .unwrap();
        // Shard over global devices {2, 3}.
        let mut leader = ShardLeader::new(1, vec![2, 3], &mu, &drift_cfg()).unwrap();
        // Target: class 0 → one task on each local device.
        let target = StateMatrix::new(2, 2, vec![1, 1, 0, 0]).unwrap();
        leader.install(1, target, mu_columns(&mu, &[2, 3]).unwrap(), &[]).unwrap();
        assert_eq!(leader.epoch(), 1);
        // Equal deficits: the tie goes to the faster column (μ(0,3)=7).
        assert_eq!(leader.route(0), Some(3));
        // Now only local device 0 (global 2) is under target.
        assert_eq!(leader.route(0), Some(2));
        assert_eq!(leader.class_deficit(0), 0);
        assert_eq!(leader.occupancy().get(0, 0), 1);
        leader.complete(0, 2, 0.25).unwrap();
        assert_eq!(leader.class_deficit(0), 1);
        // Completions on devices the shard does not own are rejected.
        assert!(leader.complete(0, 0, 0.25).is_err());
    }

    #[test]
    fn cold_shard_never_signals_drift() {
        // Satellite gate: a freshly booted shard's estimator windows are
        // shorter than the trust span (min_obs) — it must not report
        // drift no matter how far the few early samples sit from the
        // prior it was seeded with.
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let tight = DriftConfig { threshold: 0.01, ..drift_cfg() };
        let mut leader = ShardLeader::new(0, vec![0, 1], &mu, &tight).unwrap();
        assert!(!leader.drifted(), "cold shard drifted");
        // 7 samples, 10× slower than the prior: still below min_obs = 8.
        for _ in 0..7 {
            leader.occupancy.inc(0, 0);
            leader.complete(0, 0, 1.0).unwrap();
        }
        assert!(!leader.drifted(), "sub-min_obs window drifted");
        assert!(!leader.snapshot().unwrap().drifted);
        // The 8th observation warms the cell; the deviation now counts.
        leader.occupancy.inc(0, 0);
        leader.complete(0, 0, 1.0).unwrap();
        assert!(leader.drifted());
    }

    #[test]
    fn cusum_trigger_shard_alarms_and_install_resets() {
        // Under the CUSUM trigger the shard reports change via pending
        // per-cell alarms, and an install (new epoch/belief) consumes
        // them.
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let cfg = DriftConfig {
            min_obs: 4,
            trigger: Trigger::Cusum,
            cusum_delta: 0.25,
            cusum_h: 2.0,
            ..Default::default()
        };
        let mut leader = ShardLeader::new(0, vec![0, 1], &mu, &cfg).unwrap();
        assert!(!leader.drifted());
        // On-reference samples never alarm.
        for _ in 0..32 {
            leader.occupancy.inc(0, 0);
            leader.complete(0, 0, 0.1).unwrap();
        }
        assert!(!leader.drifted(), "alarmed on zero residual");
        // 2× slowdown: alarms within 3 mini-batches (12 completions).
        for _ in 0..12 {
            leader.occupancy.inc(0, 1);
            leader.complete(0, 1, 0.2).unwrap();
        }
        assert!(leader.drifted());
        assert!(leader.snapshot().unwrap().drifted);
        // Installing the re-solved belief consumes the alarm.
        let solved = AffinityMatrix::two_type(10.0, 5.0, 10.0, 10.0).unwrap();
        let target = StateMatrix::zeros(2, 2);
        leader.install(2, target, solved, &[]).unwrap();
        assert!(!leader.drifted(), "install did not consume the alarm");
        // The same service level now matches the belief: no re-alarm.
        for _ in 0..16 {
            leader.occupancy.inc(0, 1);
            leader.complete(0, 1, 0.2).unwrap();
        }
        assert!(!leader.drifted());
    }

    #[test]
    fn install_with_unchanged_rates_preserves_cusum_evidence() {
        // A population-only re-solve installs new targets against the
        // *unchanged* believed rates (the global layer's
        // set_populations path): the per-cell CUSUM accumulators must
        // survive it, or a real flip straddling population churn would
        // restart detection from zero after every swap.
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let cfg = DriftConfig {
            min_obs: 4,
            trigger: Trigger::Cusum,
            cusum_delta: 0.25,
            cusum_h: 2.0,
            ..Default::default()
        };
        let mut leader = ShardLeader::new(0, vec![0, 1], &mu, &cfg).unwrap();
        // Two mini-batches of 2×-slowdown evidence: g⁺ = 1.5, just
        // under h = 2.
        for _ in 0..8 {
            leader.occupancy.inc(0, 0);
            leader.complete(0, 0, 0.2).unwrap();
        }
        assert!(!leader.drifted(), "alarmed early");
        // Swap targets under the same solved rates.
        let same = mu_columns(&mu, &[0, 1]).unwrap();
        leader.install(2, StateMatrix::zeros(2, 2), same, &[]).unwrap();
        // One more batch crosses the threshold — only if the earlier
        // evidence survived the install.
        for _ in 0..4 {
            leader.occupancy.inc(0, 0);
            leader.complete(0, 0, 0.2).unwrap();
        }
        assert!(leader.drifted(), "unchanged-rate install wiped CUSUM evidence");
        // A swap that *does* change the rates still resets (covered in
        // cusum_trigger_shard_alarms_and_install_resets).
    }

    #[test]
    fn snapshot_gates_stale_cells_to_solved_rates() {
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let cfg = DriftConfig { min_obs: 4, stale_after: 30, ..Default::default() };
        let mut leader = ShardLeader::new(0, vec![0, 1], &mu, &cfg).unwrap();
        // Warm local cell (0, 0) at a 5× slower level.
        for _ in 0..8 {
            leader.occupancy.inc(0, 0);
            leader.complete(0, 0, 0.5).unwrap();
        }
        let snap = leader.snapshot().unwrap();
        assert!((snap.mu_hat.rate(0, 0) - 2.0).abs() < 0.01, "live estimate reported");
        assert!(snap.stale.is_empty());
        // Abandon the cell: 31 completions elsewhere demote it.
        for _ in 0..31 {
            leader.occupancy.inc(1, 1);
            leader.complete(1, 1, 0.1).unwrap();
        }
        let snap = leader.snapshot().unwrap();
        assert_eq!(snap.stale, vec![(0, 0)]);
        // The gather sees the solved rate, not the frozen 2.0 estimate.
        assert!((snap.mu_hat.rate(0, 0) - 10.0).abs() < 1e-9, "stale cell not gated");
    }

    #[test]
    fn install_swaps_priorities_atomically_with_target() {
        // The weight-epoch contract: priorities only change through
        // install, together with the target they were solved under.
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0, 7.0],
            vec![1.0, 8.0, 3.0, 2.0],
        ])
        .unwrap();
        let mut leader = ShardLeader::new(1, vec![2, 3], &mu, &drift_cfg()).unwrap();
        assert!(leader.norm_priorities().is_empty());
        let target = StateMatrix::new(2, 2, vec![1, 1, 0, 0]).unwrap();
        let local = mu_columns(&mu, &[2, 3]).unwrap();
        leader.install(1, target.clone(), local.clone(), &[3, 1]).unwrap();
        // Normalized to mean 1: [1.5, 0.5].
        assert!((leader.norm_priorities()[0] - 1.5).abs() < 1e-12);
        assert!((leader.norm_priorities()[1] - 0.5).abs() < 1e-12);
        // With uniform (cold) confidence the weighted tie-break agrees
        // with the unweighted one: equal deficits → faster device (3).
        assert_eq!(leader.route(0), Some(3));
        // Weighted shard deficit scales by the class priority: one
        // class-0 slot left, w = 1.5 × (1 + 0)/2.
        assert!((leader.weighted_class_deficit(0) - 0.75).abs() < 1e-12);
        // Bad priority vectors are rejected before anything swaps.
        assert!(leader.install(2, target.clone(), local.clone(), &[1]).is_err());
        assert!(leader.install(2, target.clone(), local.clone(), &[0, 1]).is_err());
        // An empty vector clears weighting atomically with the swap.
        leader.install(2, target, local, &[]).unwrap();
        assert!(leader.norm_priorities().is_empty());
    }

    #[test]
    fn weighted_route_discounts_low_confidence_cells() {
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let cfg = DriftConfig { min_obs: 4, stale_after: 100, ..drift_cfg() };
        let mut leader = ShardLeader::new(0, vec![0, 1], &mu, &cfg).unwrap();
        // Target: one class-0 slot on each device; equal rates.  The
        // priority vector must be non-trivial, or install clears
        // weighting entirely (equal priorities ≡ unweighted).
        let target = StateMatrix::new(2, 2, vec![1, 1, 0, 0]).unwrap();
        leader.install(1, target, mu_columns(&mu, &[0, 1]).unwrap(), &[2, 1]).unwrap();
        // Warm only cell (0, 1): its confidence rises to 1 while (0, 0)
        // stays cold at 0 — the weighted deficit now prefers device 1
        // even though the unweighted tie-break would pick device 0.
        for _ in 0..4 {
            leader.occupancy.inc(0, 1);
            leader.complete(0, 1, 0.1).unwrap();
        }
        assert_eq!(leader.route(0), Some(1), "weighted route ignored confidence");
    }

    #[test]
    fn down_devices_never_route_and_all_down_returns_none() {
        // Satellite gate: an all-down shard yields None (routed
        // elsewhere by the global layer), never a panic — and a dead
        // column never wins even with the largest deficit.
        let mu = AffinityMatrix::from_rows(&[
            vec![10.0, 2.0, 4.0, 7.0],
            vec![1.0, 8.0, 3.0, 2.0],
        ])
        .unwrap();
        let mut leader = ShardLeader::new(1, vec![2, 3], &mu, &drift_cfg()).unwrap();
        let target = StateMatrix::new(2, 2, vec![3, 1, 0, 0]).unwrap();
        leader.install(1, target, mu_columns(&mu, &[2, 3]).unwrap(), &[]).unwrap();
        // Device 2 (local 0) has the larger deficit but is down: routes
        // land on 3.
        leader.mark_down(2).unwrap();
        assert!(!leader.is_alive(2).unwrap());
        assert!(leader.has_alive());
        assert_eq!(leader.route(0), Some(3));
        // Whole shard down → None, and the snapshot masks both columns.
        leader.mark_down(3).unwrap();
        assert!(!leader.has_alive());
        assert_eq!(leader.route(0), None);
        assert_eq!(leader.route(1), None);
        let snap = leader.snapshot().unwrap();
        assert!(snap.mu_hat.rate(0, 0) < 1e-6, "dead column not masked in snapshot");
        assert!(snap.mu_hat.rate(0, 1) < 1e-6, "dead column not masked in snapshot");
        // Weighted steering honors liveness the same way.
        let target = StateMatrix::new(2, 2, vec![3, 1, 0, 0]).unwrap();
        leader.install(2, target, mu_columns(&mu, &[2, 3]).unwrap(), &[3, 1]).unwrap();
        assert_eq!(leader.route(0), None, "weighted route picked a dead device");
        // Recovery restores routing; re-marking up is idempotent.
        leader.mark_up(2).unwrap();
        leader.mark_up(2).unwrap();
        assert_eq!(leader.route(0), Some(2));
        // Devices the shard does not own are rejected, not ignored.
        assert!(leader.mark_down(0).is_err());
        assert!(leader.is_alive(7).is_err());
    }

    #[test]
    fn mark_down_clears_occupancy_so_evacuated_work_rebalances() {
        // The simulator drains a dead device and re-routes the residents
        // through route(); if the shard kept the dead column's
        // occupancy, those tasks would be double-counted and completions
        // would underflow the balance.
        let mu = AffinityMatrix::two_type(10.0, 8.0, 3.0, 9.0).unwrap();
        let mut leader = ShardLeader::new(0, vec![0, 1], &mu, &drift_cfg()).unwrap();
        let target = StateMatrix::new(2, 2, vec![2, 2, 0, 0]).unwrap();
        leader.install(1, target, mu_columns(&mu, &[0, 1]).unwrap(), &[]).unwrap();
        for _ in 0..4 {
            leader.route(0).unwrap();
        }
        assert_eq!(leader.occupancy().row_sum(0), 4);
        leader.mark_down(0).unwrap();
        assert_eq!(leader.occupancy().get(0, 0), 0, "dead column kept occupancy");
        // Evacuated tasks re-route to the survivor and complete cleanly.
        assert_eq!(leader.route(0), Some(1));
        leader.complete(0, 1, 0.1).unwrap();
    }

    #[test]
    fn install_validates_shapes() {
        let mu = AffinityMatrix::two_type(10.0, 10.0, 10.0, 10.0).unwrap();
        let mut leader = ShardLeader::new(0, vec![0], &mu, &drift_cfg()).unwrap();
        let wide = StateMatrix::zeros(2, 2);
        assert!(leader.install(1, wide, mu_columns(&mu, &[0]).unwrap(), &[]).is_err());
        let ok_target = StateMatrix::zeros(2, 1);
        assert!(leader.install(1, ok_target, mu.clone(), &[]).is_err());
        let ok_target = StateMatrix::zeros(2, 1);
        leader.install(1, ok_target, mu_columns(&mu, &[0]).unwrap(), &[]).unwrap();
    }
}
