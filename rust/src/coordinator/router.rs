//! Request router: live queue-state tracking + policy-driven placement.
//!
//! The router owns the authoritative occupancy matrix (requests in flight
//! per class × device) and per-device work estimates, hands a
//! [`SystemView`] to the configured [`Policy`] for every request, and
//! updates state on completion callbacks — the same contract the
//! simulator and the platform rig use, so any policy drops in unchanged.
//!
//! Construction and retargeting go through one surface each, mirroring
//! the [`SolveRequest`] redesign of the solve API:
//!
//! * [`RouterConfig`] + [`Router::build`] replace the old
//!   `new`/`with_weights`/`with_objective` constructor ladder (the old
//!   shapes remain as thin wrappers and route through it bit for bit).
//! * [`TargetUpdate`] + [`Router::apply`] replace the
//!   `retarget`/`retarget_weighted` split: one epoch-stamped payload
//!   `{μ, ω, weights, epoch}` carries every live target swap.  The same
//!   payload is what [`crate::coordinator::ConcurrentRouter`] snapshots
//!   on its lock-free path, so the single-threaded and concurrent front
//!   ends share one update type (and the atomicity contract of
//!   [`crate::coordinator::ShardLeader::install`]: everything in the
//!   tuple changes together, or not at all).

// srclint: allow-file(index-reachable) — routing matrices are k by l, fixed at build; class ids are range-checked at the API edge

use crate::error::{Error, Result};
use crate::model::affinity::AffinityMatrix;
use crate::model::objective::{Objective, PowerProfile};
use crate::model::state::StateMatrix;
use crate::policy::{Policy, PreparedTarget, SolveRequest, SystemView};
use crate::sim::rng::Rng;

/// One live routing-target swap: the payload a leader installs and a
/// router (single-threaded or concurrent) applies atomically.  Mirrors
/// the `(epoch, target, solved_mu, priorities)` tuple of
/// [`crate::coordinator::ShardLeader::install`]: μ, ω, the weight
/// vector and the epoch only ever change together.
#[derive(Debug, Clone)]
pub struct TargetUpdate {
    /// The (estimated) affinity matrix the new target is solved for.
    pub mu: AffinityMatrix,
    /// Matching mean service seconds per (class, device), row-major k×l.
    pub omega: Vec<f64>,
    /// Per-cell priority weights the solve runs under (row-major k×l;
    /// empty = unweighted).
    pub weights: Vec<f64>,
    /// Version of this install.  Routers record it; concurrent readers
    /// use it to detect a swap without locking.
    pub epoch: u64,
}

impl TargetUpdate {
    /// An unweighted update at epoch 0; stamp with
    /// [`with_epoch`](Self::with_epoch) before installing.
    pub fn new(mu: AffinityMatrix, omega: Vec<f64>) -> Self {
        Self { mu, omega, weights: Vec::new(), epoch: 0 }
    }

    /// Builder: attach a refreshed per-cell weight vector.
    pub fn with_weights(mut self, weights: Vec<f64>) -> Self {
        self.weights = weights;
        self
    }

    /// Builder: stamp the install version.
    pub fn with_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self
    }

    /// Shape-check μ against an expected k×l and ω against μ.
    pub fn validate_shape(&self, k: usize, l: usize) -> Result<()> {
        if self.mu.types() != k || self.mu.procs() != l {
            return Err(Error::Shape(format!(
                "target update matrix is {}×{}, router runs {k}×{l}",
                self.mu.types(),
                self.mu.procs(),
            )));
        }
        if self.omega.len() != k * l {
            return Err(Error::Shape("target update ω arity".into()));
        }
        Ok(())
    }
}

/// Everything a router needs at construction, in one value — the
/// [`SolveRequest`] of the routing layer.  Defaults reproduce the old
/// `Router::new` exactly; the builders layer weights and the objective
/// axis on top without a constructor ladder.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Measured affinity matrix (class × device).
    pub mu: AffinityMatrix,
    /// Mean service seconds per (class, device), row-major k×l.
    pub omega: Vec<f64>,
    /// Expected in-flight split driving the policy's target solve.
    pub expected_inflight: Vec<u32>,
    /// Tie-break RNG seed.
    pub seed: u64,
    /// Per-cell priority weights of the initial solve (empty =
    /// unweighted).
    pub weights: Vec<f64>,
    /// Objective every solve (initial and every applied update)
    /// optimizes.
    pub objective: Objective,
    /// Power model the objective is scored against.
    pub power: PowerProfile,
}

impl RouterConfig {
    /// Baseline config: throughput objective, default power model, no
    /// weights, seed 0.
    pub fn new(mu: AffinityMatrix, omega: Vec<f64>, expected_inflight: Vec<u32>) -> Self {
        Self {
            mu,
            omega,
            expected_inflight,
            seed: 0,
            weights: Vec::new(),
            objective: Objective::Throughput,
            power: PowerProfile::default(),
        }
    }

    /// Builder: tie-break RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder: per-cell priority weights (row-major k×l,
    /// [`crate::policy::grin::priority_weights`]).
    pub fn with_weights(mut self, weights: Vec<f64>) -> Self {
        self.weights = weights;
        self
    }

    /// Builder: solve for `objective` against `power`.  Non-throughput
    /// objectives are GrIn-only and exclude non-trivial weight vectors,
    /// exactly as [`crate::policy::grin::solve_request`] enforces.
    pub fn with_objective(mut self, objective: Objective, power: PowerProfile) -> Self {
        self.objective = objective;
        self.power = power;
        self
    }
}

/// The router.
pub struct Router {
    mu: AffinityMatrix,
    populations: Vec<u32>,
    state: StateMatrix,
    /// Mean service seconds per (class, device) — the work estimator.
    omega: Vec<f64>,
    /// Per-cell priority weights the current target was solved under
    /// (empty = unweighted); swapped together with the target in
    /// [`apply`](Self::apply).
    weights: Vec<f64>,
    /// Objective every solve (initial and applied updates) optimizes.
    objective: Objective,
    /// Power model the objective is scored against.
    power: PowerProfile,
    work: Vec<f64>,
    /// Per-device liveness: routes never land on a device marked down
    /// ([`mark_down`](Self::mark_down)), whatever the policy says.
    alive: Vec<bool>,
    policy: Box<dyn Policy>,
    rng: Rng,
    routed: u64,
    /// Epoch of the last applied [`TargetUpdate`] (0 = the boot solve).
    epoch: u64,
}

/// Run the policy's solve through one [`SolveRequest`] carrying the
/// update's weight vector and the router's objective axis — the single
/// prepare path shared by [`Router::build`], [`Router::apply`], the
/// concurrent front end and the simulator's dynamic resolve loop.
pub(crate) fn prepare_policy(
    policy: &mut dyn Policy,
    mu: &AffinityMatrix,
    populations: &[u32],
    weights: &[f64],
    objective: Objective,
    power: PowerProfile,
) -> Result<PreparedTarget> {
    let req = SolveRequest::new(mu, populations)
        .with_objective(objective, power)
        .with_weights(weights);
    policy.prepare(&req)
}

impl Router {
    /// Build a router from one [`RouterConfig`]: the initial target is
    /// solved through a [`SolveRequest`] assembled from the config.
    pub fn build(cfg: RouterConfig, mut policy: Box<dyn Policy>) -> Result<Self> {
        prepare_policy(
            policy.as_mut(),
            &cfg.mu,
            &cfg.expected_inflight,
            &cfg.weights,
            cfg.objective,
            cfg.power,
        )?;
        let (k, l) = (cfg.mu.types(), cfg.mu.procs());
        Ok(Self {
            state: StateMatrix::zeros(k, l),
            work: vec![0.0; l],
            alive: vec![true; l],
            mu: cfg.mu,
            populations: cfg.expected_inflight,
            omega: cfg.omega,
            weights: cfg.weights,
            objective: cfg.objective,
            power: cfg.power,
            policy,
            rng: Rng::new(cfg.seed),
            routed: 0,
            epoch: 0,
        })
    }

    /// Build a router; `omega[i*l + j]` is the measured mean service time
    /// of class i on device j (from [`crate::platform::measure`]).
    /// Wrapper over [`build`](Self::build) with a baseline
    /// [`RouterConfig`].
    pub fn new(
        mu: AffinityMatrix,
        omega: Vec<f64>,
        expected_inflight: Vec<u32>,
        policy: Box<dyn Policy>,
        seed: u64,
    ) -> Result<Self> {
        Self::build(RouterConfig::new(mu, omega, expected_inflight).with_seed(seed), policy)
    }

    /// [`new`](Self::new) with per-cell priority weights (row-major k×l,
    /// [`crate::policy::grin::priority_weights`]).  Wrapper over
    /// [`build`](Self::build).
    pub fn with_weights(
        mu: AffinityMatrix,
        omega: Vec<f64>,
        expected_inflight: Vec<u32>,
        policy: Box<dyn Policy>,
        seed: u64,
        weights: Vec<f64>,
    ) -> Result<Self> {
        Self::build(
            RouterConfig::new(mu, omega, expected_inflight)
                .with_seed(seed)
                .with_weights(weights),
            policy,
        )
    }

    /// [`with_weights`](Self::with_weights) under an explicit scheduling
    /// objective.  Wrapper over [`build`](Self::build).
    #[allow(clippy::too_many_arguments)]
    pub fn with_objective(
        mu: AffinityMatrix,
        omega: Vec<f64>,
        expected_inflight: Vec<u32>,
        policy: Box<dyn Policy>,
        seed: u64,
        weights: Vec<f64>,
        objective: Objective,
        power: PowerProfile,
    ) -> Result<Self> {
        Self::build(
            RouterConfig::new(mu, omega, expected_inflight)
                .with_seed(seed)
                .with_weights(weights)
                .with_objective(objective, power),
            policy,
        )
    }

    /// Route one request of `class`; returns the chosen device.  A
    /// policy pick that lands on a downed device is redirected to the
    /// least-loaded alive device; with every device down this is
    /// [`Error::NoCapacity`], never a panic.
    pub fn route(&mut self, class: usize) -> Result<usize> {
        let l = self.mu.procs();
        for j in 0..l {
            self.work[j] = (0..self.mu.types())
                .map(|i| self.state.get(i, j) as f64 * self.omega[i * l + j])
                .sum();
        }
        let view = SystemView {
            mu: &self.mu,
            state: &self.state,
            work: &self.work,
            populations: &self.populations,
        };
        let mut j = self.policy.dispatch(class, &view, &mut self.rng);
        if !self.alive[j] {
            let mut fallback: Option<usize> = None;
            for (cand, &up) in self.alive.iter().enumerate() {
                if up && fallback.map_or(true, |f| self.work[cand] < self.work[f]) {
                    fallback = Some(cand);
                }
            }
            j = fallback.ok_or_else(|| {
                Error::NoCapacity("every serving device is down".into())
            })?;
        }
        self.state.inc(class, j);
        self.routed += 1;
        Ok(j)
    }

    /// Mark `device` down: no further route lands on it.  In-flight
    /// requests keep draining through [`complete`](Self::complete) —
    /// only new placements are masked.  Pair with
    /// [`apply`](Self::apply) on a dead-column-masked μ̂ to move the
    /// solved target off the device too.  Idempotent.
    pub fn mark_down(&mut self, device: usize) -> Result<()> {
        self.liveness_slot(device).map(|j| self.alive[j] = false)
    }

    /// Revive `device`; routes may land on it again.  Idempotent.
    pub fn mark_up(&mut self, device: usize) -> Result<()> {
        self.liveness_slot(device).map(|j| self.alive[j] = true)
    }

    /// Is `device` currently routable?
    pub fn is_alive(&self, device: usize) -> Result<bool> {
        self.liveness_slot(device).map(|j| self.alive[j])
    }

    fn liveness_slot(&self, device: usize) -> Result<usize> {
        if device >= self.alive.len() {
            return Err(Error::Config(format!(
                "unknown device {device} in a {}-device fleet",
                self.alive.len()
            )));
        }
        Ok(device)
    }

    /// Completion callback.
    pub fn complete(&mut self, class: usize, device: usize) -> Result<()> {
        self.state.dec(class, device)
    }

    /// Apply one [`TargetUpdate`] without stopping traffic: the policy
    /// re-solves (`prepare`) against the update's μ under its weight
    /// vector, the work estimator picks up the matching ω, and in-flight
    /// requests keep draining under the live occupancy state.  The
    /// (μ, ω, weights, epoch) tuple swaps together or not at all — a
    /// failed solve leaves every field of the old target in place.
    pub fn apply(&mut self, update: &TargetUpdate) -> Result<()> {
        update.validate_shape(self.mu.types(), self.mu.procs())?;
        prepare_policy(
            self.policy.as_mut(),
            &update.mu,
            &self.populations,
            &update.weights,
            self.objective,
            self.power,
        )?;
        self.mu = update.mu.clone();
        self.omega = update.omega.clone();
        self.weights = update.weights.clone();
        self.epoch = update.epoch;
        Ok(())
    }

    /// Swap the routing target to a freshly estimated affinity matrix,
    /// keeping the current weight vector.  Wrapper over
    /// [`apply`](Self::apply) at the next epoch.
    pub fn retarget(&mut self, mu: AffinityMatrix, omega: Vec<f64>) -> Result<()> {
        let update = TargetUpdate::new(mu, omega)
            .with_weights(self.weights.clone())
            .with_epoch(self.epoch + 1);
        self.apply(&update)
    }

    /// [`retarget`](Self::retarget) with a refreshed weight vector (the
    /// adaptive loop recomputes priority × live confidence at every
    /// re-solve); target and weights swap in the same call.  Wrapper
    /// over [`apply`](Self::apply) at the next epoch.
    pub fn retarget_weighted(
        &mut self,
        mu: AffinityMatrix,
        omega: Vec<f64>,
        weights: Vec<f64>,
    ) -> Result<()> {
        let update = TargetUpdate::new(mu, omega)
            .with_weights(weights)
            .with_epoch(self.epoch + 1);
        self.apply(&update)
    }

    /// The affinity matrix the current routing target was solved for.
    pub fn mu(&self) -> &AffinityMatrix {
        &self.mu
    }

    /// Epoch of the target currently steering routes (0 until the first
    /// applied update).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Requests currently in flight.
    pub fn inflight(&self) -> u32 {
        self.state.total()
    }

    /// Total requests routed.
    pub fn routed(&self) -> u64 {
        self.routed
    }

    /// Live occupancy matrix.
    pub fn state(&self) -> &StateMatrix {
        &self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyKind;
    use crate::sim::workload;

    fn router(kind: PolicyKind) -> Router {
        let mu = workload::table3::p2_biased();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        Router::new(mu, omega, vec![10, 10], kind.build(), 7).unwrap()
    }

    #[test]
    fn tracks_inflight_state() {
        let mut r = router(PolicyKind::Cab);
        let d0 = r.route(0).unwrap();
        let d1 = r.route(1).unwrap();
        assert_eq!(r.inflight(), 2);
        assert_eq!(r.routed(), 2);
        r.complete(0, d0).unwrap();
        r.complete(1, d1).unwrap();
        assert_eq!(r.inflight(), 0);
        assert!(r.complete(0, 0).is_err()); // double-complete guarded
    }

    #[test]
    fn cab_routes_p2_biased_like_af() {
        // P2-biased AF target (N1, 1): all class-0 on the CPU, N2−1
        // class-1 slots on the CPU, exactly one class-1 slot on the GPU.
        let mut r = router(PolicyKind::Cab);
        for _ in 0..10 {
            assert_eq!(r.route(0).unwrap(), 0);
        }
        // Class-1: the CPU deficit (9) dominates until it fills …
        let mut placements = Vec::new();
        for _ in 0..10 {
            placements.push(r.route(1).unwrap());
        }
        assert_eq!(placements.iter().filter(|&&d| d == 0).count(), 9);
        assert_eq!(placements.iter().filter(|&&d| d == 1).count(), 1);
        // … and the full state is the AF target.
        assert_eq!(r.state().get(0, 0), 10);
        assert_eq!(r.state().get(1, 0), 9);
        assert_eq!(r.state().get(1, 1), 1);
    }

    #[test]
    fn retarget_swaps_policy_target_mid_stream() {
        // Start in the P2-biased regime, then retarget to the
        // general-symmetric matrix: CAB flips from AF (N1, 1) to BF.
        let mut r = router(PolicyKind::Cab);
        for _ in 0..4 {
            assert_eq!(r.route(0).unwrap(), 0); // AF sends class-0 to the CPU
        }
        let mu2 = workload::table3::general_symmetric();
        let omega2: Vec<f64> = mu2.data().iter().map(|&m| 1.0 / m).collect();
        r.retarget(mu2, omega2).unwrap();
        assert_eq!(r.epoch(), 1);
        // BF target: class-1 deficit now sits on the GPU.
        assert_eq!(r.route(1).unwrap(), 1);
        assert!((r.mu().rate(0, 0) - 928.0).abs() < 1e-12);
        // Shape mismatches are rejected.
        let bad = crate::model::affinity::AffinityMatrix::from_rows(&[
            vec![1.0, 2.0, 3.0],
            vec![3.0, 2.0, 1.0],
        ])
        .unwrap();
        let omega_bad = vec![1.0; 6];
        assert!(r.retarget(bad, omega_bad).is_err());
    }

    #[test]
    fn legacy_shapes_route_identically_to_config_and_apply() {
        // The constructor-ladder wrappers and the retarget pair must
        // reproduce the RouterConfig/apply surface bit for bit: same
        // placements for the same seeds and inputs.
        let mu = workload::table3::p2_biased();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        let mut old =
            Router::new(mu.clone(), omega.clone(), vec![10, 10], PolicyKind::Cab.build(), 7)
                .unwrap();
        let cfg = RouterConfig::new(mu.clone(), omega, vec![10, 10]).with_seed(7);
        let mut new = Router::build(cfg, PolicyKind::Cab.build()).unwrap();
        for i in 0..20 {
            let class = i % 2;
            assert_eq!(old.route(class).unwrap(), new.route(class).unwrap());
        }
        // retarget == apply at the next epoch with kept weights.
        let mu2 = workload::table3::general_symmetric();
        let omega2: Vec<f64> = mu2.data().iter().map(|&m| 1.0 / m).collect();
        old.retarget(mu2.clone(), omega2.clone()).unwrap();
        new.apply(&TargetUpdate::new(mu2, omega2).with_epoch(1)).unwrap();
        assert_eq!(old.epoch(), new.epoch());
        for i in 0..20 {
            let class = i % 2;
            assert_eq!(old.route(class).unwrap(), new.route(class).unwrap());
        }
        assert_eq!(old.state().data(), new.state().data());
    }

    #[test]
    fn failed_apply_keeps_old_target_whole() {
        // An update whose solve fails must not leave a half-swapped
        // (μ from the new target, weights from the old) router behind.
        let mu = workload::priority_mu();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        let w = crate::policy::grin::priority_weights(&[4, 1], &[1.0; 4], 2).unwrap();
        let mut r = Router::with_weights(
            mu.clone(),
            omega.clone(),
            vec![4, 16],
            PolicyKind::GrIn.build(),
            7,
            w.clone(),
        )
        .unwrap();
        // Wrong-arity weights fail the solve inside apply …
        let bad = TargetUpdate::new(mu.clone(), omega)
            .with_weights(vec![1.0; 3])
            .with_epoch(9);
        assert!(r.apply(&bad).is_err());
        // … and nothing changed: epoch still boot, steering unchanged.
        assert_eq!(r.epoch(), 0);
        assert_eq!(r.route(0).unwrap(), 0);
    }

    #[test]
    fn weighted_router_reserves_fast_device_for_high_priority() {
        let mu = workload::priority_mu();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        let w = crate::policy::grin::priority_weights(&[4, 1], &[1.0; 4], 2).unwrap();
        let mut r = Router::with_weights(
            mu.clone(),
            omega.clone(),
            vec![4, 16],
            PolicyKind::GrIn.build(),
            7,
            w,
        )
        .unwrap();
        // The 4:1 weighted target reserves device 0 for class 0: every
        // high-priority arrival lands there, all low-priority traffic
        // keeps off it.
        for _ in 0..4 {
            assert_eq!(r.route(0).unwrap(), 0);
        }
        for _ in 0..16 {
            assert_eq!(r.route(1).unwrap(), 1);
        }
        // A plain retarget keeps the weight vector: the re-solved
        // target still reserves device 0.
        r.retarget(mu, omega).unwrap();
        r.complete(0, 0).unwrap();
        assert_eq!(r.route(0).unwrap(), 0);
        // Non-uniform weights on a weight-blind policy are rejected.
        let mu2 = workload::priority_mu();
        let omega2: Vec<f64> = mu2.data().iter().map(|&m| 1.0 / m).collect();
        let w2 = crate::policy::grin::priority_weights(&[4, 1], &[1.0; 4], 2).unwrap();
        assert!(Router::with_weights(
            mu2,
            omega2,
            vec![4, 16],
            PolicyKind::Cab.build(),
            7,
            w2
        )
        .is_err());
    }

    #[test]
    fn objective_router_solves_under_energy() {
        use crate::model::energy::PowerScenario;
        let mu = workload::table3::general_symmetric();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        let power = PowerProfile::new(1.0, PowerScenario::Exponent(0.5));
        let mut r = Router::with_objective(
            mu.clone(),
            omega.clone(),
            vec![10, 10],
            PolicyKind::GrIn.build(),
            7,
            Vec::new(),
            Objective::EnergyPerTask,
            power,
        )
        .unwrap();
        assert!(r.route(0).unwrap() < 2);
        // Objective-blind policies reject loudly instead of silently
        // solving for throughput.
        assert!(Router::with_objective(
            mu,
            omega,
            vec![10, 10],
            PolicyKind::Cab.build(),
            7,
            Vec::new(),
            Objective::EnergyPerTask,
            power,
        )
        .is_err());
    }

    #[test]
    fn lb_balances_work() {
        // Near-symmetric service times so LB must alternate devices.
        let mu = crate::model::affinity::AffinityMatrix::two_type(10.0, 9.0, 3.0, 8.0)
            .unwrap();
        let omega: Vec<f64> = mu.data().iter().map(|&m| 1.0 / m).collect();
        let mut r = Router::new(
            mu,
            omega,
            vec![10, 10],
            PolicyKind::LoadBalance.build(),
            7,
        )
        .unwrap();
        let mut counts = [0usize; 2];
        for _ in 0..20 {
            counts[r.route(0).unwrap()] += 1;
        }
        assert!(counts[0] > 0 && counts[1] > 0, "{counts:?}");
    }

    #[test]
    fn down_device_is_masked_and_all_down_is_no_capacity() {
        // CAB's AF target sends every class-0 request to device 0; once
        // it's down they must redirect, and an all-down fleet is a typed
        // error rather than a panic.
        let mut r = router(PolicyKind::Cab);
        r.mark_down(0).unwrap();
        assert!(!r.is_alive(0).unwrap());
        for _ in 0..5 {
            assert_eq!(r.route(0).unwrap(), 1, "routed to a dead device");
        }
        r.mark_down(1).unwrap();
        match r.route(0) {
            Err(Error::NoCapacity(_)) => {}
            other => panic!("expected NoCapacity, got {other:?}"),
        }
        // In-flight requests on the dead device still complete.
        r.complete(0, 1).unwrap();
        // Recovery restores the policy's preferred placement; double
        // mark_up is a no-op.
        r.mark_up(0).unwrap();
        r.mark_up(0).unwrap();
        assert_eq!(r.route(0).unwrap(), 0);
        // Out-of-range devices are rejected.
        assert!(r.mark_down(5).is_err());
        assert!(r.is_alive(5).is_err());
    }
}
