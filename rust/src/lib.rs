//! # hetsched
//!
//! Production reproduction of *"Task Scheduling for Heterogeneous Multicore
//! Systems"* (Chen & Marculescu, 2017): optimal closed-system task
//! scheduling for heterogeneous processors.
//!
//! The paper's contributions, all implemented here:
//!
//! * **Model** ([`model`]): the closed-batch-network throughput function
//!   X(S) (Eq. 4 / Eq. 28), the affinity/power matrices and the six-regime
//!   classification of Table 1, energy & EDP (Eqs. 19–23), and the unified
//!   scheduling-objective axis ([`model::objective`]: throughput, energy,
//!   EDP, throughput-per-watt with an X floor).
//! * **CAB** ([`policy::cab`]): the analytically optimal
//!   Choose-between-Accelerate-the-fastest-and-Best-fit policy for two
//!   processor types (Lemma 4 / Table 1).
//! * **GrIn** ([`policy::grin`]): the greedy-increase heuristic for any
//!   number of processor types (Algorithms 1–2, Lemma 8), within 1.6% of
//!   the exhaustive optimum.
//! * **Baselines** ([`policy`]): Random, Best-Fit, Join-Shortest-Queue and
//!   perfect-information Load-Balancing, exactly as simulated in §5.
//! * **Solvers** ([`solver`]): the exhaustive integer oracle ("Opt") and an
//!   in-repo SLSQP (the paper's comparator [32]) over the relaxed problem,
//!   built on a dense-linalg + active-set-QP substrate.
//! * **Simulator** ([`sim`]): discrete-event closed batch network with
//!   PS / FCFS / LCFS disciplines and the four task-size distributions of
//!   §5 (exponential, bounded Pareto, uniform, constant).
//! * **Runtime** ([`runtime`]): PJRT CPU client executing the AOT-lowered
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) — the L1/L2 layers.
//! * **Platform** ([`platform`]): the §7 CPU+GPU testbed emulation — worker
//!   threads running *real* PJRT kernels with affinity-derived repetition
//!   counts, FCFS device queues, rate measurement (Table 3).
//! * **Coordinator** ([`coordinator`]): serving-style router + dynamic
//!   batcher + leader loop, so the policy suite drives a live system.
//!
//! Offline-substrate modules (no external crates available in this build
//! environment): [`cli`] (argument parsing), [`config`] (JSON/config
//! parsing), [`report`] (bench tables/series), [`testkit`] (property
//! testing), [`sim::rng`] (PCG64 + samplers).

pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod lint;
pub mod model;
pub mod platform;
pub mod policy;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod sync;
pub mod testkit;

pub use error::{Error, Result};

/// Crate-wide prelude for examples and benches.
pub mod prelude {
    pub use crate::error::{Error, Result};
    pub use crate::model::affinity::{AffinityMatrix, Regime};
    pub use crate::model::energy::{EnergyModel, PowerScenario};
    pub use crate::model::objective::{Objective, PowerProfile};
    pub use crate::model::state::StateMatrix;
    pub use crate::model::throughput;
    pub use crate::policy::{self, Policy, PolicyKind, PreparedTarget, SolveRequest};
    pub use crate::sim::distribution::Distribution;
    pub use crate::sim::engine::{ClosedNetwork, SimConfig};
    pub use crate::sim::metrics::SimResult;
    pub use crate::sim::processor::Discipline;
    pub use crate::sim::rng::Rng;
    pub use crate::solver::exhaustive::ExhaustiveSolver;
    pub use crate::solver::slsqp::Slsqp;
}
