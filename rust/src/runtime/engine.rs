//! PJRT execution engine: one compiled executable per artifact entry.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Executables are compiled lazily on
//! first use and cached for the lifetime of the engine (no retraces, no
//! recompiles on the hot path).
//!
//! `xla::PjRtLoadedExecutable` is not `Sync`; the platform/coordinator
//! layers therefore own one `Engine` per worker thread (engines share
//! nothing and PJRT CPU clients are cheap).

use std::cell::RefCell;
use std::collections::HashMap;

use crate::error::{Error, Result};

use super::artifacts::{ArtifactDir, EntryMeta};

/// Result of one NN workload execution.
#[derive(Debug, Clone, Copy)]
pub struct NnTaskResult {
    /// Checksum of the activations (numeric probe).
    pub checksum: f32,
    /// Elements produced.
    pub elems: usize,
}

/// Result of one sort workload execution.
#[derive(Debug, Clone)]
pub struct SortTaskResult {
    /// Sorted rows, row-major.
    pub rows: Vec<f32>,
    /// Checksum (must equal the input sum — sorting preserves it).
    pub checksum: f32,
}

/// The PJRT engine.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: ArtifactDir,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts: ArtifactDir) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifacts, cache: RefCell::new(HashMap::new()) })
    }

    /// Create over the default artifact location.
    pub fn open_default() -> Result<Self> {
        Self::new(ArtifactDir::open_default()?)
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Entry metadata.
    pub fn entry(&self, name: &str) -> Result<EntryMeta> {
        self.artifacts.entry(name).cloned()
    }

    /// Compile (or fetch from cache) an entry's executable.
    fn compiled(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let meta = self.artifacts.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with f32 inputs; returns the flattened f32 outputs
    /// of the result tuple (non-f32 leaves are skipped by `want` index).
    ///
    /// Inputs are validated against the manifest shapes.
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let meta = self.artifacts.entry(name)?;
        if inputs.len() != meta.arg_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs, manifest expects {}",
                inputs.len(),
                meta.arg_shapes.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            if data.len() != meta.arg_elems(i) {
                return Err(Error::Runtime(format!(
                    "{name}: arg {i} has {} elements, manifest expects {:?}",
                    data.len(),
                    meta.arg_shapes[i]
                )));
            }
            let dims: Vec<i64> = meta.arg_shapes[i].iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        self.compiled(name)?;
        let cache = self.cache.borrow();
        let exe = cache.get(name).expect("just compiled");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        if tuple.len() != meta.out_arity {
            return Err(Error::Runtime(format!(
                "{name}: result tuple arity {} vs manifest {}",
                tuple.len(),
                meta.out_arity
            )));
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for lit in tuple {
            // All shipped entries emit f32 leaves except throughput_eval's
            // best-index (i32) — surface those as f32 via i32 read.
            match lit.to_vec::<f32>() {
                Ok(v) => outs.push(v),
                Err(_) => {
                    let v = lit.to_vec::<i32>().map_err(|e| {
                        Error::Runtime(format!("{name}: unreadable output leaf: {e}"))
                    })?;
                    outs.push(v.into_iter().map(|x| x as f32).collect());
                }
            }
        }
        Ok(outs)
    }

    /// Run the NN workload entry (`nn2000` / `nn_small`).
    pub fn nn_task(&self, entry: &str, x: &[f32], w: &[f32], b: &[f32]) -> Result<NnTaskResult> {
        let outs = self.run_f32(entry, &[x, w, b])?;
        Ok(NnTaskResult { checksum: outs[1][0], elems: outs[0].len() })
    }

    /// Run the sort workload entry (`sort_small` / `sort_large`).
    pub fn sort_task(&self, entry: &str, rows: &[f32]) -> Result<SortTaskResult> {
        let outs = self.run_f32(entry, &[rows])?;
        let mut it = outs.into_iter();
        let rows = it.next().expect("arity checked");
        let checksum = it.next().expect("arity checked")[0];
        Ok(SortTaskResult { rows, checksum })
    }

    /// Evaluate the Eq.-28 objective for a padded candidate batch via the
    /// `throughput_eval` artifact: returns X_sys per candidate.
    ///
    /// `mu_padded` is `K_PAD×L_PAD` row-major, `batch` is
    /// `B×K_PAD×L_PAD`; B must match the artifact's baked batch size.
    pub fn throughput_batch(&self, mu_padded: &[f32], batch: &[f32]) -> Result<Vec<f32>> {
        let outs = self.run_f32("throughput_eval", &[mu_padded, batch])?;
        Ok(outs.into_iter().next().expect("arity checked"))
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests need built artifacts; they self-skip when
    //! `make artifacts` has not run (CI runs them via `make test`).
    use super::*;

    fn engine() -> Option<Engine> {
        match ArtifactDir::open_default() {
            Ok(a) => Some(Engine::new(a).expect("pjrt cpu client")),
            Err(_) => {
                eprintln!("skipping: artifacts not built");
                None
            }
        }
    }

    #[test]
    fn nn_small_executes_and_matches_oracle() {
        let Some(eng) = engine() else { return };
        // x = ones(8,256), w = I(256)*0.5, b = 0.25: y = relu(0.5+0.25).
        let x = vec![1.0f32; 8 * 256];
        let mut w = vec![0.0f32; 256 * 256];
        for i in 0..256 {
            w[i * 256 + i] = 0.5;
        }
        let b = vec![0.25f32; 256];
        let r = eng.nn_task("nn_small", &x, &w, &b).unwrap();
        assert_eq!(r.elems, 8 * 256);
        let want = 0.75f32 * (8 * 256) as f32;
        assert!((r.checksum - want).abs() < 0.5, "{} vs {want}", r.checksum);
    }

    #[test]
    fn sort_small_sorts() {
        let Some(eng) = engine() else { return };
        let mut rows = vec![0.0f32; 16 * 256];
        // Descending input per row.
        for r in 0..16 {
            for c in 0..256 {
                rows[r * 256 + c] = (256 - c) as f32 + r as f32;
            }
        }
        let input_sum: f32 = rows.iter().sum();
        let out = eng.sort_task("sort_small", &rows).unwrap();
        for r in 0..16 {
            let row = &out.rows[r * 256..(r + 1) * 256];
            assert!(row.windows(2).all(|w| w[0] <= w[1]), "row {r} unsorted");
        }
        assert!((out.checksum - input_sum).abs() / input_sum.abs() < 1e-5);
    }

    #[test]
    fn throughput_eval_matches_rust_objective() {
        let Some(eng) = engine() else { return };
        use crate::model::affinity::AffinityMatrix;
        use crate::model::state::StateMatrix;
        use crate::model::throughput::x_of_state;
        let (kp, lp, bsz) = (16usize, 16usize, 4096usize);
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let mut mu_p = vec![0f32; kp * lp];
        for i in 0..2 {
            for j in 0..2 {
                mu_p[i * lp + j] = mu.rate(i, j) as f32;
            }
        }
        let mut batch = vec![0f32; bsz * kp * lp];
        let mut states = Vec::new();
        let mut idx = 0;
        for n11 in 0..=10u32 {
            for n22 in 0..=10u32 {
                let s = StateMatrix::from_two_type(n11, n22, 10, 10).unwrap();
                let p = s.to_padded_f32(kp, lp).unwrap();
                batch[idx * kp * lp..(idx + 1) * kp * lp].copy_from_slice(&p);
                states.push(s);
                idx += 1;
            }
        }
        let xs = eng.throughput_batch(&mu_p, &batch).unwrap();
        assert_eq!(xs.len(), bsz);
        for (i, s) in states.iter().enumerate() {
            let want = x_of_state(&mu, s) as f32;
            assert!(
                (xs[i] - want).abs() < 1e-3 * want.max(1.0),
                "candidate {i}: pjrt {} vs rust {want}",
                xs[i]
            );
        }
        // Padding candidates evaluate to zero.
        assert_eq!(xs[idx], 0.0);
    }

    #[test]
    fn input_validation() {
        let Some(eng) = engine() else { return };
        assert!(eng.run_f32("nn_small", &[&[0.0]]).is_err()); // arity
        let bad = vec![0.0f32; 7];
        assert!(eng.run_f32("sort_small", &[&bad]).is_err()); // shape
        assert!(eng.run_f32("missing_entry", &[]).is_err());
    }
}
