//! Execution engine: one compiled executable per artifact entry.
//!
//! Two interchangeable backends sit behind the same [`Engine`] API:
//!
//! * **PJRT** (`--features pjrt`, requires a vendored `xla` crate):
//!   mirrors /opt/xla-example/load_hlo — `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `client.compile` → `execute`, with executables compiled lazily and
//!   cached for the engine's lifetime.
//! * **Native** (default): bit-faithful Rust implementations of the five
//!   shipped kernels (NN forward, sort network, Eq.-28 batch evaluator),
//!   so the platform rig, the serving coordinator and CI run without a
//!   Python/XLA toolchain.  When an artifact manifest is present its
//!   shapes are enforced exactly as the PJRT path would.
//!
//! Executables/engines are per worker thread in either mode
//! (`xla::PjRtLoadedExecutable` is not `Sync`; engines share nothing).

// srclint: allow-file(index-reachable) — kernel buffer shapes are fixed by the AOT artifact and checked at load

use crate::error::{Error, Result};

use super::artifacts::{ArtifactDir, EntryMeta};

/// Result of one NN workload execution.
#[derive(Debug, Clone, Copy)]
pub struct NnTaskResult {
    /// Checksum of the activations (numeric probe).
    pub checksum: f32,
    /// Elements produced.
    pub elems: usize,
}

/// Result of one sort workload execution.
#[derive(Debug, Clone)]
pub struct SortTaskResult {
    /// Sorted rows, row-major.
    pub rows: Vec<f32>,
    /// Checksum (must equal the input sum — sorting preserves it).
    pub checksum: f32,
}

/// Argument shapes and output arity of the shipped entries, used by the
/// native backend when no manifest is on disk.
fn native_meta(name: &str) -> Result<(Vec<Vec<usize>>, usize)> {
    match name {
        "nn2000" => Ok((vec![vec![32, 2048], vec![2048, 256], vec![256]], 2)),
        "nn_small" => Ok((vec![vec![8, 256], vec![256, 256], vec![256]], 2)),
        "sort_small" => Ok((vec![vec![16, 256]], 2)),
        "sort_large" => Ok((vec![vec![16, 1024]], 2)),
        "throughput_eval" => Ok((vec![vec![16, 16], vec![4096, 16, 16]], 2)),
        other => Err(Error::Runtime(format!("no native kernel entry '{other}'"))),
    }
}

/// The native kernel implementations (oracle-exact counterparts of the
/// AOT-lowered JAX/Pallas entries).
mod native {
    use super::{Error, Result};

    /// y = relu(x·w + b); returns `[y, [Σy]]`.
    pub fn nn_forward(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<Vec<f32>> {
        let mut y = vec![0f32; m * n];
        let mut checksum = 0f64;
        for r in 0..m {
            let row = &x[r * k..(r + 1) * k];
            for c in 0..n {
                let mut acc = b[c] as f64;
                for (t, &xv) in row.iter().enumerate() {
                    acc += xv as f64 * w[t * n + c] as f64;
                }
                if acc > 0.0 {
                    y[r * n + c] = acc as f32;
                    checksum += acc;
                }
            }
        }
        vec![y, vec![checksum as f32]]
    }

    /// Per-row ascending sort; returns `[sorted, [Σ input]]`.
    pub fn sort_rows(rows: &[f32], r: usize, w: usize) -> Vec<Vec<f32>> {
        let checksum: f64 = rows.iter().map(|&v| v as f64).sum();
        let mut out = rows.to_vec();
        for i in 0..r {
            out[i * w..(i + 1) * w].sort_by(f32::total_cmp);
        }
        vec![out, vec![checksum as f32]]
    }

    /// Eq. 28 over a padded candidate batch; returns `[X per candidate,
    /// [argmax index]]` (0/0 → 0, matching the Pallas kernel).
    pub fn throughput_eval(
        mu: &[f32],
        batch: &[f32],
        kp: usize,
        lp: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let cell = kp * lp;
        if batch.len() % cell != 0 {
            return Err(Error::Runtime("batch not a multiple of the cell size".into()));
        }
        let bsz = batch.len() / cell;
        let mut xs = vec![0f32; bsz];
        let mut best = 0usize;
        let mut best_x = f32::NEG_INFINITY;
        for (bi, x_out) in xs.iter_mut().enumerate() {
            let s = &batch[bi * cell..(bi + 1) * cell];
            let mut x = 0f64;
            for j in 0..lp {
                let mut num = 0f64;
                let mut den = 0f64;
                for i in 0..kp {
                    let nij = s[i * lp + j] as f64;
                    num += mu[i * lp + j] as f64 * nij;
                    den += nij;
                }
                if den > 0.0 {
                    x += num / den;
                }
            }
            *x_out = x as f32;
            if *x_out > best_x {
                best_x = *x_out;
                best = bi;
            }
        }
        Ok(vec![xs, vec![best as f32]])
    }
}

/// The execution engine (native backend; see the module docs for the
/// `--features pjrt` variant).
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    artifacts: Option<ArtifactDir>,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Create an engine over an artifact directory (shapes validated
    /// against its manifest).
    pub fn new(artifacts: ArtifactDir) -> Result<Self> {
        Ok(Self { artifacts: Some(artifacts) })
    }

    /// Create over the default artifact location; the native backend
    /// also runs manifest-free (built-in shapes for the shipped entries).
    pub fn open_default() -> Result<Self> {
        Ok(Self { artifacts: ArtifactDir::open_default().ok() })
    }

    /// Backend platform name.
    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Entry metadata (manifest when present, built-in table otherwise).
    pub fn entry(&self, name: &str) -> Result<EntryMeta> {
        if let Some(art) = &self.artifacts {
            return art.entry(name).cloned();
        }
        let (arg_shapes, out_arity) = native_meta(name)?;
        let arg_dtypes = vec!["float32".to_string(); arg_shapes.len()];
        Ok(EntryMeta {
            name: name.to_string(),
            path: std::path::PathBuf::from(format!("native:{name}")),
            arg_shapes,
            arg_dtypes,
            out_arity,
        })
    }

    /// Execute an entry with f32 inputs; returns the flattened f32
    /// outputs of the result tuple.  Inputs are validated against the
    /// manifest (or built-in) shapes.
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let meta = self.entry(name)?;
        if inputs.len() != meta.arg_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs, manifest expects {}",
                inputs.len(),
                meta.arg_shapes.len()
            )));
        }
        for (i, data) in inputs.iter().enumerate() {
            if data.len() != meta.arg_elems(i) {
                return Err(Error::Runtime(format!(
                    "{name}: arg {i} has {} elements, manifest expects {:?}",
                    data.len(),
                    meta.arg_shapes[i]
                )));
            }
        }
        match name {
            "nn2000" | "nn_small" => {
                let (m, k) = (meta.arg_shapes[0][0], meta.arg_shapes[0][1]);
                let n = meta.arg_shapes[2][0];
                Ok(native::nn_forward(inputs[0], inputs[1], inputs[2], m, k, n))
            }
            "sort_small" | "sort_large" => {
                let (r, w) = (meta.arg_shapes[0][0], meta.arg_shapes[0][1]);
                Ok(native::sort_rows(inputs[0], r, w))
            }
            "throughput_eval" => {
                let (kp, lp) = (meta.arg_shapes[0][0], meta.arg_shapes[0][1]);
                native::throughput_eval(inputs[0], inputs[1], kp, lp)
            }
            other => Err(Error::Runtime(format!(
                "no native implementation for entry '{other}'"
            ))),
        }
    }
}

/// The PJRT execution engine.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    artifacts: ArtifactDir,
    cache: std::cell::RefCell<std::collections::HashMap<String, xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifacts: ArtifactDir) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            artifacts,
            cache: std::cell::RefCell::new(std::collections::HashMap::new()),
        })
    }

    /// Create over the default artifact location.
    pub fn open_default() -> Result<Self> {
        Self::new(ArtifactDir::open_default()?)
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Entry metadata.
    pub fn entry(&self, name: &str) -> Result<EntryMeta> {
        self.artifacts.entry(name).cloned()
    }

    /// Compile (or fetch from cache) an entry's executable.
    fn compiled(&self, name: &str) -> Result<()> {
        if self.cache.borrow().contains_key(name) {
            return Ok(());
        }
        let meta = self.artifacts.entry(name)?;
        let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with f32 inputs; returns the flattened f32
    /// outputs of the result tuple.
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let meta = self.artifacts.entry(name)?;
        if inputs.len() != meta.arg_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs, manifest expects {}",
                inputs.len(),
                meta.arg_shapes.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            if data.len() != meta.arg_elems(i) {
                return Err(Error::Runtime(format!(
                    "{name}: arg {i} has {} elements, manifest expects {:?}",
                    data.len(),
                    meta.arg_shapes[i]
                )));
            }
            let dims: Vec<i64> = meta.arg_shapes[i].iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        self.compiled(name)?;
        let cache = self.cache.borrow();
        // srclint: allow(panic-reachable) — compiled(name) on the previous line just populated this cache entry
        let exe = cache.get(name).expect("just compiled");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        if tuple.len() != meta.out_arity {
            return Err(Error::Runtime(format!(
                "{name}: result tuple arity {} vs manifest {}",
                tuple.len(),
                meta.out_arity
            )));
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for lit in tuple {
            // All shipped entries emit f32 leaves except throughput_eval's
            // best-index (i32) — surface those as f32 via i32 read.
            match lit.to_vec::<f32>() {
                Ok(v) => outs.push(v),
                Err(_) => {
                    let v = lit.to_vec::<i32>().map_err(|e| {
                        Error::Runtime(format!("{name}: unreadable output leaf: {e}"))
                    })?;
                    outs.push(v.into_iter().map(|x| x as f32).collect());
                }
            }
        }
        Ok(outs)
    }
}

impl Engine {
    /// Run the NN workload entry (`nn2000` / `nn_small`).
    pub fn nn_task(&self, entry: &str, x: &[f32], w: &[f32], b: &[f32]) -> Result<NnTaskResult> {
        let outs = self.run_f32(entry, &[x, w, b])?;
        Ok(NnTaskResult { checksum: outs[1][0], elems: outs[0].len() })
    }

    /// Run the sort workload entry (`sort_small` / `sort_large`).
    pub fn sort_task(&self, entry: &str, rows: &[f32]) -> Result<SortTaskResult> {
        let outs = self.run_f32(entry, &[rows])?;
        let mut it = outs.into_iter();
        // srclint: allow(panic-reachable) — kernel output arity is fixed by the AOT artifact and checked at load
        let rows = it.next().expect("arity checked");
        // srclint: allow(panic-reachable) — kernel output arity is fixed by the AOT artifact and checked at load
        let checksum = it.next().expect("arity checked")[0];
        Ok(SortTaskResult { rows, checksum })
    }

    /// Evaluate the Eq.-28 objective for a padded candidate batch via the
    /// `throughput_eval` entry: returns X_sys per candidate.
    ///
    /// `mu_padded` is `K_PAD×L_PAD` row-major, `batch` is
    /// `B×K_PAD×L_PAD`; B must match the entry's baked batch size when a
    /// manifest is enforced.
    pub fn throughput_batch(&self, mu_padded: &[f32], batch: &[f32]) -> Result<Vec<f32>> {
        let outs = self.run_f32("throughput_eval", &[mu_padded, batch])?;
        // srclint: allow(panic-reachable) — kernel output arity is fixed by the AOT artifact and checked at load
        Ok(outs.into_iter().next().expect("arity checked"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nn_small_executes_and_matches_oracle() {
        let eng = Engine::open_default().expect("native engine always opens");
        // x = ones(8,256), w = I(256)*0.5, b = 0.25: y = relu(0.5+0.25).
        let x = vec![1.0f32; 8 * 256];
        let mut w = vec![0.0f32; 256 * 256];
        for i in 0..256 {
            w[i * 256 + i] = 0.5;
        }
        let b = vec![0.25f32; 256];
        let r = eng.nn_task("nn_small", &x, &w, &b).unwrap();
        assert_eq!(r.elems, 8 * 256);
        let want = 0.75f32 * (8 * 256) as f32;
        assert!((r.checksum - want).abs() < 0.5, "{} vs {want}", r.checksum);
    }

    #[test]
    fn sort_small_sorts() {
        let eng = Engine::open_default().unwrap();
        let mut rows = vec![0.0f32; 16 * 256];
        // Descending input per row.
        for r in 0..16 {
            for c in 0..256 {
                rows[r * 256 + c] = (256 - c) as f32 + r as f32;
            }
        }
        let input_sum: f32 = rows.iter().sum();
        let out = eng.sort_task("sort_small", &rows).unwrap();
        for r in 0..16 {
            let row = &out.rows[r * 256..(r + 1) * 256];
            assert!(row.windows(2).all(|w| w[0] <= w[1]), "row {r} unsorted");
        }
        assert!((out.checksum - input_sum).abs() / input_sum.abs() < 1e-5);
    }

    #[test]
    fn throughput_eval_matches_rust_objective() {
        let eng = Engine::open_default().unwrap();
        use crate::model::affinity::AffinityMatrix;
        use crate::model::state::StateMatrix;
        use crate::model::throughput::x_of_state;
        let (kp, lp, bsz) = (16usize, 16usize, 4096usize);
        let mu = AffinityMatrix::two_type(20.0, 15.0, 3.0, 8.0).unwrap();
        let mut mu_p = vec![0f32; kp * lp];
        for i in 0..2 {
            for j in 0..2 {
                mu_p[i * lp + j] = mu.rate(i, j) as f32;
            }
        }
        let mut batch = vec![0f32; bsz * kp * lp];
        let mut states = Vec::new();
        let mut idx = 0;
        for n11 in 0..=10u32 {
            for n22 in 0..=10u32 {
                let s = StateMatrix::from_two_type(n11, n22, 10, 10).unwrap();
                let p = s.to_padded_f32(kp, lp).unwrap();
                batch[idx * kp * lp..(idx + 1) * kp * lp].copy_from_slice(&p);
                states.push(s);
                idx += 1;
            }
        }
        let xs = eng.throughput_batch(&mu_p, &batch).unwrap();
        assert_eq!(xs.len(), bsz);
        for (i, s) in states.iter().enumerate() {
            let want = x_of_state(&mu, s) as f32;
            assert!(
                (xs[i] - want).abs() < 1e-3 * want.max(1.0),
                "candidate {i}: engine {} vs rust {want}",
                xs[i]
            );
        }
        // Padding candidates evaluate to zero.
        assert_eq!(xs[idx], 0.0);
    }

    #[test]
    fn input_validation() {
        let eng = Engine::open_default().unwrap();
        assert!(eng.run_f32("nn_small", &[&[0.0]]).is_err()); // arity
        let bad = vec![0.0f32; 7];
        assert!(eng.run_f32("sort_small", &[&bad]).is_err()); // shape
        assert!(eng.run_f32("missing_entry", &[]).is_err());
    }
}
