//! Artifact manifest: what `make artifacts` produced and where.
//!
//! `artifacts/manifest.json` is written by `python/compile/aot.py` and
//! parsed here with the in-repo JSON substrate.  Each entry records the
//! HLO-text file, the argument shapes/dtypes and the output tuple arity —
//! enough for the engine to validate inputs before handing them to PJRT.

// srclint: allow-file(index-reachable) — artifact tables are indexed by compile-time kernel ids

use std::path::{Path, PathBuf};

use crate::config::json::Json;
use crate::error::{Error, Result};

/// Metadata for one AOT entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    /// Entry name (e.g. "nn2000").
    pub name: String,
    /// HLO text file (absolute).
    pub path: PathBuf,
    /// Argument shapes (row-major dims per argument).
    pub arg_shapes: Vec<Vec<usize>>,
    /// Argument dtypes (e.g. "float32").
    pub arg_dtypes: Vec<String>,
    /// Output tuple arity.
    pub out_arity: usize,
}

impl EntryMeta {
    /// Element count of argument `i`.
    pub fn arg_elems(&self, i: usize) -> usize {
        self.arg_shapes[i].iter().product()
    }
}

/// A parsed artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactDir {
    /// Directory root.
    pub root: PathBuf,
    entries: Vec<EntryMeta>,
}

impl ArtifactDir {
    /// The conventional location relative to the repo root, overridable
    /// via `HETSCHED_ARTIFACTS`.
    pub fn default_root() -> PathBuf {
        if let Ok(p) = std::env::var("HETSCHED_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // Walk up from cwd looking for artifacts/manifest.json (works from
        // the repo root, examples/, benches/ and `cargo test` cwds).
        let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return cand;
            }
            if !dir.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Load and validate the manifest in `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                mpath.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let format = j.req("format")?.as_u64()?;
        if format != 1 {
            return Err(Error::Runtime(format!("unsupported manifest format {format}")));
        }
        let mut entries = Vec::new();
        for (name, e) in j.req("entries")?.as_obj()? {
            let file = e.req("file")?.as_str()?;
            let path = root.join(file);
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "artifact {} listed in manifest but missing on disk",
                    path.display()
                )));
            }
            let mut arg_shapes = Vec::new();
            let mut arg_dtypes = Vec::new();
            for a in e.req("args")?.as_arr()? {
                let dims: Vec<usize> = a
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| Ok(d.as_u64()? as usize))
                    .collect::<Result<_>>()?;
                arg_shapes.push(dims);
                arg_dtypes.push(a.req("dtype")?.as_str()?.to_string());
            }
            entries.push(EntryMeta {
                name: name.clone(),
                path,
                arg_shapes,
                arg_dtypes,
                out_arity: e.req("out_arity")?.as_u64()? as usize,
            });
        }
        if entries.is_empty() {
            return Err(Error::Runtime("manifest has no entries".into()));
        }
        Ok(Self { root, entries })
    }

    /// Open the default location.
    pub fn open_default() -> Result<Self> {
        Self::open(Self::default_root())
    }

    /// Look up an entry by name.
    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| Error::Runtime(format!("no artifact entry '{name}'")))
    }

    /// All entries.
    pub fn entries(&self) -> &[EntryMeta] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path, with_file: bool) {
        std::fs::create_dir_all(dir).unwrap();
        if with_file {
            std::fs::write(dir.join("toy.hlo.txt"), "HloModule toy").unwrap();
        }
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": 1, "entries": {"toy": {
                "file": "toy.hlo.txt", "sha256_16": "x",
                "args": [{"shape": [2, 3], "dtype": "float32"}],
                "out_arity": 2}}}"#,
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join(format!("hetsched_art_{}", std::process::id()));
        fake_manifest(&dir, true);
        let art = ArtifactDir::open(&dir).unwrap();
        let e = art.entry("toy").unwrap();
        assert_eq!(e.arg_shapes, vec![vec![2, 3]]);
        assert_eq!(e.arg_dtypes, vec!["float32"]);
        assert_eq!(e.out_arity, 2);
        assert_eq!(e.arg_elems(0), 6);
        assert!(art.entry("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_file_is_an_error() {
        let dir = std::env::temp_dir().join(format!("hetsched_art2_{}", std::process::id()));
        fake_manifest(&dir, false);
        assert!(ArtifactDir::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = ArtifactDir::open("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
