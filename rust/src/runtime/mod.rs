//! Kernel runtime: execute the workload kernels behind a uniform
//! [`Engine`] API.
//!
//! With `--features pjrt` (requires a vendored `xla` crate) the engine
//! loads the AOT-lowered JAX/Pallas artifacts produced by
//! `python/compile/aot.py` (`make artifacts`): HLO text →
//! `HloModuleProto::from_text_file` → PJRT CPU client, one cached
//! executable per entry.  The default build executes oracle-exact native
//! Rust implementations of the same five entries instead, so the L3 hot
//! paths (platform workers, the serving coordinator, the batched
//! exhaustive solver) run — and CI passes — without a Python/XLA
//! toolchain.  Python never runs at request time in either mode.
//!
//! * [`artifacts`] — manifest parsing + artifact path resolution.
//! * [`engine`] — backends, executable cache and typed entry points.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactDir, EntryMeta};
pub use engine::{Engine, NnTaskResult, SortTaskResult};
