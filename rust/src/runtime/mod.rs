//! PJRT runtime: load and execute the AOT-lowered JAX/Pallas artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once by `make
//! artifacts`) lowers every L2 entry point to HLO *text*; this module
//! loads the text with `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client and keeps one cached executable per entry.  The L3
//! hot paths (platform workers, the serving coordinator, the batched
//! exhaustive solver) call through [`Engine`] — Python never runs at
//! request time.
//!
//! * [`artifacts`] — manifest parsing + artifact path resolution.
//! * [`engine`] — client, executable cache and typed entry points.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactDir, EntryMeta};
pub use engine::{Engine, NnTaskResult, SortTaskResult};
