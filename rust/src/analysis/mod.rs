//! detlint — AST-level determinism and panic-reachability analysis.
//!
//! Three analyses over a crate-wide parse of `rust/src`:
//!
//! 1. **Panic reachability** (`panic-reachable`, `index-reachable`) —
//!    interprocedural may-panic propagation from the hot-path entry
//!    points (`sim::engine::run*`, `sim::dynamic::run_dynamic*`,
//!    `ConcurrentRouter`/`RouteHandle::route*`, `policy::grin::solve*`).
//! 2. **Determinism dataflow** (`hash-iteration`, `float-sum-order`,
//!    `raw-spawn`, `clock-in-results`, `discarded-result`,
//!    `as-truncation`) — nondeterminism sources and silent data loss,
//!    with wall-clock/thread-id checks scoped to fns that can reach a
//!    result-struct construction.
//! 3. **Metric plumbing** (`metric-plumbing`) — every `pub SimResult`
//!    metric must be registered in [`checks::PLUMBING`] with its
//!    report-side counterpart, sweep-JSON key, or an exemption
//!    rationale.
//!
//! Findings are suppressed with the same grammar srclint uses —
//! `// srclint: allow(<rule>) — <justification>` on the offending line
//! or the line above — plus a file-scoped
//! `// srclint: allow-file(<rule>) — <justification>` for rules where
//! one module-wide invariant covers every site.  A suppression whose
//! justification is shorter than 8 characters is itself a finding.
//!
//! Zero external dependencies, like everything else in this crate: the
//! lexer, parser, call graph and checks are all in-repo.

pub mod callgraph;
pub mod checks;
pub mod lexer;
pub mod parse;

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use callgraph::{flatten_fns, Graph};
use checks::Finding;
use lexer::{allow_at, file_allow, lex, Tok};
use parse::parse_items;

/// One lexed+parsed source file.
pub struct SourceFile {
    /// Repo-relative path, `/`-separated (`sim/engine.rs`).
    pub path: String,
    /// Per-line comment text (for allow parsing).
    pub comments: Vec<String>,
    pub items: Vec<parse::Item>,
    /// Cooked string literals with their lines (for Emit needles).
    pub strings: Vec<(String, usize)>,
}

/// Lex and parse in-memory sources: `(path, source)` pairs.
pub fn load_sources(files: &[(String, String)]) -> Vec<SourceFile> {
    let mut out: Vec<SourceFile> = files
        .iter()
        .map(|(path, src)| {
            let lexed = lex(src);
            let strings = lexed
                .tokens
                .iter()
                .filter_map(|t| match &t.tok {
                    Tok::Str(s) => Some((s.clone(), t.line)),
                    _ => None,
                })
                .collect();
            SourceFile {
                path: path.clone(),
                comments: lexed.comments.clone(),
                items: parse_items(&lexed.tokens),
                strings,
            }
        })
        .collect();
    out.sort_by(|a, b| a.path.cmp(&b.path));
    out
}

/// Run all three analyses over parsed sources and apply suppressions.
/// `features` lists enabled cargo features (for `#[cfg(feature)]`).
pub fn analyze(sources: &[SourceFile], features: &[String]) -> Vec<Finding> {
    let mut fns = Vec::new();
    for s in sources {
        flatten_fns(&s.path, &s.items, features, &mut fns);
    }
    let g = Graph::build(fns);

    let comment_map: BTreeMap<String, Vec<String>> = sources
        .iter()
        .map(|s| (s.path.clone(), s.comments.clone()))
        .collect();
    let mut raw = Vec::new();
    raw.extend(checks::check_panic_reachability(&g, &comment_map));
    raw.extend(checks::check_determinism(&g));

    let parsed: Vec<(String, Vec<parse::Item>)> = sources
        .iter()
        .map(|s| (s.path.clone(), s.items.clone()))
        .collect();
    let cli_strings: Vec<String> = sources
        .iter()
        .filter(|s| s.path.starts_with("cli/"))
        .flat_map(|s| s.strings.iter().map(|(t, _)| t.clone()))
        .collect();
    if let Some(inp) = checks::plumbing_inputs(&parsed, cli_strings) {
        raw.extend(checks::check_plumbing(&inp));
    }

    // Apply suppressions.
    let comments: BTreeMap<&str, &Vec<String>> =
        sources.iter().map(|s| (s.path.as_str(), &s.comments)).collect();
    let mut out = Vec::new();
    for mut f in raw {
        let cs = match comments.get(f.file.as_str()) {
            Some(c) => *c,
            None => {
                out.push(f);
                continue;
            }
        };
        // For aggregated per-fn rules the anchor line is the first
        // seed; a line-level allow there covers the whole finding.
        let li = f.line.saturating_sub(1); // comments are 0-indexed
        let mut line_allow = if li < cs.len() { allow_at(cs, li, f.rule) } else { None };
        // A justified srclint `allow(instant-now)` asserts the same
        // invariant as `clock-in-results` — honor it at the same site.
        if line_allow != Some(true) && f.rule == checks::RULE_CLOCK && li < cs.len() {
            if allow_at(cs, li, "instant-now") == Some(true) {
                line_allow = Some(true);
            }
        }
        let verdict = line_allow.or_else(|| file_allow(cs, f.rule));
        match verdict {
            Some(true) => {} // justified: suppressed
            Some(false) => {
                f.msg = format!(
                    "{} [suppression present but justification is too short — \
                     write at least 8 characters of rationale]",
                    f.msg
                );
                out.push(f);
            }
            None => out.push(f),
        }
    }
    out.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    // A construct can trip the same rule through two detectors (e.g. a
    // `for` loop over `m.iter()` hits hash-iteration via both the loop
    // and the method call) — keep one finding per (file, line, rule).
    out.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule);
    out
}

/// Convenience for tests: analyze in-memory `(path, source)` pairs.
pub fn analyze_sources(files: &[(String, String)], features: &[String]) -> Vec<Finding> {
    analyze(&load_sources(files), features)
}

/// Walk `src_root` (the crate's `src/` directory), read every `.rs`
/// file, and run the analyses.  Paths in findings are relative to
/// `src_root`, `/`-separated.
pub fn run(src_root: &Path, features: &[String]) -> io::Result<Vec<Finding>> {
    let mut files: Vec<(String, String)> = Vec::new();
    let mut stack: Vec<PathBuf> = vec![src_root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(&dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                let rel = p
                    .strip_prefix(src_root)
                    .expect("walked path under root")
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                files.push((rel, fs::read_to_string(&p)?));
            }
        }
    }
    Ok(analyze_sources(&files, features))
}

#[cfg(test)]
mod tests {
    use super::checks::{RULE_INDEX, RULE_PANIC};
    use super::*;

    fn src(files: &[(&str, &str)]) -> Vec<(String, String)> {
        files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect()
    }

    #[test]
    fn allow_suppresses_justified_findings() {
        let files = src(&[(
            "sim/engine.rs",
            "pub fn run() {\n    // srclint: allow(panic-reachable) — queue verified non-empty by caller\n    q.first().unwrap();\n}\n",
        )]);
        let findings = analyze_sources(&files, &[]);
        assert!(
            findings.iter().all(|f| f.rule != RULE_PANIC),
            "justified allow should suppress: {findings:?}"
        );
    }

    #[test]
    fn unjustified_allow_still_fires() {
        let files = src(&[(
            "sim/engine.rs",
            "pub fn run() {\n    // srclint: allow(panic-reachable) — no\n    q.first().unwrap();\n}\n",
        )]);
        let findings = analyze_sources(&files, &[]);
        assert!(findings.iter().any(|f| f.rule == RULE_PANIC
            && f.msg.contains("justification is too short")));
    }

    #[test]
    fn file_allow_covers_all_sites() {
        let files = src(&[(
            "sim/engine.rs",
            "// srclint: allow-file(index-reachable) — dense kernels, dims checked at build\npub fn run(v: &[u64]) {\n    let _x = v[0];\n    let _y = v[1];\n}\n",
        )]);
        let findings = analyze_sources(&files, &[]);
        assert!(findings.iter().all(|f| f.rule != RULE_INDEX), "{findings:?}");
    }

    #[test]
    fn findings_are_sorted_and_deterministic() {
        let files = src(&[
            ("sim/engine.rs", "pub fn run() { b::go(); x.unwrap(); }\n"),
            ("sim/b.rs", "pub fn go() { y.unwrap(); }\n"),
        ]);
        let a = analyze_sources(&files, &[]);
        let b = analyze_sources(&files, &[]);
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_by(|x, y| {
            (x.file.as_str(), x.line, x.rule).cmp(&(y.file.as_str(), y.line, y.rule))
        });
        assert_eq!(a, sorted);
    }
}
