//! Recursive-descent item/fact parser over the shared token lexer.
//!
//! Produces a per-file AST that is deliberately shallow: items (fns,
//! impls, structs, enums, mods, …) with names, line spans, `#[cfg]`
//! gates and nesting, struct field declarations with their raw type
//! text, and — for every fn — a flat list of *body facts*: calls,
//! method chains, indexing ops, `as` casts, `for` loops, `let _ =`
//! discards and struct-literal constructions.  No type inference; the
//! analyses in [`crate::analysis::checks`] work on names, paths and
//! declared types, which is exactly the level the repo's invariants
//! are stated at.

use super::lexer::{Tok, Token};

/// Item kinds the parser distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ItemKind {
    Fn,
    Struct,
    Enum,
    Union,
    Trait,
    Impl,
    Mod,
    Use,
    Const,
    Static,
    TypeAlias,
    MacroDef,
    ExternBlock,
}

/// One parsed item.
#[derive(Clone, Debug)]
pub struct Item {
    pub kind: ItemKind,
    /// Fn/struct/… name; for impls, the self-type name (generics
    /// stripped): `impl<T> Foo<T> for Bar<T>` → `Bar`.
    pub name: String,
    /// Line of the introducing keyword (1-based).
    pub line: usize,
    /// Line of the item's closing token.
    pub end_line: usize,
    /// Raw `#[cfg(…)]` argument texts attached to this item.
    pub cfg: Vec<String>,
    /// For impls: the trait being implemented, if any (`Clock`,
    /// `Policy for`, …; generics stripped).
    pub trait_name: Option<String>,
    /// Struct fields (named-struct items only).
    pub fields: Vec<FieldDecl>,
    /// Nested items (mod bodies, impl bodies).
    pub children: Vec<Item>,
    /// Body facts (fns with a body only).
    pub body: Option<FnBody>,
}

/// One named struct field.
#[derive(Clone, Debug)]
pub struct FieldDecl {
    pub name: String,
    /// Raw type text, tokens joined with spaces (`Vec < u64 >`).
    pub ty: String,
    pub line: usize,
    pub public: bool,
}

/// Facts extracted from one fn body.
#[derive(Clone, Debug, Default)]
pub struct FnBody {
    /// Free/associated calls by path (`thread::spawn`, `grin::solve`).
    pub calls: Vec<CallFact>,
    /// Method calls with receiver/chain hints.
    pub methods: Vec<MethodFact>,
    /// Macro invocations (`panic`, `assert_eq`, `vec`, …).
    pub macros: Vec<MacroFact>,
    /// Lines with slice/array indexing expressions.
    pub indexes: Vec<usize>,
    /// `as` casts with their target type head.
    pub casts: Vec<CastFact>,
    /// `for … in <expr>` loops.
    pub loops: Vec<ForFact>,
    /// `let _ = …;` statements.
    pub discards: Vec<DiscardFact>,
    /// `Name { … }` struct-literal constructions (capitalized names).
    pub struct_lits: Vec<StructLitFact>,
    /// Locals/params whose declared or constructed type is a hash
    /// collection (`HashMap`/`HashSet`).
    pub hash_locals: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct CallFact {
    pub path: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct MethodFact {
    pub name: String,
    /// Leftmost base of the postfix chain (`self.phases.iter().sum()`
    /// → `self.phases`; `std::thread::Builder::new()…` → the path).
    pub base: String,
    /// Method names earlier in the same chain, left to right.
    pub chain: Vec<String>,
    /// Turbofish text, if any (`sum::<f64>()` → `f64`).
    pub turbofish: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct MacroFact {
    pub name: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct CastFact {
    /// Head identifier of the target type (`u32`, `f64`, `usize`).
    pub to: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct ForFact {
    /// Identifiers appearing in the iterated expression.
    pub idents: Vec<String>,
    /// Iterated expression, tokens joined with spaces.
    pub text: String,
    pub line: usize,
}

#[derive(Clone, Debug)]
pub struct DiscardFact {
    pub line: usize,
    /// True when the discarded expression contains a call.
    pub has_call: bool,
}

#[derive(Clone, Debug)]
pub struct StructLitFact {
    pub name: String,
    pub line: usize,
}

/// Parse a token stream into top-level items.
pub fn parse_items(toks: &[Token]) -> Vec<Item> {
    let mut p = Parser { toks, i: 0 };
    p.items(usize::MAX)
}

struct Parser<'a> {
    toks: &'a [Token],
    i: usize,
}

impl<'a> Parser<'a> {
    // The cursor hands out owned tokens: a lint pass over a few
    // hundred files doesn't need zero-copy, and owned tokens keep
    // every `while let Some(t) = p.cur()` loop free to advance `p`.
    fn peek(&self, k: usize) -> Option<Token> {
        self.toks.get(self.i + k).cloned()
    }

    fn cur(&self) -> Option<Token> {
        self.peek(0)
    }

    fn bump(&mut self) {
        if self.i < self.toks.len() {
            self.i += 1;
        }
    }

    fn at_punct(&self, p: &str) -> bool {
        self.cur().map(|t| t.tok.is_punct(p)).unwrap_or(false)
    }

    fn at_ident(&self, k: &str) -> bool {
        self.cur().map(|t| t.tok.is_ident(k)).unwrap_or(false)
    }

    fn line(&self) -> usize {
        self.cur().map(|t| t.line).unwrap_or_else(|| {
            self.toks.last().map(|t| t.line).unwrap_or(1)
        })
    }

    fn last_line(&self) -> usize {
        self.toks[..self.i].last().map(|t| t.line).unwrap_or(1)
    }

    /// Skip a balanced group whose opener is at the cursor.  `open`
    /// and `close` are single-char puncts (`{`/`}`, `(`/`)`, `[`/`]`,
    /// `<`/`>`).  Returns the token range of the *interior*.
    fn skip_balanced(&mut self, open: &str, close: &str) -> (usize, usize) {
        debug_assert!(self.at_punct(open));
        self.bump();
        let start = self.i;
        let mut depth = 1usize;
        while let Some(t) = self.cur() {
            if t.tok.is_punct(open) {
                depth += 1;
            } else if t.tok.is_punct(close) {
                depth -= 1;
                if depth == 0 {
                    let end = self.i;
                    self.bump();
                    return (start, end);
                }
            }
            self.bump();
        }
        (start, self.i)
    }

    /// Skip generics `<…>` if present.  Angle depth only — our lexer
    /// never glues `>>`, so nested generics close one token at a time.
    fn skip_generics(&mut self) {
        if self.at_punct("<") {
            self.skip_balanced("<", ">");
        }
    }

    /// Collect attributes at the cursor; returns cfg argument texts.
    /// Inner attributes (`#![…]`) are skipped without attachment.
    fn attrs(&mut self) -> Vec<String> {
        let mut cfgs = Vec::new();
        loop {
            if !self.at_punct("#") {
                return cfgs;
            }
            let inner = self.peek(1).map(|t| t.tok.is_punct("!")).unwrap_or(false);
            self.bump(); // '#'
            if inner {
                self.bump(); // '!'
            }
            if !self.at_punct("[") {
                return cfgs;
            }
            let (s, e) = self.skip_balanced("[", "]");
            if inner {
                continue;
            }
            let body = &self.toks[s..e];
            if body.first().map(|t| t.tok.is_ident("cfg")).unwrap_or(false) {
                // `cfg ( … )` → the predicate text without the parens.
                let inner = &body[1..];
                let stripped = if inner.len() >= 2
                    && inner[0].tok.is_punct("(")
                    && inner[inner.len() - 1].tok.is_punct(")")
                {
                    &inner[1..inner.len() - 1]
                } else {
                    inner
                };
                cfgs.push(join(stripped));
            }
        }
    }

    /// Parse items until `end_depth` closing braces (or EOF for the
    /// top level, `end_depth == usize::MAX`).
    fn items(&mut self, stop_at: usize) -> Vec<Item> {
        let mut out = Vec::new();
        while self.i < self.toks.len() && self.i < stop_at {
            let cfg = self.attrs();
            // Visibility and modifiers.
            if self.at_ident("pub") {
                self.bump();
                if self.at_punct("(") {
                    self.skip_balanced("(", ")");
                }
            }
            let mut is_const_item = false;
            while self.at_ident("unsafe")
                || self.at_ident("async")
                || self.at_ident("default")
                || self.at_ident("extern")
                || self.at_ident("const")
            {
                if self.at_ident("const") {
                    // `const fn f` is a modifier; `const X: T = …;` an item.
                    let next_is_fn = self
                        .peek(1)
                        .map(|t| t.tok.is_ident("fn"))
                        .unwrap_or(false);
                    if !next_is_fn {
                        is_const_item = true;
                        break;
                    }
                }
                if self.at_ident("extern") {
                    // `extern "C" fn` / `extern crate` / extern block.
                    let block = matches!(
                        self.peek(1).map(|t| t.tok),
                        Some(Tok::Str(_))
                    ) && self
                        .peek(2)
                        .map(|t| t.tok.is_punct("{"))
                        .unwrap_or(false);
                    if block || self.peek(1).map(|t| t.tok.is_ident("crate")).unwrap_or(false) {
                        break;
                    }
                }
                self.bump();
                if matches!(self.cur().map(|t| t.tok), Some(Tok::Str(_))) {
                    self.bump(); // extern ABI string
                }
            }
            if let Some(item) = self.item(cfg, is_const_item) {
                out.push(item);
            }
            if stop_at != usize::MAX && self.i >= stop_at {
                break;
            }
        }
        out
    }

    /// Parse one item at the cursor (modifiers already consumed).
    fn item(&mut self, cfg: Vec<String>, is_const_item: bool) -> Option<Item> {
        let line = self.line();
        let kw = match self.cur().map(|t| t.tok.clone()) {
            Some(Tok::Ident(k)) => k,
            _ => {
                self.bump(); // stray token: skip
                return None;
            }
        };
        let mk = |kind, name: String, line, end_line, cfg| Item {
            kind,
            name,
            line,
            end_line,
            cfg,
            trait_name: None,
            fields: Vec::new(),
            children: Vec::new(),
            body: None,
        };
        match kw.as_str() {
            "fn" => {
                self.bump();
                let name = self.ident_or("?");
                self.skip_generics();
                let params = if self.at_punct("(") {
                    let (ps, pe) = self.skip_balanced("(", ")");
                    Some((ps, pe))
                } else {
                    None
                };
                // Return type / where clause: scan to body `{` or `;`.
                // Generic bounds may contain `<`…`>` but never a brace.
                while let Some(t) = self.cur() {
                    if t.tok.is_punct("{") || t.tok.is_punct(";") {
                        break;
                    }
                    if t.tok.is_punct("<") {
                        self.skip_balanced("<", ">");
                    } else {
                        self.bump();
                    }
                }
                let mut item = mk(ItemKind::Fn, name, line, self.line(), cfg);
                if self.at_punct("{") {
                    let (s, e) = self.skip_balanced("{", "}");
                    item.end_line = self.last_line();
                    let mut body = scan_facts(&self.toks[s..e]);
                    if let Some((ps, pe)) = params {
                        // Hash-typed params count as hash locals too.
                        body.hash_locals.extend(hash_params(&self.toks[ps..pe]));
                    }
                    item.body = Some(body);
                } else {
                    self.bump(); // ';'
                }
                Some(item)
            }
            "struct" | "union" => {
                let kind = if kw == "struct" { ItemKind::Struct } else { ItemKind::Union };
                self.bump();
                let name = self.ident_or("?");
                self.skip_generics();
                // where clause before the body.
                while let Some(t) = self.cur() {
                    if t.tok.is_punct("{") || t.tok.is_punct("(") || t.tok.is_punct(";") {
                        break;
                    }
                    if t.tok.is_punct("<") {
                        self.skip_balanced("<", ">");
                    } else {
                        self.bump();
                    }
                }
                let mut item = mk(kind, name, line, self.line(), cfg);
                if self.at_punct("{") {
                    let (s, e) = self.skip_balanced("{", "}");
                    item.end_line = self.last_line();
                    item.fields = parse_fields(&self.toks[s..e]);
                } else if self.at_punct("(") {
                    self.skip_balanced("(", ")");
                    self.skip_semi();
                    item.end_line = self.last_line();
                } else {
                    self.bump(); // unit struct ';'
                }
                Some(item)
            }
            "enum" | "trait" => {
                let kind = if kw == "enum" { ItemKind::Enum } else { ItemKind::Trait };
                self.bump();
                let name = self.ident_or("?");
                self.skip_generics();
                while let Some(t) = self.cur() {
                    if t.tok.is_punct("{") {
                        break;
                    }
                    if t.tok.is_punct("<") {
                        self.skip_balanced("<", ">");
                    } else {
                        self.bump();
                    }
                }
                let mut item = mk(kind, name, line, self.line(), cfg);
                if self.at_punct("{") {
                    self.skip_balanced("{", "}");
                }
                item.end_line = self.last_line();
                Some(item)
            }
            "impl" => {
                self.bump();
                self.skip_generics();
                // Path (and possibly `Trait for Type`) up to the body.
                let mut segs: Vec<String> = Vec::new();
                let mut trait_name = None;
                while let Some(t) = self.cur() {
                    if t.tok.is_punct("{") {
                        break;
                    }
                    if t.tok.is_ident("for") {
                        trait_name = last_type_head(&segs);
                        segs.clear();
                        self.bump();
                        continue;
                    }
                    if t.tok.is_ident("where") {
                        // The self type is complete; skip bounds.
                        while let Some(t) = self.cur() {
                            if t.tok.is_punct("{") {
                                break;
                            }
                            if t.tok.is_punct("<") {
                                self.skip_balanced("<", ">");
                            } else {
                                self.bump();
                            }
                        }
                        break;
                    }
                    if t.tok.is_punct("<") {
                        self.skip_balanced("<", ">");
                        continue;
                    }
                    if let Tok::Ident(s) = &t.tok {
                        segs.push(s.clone());
                    }
                    self.bump();
                }
                let name = last_type_head(&segs).unwrap_or_else(|| "?".to_string());
                let mut item = mk(ItemKind::Impl, name, line, self.line(), cfg);
                item.trait_name = trait_name;
                if self.at_punct("{") {
                    let (s, e) = self.skip_balanced("{", "}");
                    item.end_line = self.last_line();
                    let mut inner = Parser { toks: &self.toks[s..e], i: 0 };
                    item.children = inner.items(usize::MAX);
                }
                Some(item)
            }
            "mod" => {
                self.bump();
                let name = self.ident_or("?");
                let mut item = mk(ItemKind::Mod, name, line, self.line(), cfg);
                if self.at_punct("{") {
                    let (s, e) = self.skip_balanced("{", "}");
                    item.end_line = self.last_line();
                    let mut inner = Parser { toks: &self.toks[s..e], i: 0 };
                    item.children = inner.items(usize::MAX);
                } else {
                    self.bump(); // `mod foo;`
                }
                Some(item)
            }
            "use" => {
                self.bump();
                self.skip_semi();
                Some(mk(ItemKind::Use, String::new(), line, self.last_line(), cfg))
            }
            "const" | "static" => {
                let kind = if kw == "const" || is_const_item {
                    ItemKind::Const
                } else {
                    ItemKind::Static
                };
                self.bump();
                if self.at_ident("mut") {
                    self.bump();
                }
                let name = self.ident_or("?");
                self.skip_semi();
                Some(mk(kind, name, line, self.last_line(), cfg))
            }
            "type" => {
                self.bump();
                let name = self.ident_or("?");
                self.skip_semi();
                Some(mk(ItemKind::TypeAlias, name, line, self.last_line(), cfg))
            }
            "macro_rules" => {
                self.bump();
                if self.at_punct("!") {
                    self.bump();
                }
                let name = self.ident_or("?");
                if self.at_punct("{") {
                    self.skip_balanced("{", "}");
                } else if self.at_punct("(") {
                    self.skip_balanced("(", ")");
                    self.skip_semi();
                }
                Some(mk(ItemKind::MacroDef, name, line, self.last_line(), cfg))
            }
            "extern" => {
                self.bump();
                if matches!(self.cur().map(|t| t.tok), Some(Tok::Str(_))) {
                    self.bump();
                }
                if self.at_punct("{") {
                    self.skip_balanced("{", "}");
                } else {
                    self.skip_semi(); // extern crate …;
                }
                Some(mk(ItemKind::ExternBlock, String::new(), line, self.last_line(), cfg))
            }
            _ => {
                // Unknown construct (item macro invocation, stray
                // ident): consume one token, stay in sync.
                self.bump();
                if self.at_punct("!") {
                    self.bump();
                    self.ident_or(""); // optional macro item name
                    if self.at_punct("{") {
                        self.skip_balanced("{", "}");
                    } else if self.at_punct("(") {
                        self.skip_balanced("(", ")");
                        self.skip_semi();
                    } else if self.at_punct("[") {
                        self.skip_balanced("[", "]");
                        self.skip_semi();
                    }
                }
                None
            }
        }
    }

    fn ident_or(&mut self, default: &str) -> String {
        match self.cur().map(|t| t.tok.clone()) {
            Some(Tok::Ident(s)) => {
                self.bump();
                s
            }
            _ => default.to_string(),
        }
    }

    /// Skip to the `;` that terminates the current item, respecting
    /// every bracket kind (array types carry interior `;`, initializer
    /// expressions carry braces).
    fn skip_semi(&mut self) {
        let (mut braces, mut parens, mut brackets) = (0i32, 0i32, 0i32);
        while let Some(t) = self.cur() {
            match &t.tok {
                Tok::Punct(p) => match p.as_str() {
                    "{" => braces += 1,
                    "}" => braces -= 1,
                    "(" => parens += 1,
                    ")" => parens -= 1,
                    "[" => brackets += 1,
                    "]" => brackets -= 1,
                    ";" if braces == 0 && parens == 0 && brackets == 0 => {
                        self.bump();
                        return;
                    }
                    _ => {}
                },
                _ => {}
            }
            self.bump();
        }
    }
}

/// Join token texts with single spaces (for type/cfg/expr snippets).
pub fn join(toks: &[Token]) -> String {
    let mut out = String::new();
    for t in toks {
        let text: String = match &t.tok {
            Tok::Ident(s) | Tok::Lifetime(s) | Tok::Num(s) | Tok::Punct(s) => s.clone(),
            Tok::Str(s) => format!("\"{s}\""),
            Tok::Char => "'_'".to_string(),
        };
        if !out.is_empty() {
            out.push(' ');
        }
        out.push_str(&text);
    }
    out
}

/// Head of the last type path in `segs` (`policy :: grin :: Foo` style
/// lists arrive pre-filtered to idents; the self-type head is the last
/// segment).
fn last_type_head(segs: &[String]) -> Option<String> {
    segs.last().cloned()
}

/// Parse named struct fields from the interior tokens of a struct body.
fn parse_fields(toks: &[Token]) -> Vec<FieldDecl> {
    let mut out = Vec::new();
    let mut p = Parser { toks, i: 0 };
    loop {
        p.attrs();
        let public = if p.at_ident("pub") {
            p.bump();
            if p.at_punct("(") {
                p.skip_balanced("(", ")");
            }
            true
        } else {
            false
        };
        let (name, line) = match p.cur() {
            Some(t) => match &t.tok {
                Tok::Ident(s) => {
                    let v = (s.clone(), t.line);
                    p.bump();
                    v
                }
                _ => break,
            },
            None => break,
        };
        if !p.at_punct(":") {
            break;
        }
        p.bump();
        // Type runs to the next top-level comma.
        let ty_start = p.i;
        let (mut parens, mut brackets) = (0i32, 0i32);
        while let Some(t) = p.cur() {
            match &t.tok {
                Tok::Punct(q) => match q.as_str() {
                    "<" => {
                        p.skip_balanced("<", ">");
                        continue;
                    }
                    "(" => parens += 1,
                    ")" => parens -= 1,
                    "[" => brackets += 1,
                    "]" => brackets -= 1,
                    "," if parens == 0 && brackets == 0 => break,
                    _ => {}
                },
                _ => {}
            }
            p.bump();
        }
        let ty = join(&toks[ty_start..p.i]);
        out.push(FieldDecl { name, ty, line, public });
        if p.at_punct(",") {
            p.bump();
        } else {
            break;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Fn-body fact extraction
// ---------------------------------------------------------------------------

const ITER_IDENT_KEYWORDS: &[&str] = &[
    "if", "else", "match", "for", "while", "loop", "in", "let", "mut", "ref", "move", "as",
    "return", "break", "continue", "self", "Self", "true", "false", "fn", "impl", "dyn",
];

/// Words excluded from struct-literal detection when they precede
/// `Name {` (match scrutinees, `let`/`if let` destructuring patterns,
/// iterated expressions, item keywords).
const STRUCT_LIT_EXCLUDE_PREV: &[&str] = &[
    "match", "in", "impl", "struct", "enum", "union", "trait", "mod", "fn", "dyn", "for", "let",
];

/// Extract body facts from the interior tokens of a fn body.
pub fn scan_facts(toks: &[Token]) -> FnBody {
    let mut b = FnBody::default();
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        match &t.tok {
            Tok::Ident(name) => {
                // Macro invocation.
                if toks.get(i + 1).map(|t| t.tok.is_punct("!")).unwrap_or(false)
                    && toks
                        .get(i + 2)
                        .map(|t| {
                            t.tok.is_punct("(") || t.tok.is_punct("[") || t.tok.is_punct("{")
                        })
                        .unwrap_or(false)
                {
                    b.macros.push(MacroFact { name: name.clone(), line: t.line });
                    i += 2;
                    continue;
                }
                // `for` loop: record the iterated expression.  Only
                // advance past the keyword — the expression tokens are
                // re-scanned by the main loop so method/call facts
                // inside it (`.iter()` etc.) are still collected.
                if name == "for"
                    && !toks.get(i + 1).map(|t| t.tok.is_punct("<")).unwrap_or(false)
                {
                    if let Some(fact) = scan_for_loop(toks, i) {
                        b.loops.push(fact);
                        i += 1;
                        continue;
                    }
                }
                // `let` statements: `_ =` discards and hash-typed locals.
                if name == "let" {
                    scan_let(toks, i, &mut b);
                    i += 1;
                    continue;
                }
                // `as` casts.
                if name == "as" {
                    if let Some(Tok::Ident(ty)) = toks.get(i + 1).map(|t| &t.tok) {
                        b.casts.push(CastFact { to: ty.clone(), line: t.line });
                    }
                    i += 1;
                    continue;
                }
                // Path call `a::b::f(…)` (not a method: previous token
                // isn't `.`; not a declaration: previous isn't `fn`).
                let prev_dot = i > 0 && toks[i - 1].tok.is_punct(".");
                let prev_fn = i > 0 && toks[i - 1].tok.is_ident("fn");
                if !prev_dot && !prev_fn {
                    let (path, after) = scan_path(toks, i);
                    if after > i {
                        let mut j = after;
                        // Optional turbofish.
                        if toks.get(j).map(|t| t.tok.is_punct("::")).unwrap_or(false)
                            && toks.get(j + 1).map(|t| t.tok.is_punct("<")).unwrap_or(false)
                        {
                            j = skip_angle(toks, j + 1);
                        }
                        if toks.get(j).map(|t| t.tok.is_punct("(")).unwrap_or(false) {
                            b.calls.push(CallFact { path: path.clone(), line: t.line });
                        }
                        // Struct literal `Name { … }`.
                        if toks.get(j).map(|t| t.tok.is_punct("{")).unwrap_or(false) {
                            let head = path.rsplit("::").next().unwrap_or("");
                            let cap = head.chars().next().map(char::is_uppercase).unwrap_or(false);
                            let prev_excluded = i > 0
                                && STRUCT_LIT_EXCLUDE_PREV
                                    .iter()
                                    .any(|k| toks[i - 1].tok.is_ident(k));
                            if cap && !prev_excluded {
                                b.struct_lits
                                    .push(StructLitFact { name: head.to_string(), line: t.line });
                            }
                        }
                        i = after;
                        continue;
                    }
                }
                i += 1;
            }
            Tok::Punct(p) if p == "." => {
                // Method call `.name(…)` (possibly with turbofish).
                if let Some(Tok::Ident(m)) = toks.get(i + 1).map(|t| &t.tok) {
                    let mut j = i + 2;
                    let mut turbofish = String::new();
                    if toks.get(j).map(|t| t.tok.is_punct("::")).unwrap_or(false)
                        && toks.get(j + 1).map(|t| t.tok.is_punct("<")).unwrap_or(false)
                    {
                        let close = skip_angle(toks, j + 1);
                        turbofish = join(&toks[j + 2..close.saturating_sub(1)]);
                        j = close;
                    }
                    if toks.get(j).map(|t| t.tok.is_punct("(")).unwrap_or(false) {
                        let (base, chain) = postfix_chain(toks, i);
                        b.methods.push(MethodFact {
                            name: m.clone(),
                            base,
                            chain,
                            turbofish,
                            line: toks[i + 1].line,
                        });
                    }
                }
                i += 1;
            }
            Tok::Punct(p) if p == "[" => {
                // Indexing: `[` directly after a value (ident, `)`, `]`).
                let is_index = i > 0
                    && match &toks[i - 1].tok {
                        Tok::Ident(name) => {
                            !ITER_IDENT_KEYWORDS.contains(&name.as_str())
                        }
                        Tok::Punct(q) => q == ")" || q == "]",
                        _ => false,
                    };
                if is_index {
                    b.indexes.push(t.line);
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    b
}

/// Scan a `::`-joined ident path starting at `i`.  Returns the joined
/// path and the index just past it (== `i` if `toks[i]` is no ident).
fn scan_path(toks: &[Token], i: usize) -> (String, usize) {
    let mut parts: Vec<String> = Vec::new();
    let mut j = i;
    loop {
        match toks.get(j).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => parts.push(s.clone()),
            _ => break,
        }
        if toks.get(j + 1).map(|t| t.tok.is_punct("::")).unwrap_or(false)
            && matches!(toks.get(j + 2).map(|t| &t.tok), Some(Tok::Ident(_)))
        {
            j += 2;
        } else {
            j += 1;
            break;
        }
    }
    (parts.join("::"), j)
}

/// Skip an angle-bracket group opening at `open_idx`; returns the
/// index just past the closing `>`.
fn skip_angle(toks: &[Token], open_idx: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open_idx;
    while let Some(t) = toks.get(j) {
        if t.tok.is_punct("<") {
            depth += 1;
        } else if t.tok.is_punct(">") {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    j
}

/// Walk left from the `.` at `dot` to reconstruct the postfix chain:
/// returns (base text, method names left of this call).  Each link is
/// tagged call-vs-field while walking; leading field links (`.phases`
/// in `self.phases.iter()…`) extend the base, everything from the
/// first call onward is the method chain.
fn postfix_chain(toks: &[Token], dot: usize) -> (String, Vec<String>) {
    let mut links: Vec<(String, bool)> = Vec::new(); // (name, is_call)
    let mut base: Vec<String> = Vec::new();
    let mut j = dot; // index of a '.' punct
    loop {
        if j == 0 {
            break;
        }
        let prev = j - 1;
        match &toks[prev].tok {
            // `…)`: skip the group backwards; the ident before its
            // opener is a call in the chain.
            Tok::Punct(p) if p == ")" || p == "]" => {
                let open = if p == ")" { "(" } else { "[" };
                let mut depth = 1i32;
                let mut k = prev;
                while k > 0 && depth > 0 {
                    k -= 1;
                    if toks[k].tok.is_punct(p) {
                        depth += 1;
                    } else if toks[k].tok.is_punct(open) {
                        depth -= 1;
                    }
                }
                if k > 0 {
                    if let Tok::Ident(name) = &toks[k - 1].tok {
                        if k >= 2 && toks[k - 2].tok.is_punct(".") {
                            links.insert(0, (name.clone(), true));
                            j = k - 2;
                            continue;
                        }
                        // Base is itself a call: collect its full path.
                        let mut lo = k - 1;
                        while lo >= 2
                            && toks[lo - 1].tok.is_punct("::")
                            && matches!(&toks[lo - 2].tok, Tok::Ident(_))
                        {
                            lo -= 2;
                        }
                        base = toks[lo..k]
                            .iter()
                            .filter_map(|t| t.tok.ident().map(str::to_string))
                            .collect();
                    }
                }
                break;
            }
            Tok::Ident(name) => {
                // Field access or bare base.
                if prev >= 1 && toks[prev - 1].tok.is_punct(".") {
                    links.insert(0, (name.clone(), false));
                    j = prev - 1;
                    continue;
                }
                base = vec![name.clone()];
                break;
            }
            _ => break,
        }
    }
    let mut i = 0;
    while i < links.len() && !links[i].1 {
        base.push(links[i].0.clone());
        i += 1;
    }
    let chain = links[i..].iter().map(|(n, _)| n.clone()).collect();
    (base.join("."), chain)
}

/// Scan a `for <pat> in <expr> {` construct starting at the `for`.
fn scan_for_loop(toks: &[Token], for_idx: usize) -> Option<ForFact> {
    // Find `in` at paren/bracket depth 0.
    let mut j = for_idx + 1;
    let (mut parens, mut brackets) = (0i32, 0i32);
    loop {
        let t = toks.get(j)?;
        match &t.tok {
            Tok::Ident(s) if s == "in" && parens == 0 && brackets == 0 => break,
            Tok::Punct(p) => match p.as_str() {
                "(" => parens += 1,
                ")" => parens -= 1,
                "[" => brackets += 1,
                "]" => brackets -= 1,
                "{" => return None, // not a for-loop we understand
                _ => {}
            },
            _ => {}
        }
        j += 1;
    }
    let expr_start = j + 1;
    // Expression runs to the body `{` at depth 0.
    let (mut parens, mut brackets, mut angles) = (0i32, 0i32, 0i32);
    let mut k = expr_start;
    loop {
        let t = toks.get(k)?;
        match &t.tok {
            Tok::Punct(p) => match p.as_str() {
                "(" => parens += 1,
                ")" => parens -= 1,
                "[" => brackets += 1,
                "]" => brackets -= 1,
                "<" => angles += 1,
                ">" => angles -= 1,
                "{" if parens == 0 && brackets == 0 && angles <= 0 => break,
                _ => {}
            },
            _ => {}
        }
        k += 1;
    }
    let expr = &toks[expr_start..k];
    let idents = expr
        .iter()
        .filter_map(|t| t.tok.ident())
        .filter(|s| !ITER_IDENT_KEYWORDS.contains(s))
        .map(str::to_string)
        .collect();
    Some(ForFact { idents, text: join(expr), line: toks[for_idx].line })
}

/// Handle a `let` statement starting at `let_idx`: record `_ =`
/// discards and hash-typed local declarations.
fn scan_let(toks: &[Token], let_idx: usize, b: &mut FnBody) {
    let line = toks[let_idx].line;
    let mut j = let_idx + 1;
    if toks.get(j).map(|t| t.tok.is_ident("mut")).unwrap_or(false) {
        j += 1;
    }
    // `let _ = …;`
    if toks.get(j).map(|t| t.tok.is_ident("_")).unwrap_or(false)
        && toks.get(j + 1).map(|t| t.tok.is_punct("=")).unwrap_or(false)
    {
        let mut has_call = false;
        let mut k = j + 2;
        let (mut braces, mut parens, mut brackets) = (0i32, 0i32, 0i32);
        while let Some(t) = toks.get(k) {
            match &t.tok {
                Tok::Punct(p) => match p.as_str() {
                    "(" => {
                        has_call = has_call
                            || matches!(toks.get(k - 1).map(|t| &t.tok), Some(Tok::Ident(_)));
                        parens += 1;
                    }
                    ")" => parens -= 1,
                    "{" => braces += 1,
                    "}" => braces -= 1,
                    "[" => brackets += 1,
                    "]" => brackets -= 1,
                    ";" if braces == 0 && parens == 0 && brackets == 0 => break,
                    _ => {}
                },
                _ => {}
            }
            k += 1;
        }
        b.discards.push(DiscardFact { line, has_call });
        return;
    }
    // `let [mut] name [: Type] [= expr]` — hash-typed local tracking.
    let name = match toks.get(j).map(|t| &t.tok) {
        Some(Tok::Ident(s)) if s != "_" => s.clone(),
        _ => return,
    };
    let mut k = j + 1;
    let mut is_hash = false;
    if toks.get(k).map(|t| t.tok.is_punct(":")).unwrap_or(false) {
        // Type annotation up to `=` or `;`.
        k += 1;
        let ty_start = k;
        while let Some(t) = toks.get(k) {
            match &t.tok {
                Tok::Punct(p) if p == "<" => {
                    k = skip_angle(toks, k);
                    continue;
                }
                Tok::Punct(p) if p == "=" || p == ";" => break,
                _ => {}
            }
            k += 1;
        }
        is_hash = toks[ty_start..k]
            .iter()
            .any(|t| t.tok.is_ident("HashMap") || t.tok.is_ident("HashSet"));
    }
    if !is_hash && toks.get(k).map(|t| t.tok.is_punct("=")).unwrap_or(false) {
        // Initializer up to `;` at depth 0: constructed-hash detection.
        let mut m = k + 1;
        let (mut braces, mut parens, mut brackets) = (0i32, 0i32, 0i32);
        while let Some(t) = toks.get(m) {
            match &t.tok {
                Tok::Ident(s) if s == "HashMap" || s == "HashSet" => {
                    is_hash = true;
                }
                Tok::Punct(p) => match p.as_str() {
                    "(" => parens += 1,
                    ")" => parens -= 1,
                    "{" => braces += 1,
                    "}" => braces -= 1,
                    "[" => brackets += 1,
                    "]" => brackets -= 1,
                    ";" if braces == 0 && parens == 0 && brackets == 0 => break,
                    _ => {}
                },
                _ => {}
            }
            m += 1;
        }
    }
    if is_hash {
        b.hash_locals.push(name);
    }
}

/// Hash-typed fn parameters: parse `name: Type` pairs from a param
/// list's interior tokens and return names with hash-collection types.
pub fn hash_params(toks: &[Token]) -> Vec<String> {
    let mut out = Vec::new();
    let mut p = Parser { toks, i: 0 };
    loop {
        // Skip pattern prefix tokens up to an ident followed by ':'.
        let (name, _) = match p.cur() {
            Some(t) => match &t.tok {
                Tok::Ident(s) => {
                    let v = (s.clone(), t.line);
                    p.bump();
                    v
                }
                _ => {
                    p.bump();
                    if p.cur().is_none() {
                        break;
                    }
                    continue;
                }
            },
            None => break,
        };
        if !p.at_punct(":") {
            continue;
        }
        p.bump();
        let ty_start = p.i;
        let (mut parens, mut brackets) = (0i32, 0i32);
        while let Some(t) = p.cur() {
            match &t.tok {
                Tok::Punct(q) => match q.as_str() {
                    "<" => {
                        p.skip_balanced("<", ">");
                        continue;
                    }
                    "(" => parens += 1,
                    ")" => parens -= 1,
                    "[" => brackets += 1,
                    "]" => brackets -= 1,
                    "," if parens == 0 && brackets == 0 => break,
                    _ => {}
                },
                _ => {}
            }
            p.bump();
        }
        let hash = p.toks[ty_start..p.i]
            .iter()
            .any(|t| t.tok.is_ident("HashMap") || t.tok.is_ident("HashSet"));
        if hash {
            out.push(name);
        }
        if p.at_punct(",") {
            p.bump();
        } else {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn parse(src: &str) -> Vec<Item> {
        parse_items(&lex(src).tokens)
    }

    #[test]
    fn items_with_spans_and_cfg() {
        let src = "\
fn alpha() { beta(); }

#[cfg(test)]
mod tests {
    fn inner() {}
}

#[cfg(feature = \"model\")]
pub struct Gated { pub x: u64 }
";
        let items = parse(src);
        assert_eq!(items.len(), 3);
        assert_eq!(items[0].name, "alpha");
        assert_eq!((items[0].line, items[0].end_line), (1, 1));
        assert_eq!(items[1].kind, ItemKind::Mod);
        assert_eq!(items[1].cfg, ["test"]);
        assert_eq!(items[1].children[0].name, "inner");
        assert_eq!(items[2].cfg, ["feature = \"model\""]);
        assert_eq!(items[2].fields[0].name, "x");
    }

    #[test]
    fn impl_names_and_traits() {
        let src = "impl<T: Clone> Foo<T> { fn m(&self) {} }\nimpl Clock for Wall { fn now(&self) {} }\n";
        let items = parse(src);
        assert_eq!(items[0].name, "Foo");
        assert_eq!(items[0].children[0].name, "m");
        assert_eq!(items[1].name, "Wall");
        assert_eq!(items[1].trait_name.as_deref(), Some("Clock"));
    }

    #[test]
    fn body_facts_calls_methods_index_cast() {
        let src = "fn f(v: Vec<u64>) -> u32 {\n    let x = grin::solve(&v).unwrap();\n    let y = v[0] as u32;\n    std::thread::spawn(|| {});\n    y\n}\n";
        let items = parse(src);
        let b = items[0].body.as_ref().expect("body");
        assert!(b.calls.iter().any(|c| c.path == "grin::solve"));
        assert!(b.calls.iter().any(|c| c.path == "std::thread::spawn"));
        assert!(b.methods.iter().any(|m| m.name == "unwrap"));
        assert_eq!(b.indexes, [3]);
        assert_eq!(b.casts[0].to, "u32");
        assert_eq!(b.casts[0].line, 3);
    }

    #[test]
    fn for_loops_and_hash_locals() {
        let src = "fn f() {\n    let mut m = HashMap::new();\n    m.insert(1, 2);\n    for (k, v) in m.iter() { drop((k, v)); }\n    for x in &m { drop(x); }\n}\n";
        let b = parse(src)[0].body.clone().expect("body");
        assert_eq!(b.hash_locals, ["m"]);
        assert_eq!(b.loops.len(), 2);
        assert!(b.loops[0].text.contains("m . iter"));
        assert!(b.loops[1].idents.contains(&"m".to_string()));
        assert!(b.methods.iter().any(|mc| mc.name == "iter" && mc.base == "m"));
    }

    #[test]
    fn discards_and_struct_lits() {
        let src = "fn f() -> R {\n    let _ = fallible();\n    let _ = x;\n    R { a: 1 }\n}\n";
        let b = parse(src)[0].body.clone().expect("body");
        assert_eq!(b.discards.len(), 2);
        assert!(b.discards[0].has_call);
        assert!(!b.discards[1].has_call);
        assert_eq!(b.struct_lits[0].name, "R");
    }

    #[test]
    fn method_chain_bases() {
        let src = "fn f(&self) -> f64 {\n    self.phases.iter().map(|r| r.x).sum::<f64>()\n}\n";
        let b = parse(src)[0].body.clone().expect("body");
        let sum = b.methods.iter().find(|m| m.name == "sum").expect("sum");
        assert_eq!(sum.base, "self.phases");
        assert!(sum.chain.contains(&"iter".to_string()));
        assert!(sum.chain.contains(&"map".to_string()));
        assert_eq!(sum.turbofish, "f64");
    }

    #[test]
    fn nested_generics_fields() {
        let src = "struct S {\n    pub inner: Vec<Arc<Mutex<T>>>,\n    flag: bool,\n}\n";
        let items = parse(src);
        assert_eq!(items[0].fields.len(), 2);
        assert!(items[0].fields[0].public);
        assert!(items[0].fields[0].ty.contains("Vec"));
        assert!(!items[0].fields[1].public);
    }

    #[test]
    fn hash_params_detected() {
        let src = "fn f(a: &HashMap<String, u64>, b: u32) {}";
        let toks = lex(src).tokens;
        // Interior of the param list.
        let open = toks.iter().position(|t| t.tok.is_punct("(")).expect("open");
        let close = toks.iter().rposition(|t| t.tok.is_punct(")")).expect("close");
        assert_eq!(hash_params(&toks[open + 1..close]), ["a"]);
    }
}
