//! Crate-wide call graph over the parsed items.
//!
//! Resolution is name-based and over-approximating: a call `a::b::f(…)`
//! matches any fn named `f` whose enclosing path ends with the call's
//! qualifier segments; a bare call `f(…)` prefers same-file fns; a
//! method call `.m(…)` matches every impl fn named `m` anywhere in the
//! crate.  Over-approximation is the right polarity for panic
//! reachability — we must never miss a path — and the allow syntax
//! absorbs the (rare) false positives.
//!
//! All maps are `BTreeMap`/`BTreeSet` so analysis output is
//! byte-deterministic run to run — the same invariant detlint enforces
//! on the rest of the crate.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::parse::{FnBody, Item, ItemKind};

/// One fn, flattened out of the item tree.
#[derive(Clone, Debug)]
pub struct FnInfo {
    /// Repo-relative file path, `/`-separated (`sim/engine.rs`).
    pub file: String,
    /// Fn name.
    pub name: String,
    /// Enclosing type name for impl fns (`ConcurrentRouter`), or the
    /// enclosing mod chain's last segment, if any.
    pub owner: Option<String>,
    /// Trait being implemented, when the fn sits in a trait impl.
    pub trait_name: Option<String>,
    pub line: usize,
    pub end_line: usize,
    /// True when any enclosing item (or the fn itself) is `#[cfg(test)]`.
    pub in_test: bool,
    pub body: FnBody,
}

impl FnInfo {
    /// `file::Owner::name` display label for findings.
    pub fn label(&self) -> String {
        match &self.owner {
            Some(o) => format!("{}::{}::{}", self.file, o, self.name),
            None => format!("{}::{}", self.file, self.name),
        }
    }
}

/// Node index into [`Graph::fns`].
pub type FnId = usize;

pub struct Graph {
    pub fns: Vec<FnInfo>,
    /// name → fn ids bearing that name.
    by_name: BTreeMap<String, Vec<FnId>>,
    /// Forward edges, resolved; parallel to `fns`.
    pub callees: Vec<BTreeSet<FnId>>,
    /// Reverse edges; parallel to `fns`.
    pub callers: Vec<BTreeSet<FnId>>,
}

/// Whether a cfg gate admits this build.  `#[cfg(test)]` items are
/// always excluded (detlint analyses shipping code); feature gates are
/// included iff the feature is enabled; any other predicate is
/// conservatively included.
fn cfg_active(cfg: &str, features: &[String]) -> CfgState {
    let c = cfg.trim();
    if c == "test" {
        return CfgState::Test;
    }
    if let Some(rest) = c.strip_prefix("feature") {
        let rest = rest.trim_start().trim_start_matches('=').trim();
        let feat = rest.trim_matches('"');
        if features.iter().any(|f| f == feat) {
            return CfgState::On;
        }
        return CfgState::Off;
    }
    if let Some(inner) = c.strip_prefix("not") {
        let inner = inner.trim().trim_start_matches('(').trim_end_matches(')');
        return match cfg_active(inner, features) {
            CfgState::On => CfgState::Off,
            CfgState::Off => CfgState::On,
            CfgState::Test => CfgState::Off,
        };
    }
    CfgState::On
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum CfgState {
    On,
    Off,
    Test,
}

/// Flatten one file's item tree into `out`, tracking cfg context.
pub fn flatten_fns(
    file: &str,
    items: &[Item],
    features: &[String],
    out: &mut Vec<FnInfo>,
) {
    fn walk(
        file: &str,
        items: &[Item],
        owner: Option<&str>,
        trait_name: Option<&str>,
        in_test: bool,
        features: &[String],
        out: &mut Vec<FnInfo>,
    ) {
        for it in items {
            let mut test = in_test;
            let mut off = false;
            for c in &it.cfg {
                match cfg_active(c, features) {
                    CfgState::Test => test = true,
                    CfgState::Off => off = true,
                    CfgState::On => {}
                }
            }
            if off {
                continue;
            }
            match it.kind {
                ItemKind::Fn => {
                    if let Some(body) = &it.body {
                        out.push(FnInfo {
                            file: file.to_string(),
                            name: it.name.clone(),
                            owner: owner.map(str::to_string),
                            trait_name: trait_name.map(str::to_string),
                            line: it.line,
                            end_line: it.end_line,
                            in_test: test,
                            body: body.clone(),
                        });
                    }
                }
                ItemKind::Impl => walk(
                    file,
                    &it.children,
                    Some(&it.name),
                    it.trait_name.as_deref(),
                    test,
                    features,
                    out,
                ),
                ItemKind::Mod => {
                    walk(file, &it.children, owner, None, test, features, out)
                }
                _ => {}
            }
        }
    }
    walk(file, items, None, None, false, features, out);
}

impl Graph {
    /// Build the graph from flattened fns, resolving every call and
    /// method fact to candidate callees.
    pub fn build(fns: Vec<FnInfo>) -> Graph {
        let mut by_name: BTreeMap<String, Vec<FnId>> = BTreeMap::new();
        for (id, f) in fns.iter().enumerate() {
            by_name.entry(f.name.clone()).or_default().push(id);
        }
        let mut callees: Vec<BTreeSet<FnId>> = vec![BTreeSet::new(); fns.len()];
        for (id, f) in fns.iter().enumerate() {
            if f.in_test {
                continue; // test fns are not analysis roots or edges
            }
            for call in &f.body.calls {
                for target in resolve_call(&call.path, id, &fns, &by_name) {
                    callees[id].insert(target);
                }
            }
            for m in &f.body.methods {
                // Method resolution: any non-test impl fn by that name.
                if let Some(cands) = by_name.get(&m.name) {
                    for &c in cands {
                        if fns[c].owner.is_some() && !fns[c].in_test {
                            callees[id].insert(c);
                        }
                    }
                }
            }
        }
        let mut callers: Vec<BTreeSet<FnId>> = vec![BTreeSet::new(); fns.len()];
        for (id, cs) in callees.iter().enumerate() {
            for &c in cs {
                callers[c].insert(id);
            }
        }
        Graph { fns, by_name, callees, callers }
    }

    /// Fns matching `(file_suffix, name_glob)` entry-point patterns.
    /// `name_glob` supports one trailing `*` (`solve*`).
    pub fn entry_points(&self, patterns: &[(&str, &str)]) -> Vec<FnId> {
        let mut out = Vec::new();
        for (id, f) in self.fns.iter().enumerate() {
            if f.in_test {
                continue;
            }
            for (file_suffix, glob) in patterns {
                if f.file.ends_with(file_suffix) && glob_match(glob, &f.name) {
                    out.push(id);
                    break;
                }
            }
        }
        out
    }

    /// Forward BFS from `roots`; returns, for each reached fn, one
    /// sample call path (root-first list of fn ids).  Nodes for which
    /// `skip` is true are neither visited nor traversed through.
    pub fn reach_forward(
        &self,
        roots: &[FnId],
        skip: &dyn Fn(&FnInfo) -> bool,
    ) -> BTreeMap<FnId, Vec<FnId>> {
        let mut paths: BTreeMap<FnId, Vec<FnId>> = BTreeMap::new();
        let mut q: VecDeque<FnId> = VecDeque::new();
        for &r in roots {
            if !paths.contains_key(&r) && !skip(&self.fns[r]) {
                paths.insert(r, vec![r]);
                q.push_back(r);
            }
        }
        while let Some(n) = q.pop_front() {
            let base = paths[&n].clone();
            for &c in &self.callees[n] {
                if !paths.contains_key(&c) && !skip(&self.fns[c]) {
                    let mut p = base.clone();
                    p.push(c);
                    paths.insert(c, p);
                    q.push_back(c);
                }
            }
        }
        paths
    }

    /// Reverse BFS: every fn from which some fn in `sinks` is
    /// reachable (inclusive).
    pub fn reach_reverse(&self, sinks: &[FnId]) -> BTreeSet<FnId> {
        let mut seen: BTreeSet<FnId> = sinks.iter().copied().collect();
        let mut q: VecDeque<FnId> = sinks.iter().copied().collect();
        while let Some(n) = q.pop_front() {
            for &c in &self.callers[n] {
                if seen.insert(c) {
                    q.push_back(c);
                }
            }
        }
        seen
    }

    /// Fn ids by bare name (all files).
    pub fn named(&self, name: &str) -> &[FnId] {
        self.by_name.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Render a sample path as `a -> b -> c` using fn labels.
    pub fn path_label(&self, path: &[FnId]) -> String {
        path.iter()
            .map(|&id| self.fns[id].label())
            .collect::<Vec<_>>()
            .join(" -> ")
    }
}

/// Resolve a path call to candidate fn ids.
fn resolve_call(
    path: &str,
    caller: FnId,
    fns: &[FnInfo],
    by_name: &BTreeMap<String, Vec<FnId>>,
) -> Vec<FnId> {
    let segs: Vec<&str> = path.split("::").collect();
    let name = *segs.last().expect("non-empty path");
    let quals = &segs[..segs.len() - 1];
    let cands = match by_name.get(name) {
        Some(c) => c,
        None => return Vec::new(),
    };
    let live: Vec<FnId> = cands.iter().copied().filter(|&c| !fns[c].in_test).collect();
    if quals.is_empty() {
        // Bare call: same-file fns only — a bare name can't reach
        // another module without a `use`, and over-matching here would
        // wire every `new()` to every other `new()`.
        let same: Vec<FnId> = live
            .iter()
            .copied()
            .filter(|&c| fns[c].file == fns[caller].file)
            .collect();
        return same;
    }
    // Qualified: every qualifier segment must appear in the candidate's
    // file path (module chain) or owner/type name.  `Self::f` and
    // `<Type>::f` qualify by owner.
    let filtered: Vec<FnId> = live
        .iter()
        .copied()
        .filter(|&c| {
            let f = &fns[c];
            quals.iter().all(|q| {
                if *q == "Self" {
                    return f.file == fns[caller].file;
                }
                let in_file = f
                    .file
                    .trim_end_matches(".rs")
                    .split('/')
                    .any(|seg| seg == *q);
                let in_owner = f.owner.as_deref() == Some(*q);
                in_file || in_owner
            })
        })
        .collect();
    filtered
}

/// Glob with one optional trailing `*`.
pub fn glob_match(glob: &str, name: &str) -> bool {
    match glob.strip_suffix('*') {
        Some(prefix) => name.starts_with(prefix),
        None => glob == name,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;
    use crate::analysis::parse::parse_items;

    fn graph(files: &[(&str, &str)]) -> Graph {
        let mut fns = Vec::new();
        for (path, src) in files {
            let items = parse_items(&lex(src).tokens);
            flatten_fns(path, &items, &[], &mut fns);
        }
        Graph::build(fns)
    }

    #[test]
    fn qualified_and_bare_resolution() {
        let g = graph(&[
            (
                "sim/engine.rs",
                "pub fn run() { helper(); grin::solve(); }\nfn helper() {}\n",
            ),
            ("policy/grin.rs", "pub fn solve() { refine(); }\nfn refine() { data[0]; }\n"),
            ("policy/other.rs", "pub fn solve() {}\n"),
        ]);
        let run = g.entry_points(&[("sim/engine.rs", "run*")]);
        assert_eq!(run.len(), 1);
        let reach = g.reach_forward(&run, &|_| false);
        let names: Vec<&str> = reach.keys().map(|&id| g.fns[id].name.as_str()).collect();
        assert!(names.contains(&"helper"));
        assert!(names.contains(&"refine"));
        // `grin::solve` must NOT resolve to policy/other.rs's solve.
        let solves: Vec<&FnInfo> = reach
            .keys()
            .map(|&id| &g.fns[id])
            .filter(|f| f.name == "solve")
            .collect();
        assert_eq!(solves.len(), 1);
        assert_eq!(solves[0].file, "policy/grin.rs");
    }

    #[test]
    fn method_calls_over_approximate() {
        let g = graph(&[
            (
                "coordinator/frontend.rs",
                "struct R;\nimpl R { pub fn route(&self) { self.pick(); } fn pick(&self) {} }\n",
            ),
        ]);
        let entry = g.entry_points(&[("coordinator/frontend.rs", "route*")]);
        let reach = g.reach_forward(&entry, &|_| false);
        assert!(reach
            .keys()
            .any(|&id| g.fns[id].name == "pick"));
    }

    #[test]
    fn test_cfg_items_excluded() {
        let g = graph(&[(
            "sim/engine.rs",
            "pub fn run() {}\n#[cfg(test)]\nmod tests { fn run_helper() {} }\n",
        )]);
        assert_eq!(g.fns.iter().filter(|f| !f.in_test).count(), 1);
    }

    #[test]
    fn feature_gating() {
        let src = "#[cfg(feature = \"model\")]\npub fn gated() {}\npub fn always() {}\n";
        let items = parse_items(&lex(src).tokens);
        let mut off = Vec::new();
        flatten_fns("x.rs", &items, &[], &mut off);
        assert_eq!(off.len(), 1);
        let mut on = Vec::new();
        flatten_fns("x.rs", &items, &["model".to_string()], &mut on);
        assert_eq!(on.len(), 2);
    }

    #[test]
    fn reverse_reachability() {
        let g = graph(&[(
            "sim/metrics.rs",
            "pub fn build() -> SimResult { helper(); SimResult { x: 1 } }\nfn helper() {}\npub fn unrelated() {}\n",
        )]);
        let sinks: Vec<FnId> = g
            .fns
            .iter()
            .enumerate()
            .filter(|(_, f)| f.body.struct_lits.iter().any(|s| s.name == "SimResult"))
            .map(|(id, _)| id)
            .collect();
        let up = g.reach_reverse(&sinks);
        let names: Vec<&str> = up.iter().map(|&id| g.fns[id].name.as_str()).collect();
        assert!(names.contains(&"build"));
        assert!(!names.contains(&"unrelated"));
    }
}
